"""Fig. 11 — dedicated cluster of 128 servers (d=4): training iteration time
across fabrics for the paper's six models, sweeping link bandwidth.

Fluid evaluation goes through the :mod:`repro.core.simengine` facade (which
subsumes the old ``netsim`` helpers)."""

from __future__ import annotations

import time

from repro.core.alternating import alternating_optimize, evaluate
from repro.core.costmodel import ClusterSpec, cost_equivalent_bandwidth_fraction
from repro.core.fabrics import expander_topology, generic_comm_time, sipml_ring_topology
from repro.core.simengine import (
    HardwareSpec,
    compute_time,
    fat_tree_comm_time,
    ideal_switch_comm_time,
    iteration_time,
)
from repro.core.workloads import PAPER_JOBS

N = 128
DEGREE = 4
MODELS = ("candle", "vgg16", "bert", "dlrm", "ncf", "resnet50")
BANDWIDTHS_GBPS = (25, 100, 400)


def run(models=MODELS, bandwidths=BANDWIDTHS_GBPS, n=N, mcmc_iters=80) -> list[dict]:
    frac = cost_equivalent_bandwidth_fraction(
        ClusterSpec(n_servers=n, degree=DEGREE, link_gbps=100)
    )
    rows = []
    for name in models:
        job = PAPER_JOBS[name]
        for gbps in bandwidths:
            hw = HardwareSpec(link_bandwidth=gbps * 1e9 / 8, degree=DEGREE)
            t0 = time.perf_counter()
            res = alternating_optimize(job, n, hw, rounds=2, mcmc_iters=mcmc_iters,
                                       seed=0)
            us = (time.perf_counter() - t0) * 1e6
            comp = compute_time(job.flops_per_sample * job.batch_per_gpu * n, n, hw)
            t_topo = res.iter_time
            dem = res.demand
            t_ideal = iteration_time(ideal_switch_comm_time(dem, hw), comp)
            # two similar-cost points: our BOM's parity fraction and the
            # paper's implied B'/B ~ 0.35 (their Fig. 11 gains ~2.8x).
            t_ft = iteration_time(fat_tree_comm_time(dem, hw, frac), comp)
            t_ft_paper = iteration_time(fat_tree_comm_time(dem, hw, 0.35), comp)
            exp = expander_topology(n, DEGREE, seed=0)
            t_exp = iteration_time(generic_comm_time(exp, dem, hw), comp)
            sip = sipml_ring_topology(n, DEGREE)
            t_sip = iteration_time(generic_comm_time(sip, dem, hw), comp)
            rows.append(
                dict(
                    name=f"dedicated_{name}_{gbps}g",
                    us_per_call=us,
                    derived=(
                        f"ft/topo={t_ft / t_topo:.2f};"
                        f"ft35/topo={t_ft_paper / t_topo:.2f};"
                        f"ideal/topo={t_ideal / t_topo:.2f}"
                    ),
                    topoopt_s=t_topo,
                    ideal_s=t_ideal,
                    fat_tree_s=t_ft,
                    fat_tree_paper_s=t_ft_paper,
                    expander_s=t_exp,
                    sipml_s=t_sip,
                    strategy=res.strategy.mode,
                )
            )
    return rows
