"""Chaos benchmark: seeded fault storms against the fabric (§7 hardening).

Sweeps fiber MTBF from heavy to light over randomized transient-fault
storms (:class:`repro.core.faults.FaultModel`: independent fiber flaps plus
a correlated server domain and an OCS-stride domain) and drives them at two
granularities:

* **engine** — the storm as transient
  :class:`~repro.core.simengine.LinkFailure` events (``repair_time`` set)
  against a single-job scenario with checkpoint-restore restart costs
  (:func:`~repro.core.costmodel.checkpoint_restart_s`); records per-job
  downtime, restart counts, availability, and goodput.
* **driver** — the storm as an iteration-granularity fail/repair trace
  through :func:`~repro.core.online.run_online_jobset`, static (§7 repair
  only) vs reactive (hardened replan path: validation, deadline, bounded
  retries + backoff).

Gating invariants (an ``AssertionError`` fails the bench):

* no crash / no wedge — every run completes with a finite makespan and the
  hardened controller never exceeds its bounded retry budget;
* byte conservation — the storm run delivers exactly the fault-free run's
  bytes (transient cuts reroute and resume, they never lose traffic);
* reactive >= static-repair goodput (within ``SLACK``) on every storm.

A perf record lands in ``experiments/bench/BENCH_faults.json``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.alternating import co_optimize_jobset
from repro.core.costmodel import OCS_FIBER_MOVE_S, checkpoint_restart_s
from repro.core.faults import FaultModel, server_domain, stride_domain
from repro.core.netsim import HardwareSpec, compute_time
from repro.core.online import (
    ReoptPolicy,
    links_from_topology,
    run_online_jobset,
)
from repro.core.simengine import Scenario, SimEngine, SimJob, iteration_tasks
from repro.core.workloads import BERT, DLRM, JobSet, TenantJob, job_demand

DEGREE = 4
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_faults.json")
# Reactive must stay within this fraction of the static-repair operator's
# goodput on every storm (it usually *beats* static; the slack absorbs
# pause-charging noise on tiny smoke fabrics).
SLACK = 0.10


def _jobset(n: int) -> JobSet:
    third = n // 3
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, third)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(third, 2 * third)),
                  name="bert"),
    ])


def _storm(topo, horizon: float, mtbf_scale: float, seed: int) -> FaultModel:
    """A randomized storm over ``topo``'s live fibers: independent flaps at
    ``mtbf_scale * horizon`` mean inter-failure time, plus one correlated
    server domain and one OCS-stride domain flapping an order of magnitude
    more rarely."""
    pairs = sorted({(min(a, b), max(a, b)) for a, b in topo.graph.edges()})
    domains = [
        server_domain(1, pairs, mtbf=8 * mtbf_scale * horizon,
                      mttr=0.05 * horizon),
        stride_domain(topo.n, 1, mtbf=12 * mtbf_scale * horizon,
                      mttr=0.05 * horizon),
    ]
    return FaultModel(
        n=topo.n, links=tuple(pairs), link_mtbf=mtbf_scale * horizon,
        link_mttr=0.1 * horizon, domains=domains, seed=seed,
    )


def _engine_storm_row(n, hw, topo, mtbf_scale, seed):
    """Engine granularity: transient LinkFailures + restart costs against a
    single-DLRM scenario; gates byte conservation and availability."""
    demand = job_demand(DLRM, n)
    comp = compute_time(DLRM.flops_per_sample * DLRM.batch_per_gpu * n, n, hw)
    links = links_from_topology(topo, hw)
    jobs = [SimJob("dlrm", iteration_tasks(topo, demand,
                                           compute_duration=comp))]
    eng = SimEngine(hw)
    base = eng.run(Scenario(links=links, jobs=jobs, n=n))
    assert np.isfinite(base.makespan) and not base.stalled

    horizon = base.makespan
    fm = _storm(topo, horizon, mtbf_scale, seed)
    failures = tuple(fm.link_failures(horizon))
    restart = checkpoint_restart_s(DLRM.state_bytes)
    chaos = eng.run(Scenario(
        links=links, jobs=jobs, n=n, failures=failures,
        restart_s={"dlrm": restart},
    ))

    # Gate: no crash, bytes conserved, sane availability accounting.
    assert np.isfinite(chaos.makespan), "storm run never finished"
    assert chaos.delivered == base.delivered, (
        f"bytes lost under storm: {chaos.delivered} != {base.delivered}"
    )
    avail = chaos.availability("dlrm")
    assert 0.0 <= avail <= 1.0, f"availability {avail} out of range"
    return dict(
        n_failures=len(failures),
        downtime_s=chaos.downtime.get("dlrm", 0.0),
        restarts=chaos.restarts.get("dlrm", 0),
        availability=avail,
        goodput=chaos.goodput.get("dlrm", 0.0),
        base_goodput=base.goodput.get("dlrm", 0.0),
        makespan_s=chaos.makespan,
        base_makespan_s=base.makespan,
    )


def _driver_storm_row(n, hw, jobset, plan, n_iters, mtbf_scale, seed):
    """Driver granularity: the storm as a fail/repair trace, static §7
    repair vs the hardened reactive replan path; gates the goodput floor
    and the bounded-retry invariant."""
    calm = run_online_jobset(jobset, hw, policy=ReoptPolicy.never(),
                             n_iters=1, seed=0, plan=plan)
    iter_est = max(calm.total_time, 1e-9)
    fm = _storm(plan.topology, n_iters * iter_est, mtbf_scale * n_iters,
                seed)
    trace = fm.events(n_iters, iter_est)

    static = run_online_jobset(
        jobset, hw, policy=ReoptPolicy.never(), trace=trace,
        n_iters=n_iters, seed=0, plan=plan)
    reactive_policy = ReoptPolicy.reactive(
        fiber_move_latency=OCS_FIBER_MOVE_S, adaptive=True)
    from dataclasses import replace
    reactive_policy = replace(
        reactive_policy, replan_deadline=30.0, replan_retries=1,
        validate_plans=True)
    reactive = run_online_jobset(
        jobset, hw, policy=reactive_policy, trace=trace,
        n_iters=n_iters, seed=0, plan=plan)

    # Gates: both operators finish, reactive keeps the goodput floor, and
    # a storm never wedges the controller in an unbounded replan loop.
    assert np.isfinite(static.total_time) and np.isfinite(
        reactive.total_time), "storm wedged a driver run"
    ratio = static.total_time / max(reactive.total_time, 1e-12)
    assert ratio >= 1.0 - SLACK, (
        f"reactive goodput fell {ratio:.3f}x below static repair"
    )
    n_events = sum(1 for ev in trace if ev.kind in ("fail", "repair"))
    max_opt_runs = (1 + 1) * max(n_events, 1)  # retries+1 per trigger
    n_opt_records = sum(
        1 for r in reactive.log
        if r.trigger.endswith(":error") or r.trigger.endswith(":deadline")
    )
    assert n_opt_records <= max_opt_runs, "retry budget exceeded"
    return dict(
        n_trace_events=len(trace),
        static_s=static.total_time,
        reactive_s=reactive.total_time,
        static_over_reactive=ratio,
        reactive_replans=reactive.n_replans,
        edges_moved=reactive.edges_moved,
        refused=list(reactive.refused),
    )


def run(smoke: bool = False) -> list[dict]:
    n = 9 if smoke else 18
    n_iters = 3 if smoke else 6
    rounds, iters = (1, 15) if smoke else (2, 60)
    mtbf_scales = [0.5, 4.0] if smoke else [0.25, 1.0, 4.0]
    storm_seeds = [0] if smoke else [0, 1]
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)

    jobset = _jobset(n)
    plan = co_optimize_jobset(jobset, hw, rounds=rounds, mcmc_iters=iters,
                              seed=1)

    rows: list[dict] = []
    for mtbf_scale in mtbf_scales:
        t0 = time.perf_counter()
        eng_rows = [
            _engine_storm_row(n, hw, plan.topology, mtbf_scale, seed)
            for seed in storm_seeds
        ]
        drv_rows = [
            _driver_storm_row(n, hw, jobset, plan, n_iters, mtbf_scale, seed)
            for seed in storm_seeds
        ]
        us = (time.perf_counter() - t0) * 1e6
        avail = float(np.mean([r["availability"] for r in eng_rows]))
        restarts = int(sum(r["restarts"] for r in eng_rows))
        ratio = float(np.mean([r["static_over_reactive"] for r in drv_rows]))
        rows.append(dict(
            name=f"faults_mtbf_{mtbf_scale:g}x",
            us_per_call=us,
            derived=(
                f"avail={avail:.3f};restarts={restarts};"
                f"static/reactive={ratio:.2f}"
            ),
            mtbf_scale=mtbf_scale,
            availability=avail,
            restarts=restarts,
            static_over_reactive=ratio,
            engine=eng_rows,
            driver=drv_rows,
        ))

    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_faults.json: the headline numbers CI tracks over time."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    record = dict(
        bench="faults",
        smoke=smoke,
        points=[
            dict(
                mtbf_scale=r["mtbf_scale"],
                availability=r["availability"],
                restarts=r["restarts"],
                static_over_reactive=r["static_over_reactive"],
            )
            for r in rows
        ],
        worst_availability=min(r["availability"] for r in rows),
        total_restarts=sum(r["restarts"] for r in rows),
        wall_us=sum(r["us_per_call"] for r in rows),
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    for row in run(smoke=os.environ.get("SMOKE", "") == "1"):
        print(row["name"], row["derived"])
