"""Fig. 16 — shared cluster of 432 servers (d=8).

TopoOpt shards the optical fabric per job, so a job's iteration time is
independent of cluster load (dedicated links).  Fat-tree variants share a
two-level tree; jobs are fragmented across racks (ToR radix 16), so ring +
MP traffic crosses the oversubscribable core.  Fluid bottleneck analysis:
per-link loads accumulate across jobs; a job's comm time is the worst link
it crosses; iteration = compute + comm.

Job mix (paper): 40% DLRM, 30% BERT, 20% CANDLE, 10% VGG, 16 servers each.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.costmodel import ClusterSpec, cost_equivalent_bandwidth_fraction
from repro.core.netsim import HardwareSpec, compute_time, mp_flows, topoopt_comm_time
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, CANDLE, DLRM, VGG16, job_demand

N = 432
JOB_SIZE = 16
DEGREE = 8
MIX = [(DLRM, 0.4), (BERT, 0.3), (CANDLE, 0.2), (VGG16, 0.1)]


def _jobs_for_load(load: float, rng) -> list:
    n_jobs = max(1, int(round(load * (N // JOB_SIZE))))
    jobs = []
    for _ in range(n_jobs):
        r = rng.random()
        acc = 0.0
        for job, frac in MIX:
            acc += frac
            if r <= acc:
                jobs.append(job)
                break
        else:
            jobs.append(MIX[-1][0])
    return jobs


def _job_demand(job):
    return job_demand(
        job, JOB_SIZE,
        table_hosts=range(0, JOB_SIZE, 4) if job.n_tables else None,
    )


def _topoopt_times(jobs, hw) -> np.ndarray:
    """Dedicated shards: per-job fluid time, no cross-job contention."""
    times = []
    cache: dict = {}
    for job in jobs:
        if job.name not in cache:
            dem = _job_demand(job)
            topo = topology_finder(dem, DEGREE)
            comm = topoopt_comm_time(topo, dem, hw)["comm_time"]
            comp = compute_time(
                job.flops_per_sample * job.batch_per_gpu * JOB_SIZE, JOB_SIZE, hw
            )
            cache[job.name] = comm + comp
        times.append(cache[job.name])
    return np.array(times)


def _tree_times(jobs, hw, bandwidth_fraction: float, oversub: float,
                rng) -> np.ndarray:
    """Shared two-level tree with fragmented job placement."""
    n_jobs = len(jobs)
    bw = hw.link_bandwidth * hw.degree * bandwidth_fraction

    link_bytes: dict = {}
    job_links: list[list] = []
    for j, job in enumerate(jobs):
        servers = [(i * n_jobs + j) % N for i in range(JOB_SIZE)]
        dem = _job_demand(job)
        flows = []
        for group in dem.allreduce:
            k = len(group.members)
            per_link = 2.0 * (k - 1) / k * group.nbytes
            for idx in range(k):
                flows.append(
                    (group.members[idx], group.members[(idx + 1) % k], per_link)
                )
        flows += mp_flows(dem)
        links_used = set()
        for a, b, nbytes in flows:
            sa, sb = servers[a], servers[b]
            ta, tb = ("tor", sa // 16), ("tor", sb // 16)
            hops = [(sa, ta), (ta, "core"), ("core", tb), (tb, sb)] if ta != tb \
                else [(sa, ta), (ta, sb)]
            for hop in hops:
                link_bytes[hop] = link_bytes.get(hop, 0.0) + nbytes
                links_used.add(hop)
        job_links.append(links_used)

    def cap(link):
        a, b = link
        core = a == "core" or b == "core"
        # full-bisection ToR uplink aggregate = 16 host links; oversub
        # removes half of it.
        return 16 * bw / oversub if core else bw

    times = []
    for j, job in enumerate(jobs):
        comm = max(
            (link_bytes[l] / cap(l) for l in job_links[j]), default=0.0
        )
        comp = compute_time(
            job.flops_per_sample * job.batch_per_gpu * JOB_SIZE, JOB_SIZE, hw
        )
        times.append(comm + comp)
    return np.array(times)


def run(loads=(0.2, 0.4, 0.6, 0.8, 1.0), seed=0) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=100e9 / 8, degree=DEGREE)
    frac = cost_equivalent_bandwidth_fraction(
        ClusterSpec(n_servers=N, degree=DEGREE, link_gbps=100)
    )
    rng = np.random.default_rng(seed)
    rows = []
    for load in loads:
        jobs = _jobs_for_load(load, rng)
        t0 = time.perf_counter()
        t_topo = _topoopt_times(jobs, hw)
        t_ft = _tree_times(jobs, hw, frac, 1.0, rng)
        t_over = _tree_times(jobs, hw, 1.0, 2.0, rng)
        us = (time.perf_counter() - t0) * 1e6
        rows.append(
            dict(
                name=f"shared_load{int(load * 100)}",
                us_per_call=us,
                derived=(
                    f"jobs={len(jobs)};"
                    f"ft/topo_mean={t_ft.mean() / t_topo.mean():.2f};"
                    f"ft/topo_p99={np.percentile(t_ft, 99) / np.percentile(t_topo, 99):.2f};"
                    f"oversub/topo_mean={t_over.mean() / t_topo.mean():.2f}"
                ),
                topoopt_mean=float(t_topo.mean()),
                fat_tree_mean=float(t_ft.mean()),
                oversub_mean=float(t_over.mean()),
                topoopt_p99=float(np.percentile(t_topo, 99)),
                fat_tree_p99=float(np.percentile(t_ft, 99)),
            )
        )
    return rows
