"""Fig. 16 — shared cluster of 432 servers (d=8).

TopoOpt shards the optical fabric per job, so a job's iteration time is
independent of cluster load (dedicated links).  Fat-tree variants share a
two-level tree; jobs are fragmented across racks (ToR radix 16), so ring +
MP traffic crosses the oversubscribable core.  Fluid bottleneck analysis:
per-link loads accumulate across jobs; a job's comm time is the worst link
it crosses; iteration = compute + comm.

Driven by :class:`repro.core.simengine.SimEngine` (vectorized flows x links
accumulation).  The pre-SimEngine pure-Python loops are retained as
``_tree_times_legacy`` / ``_topoopt_times_legacy`` so every run
cross-checks the numbers and reports the measured speedup in its output
rows (``speedup=`` in ``derived``).

Job mix (paper): 40% DLRM, 30% BERT, 20% CANDLE, 10% VGG, 16 servers each.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.costmodel import ClusterSpec, cost_equivalent_bandwidth_fraction
from repro.core.simengine import (
    HardwareSpec,
    SimEngine,
    compute_time,
    mp_flows,
    topoopt_comm_time,
)
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, CANDLE, DLRM, VGG16, job_demand

N = 432
JOB_SIZE = 16
DEGREE = 8
MIX = [(DLRM, 0.4), (BERT, 0.3), (CANDLE, 0.2), (VGG16, 0.1)]


def _jobs_for_load(load: float, rng) -> list:
    n_jobs = max(1, int(round(load * (N // JOB_SIZE))))
    jobs = []
    for _ in range(n_jobs):
        r = rng.random()
        acc = 0.0
        for job, frac in MIX:
            acc += frac
            if r <= acc:
                jobs.append(job)
                break
        else:
            jobs.append(MIX[-1][0])
    return jobs


def _job_demand(job):
    return job_demand(
        job, JOB_SIZE,
        table_hosts=range(0, JOB_SIZE, 4) if job.n_tables else None,
    )


# ---------------------------------------------------------------------------
# Legacy (pre-SimEngine) pure-Python reference paths, kept for the
# correctness cross-check + speedup measurement.
# ---------------------------------------------------------------------------


def _topoopt_times_legacy(jobs, hw) -> np.ndarray:
    """Dedicated shards: per-job fluid time, no cross-job contention."""
    times = []
    cache: dict = {}
    for job in jobs:
        if job.name not in cache:
            dem = _job_demand(job)
            topo = topology_finder(dem, DEGREE)
            comm = topoopt_comm_time(topo, dem, hw)["comm_time"]
            comp = compute_time(
                job.flops_per_sample * job.batch_per_gpu * JOB_SIZE, JOB_SIZE, hw
            )
            cache[job.name] = comm + comp
        times.append(cache[job.name])
    return np.array(times)


def _tree_times_legacy(
    jobs, hw, bandwidth_fraction: float, oversub: float
) -> np.ndarray:
    """Shared two-level tree with fragmented job placement."""
    n_jobs = len(jobs)
    bw = hw.link_bandwidth * hw.degree * bandwidth_fraction

    link_bytes: dict = {}
    job_links: list[list] = []
    for j, job in enumerate(jobs):
        servers = [(i * n_jobs + j) % N for i in range(JOB_SIZE)]
        dem = _job_demand(job)
        flows = []
        for group in dem.allreduce:
            k = len(group.members)
            per_link = 2.0 * (k - 1) / k * group.nbytes
            for idx in range(k):
                flows.append(
                    (group.members[idx], group.members[(idx + 1) % k], per_link)
                )
        flows += mp_flows(dem)
        links_used = set()
        for a, b, nbytes in flows:
            sa, sb = servers[a], servers[b]
            ta, tb = ("tor", sa // 16), ("tor", sb // 16)
            hops = [(sa, ta), (ta, "core"), ("core", tb), (tb, sb)] if ta != tb \
                else [(sa, ta), (ta, sb)]
            for hop in hops:
                link_bytes[hop] = link_bytes.get(hop, 0.0) + nbytes
                links_used.add(hop)
        job_links.append(links_used)

    def cap(link):
        a, b = link
        core = a == "core" or b == "core"
        # full-bisection ToR uplink aggregate = 16 host links; oversub
        # removes half of it.
        return 16 * bw / oversub if core else bw

    times = []
    for j, job in enumerate(jobs):
        comm = max(
            (link_bytes[l] / cap(l) for l in job_links[j]), default=0.0
        )
        comp = compute_time(
            job.flops_per_sample * job.batch_per_gpu * JOB_SIZE, JOB_SIZE, hw
        )
        times.append(comm + comp)
    return np.array(times)


def run(loads=(0.2, 0.4, 0.6, 0.8, 1.0), seed=0, check_legacy=True) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=100e9 / 8, degree=DEGREE)
    engine = SimEngine(hw)
    frac = cost_equivalent_bandwidth_fraction(
        ClusterSpec(n_servers=N, degree=DEGREE, link_gbps=100)
    )
    rng = np.random.default_rng(seed)
    rows = []
    total_new = 0.0
    total_legacy = 0.0
    for load in loads:
        jobs = _jobs_for_load(load, rng)

        def _new_pass():
            t0 = time.perf_counter()
            t_topo = engine.dedicated_job_times(jobs, JOB_SIZE, _job_demand, DEGREE)
            t_ft = engine.tree_times(jobs, N, JOB_SIZE, _job_demand, frac, 1.0)
            t_over = engine.tree_times(jobs, N, JOB_SIZE, _job_demand, 1.0, 2.0)
            return (time.perf_counter() - t0) * 1e6, t_topo, t_ft, t_over

        # First pass builds the per-job-type topology/flow caches; the
        # steady-state second pass is what ``us_per_call`` reports.  The
        # ``speedup=`` figure therefore measures the new sweep regime
        # (engine caches across calls + vectorized accumulation) against the
        # legacy implementation, which recomputed topology_finder and the
        # flow translation on every call — both effects are part of the
        # SimEngine consolidation, but the ratio is not vectorization alone.
        us_cold, *_ = _new_pass()
        us, t_topo, t_ft, t_over = _new_pass()
        total_new += us

        us_legacy = float("nan")
        if check_legacy:
            t1 = time.perf_counter()
            t_topo_ref = _topoopt_times_legacy(jobs, hw)
            t_ft_ref = _tree_times_legacy(jobs, hw, frac, 1.0)
            t_over_ref = _tree_times_legacy(jobs, hw, 1.0, 2.0)
            us_legacy = (time.perf_counter() - t1) * 1e6
            total_legacy += us_legacy
            np.testing.assert_allclose(t_topo, t_topo_ref, rtol=1e-9)
            np.testing.assert_allclose(t_ft, t_ft_ref, rtol=1e-9)
            np.testing.assert_allclose(t_over, t_over_ref, rtol=1e-9)

        rows.append(
            dict(
                name=f"shared_load{int(load * 100)}",
                us_per_call=us,
                us_cold=us_cold,
                us_legacy=us_legacy,
                derived=(
                    f"jobs={len(jobs)};"
                    f"ft/topo_mean={t_ft.mean() / t_topo.mean():.2f};"
                    f"ft/topo_p99={np.percentile(t_ft, 99) / np.percentile(t_topo, 99):.2f};"
                    f"oversub/topo_mean={t_over.mean() / t_topo.mean():.2f}"
                    + (f";speedup={us_legacy / us:.1f}x" if check_legacy else "")
                ),
                topoopt_mean=float(t_topo.mean()),
                fat_tree_mean=float(t_ft.mean()),
                oversub_mean=float(t_over.mean()),
                topoopt_p99=float(np.percentile(t_topo, 99)),
                fat_tree_p99=float(np.percentile(t_ft, 99)),
            )
        )
    if check_legacy and rows:
        total_speedup = total_legacy / max(total_new, 1e-9)
        rows[-1]["total_speedup"] = total_speedup
        rows[-1]["derived"] += f";total_speedup={total_speedup:.1f}x"
    return rows
