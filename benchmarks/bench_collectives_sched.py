"""Collective-schedule co-optimization: searched schedules vs ring-only.

The fluid model prices every AllReduce as a bandwidth-optimal ring —
``2 (k-1)`` serial rounds.  With the (α, β) cost model's latency term on
(``hw.link_latency``), small-message groups are *latency*-dominated and the
``O(log k)``-round schedules of :mod:`repro.core.schedules` win at equal
wire bytes.  This benchmark searches the schedule axis jointly with
strategy and topology (``schedules=...`` through ``alternating_optimize`` /
``co_optimize_jobset``) and gates the two regimes the paper's story needs:

* ``sched_small_bert`` / ``sched_jobset`` — a fine-tuning BERT whose
  bucketed gradient sync moves ~2 MB per iteration (plus, in the jobset
  arm, a small-dense MoE tenant whose expert all-to-all stays pinned MP
  traffic).  The searched schedule must beat ring-only comm time by
  >= 1.2x (it finds the log-depth halving-doubling / multi-tree compiles).
* ``sched_dlrm_bandwidth`` — bandwidth-dominated DLRM, where ring is
  optimal: the searched plan must keep the ring schedule and match
  ring-only comm time.

Every arm also re-prices the winning demand on both the compiled planner
and the reference fluid model and asserts **bit-identical** agreement
(``max_rel_err = 0``) — the latency term uses the same expression on both
paths.  A perf record lands in
``experiments/bench/BENCH_collectives_sched.json``.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import replace

from repro.core.alternating import alternating_optimize, co_optimize_jobset
from repro.core.netsim import HardwareSpec, reference_comm_time
from repro.core.planeval import plan_evaluator
from repro.core.workloads import BERT, DLRM, MOE_16E, JobSet, TenantJob

PERF_RECORD = os.path.join(
    "experiments", "bench", "BENCH_collectives_sched.json"
)

SCHEDULES = ("ring", "recursive_hd", "multi_tree")
# 20 us per serial collective round: OCS direct-connect with host-based
# forwarding pays NIC + host-stack latency every round.
ALPHA = 2e-5
# Fine-tuning BERT: frozen encoder, ~100k trainable params (adapter /
# LoRA-style head) -> ~400 KB gradient sync per iteration —
# latency-dominated at 12.5 GB/s links.
BERT_FT = replace(BERT, name="bert_ft", dense_params=1e5)
# MoE tenant with a small dense trunk: the expert all-to-all (pinned MP)
# dominates bytes, the dense sync rounds dominate latency.
MOE_FT = replace(MOE_16E, name="moe_ft", dense_params=2e5)


def _max_rel_err(topo, demand, hw: HardwareSpec) -> float:
    """Compiled-vs-reference disagreement on one demand (must be 0.0)."""
    fast = plan_evaluator(topo, hw).comm_time(demand)
    ref = reference_comm_time(topo, demand, hw)
    return abs(fast - ref) / max(abs(ref), 1e-30)


def _bench_single(name: str, job, n: int, iters: int, hw: HardwareSpec,
                  expect_win: float | None) -> dict:
    t0 = time.perf_counter()
    ring = alternating_optimize(job, n, hw, rounds=2, mcmc_iters=iters,
                                seed=0)
    sched = alternating_optimize(job, n, hw, rounds=2, mcmc_iters=iters,
                                 seed=0, schedules=SCHEDULES)
    wall = time.perf_counter() - t0
    comm_ring = reference_comm_time(ring.topology, ring.demand, hw)
    comm_sched = reference_comm_time(sched.topology, sched.demand, hw)
    win = comm_ring / comm_sched
    max_rel = max(
        _max_rel_err(ring.topology, ring.demand, hw),
        _max_rel_err(sched.topology, sched.demand, hw),
    )
    assert max_rel == 0.0, f"compiled disagrees with reference: {max_rel}"
    if expect_win is not None:
        assert win >= expect_win, (
            f"{name}: searched schedule win {win:.2f}x < {expect_win}x "
            f"(schedule={sched.strategy.schedule})"
        )
        assert sched.strategy.schedule != "ring", (
            f"{name}: latency-dominated search kept ring"
        )
    else:
        # Bandwidth-dominated: ring is optimal, the search must keep it
        # and match ring-only comm time.
        assert sched.strategy.schedule == "ring", (
            f"{name}: bandwidth-dominated search left ring for "
            f"{sched.strategy.schedule}"
        )
        assert 0.95 <= win <= 1.05, f"{name}: comm drifted {win:.3f}x"
    return dict(
        name=name,
        us_per_call=wall * 1e6,
        derived=(
            f"comm_win={win:.2f}x;schedule={sched.strategy.schedule};"
            f"comm_ring_us={comm_ring * 1e6:.0f};"
            f"comm_sched_us={comm_sched * 1e6:.0f};max_rel_err={max_rel:.0e}"
        ),
        comm_win=win,
        schedule=sched.strategy.schedule,
        comm_ring_us=comm_ring * 1e6,
        comm_sched_us=comm_sched * 1e6,
        max_rel_err=max_rel,
    )


def _bench_jobset(n: int, iters: int, hw: HardwareSpec,
                  expect_win: float) -> dict:
    half = n // 2
    js = JobSet(n=n, tenants=[
        TenantJob(spec=BERT_FT, servers=tuple(range(0, half))),
        TenantJob(spec=MOE_FT, servers=tuple(range(half, n))),
    ])
    t0 = time.perf_counter()
    ring = co_optimize_jobset(js, hw, rounds=2, mcmc_iters=iters, seed=1)
    sched = co_optimize_jobset(js, hw, rounds=2, mcmc_iters=iters, seed=1,
                               schedules=SCHEDULES)
    wall = time.perf_counter() - t0
    # The MoE tenant's expert all-to-all is pinned MP traffic — schedules
    # cannot (and must not) change it.  The schedule win is the
    # latency-dominated tenant's own comm time on the *shared* fabric; the
    # all-to-all rider must not regress while the fabric re-forms around
    # the compiled pairs.
    bert = BERT_FT.name
    moe = MOE_FT.name
    win = ring.per_job_comm[bert] / sched.per_job_comm[bert]
    moe_ratio = sched.per_job_comm[moe] / ring.per_job_comm[moe]
    max_rel = max(
        _max_rel_err(ring.topology, ring.demand, hw),
        _max_rel_err(sched.topology, sched.demand, hw),
    )
    assert max_rel == 0.0, f"compiled disagrees with reference: {max_rel}"
    flipped = sorted(
        s.schedule for s in sched.strategies.values() if s.schedule != "ring"
    )
    assert win >= expect_win, (
        f"jobset: searched schedule win {win:.2f}x < {expect_win}x "
        f"(flipped={flipped})"
    )
    assert flipped, "jobset: latency-dominated search kept ring everywhere"
    assert moe_ratio <= 1.02, (
        f"jobset: all-to-all tenant regressed {moe_ratio:.3f}x"
    )
    return dict(
        name=f"sched_jobset_n{n}",
        us_per_call=wall * 1e6,
        derived=(
            f"comm_win={win:.2f}x;flipped={','.join(flipped)};"
            f"bert_ring_us={ring.per_job_comm[bert] * 1e6:.0f};"
            f"bert_sched_us={sched.per_job_comm[bert] * 1e6:.0f};"
            f"moe_ratio={moe_ratio:.3f};max_rel_err={max_rel:.0e}"
        ),
        comm_win=win,
        flipped=flipped,
        bert_ring_us=ring.per_job_comm[bert] * 1e6,
        bert_sched_us=sched.per_job_comm[bert] * 1e6,
        moe_ratio=moe_ratio,
        max_rel_err=max_rel,
    )


def run(smoke: bool = False) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=4, link_latency=ALPHA)
    # The jobset arm stays at n=12 in both modes: at n=16 the MoE tenant's
    # 8-way expert all-to-all already saturates the degree-4 fabric, so the
    # schedule flip's pinned tree pairs are genuinely unprofitable there —
    # n=12 is the regime the latency-win story targets.
    if smoke:
        n_single, n_js, iters = 16, 12, 40
    else:
        n_single, n_js, iters = 16, 12, 120
    rows = [
        _bench_single(f"sched_small_bert_n{n_single}", BERT_FT, n_single,
                      iters, hw, expect_win=1.2),
        _bench_jobset(n_js, iters, hw, expect_win=1.2),
        _bench_single(f"sched_dlrm_bandwidth_n{n_single}", DLRM, n_single,
                      iters, hw, expect_win=None),
    ]
    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_collectives_sched.json: the headline schedule wins CI tracks."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    by_name = {r["name"].rsplit("_n", 1)[0]: r for r in rows}
    record = dict(
        bench="collectives_sched",
        smoke=smoke,
        small_message_win=by_name["sched_small_bert"]["comm_win"],
        small_message_schedule=by_name["sched_small_bert"]["schedule"],
        jobset_win=by_name["sched_jobset"]["comm_win"],
        dlrm_bandwidth_ratio=by_name["sched_dlrm_bandwidth"]["comm_win"],
        dlrm_schedule=by_name["sched_dlrm_bandwidth"]["schedule"],
        max_rel_err=max(r["max_rel_err"] for r in rows),
        wall_us=sum(r["us_per_call"] for r in rows),
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full sizes (direct runs default to smoke)")
    cli = ap.parse_args()
    for row in run(smoke=not cli.full):
        print(row["name"], row["derived"])
