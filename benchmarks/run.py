"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus per-bench extras to
JSON files under experiments/bench/).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

BENCHES = [
    ("cost", "Fig. 10 interconnect cost"),
    ("dedicated", "Fig. 11 dedicated 128-server cluster"),
    ("alltoall", "Fig. 12/13 all-to-all impact + bandwidth tax"),
    ("pathlen", "Fig. 14/15 path length + link utilization"),
    ("shared", "Fig. 16 shared 432-server cluster"),
    ("reconfig", "Fig. 17 reconfiguration latency"),
    ("online", "Online re-optimization: static vs reactive replanning"),
    ("multitenant", "Multi-tenant shared fabric: JobSet churn + fairness"),
    ("planner", "Compiled plan evaluator: reference vs compiled planner speed"),
    ("planner_jax", "JAX planner backend: batched chains vs NumPy pricing"),
    ("placement", "Placement co-search + churn-priced migration vs greedy"),
    ("collectives_sched", "Collective-schedule co-optimization vs ring-only"),
    ("roofline", "Roofline dry-run terms"),
    ("fleet", "Fleet-scale pricing: sparse vs dense at 256-1024 nodes"),
    ("faults", "Chaos: MTBF storm sweep, availability + hardened replanning"),
    ("admission_jax", "Fused admission co-search: candidate x ladder grid"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (benches that support it)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    os.makedirs(args.out, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for bench, desc in BENCHES:
        if only and bench not in only:
            continue
        try:
            mod = __import__(f"benchmarks.bench_{bench}", fromlist=["run"])
            import inspect

            kwargs = (
                {"smoke": True}
                if args.smoke
                and "smoke" in inspect.signature(mod.run).parameters
                else {}
            )
            rows = mod.run(**kwargs)
            # One canonical record per bench: modules with a PERF_RECORD
            # write their own BENCH_<name>.json (rich derived metrics);
            # for the rest the harness writes the row dump under the same
            # naming scheme.  (The harness used to always dump a stray
            # lowercase <name>.json that shadowed the canonical record.)
            if not hasattr(mod, "PERF_RECORD"):
                record = os.path.join(args.out, f"BENCH_{bench}.json")
                with open(record, "w") as f:
                    json.dump(rows, f, indent=1, default=str)
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        except Exception:
            failures += 1
            print(f"{bench},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
