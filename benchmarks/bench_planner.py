"""Planner speed: reference vs compiled plan evaluator.

The alternating loop (§4.1) evaluates hundreds of (strategy, topology)
candidates per replan; the online layer re-enters it on every
failure/arrival, so planner latency bounds how often TopoOpt can react.
This benchmark measures the compiled evaluator (:mod:`repro.core.planeval`)
against the reference :func:`~repro.core.netsim.topoopt_comm_time` path:

* ``planner_candidate_evals`` — raw candidate pricing throughput for the
  multi-tenant objective: reference ``evaluate_jobset`` (union + full fluid
  walk per candidate) vs the incremental ``JobSetEvaluator.propose``
  (cached per-tenant link-load vectors, one ``total - old + new`` swap).
* ``planner_alternating`` — end-to-end ``alternating_optimize`` wall time,
  ``compiled=False`` vs ``compiled=True``, at a realistic MCMC budget
  (fixed seeds; the two runs return identical plans, which is asserted).
* ``planner_replan`` — end-to-end replan latency of the multi-tenant
  ``co_optimize_jobset`` (the call every online failure/arrival pays).

``derived`` reports the speedups plus the max relative compiled-vs-
reference disagreement over the sampled candidates (must be <= 1e-9).  A
perf record lands in ``experiments/bench/BENCH_planner.json``.
"""

from __future__ import annotations

import json
import os
import random
import time

from repro.core.alternating import alternating_optimize, co_optimize_jobset
from repro.core.netsim import HardwareSpec
from repro.core.planeval import JobSetEvaluator, plan_evaluator
from repro.core.strategy_search import (
    Strategy,
    _propose,
    default_strategy,
    evaluate_jobset,
)
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, DLRM, MOE_16E, JobSet, TenantJob

DEGREE = 4
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_planner.json")


def _jobset(n: int) -> JobSet:
    third = n // 3
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, third)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(third, 2 * third)),
                  name="bert"),
        TenantJob(spec=MOE_16E, servers=tuple(range(2 * third, n)),
                  name="moe"),
    ])


def _candidate_moves(js: JobSet, n_moves: int, seed: int = 0):
    """A fixed stream of single-tenant MCMC moves (tenant label + proposed
    strategy), shared verbatim by both pricing paths."""
    rng = random.Random(seed)
    current = {t.label: default_strategy(t.spec) for t in js.tenants}
    moves = []
    for _ in range(n_moves):
        t = js.tenants[rng.randrange(len(js.tenants))]
        cand = _propose(current[t.label], t.spec, t.k, rng)
        moves.append((t.label, cand))
    return current, moves


def _bench_candidate_evals(n: int, n_moves: int, hw: HardwareSpec) -> dict:
    js = _jobset(n)
    init, moves = _candidate_moves(js, n_moves)
    topo = topology_finder(js.union_for(init), hw.degree, pack="per_node")

    # Warm both paths' demand caches so the measurement isolates pricing
    # (demand construction is identical work on both sides).  The vector
    # cache must hold every warmed move or the timed loop re-derives
    # evicted entries.
    cache: dict = {}
    jse = JobSetEvaluator(js, topo, hw, demand_cache=cache,
                          vector_cache_size=n_moves + len(js.tenants) + 1)
    jse.set_strategies(init)
    for label, cand in moves:
        jse.tenant_loads(label, cand)
    evaluate_jobset(init, js, topo, hw, _demand_cache=cache)

    max_rel = 0.0
    t0 = time.perf_counter()
    ref_objs = []
    for label, cand in moves:
        state = dict(init)
        state[label] = cand
        ref_objs.append(
            evaluate_jobset(state, js, topo, hw, _demand_cache=cache)[0]
        )
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    fast_objs = [jse.propose(label, cand)[0] for label, cand in moves]
    t_fast = time.perf_counter() - t0

    for r, f in zip(ref_objs, fast_objs):
        max_rel = max(max_rel, abs(f - r) / max(abs(r), 1e-30))
    assert max_rel <= 1e-9, f"compiled disagrees with reference: {max_rel}"

    return dict(
        name=f"planner_candidate_evals_n{n}",
        us_per_call=t_fast / n_moves * 1e6,
        derived=(
            f"speedup={t_ref / t_fast:.1f}x;"
            f"ref_evals_per_s={n_moves / t_ref:.0f};"
            f"compiled_evals_per_s={n_moves / t_fast:.0f};"
            f"max_rel_err={max_rel:.1e}"
        ),
        speedup=t_ref / t_fast,
        ref_evals_per_s=n_moves / t_ref,
        compiled_evals_per_s=n_moves / t_fast,
        max_rel_err=max_rel,
    )


def _bench_alternating(n: int, rounds: int, iters: int,
                       hw: HardwareSpec, reps: int = 2) -> dict:
    # Min over repetitions: the standard noise-robust latency estimator
    # (scheduler jitter only ever adds time).
    t_ref = t_fast = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        ref = alternating_optimize(DLRM, n, hw, rounds=rounds,
                                   mcmc_iters=iters, seed=0, compiled=False)
        t_ref = min(t_ref, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fast = alternating_optimize(DLRM, n, hw, rounds=rounds,
                                    mcmc_iters=iters, seed=0, compiled=True)
        t_fast = min(t_fast, time.perf_counter() - t0)
    identical = (
        fast.strategy == ref.strategy
        and abs(fast.iter_time - ref.iter_time) <= 1e-9 * ref.iter_time
    )
    assert identical, "compiled alternating_optimize changed the plan"
    return dict(
        name=f"planner_alternating_n{n}",
        us_per_call=t_fast * 1e6,
        derived=(
            f"speedup={t_ref / t_fast:.1f}x;"
            f"ref_s={t_ref:.2f};compiled_s={t_fast:.2f};identical=True"
        ),
        speedup=t_ref / t_fast,
        ref_s=t_ref,
        compiled_s=t_fast,
        identical=identical,
    )


def _bench_replan(n: int, rounds: int, iters: int, hw: HardwareSpec,
                  reps: int = 2) -> dict:
    js = _jobset(n)
    t_ref = t_fast = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        ref = co_optimize_jobset(js, hw, rounds=rounds, mcmc_iters=iters,
                                 seed=1, compiled=False)
        t_ref = min(t_ref, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fast = co_optimize_jobset(js, hw, rounds=rounds, mcmc_iters=iters,
                                  seed=1, compiled=True)
        t_fast = min(t_fast, time.perf_counter() - t0)
    identical = (
        fast.strategies == ref.strategies
        and abs(fast.iter_time - ref.iter_time) <= 1e-9 * ref.iter_time
    )
    assert identical, "compiled co_optimize_jobset changed the plan"
    return dict(
        name=f"planner_replan_n{n}",
        us_per_call=t_fast * 1e6,
        derived=(
            f"speedup={t_ref / t_fast:.1f}x;"
            f"ref_s={t_ref:.2f};compiled_s={t_fast:.2f};identical=True"
        ),
        speedup=t_ref / t_fast,
        ref_s=t_ref,
        compiled_s=t_fast,
        identical=identical,
    )


def run(smoke: bool = False) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    if smoke:
        n_js, n_moves = 12, 150
        n_alt, rounds, iters = 16, 2, 120
    else:
        n_js, n_moves = 24, 600
        n_alt, rounds, iters = 32, 2, 400
    rows = [
        _bench_candidate_evals(n_js, n_moves, hw),
        _bench_alternating(n_alt, rounds, iters, hw),
        _bench_replan(n_js, rounds, max(iters // 2, 60), hw),
    ]
    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_planner.json: the headline numbers CI tracks over time."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    by_name = {r["name"].rsplit("_n", 1)[0]: r for r in rows}
    record = dict(
        bench="planner",
        smoke=smoke,
        candidate_eval_speedup=by_name["planner_candidate_evals"]["speedup"],
        compiled_evals_per_s=(
            by_name["planner_candidate_evals"]["compiled_evals_per_s"]
        ),
        max_rel_err=by_name["planner_candidate_evals"]["max_rel_err"],
        alternating_speedup=by_name["planner_alternating"]["speedup"],
        replan_speedup=by_name["planner_replan"]["speedup"],
        results_identical=(
            by_name["planner_alternating"]["identical"]
            and by_name["planner_replan"]["identical"]
        ),
        wall_us=sum(r["us_per_call"] for r in rows),
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="jax runs the batched-planner bench "
                         "(benchmarks.bench_planner_jax) instead")
    ap.add_argument("--full", action="store_true",
                    help="full sizes (direct runs default to smoke)")
    cli = ap.parse_args()
    if cli.backend == "jax":
        if __package__ in (None, ""):  # script-style: python benchmarks/...
            import sys

            sys.path.insert(
                0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
            )
        from benchmarks.bench_planner_jax import run as run_jax

        rows = run_jax(smoke=not cli.full)
    else:
        rows = run(smoke=not cli.full)
    for row in rows:
        print(row["name"], row["derived"])
