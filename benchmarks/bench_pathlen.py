"""Fig. 14 + 15 — path-length CDF and per-link traffic distribution for the
all-to-all DLRM on 128 servers, d in {4, 8}."""

from __future__ import annotations

import time

import numpy as np

from repro.core.netsim import mp_flows
from repro.core.routing import link_loads, path_length_stats
from repro.core.topology_finder import topology_finder
from repro.core.workloads import DLRM_A2A, job_demand

N = 128


def run(degrees=(4, 8)) -> list[dict]:
    rows = []
    for d in degrees:
        job = DLRM_A2A.with_batch(128)
        dem = job_demand(job, N, table_hosts=range(N))
        t0 = time.perf_counter()
        topo = topology_finder(dem, d)
        stats = path_length_stats(topo.routing)
        flows = mp_flows(dem)
        loads = link_loads(topo.graph, flows, topo.routing)
        us = (time.perf_counter() - t0) * 1e6
        vals = np.array([v for v in loads.values() if v > 0])
        imbalance = 1.0 - vals.min() / vals.max() if len(vals) else 0.0
        rows.append(
            dict(
                name=f"pathlen_d{d}",
                us_per_call=us,
                derived=f"mean_path={stats['mean']:.2f};imbalance={imbalance:.2f}",
                mean_path=stats["mean"],
                p99_path=stats["p99"],
                max_path=stats["max"],
                link_min_vs_max=imbalance,
            )
        )
    return rows
