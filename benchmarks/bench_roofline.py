"""§Roofline — aggregate the dry-run records into the per-(arch x shape x
mesh) roofline table (reads experiments/dryrun/*.json)."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "experiments", "dryrun")


def load_records(tag: str = "baseline") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run() -> list[dict]:
    rows = []
    for rec in load_records():
        r = rec["roofline"]
        rows.append(
            dict(
                name=f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh_name']}",
                us_per_call=rec["compile_s"] * 1e6,
                derived=(
                    f"dom={r['dominant']};mfu={r['mfu']:.3f};"
                    f"useful={r['useful_fraction']:.2f}"
                ),
                compute_ms=r["compute_s"] * 1e3,
                memory_ms=r["memory_s"] * 1e3,
                collective_ms=r["collective_s"] * 1e3,
                dominant=r["dominant"],
                mfu=r["mfu"],
                useful_fraction=r["useful_fraction"],
                chips=r["chips"],
            )
        )
    if not rows:
        rows.append(dict(name="roofline_missing", us_per_call=0.0,
                         derived="run repro.launch.dryrun first"))
    return rows
