"""Fleet-scale pricing core: sparse vs dense at 256-1024 nodes (ISSUE 8).

The paper's headline claims rest on pricing direct-connect fabrics far
beyond the 12-64 nodes the seed engine was written for.  This benchmark
gates the O(active-edges) fast paths — COO demand caching + segment-sum
pricing in :class:`~repro.core.planeval.PlanEvaluator`, the embedded
incremental union (:func:`~repro.core.demand.union_embedded`), and the
event-queue max-min filling in :mod:`~repro.core.simengine` — against the
dense baseline (forced via ``REPRO_SPARSE_MIN_NODES`` /
``REPRO_MAXMIN_METHOD``, the same knobs fleet operators tune):

* **candidate pricing** — per-tenant demand pricing through the compiled
  evaluator at 256 nodes must beat the dense path by >= 10x,
* **end-to-end replan** — churn events (tenant departs / arrives, union
  demand rebuilt and re-priced) must beat dense by >= 5x,
* **bit identity** — sparse and dense agree to the bit on union matrices,
  load vectors, comm times, and max-min rates at seed sizes *and* at the
  gate size,
* **fleet churn** — a 512-node (smoke; 1024 full) fabric with ~200
  churning tenants completes a full trace on the sparse path, the regime
  where the dense path stops being interactive.

A perf record lands in ``experiments/bench/BENCH_fleet.json``.
"""

from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

import numpy as np

from repro.core.alternating import initial_topology
from repro.core.demand import remap_demand
from repro.core.netsim import HardwareSpec
from repro.core.planeval import PlanEvaluator
from repro.core.workloads import BERT, DLRM, JobSet, TenantJob, job_demand

DEGREE = 4
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_fleet.json")

# Gates from ISSUE 8 acceptance criteria.
MIN_PRICING_SPEEDUP = 10.0
MIN_REPLAN_SPEEDUP = 5.0

_DENSE_ENV = {
    "REPRO_SPARSE_MIN_NODES": str(1 << 30),  # no fabric is "big enough"
    "REPRO_MAXMIN_METHOD": "dense",
}


@contextmanager
def _forced_dense():
    """Run a block on the dense baseline paths (env knobs, restored after)."""
    old = {k: os.environ.get(k) for k in _DENSE_ENV}
    os.environ.update(_DENSE_ENV)
    try:
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _fleet(n: int, n_tenants: int, seed: int) -> tuple[JobSet, dict]:
    """~``n_tenants`` disjoint tenants (mixed DP transformer / DLRM) plus
    their job-local demands keyed by label."""
    rng = np.random.default_rng(seed)
    nodes = rng.permutation(n)
    tenants, demands, at = [], {}, 0
    for t in range(n_tenants):
        k = 2 + (t % 2)  # mixed 2/3-server jobs (~200 fit on 512 nodes)
        if at + k > n:
            break
        servers = tuple(int(v) for v in nodes[at:at + k])
        at += k
        spec = DLRM if t % 2 else BERT
        label = f"t{t}"
        tenants.append(TenantJob(spec=spec, servers=servers, name=label))
        demands[label] = (
            job_demand(spec, k, table_hosts=tuple(range(0, k, 2)))
            if spec is DLRM else job_demand(spec, k)
        )
    return JobSet(n=n, tenants=tenants), demands


def _embedded(jobset: JobSet, demands: dict, n: int) -> list:
    return [
        remap_demand(demands[t.label], t.servers, n) for t in jobset.tenants
    ]


def _time_pricing(ev: PlanEvaluator, pool: list, reps: int) -> float:
    """Seconds per candidate (one ``comm_time`` call), warm caches."""
    for d in pool:  # compile routes / group incidence outside the clock
        ev.comm_time(d)
    t0 = time.perf_counter()
    for _ in range(reps):
        for d in pool:
            ev.comm_time(d)
    return (time.perf_counter() - t0) / (reps * len(pool))


def _churn_events(jobset: JobSet, demands: dict, n_events: int):
    """Alternating depart / re-arrive trace over the tenant list."""
    events = []
    for i in range(n_events):
        events.append(("depart" if i % 2 == 0 else "arrive",
                       jobset.tenants[i % len(jobset.tenants)].label))
    return events


def _run_replan(jobset: JobSet, demands: dict, ev: PlanEvaluator,
                events, n: int) -> tuple[float, float]:
    """Process the churn trace: every event rebuilds + re-prices the union.

    Returns (seconds per event, last union comm_time)."""
    resident = list(jobset.tenants)
    by_label = {t.label: t for t in jobset.tenants}
    last = 0.0
    t0 = time.perf_counter()
    for kind, label in events:
        if kind == "depart":
            resident = [t for t in resident if t.label != label]
        elif all(t.label != label for t in resident):
            resident = resident + [by_label[label]]
        js = JobSet(n=n, tenants=resident)
        union = js.union(demands)
        last = ev.comm_time(union)
    dt = (time.perf_counter() - t0) / max(len(events), 1)
    return dt, last


def _assert_bit_identity(n: int, hw: HardwareSpec) -> None:
    """Sparse == dense to the bit at seed sizes: union matrix, load
    vectors, comm times, and event-queue max-min rates."""
    from repro.core.simengine import Task, _FlowState, _LinkTable, _max_min_rates

    jobset, demands = _fleet(n, n_tenants=max(3, n // 4), seed=n)
    topo = initial_topology(n, DEGREE)
    sparse_ev = PlanEvaluator(topo, hw)
    dense_ev = PlanEvaluator(topo, hw, sparse_min_nodes_=1 << 30)

    sparse_union = jobset.union(demands)
    with _forced_dense():
        dense_union = jobset.union(demands)
    assert np.array_equal(sparse_union.mp, dense_union.mp), n

    for d in _embedded(jobset, demands, n) + [sparse_union]:
        assert sparse_ev.comm_time(d) == dense_ev.comm_time(d), n
        assert np.array_equal(sparse_ev.loads(d), dense_ev.loads(d)), n

    rng = np.random.default_rng(n)
    table = _LinkTable({
        (i, (i + s) % n): float(rng.uniform(1.0, 50.0))
        for i in range(n) for s in (1, 2)
    })
    flows = []
    for t in range(2 * n):
        a = int(rng.integers(n))
        route = (a, (a + 1) % n, (a + 3) % n)
        lids, cnts = table.indices_for(route)
        flows.append(_FlowState(
            task=Task(tid=t, kind="flow", nbytes=1e3, route=route),
            remaining=1e3, lids=lids, cnts=cnts, hops=2,
        ))
    dense_r = _max_min_rates(flows, table.cap, method="dense")
    heap_r = _max_min_rates(flows, table.cap, method="heap")
    assert np.array_equal(dense_r, heap_r), n


def run(smoke: bool = False) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    rows: list[dict] = []

    # -- bit identity at seed sizes (the existing goldens' regime) ----------
    t0 = time.perf_counter()
    for n in (12, 16, 24):
        _assert_bit_identity(n, hw)
    rows.append(dict(
        name="fleet_bit_identity",
        us_per_call=(time.perf_counter() - t0) * 1e6,
        derived="sparse==dense bitwise at n=12/16/24",
    ))

    # -- candidate pricing + replan gates at 256 nodes ----------------------
    n_gate = 256
    jobset, demands = _fleet(n_gate, n_tenants=80, seed=0)
    topo = initial_topology(n_gate, DEGREE)
    pool = _embedded(jobset, demands, n_gate)

    sparse_ev = PlanEvaluator(topo, hw)
    dense_ev = PlanEvaluator(topo, hw, sparse_min_nodes_=1 << 30)
    sparse_s = _time_pricing(sparse_ev, pool, reps=6 if smoke else 20)
    dense_s = _time_pricing(dense_ev, pool, reps=2 if smoke else 5)
    pricing_speedup = dense_s / sparse_s
    # Same candidates, same bits, 10x less time.
    for d in pool[:8]:
        assert sparse_ev.comm_time(d) == dense_ev.comm_time(d)
    assert pricing_speedup >= MIN_PRICING_SPEEDUP, (
        f"candidate pricing speedup {pricing_speedup:.1f}x < "
        f"{MIN_PRICING_SPEEDUP}x at n={n_gate} "
        f"(sparse {sparse_s*1e6:.1f}us vs dense {dense_s*1e6:.1f}us)"
    )
    rows.append(dict(
        name="fleet_candidate_pricing",
        us_per_call=sparse_s * 1e6,
        derived=f"speedup={pricing_speedup:.1f}x;dense_us={dense_s*1e6:.1f}",
        sparse_us=sparse_s * 1e6,
        dense_us=dense_s * 1e6,
        speedup=pricing_speedup,
        n=n_gate,
        n_tenants=len(jobset.tenants),
    ))

    events = _churn_events(jobset, demands, 10 if smoke else 30)
    sparse_ev.comm_time(jobset.union(demands))  # warm route compile
    sparse_dt, sparse_ct = _run_replan(jobset, demands, sparse_ev, events,
                                       n_gate)
    with _forced_dense():
        dense_ev.comm_time(jobset.union(demands))
        dense_dt, dense_ct = _run_replan(
            jobset, demands, dense_ev,
            events[: max(4, len(events) // 3)], n_gate)
    assert sparse_ct == dense_ct  # same final union, same bits
    replan_speedup = dense_dt / sparse_dt
    assert replan_speedup >= MIN_REPLAN_SPEEDUP, (
        f"replan speedup {replan_speedup:.1f}x < {MIN_REPLAN_SPEEDUP}x "
        f"at n={n_gate} (sparse {sparse_dt*1e3:.2f}ms vs dense "
        f"{dense_dt*1e3:.2f}ms per event)"
    )
    rows.append(dict(
        name="fleet_replan",
        us_per_call=sparse_dt * 1e6,
        derived=f"speedup={replan_speedup:.1f}x;dense_ms={dense_dt*1e3:.2f}",
        sparse_us=sparse_dt * 1e6,
        dense_us=dense_dt * 1e6,
        speedup=replan_speedup,
        n=n_gate,
        n_events=len(events),
    ))

    # -- fleet churn trace: ~200 tenants on 512 (smoke) / 1024 nodes --------
    n_fleet = 512 if smoke else 1024
    fleet_js, fleet_demands = _fleet(n_fleet, n_tenants=200, seed=1)
    fleet_topo = initial_topology(n_fleet, DEGREE)
    fleet_ev = PlanEvaluator(fleet_topo, hw)
    fleet_ev.comm_time(fleet_js.union(fleet_demands))  # warm route compile
    fleet_events = _churn_events(fleet_js, fleet_demands,
                                 12 if smoke else 60)
    fleet_dt, fleet_ct = _run_replan(fleet_js, fleet_demands, fleet_ev,
                                     fleet_events, n_fleet)
    assert np.isfinite(fleet_ct) and fleet_ct > 0.0
    rows.append(dict(
        name="fleet_churn",
        us_per_call=fleet_dt * 1e6,
        derived=(
            f"n={n_fleet};tenants={len(fleet_js.tenants)};"
            f"events_per_s={1.0/fleet_dt:.1f}"
        ),
        n=n_fleet,
        n_tenants=len(fleet_js.tenants),
        n_events=len(fleet_events),
        events_per_s=1.0 / fleet_dt,
        union_comm_time_s=fleet_ct,
    ))

    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_fleet.json: the headline numbers CI tracks over time."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    by_name = {r["name"]: r for r in rows}
    record = dict(
        bench="fleet",
        smoke=smoke,
        candidate_pricing_speedup=by_name["fleet_candidate_pricing"]["speedup"],
        replan_speedup=by_name["fleet_replan"]["speedup"],
        gate_nodes=by_name["fleet_candidate_pricing"]["n"],
        fleet_nodes=by_name["fleet_churn"]["n"],
        fleet_tenants=by_name["fleet_churn"]["n_tenants"],
        fleet_events_per_s=by_name["fleet_churn"]["events_per_s"],
        bit_identical=True,  # asserted above, run fails otherwise
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")


if __name__ == "__main__":
    for row in run(smoke=os.environ.get("SMOKE") == "1"):
        print(row["name"], f"{row['us_per_call']:.1f}us", row["derived"])
