"""Generate the EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from the
dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.report [--tag baseline]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)

BENCH_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "bench",
)


def load(tag: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is None or r.get("tag") == tag:
            recs.append(r)
    return recs


def load_bench() -> list[tuple[str, object]]:
    """Canonical perf records only: one ``BENCH_<name>.json`` per bench.

    The glob is deliberately anchored on the ``BENCH_`` prefix — the run
    harness used to also dump stray lowercase ``<name>.json`` twins, and a
    bare ``*.json`` glob would double-count any that linger in a working
    tree."""
    recs = []
    for path in sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json"))):
        name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        with open(path) as f:
            recs.append((name, json.load(f)))
    return recs


def bench_table() -> str:
    """§Perf-records table: the scalar headline fields of every canonical
    bench record (list records are summarized by row count)."""
    out = [
        "| bench | headline metrics |",
        "|---|---|",
    ]
    for name, rec in load_bench():
        if isinstance(rec, dict):
            scalars = [
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in rec.items()
                if isinstance(v, (int, float, bool)) and k != "smoke"
            ]
            headline = ", ".join(scalars[:6]) or f"{len(rec)} fields"
        else:
            headline = f"{len(rec)} rows"
        out.append(f"| {name} | {headline} |")
    return "\n".join(out)


def _fmt_bytes(b: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b / div:.1f}{unit}"
    return f"{b:.0f}B"


def roofline_table(recs: list[dict], mesh: str = "single_pod") -> str:
    rows = [r for r in recs if r.get("mesh_name") == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL/HLO flops | MFU@roofline |",
        "|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']*1e3:.1f} "
            f"| {rf['memory_s']*1e3:.1f} | {rf['collective_s']*1e3:.1f} "
            f"| {rf['dominant']} | {rf['useful_fraction']:.2f} "
            f"| {rf['mfu']:.3f} |"
        )
    return "\n".join(out)


def dryrun_table(recs: list[dict]) -> str:
    by_cell = defaultdict(dict)
    for r in recs:
        by_cell[(r["arch"], r["shape"])][r["mesh_name"]] = r
    out = [
        "| arch | shape | mesh | compile (s) | HLO flops/dev | HLO bytes/dev | "
        "collective bytes/dev | top collectives |",
        "|---|---|---|---:|---:|---:|---:|---|",
    ]
    for (arch, shape), meshes in sorted(by_cell.items()):
        for mesh_name, r in sorted(meshes.items()):
            coll = r["collectives"]["by_type"]
            top = ", ".join(
                f"{k}:{_fmt_bytes(v)}"
                for k, v in sorted(coll.items(), key=lambda kv: -kv[1])[:3]
            )
            out.append(
                f"| {arch} | {shape} | {mesh_name} | {r['compile_s']:.1f} "
                f"| {r['hlo']['flops_per_dev']:.2e} "
                f"| {_fmt_bytes(r['hlo']['bytes_per_dev'])} "
                f"| {_fmt_bytes(r['collectives']['total_bytes'])} | {top} |"
            )
    return "\n".join(out)


def perf_table(arch: str, shape: str, mesh: str = "single_pod") -> str:
    recs = [
        r for r in load(None)
        if r["arch"] == arch and r["shape"] == shape and r["mesh_name"] == mesh
    ]
    recs.sort(key=lambda r: (r["tag"] != "baseline", r["tag"]))
    out = [
        "| tag | compute (ms) | memory (ms) | collective (ms) | dominant | "
        "MODEL/HLO | step@roofline (ms) |",
        "|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in recs:
        rf = r["roofline"]
        out.append(
            f"| {r['tag']} | {rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} "
            f"| {rf['collective_s']*1e3:.1f} | {rf['dominant']} "
            f"| {rf['useful_fraction']:.2f} | {rf['step_time_s']*1e3:.1f} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument(
        "--section", default="all",
        choices=["all", "dryrun", "roofline", "perf", "bench"],
    )
    ap.add_argument("--perf-cells", default=(
        "granite-8b:train_4k,falcon-mamba-7b:train_4k,"
        "qwen3-moe-30b-a3b:train_4k"
    ))
    args = ap.parse_args()
    recs = load(args.tag)

    if args.section in ("all", "dryrun"):
        print("### Dry-run records (per-device SPMD program)\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        for mesh in ("single_pod", "multi_pod"):
            print(f"### Roofline — {mesh}\n")
            print(roofline_table(recs, mesh))
            print()
    if args.section in ("all", "perf"):
        for cell in args.perf_cells.split(","):
            arch, shape = cell.split(":")
            print(f"### Perf iterations — {arch} x {shape}\n")
            print(perf_table(arch, shape))
            print()
    if args.section in ("all", "bench"):
        print("### Benchmark perf records (experiments/bench)\n")
        print(bench_table())
        print()


if __name__ == "__main__":
    main()
