"""JAX planner backend speed: batched on-device chains vs NumPy pricing.

The acceptance bar of the JAX port (:mod:`repro.core.planeval_jax`) is raw
candidate-pricing throughput: the batched MCMC kernel — ``chains``
independent annealing chains carried through one jitted ``lax.scan`` —
must price candidate assignments at least **5x** faster than the NumPy
incremental path (:meth:`JobSetEvaluator.propose`, itself already the
fast path that beat the reference walk in ``bench_planner``).

* ``planner_jax_chains`` — chain-step throughput: ``chains x iters``
  candidate evaluations in one device dispatch vs the same number of
  sequential incremental proposals.  The jit compile is warmed on the
  exact shapes first; the measured dispatch is steady-state.  Asserts the
  >= 5x acceptance bar and records ``chains_per_s`` / both
  ``evals_per_s`` figures.
* ``planner_jax_pricing`` — batched demand pricing
  (:meth:`JaxPlanEvaluator.comm_times`): K padded demands in one
  ``segment_sum`` dispatch vs a loop of bit-exact ``comm_time`` calls
  (reported, not gated: on CPU the scatter is memory-bound and the win is
  modest — the chains are where the batching pays).

A perf record lands in ``experiments/bench/BENCH_planner_jax.json``.
Run directly, or as ``python benchmarks/bench_planner.py --backend=jax``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.bench_planner import _candidate_moves, _jobset
from repro.core.netsim import HardwareSpec, compute_time
from repro.core.planeval import JobSetEvaluator, plan_evaluator
from repro.core.planeval_jax import (
    JAX_EQUIV_RTOL,
    ChainKernel,
    draw_proposal_streams,
    jax_plan_evaluator,
    strategy_pool,
)
from repro.core.strategy_search import default_strategy
from repro.core.topology_finder import topology_finder
from repro.core.workloads import JobSet

DEGREE = 4
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_planner_jax.json")

# The tentpole acceptance bar: batched chains must price candidates at
# least this much faster than the NumPy incremental path.
MIN_CHAIN_SPEEDUP = 5.0


def _numpy_evals_per_s(js: JobSet, topo, hw: HardwareSpec,
                       n_moves: int) -> float:
    """Throughput of the NumPy incremental candidate pricer (the
    ``bench_planner`` fast path), warmed exactly like that bench."""
    init, moves = _candidate_moves(js, n_moves)
    cache: dict = {}
    jse = JobSetEvaluator(js, topo, hw, demand_cache=cache,
                          vector_cache_size=n_moves + len(js.tenants) + 1)
    jse.set_strategies(init)
    for label, cand in moves:
        jse.tenant_loads(label, cand)
    t0 = time.perf_counter()
    for label, cand in moves:
        jse.propose(label, cand)
    return n_moves / (time.perf_counter() - t0)


def _bench_chain_throughput(n: int, chains: int, iters: int,
                            pool_size: int, hw: HardwareSpec) -> dict:
    js = _jobset(n)
    init = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(init), hw.degree, pack="per_node")

    np_evals_per_s = _numpy_evals_per_s(js, topo, hw, n_moves=600)

    # Build the chain kernel exactly as jax_mcmc_search_jobset does.
    jse = JobSetEvaluator(js, topo, hw)
    tenants = js.tenants
    pools = [
        strategy_pool(t.spec, t.k, pool_size, seed=i, init=init[t.label])
        for i, t in enumerate(tenants)
    ]
    vecs = [
        [jse.tenant_loads_at(t.label, s, t.servers) for s in pools[i]]
        for i, t in enumerate(tenants)
    ]
    L = jse.ev.n_links
    V = np.zeros((len(tenants), pool_size, L))
    for i in range(len(tenants)):
        for s, v in enumerate(vecs[i]):
            V[i, s, : v.size] = v
    comps = np.array([
        compute_time(t.flops_per_iteration, t.k, hw) for t in tenants
    ])
    weights = np.array([t.weight for t in tenants])
    kernel = ChainKernel(V, jse.ev.caps, comps, weights)
    t_idx, s_idx, u = draw_proposal_streams(
        0, chains, iters, len(tenants), pool_size
    )
    temps = np.full(chains, 0.1)
    a0 = np.zeros(len(tenants), dtype=np.int64)

    # Warm the jit cache on the exact shapes, then time steady-state.
    kernel.run(a0, temps, t_idx, s_idx, u)
    t_best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        kernel.run(a0, temps, t_idx, s_idx, u)
        t_best = min(t_best, time.perf_counter() - t0)

    total_evals = chains * iters
    jax_evals_per_s = total_evals / t_best
    chains_per_s = chains / t_best
    speedup = jax_evals_per_s / np_evals_per_s
    assert speedup >= MIN_CHAIN_SPEEDUP, (
        f"jax chains priced {speedup:.1f}x the NumPy path, "
        f"need >= {MIN_CHAIN_SPEEDUP}x"
    )
    return dict(
        name=f"planner_jax_chains_n{n}",
        us_per_call=t_best * 1e6,
        derived=(
            f"speedup={speedup:.1f}x;"
            f"jax_evals_per_s={jax_evals_per_s:.0f};"
            f"numpy_evals_per_s={np_evals_per_s:.0f};"
            f"chains_per_s={chains_per_s:.0f}"
        ),
        speedup=speedup,
        jax_evals_per_s=jax_evals_per_s,
        numpy_evals_per_s=np_evals_per_s,
        chains_per_s=chains_per_s,
        chains=chains,
        iters=iters,
    )


def _bench_batched_pricing(n: int, batch: int, hw: HardwareSpec) -> dict:
    js = _jobset(n)
    init = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(init), hw.degree, pack="per_node")
    demands = []
    for i, t in enumerate(js.tenants):
        for s in strategy_pool(t.spec, t.k, batch // len(js.tenants) + 1,
                               seed=50 + i):
            demands.append(js.union_for({**init, t.label: s}))
    demands = demands[:batch]

    ev = plan_evaluator(topo, hw)
    jev = jax_plan_evaluator(topo, hw)
    jev.comm_times(demands)  # warm: compiles scatter + jit at these shapes

    t0 = time.perf_counter()
    ref = np.array([ev.comm_time(d) for d in demands])
    t_np = time.perf_counter() - t0
    t_jax = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out = jev.comm_times(demands)
        t_jax = min(t_jax, time.perf_counter() - t0)
    max_rel = float(np.max(np.abs(out - ref) / np.maximum(np.abs(ref),
                                                          1e-30)))
    assert max_rel <= JAX_EQUIV_RTOL, f"jax pricing drifted: {max_rel}"
    return dict(
        name=f"planner_jax_pricing_n{n}",
        us_per_call=t_jax / batch * 1e6,
        derived=(
            f"speedup={t_np / t_jax:.1f}x;"
            f"jax_evals_per_s={batch / t_jax:.0f};"
            f"numpy_evals_per_s={batch / t_np:.0f};"
            f"max_rel_err={max_rel:.1e}"
        ),
        speedup=t_np / t_jax,
        jax_evals_per_s=batch / t_jax,
        numpy_evals_per_s=batch / t_np,
        max_rel_err=max_rel,
    )


def run(smoke: bool = False) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    if smoke:
        n, chains, iters, pool, batch = 12, 8, 200, 16, 48
    else:
        n, chains, iters, pool, batch = 24, 32, 400, 32, 128
    rows = [
        _bench_chain_throughput(n, chains, iters, pool, hw),
        _bench_batched_pricing(n, batch, hw),
    ]
    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_planner_jax.json: the acceptance numbers CI tracks."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    by_name = {r["name"].rsplit("_n", 1)[0]: r for r in rows}
    chains_row = by_name["planner_jax_chains"]
    pricing_row = by_name["planner_jax_pricing"]
    record = dict(
        bench="planner_jax",
        smoke=smoke,
        chain_speedup=chains_row["speedup"],
        chains_per_s=chains_row["chains_per_s"],
        jax_evals_per_s=chains_row["jax_evals_per_s"],
        numpy_evals_per_s=chains_row["numpy_evals_per_s"],
        pricing_speedup=pricing_row["speedup"],
        pricing_max_rel_err=pricing_row["max_rel_err"],
        meets_bar=bool(chains_row["speedup"] >= MIN_CHAIN_SPEEDUP),
        wall_us=sum(r["us_per_call"] for r in rows),
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    for row in run(smoke=True):
        print(row["name"], row["derived"])
