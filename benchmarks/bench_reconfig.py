"""Fig. 17 — impact of OCS reconfiguration latency.

Time-stepped simulation: OCS-reconfig rebuilds the topology from unsatisfied
demand every 50 ms window (Algorithm 5), pausing traffic for the reconfig
latency; remaining demand drains at fluid rates on the current topology.
Compared against TopoOpt's one-shot (latency-free) topology, with and
without host-based forwarding.
"""

from __future__ import annotations

import time

import networkx as nx
import numpy as np

from repro.core.netsim import HardwareSpec, compute_time, iteration_time, topoopt_comm_time
from repro.core.ocs_reconfig import RECONFIG_WINDOW, ocs_topology
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, DLRM, job_demand

N = 128
DEGREE = 8


def _demand_matrix(dem) -> np.ndarray:
    m = dem.mp.copy()
    for group in dem.allreduce:
        k = len(group.members)
        per_link = 2.0 * (k - 1) / k * group.nbytes / max(1, k)
        for idx in range(k):
            a, b = group.members[idx], group.members[(idx + 1) % k]
            m[a, b] += per_link * k
    return m


def _drain_time(job, dem, hw, reconfig_latency: float, forwarding: bool) -> float:
    """Simulate draining one iteration's demand with periodic reconfigs.

    The demand-estimation window shrinks with the reconfiguration latency
    (fast switches reconfigure per-transfer; slow ones amortize over the
    paper's 50 ms window)."""
    remaining = _demand_matrix(dem)
    window = min(RECONFIG_WINDOW, max(1e-3, 50.0 * reconfig_latency))
    t = 0.0
    for _ in range(500):  # safety bound
        if remaining.sum() <= 1e-3:
            break
        g = ocs_topology(N, remaining, DEGREE)
        t += reconfig_latency
        # fluid drain on current circuits for one window
        caps = {}
        for a, b in g.edges():
            caps[(a, b)] = caps.get((a, b), 0.0) + hw.link_bandwidth
        if forwarding:
            simple = nx.DiGraph(g)
        budget = window
        drained = np.zeros_like(remaining)
        for (a, b), cap in caps.items():
            move = min(remaining[a, b], cap * budget)
            drained[a, b] += move
        if forwarding:
            # forwarded traffic: anything with no direct link crawls over
            # shortest path at 1/hops efficiency of a single link.
            srcs, dsts = np.nonzero(remaining - drained > 1e-6)
            spare = {k: max(0.0, caps[k] * budget - drained[k]) for k in caps}
            for a, b in zip(srcs.tolist(), dsts.tolist()):
                if (a, b) in caps:
                    continue
                try:
                    path = nx.shortest_path(simple, a, b)
                except (nx.NetworkXNoPath, nx.NodeNotFound):
                    continue
                links = list(zip(path[:-1], path[1:]))
                room = min(spare.get(l, 0.0) for l in links)
                move = min(remaining[a, b], room)
                if move > 0:
                    drained[a, b] += move
                    for l in links:
                        spare[l] -= move
        remaining = np.maximum(remaining - drained, 0.0)
        t += budget
    return t


def run(latencies=(1e-6, 1e-4, 1e-2), models=("dlrm", "bert")) -> list[dict]:
    from repro.core.workloads import PAPER_JOBS

    hw = HardwareSpec(link_bandwidth=100e9 / 8, degree=DEGREE)
    rows = []
    for name in models:
        job = PAPER_JOBS[name]
        hosts = range(0, N, 2) if job.n_tables else None
        dem = job_demand(job, N, table_hosts=hosts)
        comp = compute_time(job.flops_per_sample * job.batch_per_gpu * N, N, hw)
        topo = topology_finder(dem, DEGREE)
        t_static = iteration_time(
            topoopt_comm_time(topo, dem, hw)["comm_time"], comp
        )
        for lat in latencies:
            t0 = time.perf_counter()
            t_fw = iteration_time(_drain_time(job, dem, hw, lat, True), comp)
            t_nofw = iteration_time(_drain_time(job, dem, hw, lat, False), comp)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(
                dict(
                    name=f"reconfig_{name}_lat{lat:g}",
                    us_per_call=us,
                    derived=(
                        f"ocs_fw/topo={t_fw / t_static:.2f};"
                        f"ocs_nofw/topo={t_nofw / t_static:.2f}"
                    ),
                    topoopt_s=t_static,
                    ocs_fw_s=t_fw,
                    ocs_nofw_s=t_nofw,
                )
            )
    return rows
