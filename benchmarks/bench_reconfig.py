"""Fig. 17 — impact of OCS reconfiguration latency.

Time-stepped simulation: OCS-reconfig rebuilds the topology from unsatisfied
demand every 50 ms window (Algorithm 5), pausing traffic for the reconfig
latency; remaining demand drains at fluid rates on the current topology.
Compared against TopoOpt's one-shot (latency-free) topology, with and
without host-based forwarding.

The drain loop is :meth:`repro.core.simengine.SimEngine.reconfig_drain`
(vectorized circuit drain + per-window BFS cache for forwarded traffic);
``_drain_time`` remains as a thin shim over it.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.simengine import (
    HardwareSpec,
    SimEngine,
    compute_time,
    iteration_time,
    topoopt_comm_time,
)
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, DLRM, job_demand

N = 128
DEGREE = 8


def _demand_matrix(dem) -> np.ndarray:
    m = dem.mp.copy()
    for group in dem.allreduce:
        k = len(group.members)
        per_link = 2.0 * (k - 1) / k * group.nbytes / max(1, k)
        for idx in range(k):
            a, b = group.members[idx], group.members[(idx + 1) % k]
            m[a, b] += per_link * k
    return m


def _drain_time(job, dem, hw, reconfig_latency: float, forwarding: bool) -> float:
    """Deprecated shim over :meth:`SimEngine.reconfig_drain`."""
    return SimEngine(hw).reconfig_drain(
        _demand_matrix(dem), N, DEGREE, reconfig_latency, forwarding
    )


def run(latencies=(1e-6, 1e-4, 1e-2), models=("dlrm", "bert")) -> list[dict]:
    from repro.core.workloads import PAPER_JOBS

    hw = HardwareSpec(link_bandwidth=100e9 / 8, degree=DEGREE)
    engine = SimEngine(hw)
    rows = []
    for name in models:
        job = PAPER_JOBS[name]
        hosts = range(0, N, 2) if job.n_tables else None
        dem = job_demand(job, N, table_hosts=hosts)
        remaining = _demand_matrix(dem)
        comp = compute_time(job.flops_per_sample * job.batch_per_gpu * N, N, hw)
        topo = topology_finder(dem, DEGREE)
        t_static = iteration_time(
            topoopt_comm_time(topo, dem, hw)["comm_time"], comp
        )
        for lat in latencies:
            t0 = time.perf_counter()
            t_fw = iteration_time(
                engine.reconfig_drain(remaining, N, DEGREE, lat, True), comp
            )
            t_nofw = iteration_time(
                engine.reconfig_drain(remaining, N, DEGREE, lat, False), comp
            )
            us = (time.perf_counter() - t0) * 1e6
            rows.append(
                dict(
                    name=f"reconfig_{name}_lat{lat:g}",
                    us_per_call=us,
                    derived=(
                        f"ocs_fw/topo={t_fw / t_static:.2f};"
                        f"ocs_nofw/topo={t_nofw / t_static:.2f}"
                    ),
                    topoopt_s=t_static,
                    ocs_fw_s=t_fw,
                    ocs_nofw_s=t_nofw,
                )
            )
    return rows
