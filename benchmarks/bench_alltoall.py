"""Fig. 12 + 13 — impact of all-to-all traffic (DLRM with 128 tables on 128
servers) as batch size grows; bandwidth tax per (batch, degree)."""

from __future__ import annotations

import time

from repro.core.netsim import HardwareSpec, compute_time
from repro.core.simengine import (
    fat_tree_comm_time,
    ideal_switch_comm_time,
    iteration_time,
    topoopt_comm_time,
)
from repro.core.topology_finder import topology_finder
from repro.core.workloads import DLRM_A2A, job_demand

N = 128
BATCHES = (32, 64, 128, 512, 2048)


def run(batches=BATCHES, degrees=(4, 8)) -> list[dict]:
    rows = []
    for d in degrees:
        hw = HardwareSpec(link_bandwidth=100e9 / 8, degree=d)
        for bs in batches:
            job = DLRM_A2A.with_batch(bs)
            # worst case: one big table per server.
            dem = job_demand(job, N, table_hosts=range(N))
            t0 = time.perf_counter()
            topo = topology_finder(dem, d)
            res = topoopt_comm_time(topo, dem, hw)
            us = (time.perf_counter() - t0) * 1e6
            comp = compute_time(job.flops_per_sample * bs * N, N, hw)
            t_topo = iteration_time(res["comm_time"], comp)
            t_ideal = iteration_time(ideal_switch_comm_time(dem, hw), comp)
            t_ft = iteration_time(fat_tree_comm_time(dem, hw, 0.35), comp)
            a2a_ratio = dem.sum_mp / max(dem.sum_allreduce, 1e-9)
            # Paper's §5.4 tax is over the whole job (AllReduce rides direct
            # rings at tax 1; only forwarded MP pays the multi-hop tax).
            mp_tax = res["bandwidth_tax"]
            tax = (dem.sum_allreduce + mp_tax * dem.sum_mp) / (
                dem.sum_allreduce + dem.sum_mp
            )
            rows.append(
                dict(
                    name=f"alltoall_d{d}_bs{bs}",
                    us_per_call=us,
                    derived=(
                        f"tax={tax:.2f};"
                        f"a2a/ar={a2a_ratio:.2f};ft/topo={t_ft / t_topo:.2f}"
                    ),
                    bandwidth_tax=tax,
                    mp_only_tax=mp_tax,
                    topoopt_s=t_topo,
                    ideal_s=t_ideal,
                    fat_tree_s=t_ft,
                )
            )
    return rows
