"""Multi-tenant shared fabric: JobSet co-optimization under churn.

The §6 deployment story is a fleet of concurrent jobs contending for one
direct-connect fabric.  This benchmark drives
:func:`repro.core.online.run_online_jobset` over a mixed
DLRM + DP-transformer + MoE churn trace (a job arriving mid-run, a tenant
departing, fibers dying) and compares:

* **static** — one shared plan computed offline
  (:func:`~repro.core.alternating.co_optimize_jobset`), never touched; the
  arriving MoE job rides the connectivity ring, failures get route repair.
* **reactive** — replan the union demand on every arrival / departure /
  failure, warm-started, with churn-proportional pauses
  (``fiber_move_latency`` x edges moved) and the adaptive benefit-vs-cost
  gate.

A second experiment pins the fairness story: the same *contending* jobset
(an un-replanned MoE arrival riding the shared fabric plus a
failure-induced reroute, so tenants genuinely share links) run with unit
weights vs ``weight=2`` on the DLRM tenant — weighted max-min must speed
the weighted job up, never slow it down.

``derived`` reports the static/reactive makespan ratio (> 1 means reactive
shared-fabric re-optimization won despite paying for every moved fiber)
and the weighted-fairness speedup.  A perf record lands in
``experiments/bench/BENCH_multitenant.json``.
"""

from __future__ import annotations

import json
import os
import time

from repro.core.alternating import co_optimize_jobset
from repro.core.costmodel import OCS_FIBER_MOVE_S, fiber_move_cost
from repro.core.netsim import HardwareSpec
from repro.core.online import ReoptPolicy, TraceEvent, run_online_jobset
from repro.core.workloads import BERT, DLRM, MOE_16E, JobSet, TenantJob

DEGREE = 4
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_multitenant.json")


def _jobset(n: int, dlrm_weight: float = 1.0) -> JobSet:
    third = n // 3
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, third)),
                  weight=dlrm_weight, name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(third, 2 * third)),
                  name="bert"),
    ])


def _churn_trace(n: int, moe_k: int) -> tuple[TraceEvent, ...]:
    return (
        TraceEvent(iteration=1, kind="arrive", job=MOE_16E, k=moe_k,
                   name="moe"),
        TraceEvent(iteration=2, kind="fail", link=(0, 3)),
        TraceEvent(iteration=3, kind="depart", name="bert"),
        TraceEvent(iteration=4, kind="fail", link=(1, n // 3), frac=0.5),
    )


def run(smoke: bool = False) -> list[dict]:
    n = 12 if smoke else 18
    n_iters = 4 if smoke else 8
    rounds, iters = (1, 15) if smoke else (2, 60)
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    rows: list[dict] = []

    # -- churn: static shared plan vs reactive union re-optimization --------
    jobset = _jobset(n)
    plan = co_optimize_jobset(jobset, hw, rounds=rounds, mcmc_iters=iters,
                              seed=1)
    trace = _churn_trace(n, moe_k=max(2, n - 2 * (n // 3)))
    t0 = time.perf_counter()
    static = run_online_jobset(
        jobset, hw, policy=ReoptPolicy.never(), trace=trace,
        n_iters=n_iters, seed=0, plan=plan)
    reactive = run_online_jobset(
        jobset, hw,
        policy=ReoptPolicy.reactive(
            fiber_move_latency=OCS_FIBER_MOVE_S, adaptive=True),
        trace=trace, n_iters=n_iters, seed=0, plan=plan)
    us = (time.perf_counter() - t0) * 1e6
    ratio = static.total_time / reactive.total_time
    rows.append(dict(
        name="multitenant_churn",
        us_per_call=us,
        derived=(
            f"static/reactive={ratio:.2f};replans={reactive.n_replans};"
            f"edges_moved={reactive.edges_moved}"
        ),
        static_s=static.total_time,
        reactive_s=reactive.total_time,
        reactive_replans=reactive.n_replans,
        edges_moved=reactive.edges_moved,
        churn_usd=fiber_move_cost(reactive.edges_moved),
        n_failures=reactive.n_failures,
        job_times_static=static.job_times,
        job_times_reactive=reactive.job_times,
        iter_times_static=static.iter_times,
        iter_times_reactive=reactive.iter_times,
    ))

    # -- fairness: unit weights vs weight=2 on the DLRM tenant --------------
    # Contention is what makes weights matter: a static (never-replan)
    # operator admits the MoE job onto the incumbent fabric (its traffic
    # rides shared reroute paths) and loses a DLRM fiber (reroutes cross
    # other tenants' links).
    contention = (
        TraceEvent(iteration=0, kind="arrive", job=MOE_16E,
                   k=max(2, n - 2 * (n // 3)), name="moe"),
        TraceEvent(iteration=1, kind="fail", link=(0, 2)),
        TraceEvent(iteration=1, kind="fail", link=(1, 3)),
    )
    t0 = time.perf_counter()
    flat_plan = co_optimize_jobset(_jobset(n), hw, rounds=rounds,
                                   mcmc_iters=iters, seed=1)
    unweighted = run_online_jobset(
        _jobset(n), hw, policy=ReoptPolicy.never(), trace=contention,
        n_iters=max(2, n_iters // 2), seed=0, plan=flat_plan)
    weighted = run_online_jobset(
        _jobset(n, dlrm_weight=2.0), hw, policy=ReoptPolicy.never(),
        trace=contention, n_iters=max(2, n_iters // 2), seed=0,
        plan=flat_plan)
    us = (time.perf_counter() - t0) * 1e6
    speedup = (
        unweighted.job_times["dlrm"] / max(weighted.job_times["dlrm"], 1e-12)
    )
    rows.append(dict(
        name="multitenant_weighted",
        us_per_call=us,
        derived=f"dlrm_unweighted/weighted={speedup:.3f}",
        dlrm_unweighted_s=unweighted.job_times["dlrm"],
        dlrm_weighted_s=weighted.job_times["dlrm"],
        job_times_unweighted=unweighted.job_times,
        job_times_weighted=weighted.job_times,
    ))

    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_multitenant.json: the headline numbers CI tracks over time."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    churn = rows[0]
    weighted = rows[1]
    record = dict(
        bench="multitenant",
        smoke=smoke,
        static_over_reactive=churn["static_s"] / churn["reactive_s"],
        reactive_replans=churn["reactive_replans"],
        edges_moved=churn["edges_moved"],
        churn_usd=churn["churn_usd"],
        dlrm_weighted_speedup=(
            weighted["dlrm_unweighted_s"]
            / max(weighted["dlrm_weighted_s"], 1e-12)
        ),
        wall_us=churn["us_per_call"] + weighted["us_per_call"],
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    for row in run(smoke=True):
        print(row["name"], row["derived"])
