"""Fig. 10 — interconnect cost vs cluster size."""

from __future__ import annotations

import time

from repro.core.costmodel import ClusterSpec, cost_report


def run() -> list[dict]:
    rows = []
    for n in (128, 256, 432, 1024, 4394):
        spec = ClusterSpec(n_servers=n, degree=4, link_gbps=100)
        t0 = time.perf_counter()
        rep = cost_report(spec)
        us = (time.perf_counter() - t0) * 1e6
        ratio = rep["ideal_switch"] / rep["topoopt_patch"]
        ocs_ratio = rep["topoopt_ocs"] / rep["topoopt_patch"]
        rows.append(
            dict(
                name=f"cost_n{n}",
                us_per_call=us,
                derived=f"ideal/topoopt={ratio:.2f};ocs/patch={ocs_ratio:.2f}",
                **{k: round(v) for k, v in rep.items()},
            )
        )
    return rows
