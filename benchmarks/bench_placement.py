"""Placement co-search + churn-priced migration on a fragmented fabric.

The ROADMAP's placement open items: `JobSetController.admit` used to place
greedily (:func:`~repro.core.online.place_arrival`) and *then* replan, and
tenants were pinned to their placement forever.  This benchmark drives the
placement-as-a-co-optimization-axis pipeline over a deliberately
*fragmented* cluster — DLRM and BERT interleaved across the fabric so the
free pool is scattered, several free-pool fiber pairs dead so some
placements cannot build cheap rings — and an arrival + departure churn
trace (an MoE job arriving onto the damaged pool, BERT departing and
freeing a healthy block).  Four operators run the same trace from the same
offline plan:

* **greedy** — greedy-then-replan admission (``candidates=1``), tenants
  pinned (``max_migrations=0``): the PR-3 behaviour.
* **rebal** — greedy admission + post-departure rebalancing
  (``max_migrations=2``): migrations priced by
  :func:`~repro.core.costmodel.migration_cost` (checkpoint-restore +
  churn-priced fiber moves) and adopted only when the probed win amortized
  over ``payback_horizon`` iterations clears the price — the DLRM tenant's
  ~33 s embedding-table checkpoint keeps it pinned, the MoE tenant's ~0.4 s
  state moves.
* **cosearch** — co-searched admission (``candidates=4``): every
  :func:`~repro.core.online.place_candidates` variant carried through the
  full alternating loop, best plan (placement included) adopted.
* **co+rebal** — both: the headline operator.

``derived`` reports greedy/co+rebal on total makespan and on the mean
per-tenant time; the bench *asserts* the headline strictly beats greedy on
both, and that the ``candidates=1, max_migrations=0`` policy reproduces the
plain reactive run bit-identically (the golden equivalence the tests pin).
A perf record lands in ``experiments/bench/BENCH_placement.json``.
"""

from __future__ import annotations

import json
import os
import time

from repro.core.alternating import co_optimize_jobset
from repro.core.costmodel import OCS_FIBER_MOVE_S
from repro.core.netsim import HardwareSpec
from repro.core.online import ReoptPolicy, TraceEvent, run_online_jobset
from repro.core.workloads import BERT, DLRM, MOE_16E, JobSet, TenantJob

DEGREE = 3
PAYBACK = 200.0  # iterations a migration is amortized over
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_placement.json")


def _fragmented_jobset(n: int) -> JobSet:
    """DLRM and BERT interleaved at stride 3: the free pool is scattered."""
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, n, 3)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(1, n, 3)), name="bert"),
    ])


def _trace(dead: tuple[tuple[int, int], ...], k: int) -> tuple[TraceEvent, ...]:
    return tuple(
        TraceEvent(iteration=0, kind="fail", link=p) for p in dead
    ) + (
        TraceEvent(iteration=1, kind="arrive", job=MOE_16E, k=k, name="moe"),
        TraceEvent(iteration=3, kind="depart", name="bert"),
    )


def _mean_job_time(result) -> float:
    return sum(result.job_times.values()) / max(len(result.job_times), 1)


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        n, k, n_iters = 12, 3, 4
        dead = ((2, 5), (5, 8), (2, 8))
    else:
        n, k, n_iters = 18, 4, 8
        dead = ((2, 5), (5, 8), (8, 11), (2, 8), (5, 11))
    rounds, iters = (1, 20) if smoke else (2, 40)
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    jobset = _fragmented_jobset(n)
    plan = co_optimize_jobset(jobset, hw, rounds=max(rounds, 2),
                              mcmc_iters=iters, seed=1)
    trace = _trace(dead, k)
    churn = dict(fiber_move_latency=OCS_FIBER_MOVE_S)
    migration = dict(max_migrations=2, payback_horizon=PAYBACK,
                     migration_restart=1e-3)
    arms = {
        "greedy": ReoptPolicy.reactive(**churn),
        "rebal": ReoptPolicy.reactive(**churn, **migration),
        "cosearch": ReoptPolicy.reactive(candidates=4, **churn),
        "co_rebal": ReoptPolicy.reactive(candidates=4, **churn, **migration),
    }

    rows: list[dict] = []
    results = {}
    t0 = time.perf_counter()
    for name, policy in arms.items():
        results[name] = run_online_jobset(
            jobset, hw, policy=policy, trace=trace, n_iters=n_iters,
            seed=0, plan=plan)
    us = (time.perf_counter() - t0) * 1e6

    greedy, headline = results["greedy"], results["co_rebal"]
    total_ratio = greedy.total_time / headline.total_time
    mean_ratio = _mean_job_time(greedy) / _mean_job_time(headline)
    # The acceptance bar: co-searched admission + rebalancing must strictly
    # beat greedy-then-replan on this fragmented trace.
    assert headline.total_time < greedy.total_time, (
        f"co+rebal {headline.total_time} !< greedy {greedy.total_time}")
    assert _mean_job_time(headline) < _mean_job_time(greedy), (
        f"co+rebal mean {_mean_job_time(headline)} !< "
        f"greedy mean {_mean_job_time(greedy)}")

    # Golden equivalence: candidates=1 / max_migrations=0 explicitly spelled
    # out must reproduce the plain reactive (greedy) run bit for bit.
    explicit = run_online_jobset(
        jobset, hw,
        policy=ReoptPolicy.reactive(candidates=1, max_migrations=0, **churn),
        trace=trace, n_iters=n_iters, seed=0, plan=plan)
    assert explicit.total_time == greedy.total_time
    assert explicit.iter_times == greedy.iter_times
    assert explicit.job_times == greedy.job_times

    rows.append(dict(
        name="placement_cosearch",
        us_per_call=us,
        derived=(
            f"greedy/co_rebal total={total_ratio:.2f} "
            f"mean={mean_ratio:.2f};migrations={headline.n_migrations}"
        ),
        **{f"{name}_total_s": r.total_time for name, r in results.items()},
        **{f"{name}_mean_s": _mean_job_time(r) for name, r in results.items()},
        migrations=[
            dict(tenant=m.tenant, src=list(m.src), dst=list(m.dst),
                 adopted=m.adopted, cost_s=m.cost,
                 est_before=m.est_before, est_after=m.est_after)
            for m in headline.migrations
        ],
        n_migrations=headline.n_migrations,
        replans={name: r.n_replans for name, r in results.items()},
        edges_moved={name: r.edges_moved for name, r in results.items()},
        job_times={name: r.job_times for name, r in results.items()},
    ))

    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_placement.json: the headline numbers CI tracks over time."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    row = rows[0]
    record = dict(
        bench="placement",
        smoke=smoke,
        greedy_over_co_rebal_total=(
            row["greedy_total_s"] / row["co_rebal_total_s"]),
        greedy_over_co_rebal_mean=(
            row["greedy_mean_s"] / row["co_rebal_mean_s"]),
        n_migrations=row["n_migrations"],
        migrations=row["migrations"],
        wall_us=row["us_per_call"],
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r["name"], r["derived"])
