"""Fused admission co-search: candidate x ladder grid vs sequential rounds.

The PR-10 tentpole fuses the whole admission co-search — ``k`` placement
candidates x a parallel-tempering temperature ladder x ``chains`` — into
one jitted grid dispatch per alternating round
(:func:`repro.core.alternating.co_optimize_jobset` with ``temperatures``),
with the winning assignment indices staying on-device between rounds.
The acceptance bar is end-to-end: the fused path must finish the same
admission decision at least **3x** faster than the PR-6 sequential
per-candidate loop (``backend="jax"``, ``temperatures=None``) at equal or
better plan quality on the same fixed seed.

* ``admission_jax_fused`` — wall-clock of one warm admission co-search,
  sequential vs fused, best-of-N after a jit-warming run of each path.
  Asserts the >= 3x bar, that the fused plan's weighted iteration time is
  never worse than the sequential baseline's, and that the adopted winner
  re-prices **bit-exactly** on the NumPy evaluator
  (:func:`repro.core.strategy_search.evaluate_jobset`) — the fused loop's
  device energies are advisory; the committed number is always NumPy's.

A perf record lands in ``experiments/bench/BENCH_admission_jax.json``.
"""

from __future__ import annotations

import json
import os
import time

from repro.core.alternating import co_optimize_jobset
from repro.core.netsim import HardwareSpec
from repro.core.planeval_jax import DEFAULT_TEMPER_LADDER
from repro.core.strategy_search import evaluate_jobset
from repro.core.workloads import BERT, DLRM, JobSet, TenantJob

DEGREE = 4
PERF_RECORD = os.path.join("experiments", "bench", "BENCH_admission_jax.json")

# The tentpole acceptance bar: the fused candidate x ladder grid must beat
# the sequential per-candidate loop end-to-end by at least this factor.
MIN_ADMISSION_SPEEDUP = 3.0


def _candidates(n: int, k: int) -> tuple[JobSet, list[JobSet]]:
    """An admission scenario: two tenants under ``k`` shifted placements.

    Mirrors :func:`repro.core.online.place_candidates` admission variants —
    the same tenants, rotated around the ring so each candidate stresses a
    different region of the shared fabric."""

    def _at(off: int) -> JobSet:
        return JobSet(n=n, tenants=[
            TenantJob(spec=DLRM, weight=2.0, name="dlrm",
                      servers=tuple((s + off) % n for s in range(0, 6))),
            TenantJob(spec=BERT, weight=1.0, name="bert",
                      servers=tuple((s + off) % n for s in range(6, 12))),
        ])

    return _at(0), [_at(off) for off in range(k)]


def _bench_admission(n: int, k: int, chains: int, rounds: int,
                     iters: int, repeats: int, hw: HardwareSpec) -> dict:
    base, cands = _candidates(n, k)
    ladder = DEFAULT_TEMPER_LADDER
    kw = dict(rounds=rounds, mcmc_iters=iters, seed=3,
              placement_candidates=cands, backend="jax", chains=chains)

    def _seq():
        return co_optimize_jobset(base, hw, **kw)

    def _fused():
        return co_optimize_jobset(base, hw, temperatures=ladder, **kw)

    # Warm each path's jit cache (the fused grid program and the flat
    # per-candidate kernel compile at different shapes), then time
    # steady-state admissions — what the online controller actually pays
    # on every arrival after the first.
    plan_seq, plan_fused = _seq(), _fused()
    t_seq = t_fused = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        _seq()
        t_seq = min(t_seq, time.perf_counter() - t0)
        t0 = time.perf_counter()
        _fused()
        t_fused = min(t_fused, time.perf_counter() - t0)

    speedup = t_seq / t_fused
    assert speedup >= MIN_ADMISSION_SPEEDUP, (
        f"fused admission ran {speedup:.2f}x the sequential path, "
        f"need >= {MIN_ADMISSION_SPEEDUP}x"
    )
    # Equal-or-better quality on the same fixed seed: the ladder explores
    # strictly more of the move space than the single-temperature chains.
    assert plan_fused.iter_time <= plan_seq.iter_time * (1 + 1e-9), (
        f"fused plan regressed quality: {plan_fused.iter_time} vs "
        f"sequential {plan_seq.iter_time}"
    )
    # The adopted winner must re-price bit-exactly on the NumPy path —
    # the committed iter_time is never a device-side float.
    repriced, _, _ = evaluate_jobset(
        plan_fused.strategies, plan_fused.jobset, plan_fused.topology, hw
    )
    assert repriced == plan_fused.iter_time, (
        f"fused plan not NumPy-exact: {repriced} != {plan_fused.iter_time}"
    )
    return dict(
        name=f"admission_jax_fused_n{n}",
        us_per_call=t_fused * 1e6,
        derived=(
            f"speedup={speedup:.1f}x;"
            f"fused_s={t_fused:.3f};seq_s={t_seq:.3f};"
            f"fused_iter_time={plan_fused.iter_time:.6g};"
            f"seq_iter_time={plan_seq.iter_time:.6g};"
            f"candidates={k};ladder={len(ladder)};chains={chains}"
        ),
        speedup=speedup,
        fused_s=t_fused,
        seq_s=t_seq,
        fused_iter_time=plan_fused.iter_time,
        seq_iter_time=plan_seq.iter_time,
        candidates=k,
        ladder=len(ladder),
        chains=chains,
        rounds=rounds,
        mcmc_iters=iters,
    )


def run(smoke: bool = False) -> list[dict]:
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    if smoke:
        n, k, chains, rounds, iters, repeats = 16, 4, 4, 2, 40, 1
    else:
        n, k, chains, rounds, iters, repeats = 16, 4, 4, 2, 120, 2
    rows = [_bench_admission(n, k, chains, rounds, iters, repeats, hw)]
    _write_perf_record(rows, smoke=smoke)
    return rows


def _write_perf_record(rows: list[dict], smoke: bool) -> None:
    """BENCH_admission_jax.json: the acceptance numbers CI tracks."""
    os.makedirs(os.path.dirname(PERF_RECORD), exist_ok=True)
    row = rows[0]
    record = dict(
        bench="admission_jax",
        smoke=smoke,
        admission_speedup=row["speedup"],
        fused_s=row["fused_s"],
        seq_s=row["seq_s"],
        fused_iter_time=row["fused_iter_time"],
        seq_iter_time=row["seq_iter_time"],
        candidates=row["candidates"],
        ladder=row["ladder"],
        chains=row["chains"],
        meets_bar=bool(row["speedup"] >= MIN_ADMISSION_SPEEDUP),
        wall_us=sum(r["us_per_call"] for r in rows),
    )
    with open(PERF_RECORD, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r["name"], r["derived"])
