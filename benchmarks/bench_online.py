"""Online re-optimization: static plan vs reactive replanning.

Runs :func:`repro.core.online.run_online` on failure and load-shift
(churn) traces and compares three operators over the same disruptions:

* **static** — ``ReoptPolicy.never()``: the offline plan runs unmodified;
  failures get route repair over the survivors, nothing else.
* **reactive** — replan on every failure / load shift (warm-started
  alternating optimization, dead pairs forbidden, OCS-style pause charged).
* **degradation** — replan only when a periodic probe sees the estimated
  iteration time exceed 1.3x the adoption-time baseline.

``derived`` reports total-makespan ratios (static/reactive > 1 means
reactive replanning won despite paying the replan pauses).
"""

from __future__ import annotations

import time

from repro.core.alternating import alternating_optimize
from repro.core.netsim import HardwareSpec
from repro.core.online import ReoptPolicy, TraceEvent, run_online
from repro.core.workloads import DLRM, VGG16

N = 16
DEGREE = 4
N_ITERS = 8


# Fiber pairs die under a running job; one failure lands mid-iteration so
# the engine swaps the fabric under live flows.
FAILURES = (
    TraceEvent(iteration=1, kind="fail", link=(0, 1)),
    TraceEvent(iteration=2, kind="fail", link=(3, 7), frac=0.4),
    TraceEvent(iteration=4, kind="fail", link=(2, 6)),
)

# Load shift: the cluster's resident workload changes from a pure-DP CNN to
# DLRM at iteration 2 (then a fiber dies).  A static operator keeps the old
# DP strategy, which replicates the embedding tables and AllReduces them
# every iteration (the paper's Fig. 1a pathology); reactive replanning
# re-runs the strategy search and moves the tables to hybrid placement.
CHURN = (
    TraceEvent(iteration=2, kind="load", job=DLRM),
    TraceEvent(iteration=4, kind="fail", link=(1, 5)),
)


def run() -> list[dict]:
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=DEGREE)
    policies = {
        "static": ReoptPolicy.never(),
        "reactive": ReoptPolicy.reactive(),
        "degradation": ReoptPolicy.degradation(
            threshold=1.3, check_interval=0.05
        ),
    }
    rows = []
    cases = [
        (DLRM, "failures", FAILURES),
        (VGG16, "failures", FAILURES),
        (VGG16, "churn", CHURN),
    ]
    plans = {
        job.name: alternating_optimize(job, N, hw, rounds=3, mcmc_iters=80,
                                       seed=1)
        for job in (DLRM, VGG16)
    }
    for job, trace_name, trace in cases:
        plan = plans[job.name]
        results = {}
        for pol_name, pol in policies.items():
            t0 = time.perf_counter()
            results[pol_name] = (
                run_online(job, N, hw, policy=pol, trace=trace,
                           n_iters=N_ITERS, seed=0, plan=plan),
                (time.perf_counter() - t0) * 1e6,
            )
        static, us = results["static"]
        reactive, _ = results["reactive"]
        degr, _ = results["degradation"]
        rows.append(dict(
            name=f"online_{job.name}_{trace_name}",
            us_per_call=us,
            derived=(
                f"static/reactive={static.total_time / reactive.total_time:.2f};"
                f"static/degradation={static.total_time / degr.total_time:.2f};"
                f"replans={reactive.n_replans}"
            ),
            static_s=static.total_time,
            reactive_s=reactive.total_time,
            degradation_s=degr.total_time,
            reactive_replans=reactive.n_replans,
            degradation_replans=degr.n_replans,
            n_failures=reactive.n_failures,
            iter_times_static=static.iter_times,
            iter_times_reactive=reactive.iter_times,
        ))
    return rows
