import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.select_perms import (
    coin_change_diameter,
    geometric_targets,
    select_permutations,
    theorem1_bound,
)
from repro.core.totient import totient_perms


def test_geometric_targets_ratio():
    t = geometric_targets(64, 3)
    assert t[0] == 1.0
    assert t[1] / t[0] == pytest.approx(64 ** (1 / 3))


def test_geometric_targets_small_ratio_clamps_to_2():
    t = geometric_targets(8, 6)  # 8^(1/6) < 2
    assert t[1] / t[0] == 2.0


def test_select_permutations_count_and_membership():
    ps = totient_perms(range(16), prime_only=False)
    sel = select_permutations(ps, 3)
    assert len(sel) == 3
    strides = [r.p for r in sel]
    assert len(set(strides)) == 3
    assert all(math.gcd(p, 16) == 1 for p in strides)
    assert strides[0] == 1  # starts from the minimum candidate


def test_select_more_than_available():
    ps = totient_perms(range(6), prime_only=False)  # phi(6) = 2
    sel = select_permutations(ps, 5)
    assert len(sel) == 2


def test_diameter_stride1_only():
    assert coin_change_diameter(16, [1]) == 15
    assert coin_change_diameter(16, []) == -1


@pytest.mark.parametrize("n,d", [(16, 2), (16, 3), (64, 3), (128, 4), (60, 3)])
def test_theorem1_diameter_bound(n, d):
    ps = totient_perms(range(n), prime_only=False)
    sel = select_permutations(ps, d)
    diam = coin_change_diameter(n, [r.p for r in sel])
    assert diam > 0
    bound = theorem1_bound(n, len(sel))
    # Theorem 1 is O(d * n^(1/d)); allow the constant factor 2.
    assert diam <= 2 * bound, (n, d, diam, bound)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=256),
    d=st.integers(min_value=1, max_value=5),
)
def test_selected_strides_always_connect(n, d):
    # Property: any SelectPermutations output keeps the group reachable
    # (stride 1 is always selected first so the ring is connected).
    ps = totient_perms(range(n), prime_only=False)
    sel = select_permutations(ps, d)
    if not sel:
        return
    diam = coin_change_diameter(n, [r.p for r in sel])
    assert 0 < diam <= n - 1
