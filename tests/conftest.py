"""Repo-wide pytest wiring.

* Prepends ``src/`` (and ``tests/`` for helper modules) to ``sys.path`` so a
  bare ``python -m pytest`` works without the ``PYTHONPATH=src`` incantation.
* Registers the ``slow`` marker for the multi-minute subprocess tests; the
  quick loop is ``python -m pytest -m "not slow"``.
"""

from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (
    os.path.join(_ROOT, "src"),
    os.path.dirname(os.path.abspath(__file__)),
    _ROOT,  # `import benchmarks.*` under a bare `pytest` invocation
):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-minute subprocess tests; deselect with -m \"not slow\"",
    )
