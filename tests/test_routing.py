import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.routing import (
    RoutingTable,
    allreduce_routes,
    bandwidth_tax,
    coin_change_mod,
    path_length_stats,
)


def test_coin_change_reaches_every_distance():
    bt = coin_change_mod(16, [1, 3, 7])
    assert set(bt) == set(range(1, 16))
    for m, coins in bt.items():
        assert sum(coins) % 16 == m


def test_coin_change_minimality_stride1():
    bt = coin_change_mod(8, [1])
    for m, coins in bt.items():
        assert len(coins) == m  # only +1 hops available


def test_coin_change_uses_big_stride():
    bt = coin_change_mod(16, [1, 5])
    # distance 10 = 5+5 (2 hops), not 10 x 1.
    assert len(bt[10]) == 2


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=64),
    data=st.data(),
)
def test_coin_change_complete_for_coprime_strides(n, data):
    import math

    cands = [p for p in range(1, n) if math.gcd(p, n) == 1]
    strides = data.draw(
        st.lists(st.sampled_from(cands), min_size=1, max_size=3, unique=True)
    )
    bt = coin_change_mod(n, strides)
    assert set(bt) == set(range(1, n))


def test_allreduce_routes_follow_rings():
    members = (0, 1, 2, 3, 4, 5, 6, 7)
    table = allreduce_routes(members, [1, 3])
    # every ordered pair routed
    assert len(table.routes) == 8 * 7
    for (src, dst), routes in table.routes.items():
        for r in routes:
            assert r.path[0] == src and r.path[-1] == dst
            for a, b in zip(r.path[:-1], r.path[1:]):
                assert (b - a) % 8 in (1, 3)  # every hop rides a ring edge


def test_bandwidth_tax_direct_is_one():
    t = RoutingTable()
    t.add(0, 1, (0, 1))
    assert bandwidth_tax([(0, 1, 100.0)], t) == pytest.approx(1.0)


def test_bandwidth_tax_two_hops():
    t = RoutingTable()
    t.add(0, 2, (0, 1, 2))
    assert bandwidth_tax([(0, 2, 100.0)], t) == pytest.approx(2.0)


def test_path_length_stats():
    t = RoutingTable()
    t.add(0, 1, (0, 1))
    t.add(0, 2, (0, 1, 2))
    t.add(0, 3, (0, 1, 2, 3))
    stats = path_length_stats(t)
    assert stats["mean"] == pytest.approx(2.0)
    assert stats["max"] == 3
