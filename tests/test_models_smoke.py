"""Per-arch smoke tests (REQUIRED): reduced same-family config, one
forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill/decode consistency against the full forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ALL_SHAPES, all_configs, shape_applicability
from repro.models import lm

ARCHS = [n for n, c in all_configs().items() if c.family != "recsys"]


def _batch(cfg, key, B=2, S=32):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model),
                                        jnp.dtype(cfg.activation_dtype)),
            "labels": jnp.zeros((B, S), jnp.int32),
        }
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            key, (B, cfg.img_tokens, cfg.d_model), jnp.dtype(cfg.activation_dtype)
        )
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = all_configs()[arch].smoke()
    key = jax.random.PRNGKey(0)
    params = lm.init(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = jax.jit(lambda p, b: lm.forward(p, b, cfg))(params, batch)
    B, S = 2, 32
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_updates_params(arch):
    from repro.optim import adamw, constant

    cfg = all_configs()[arch].smoke()
    key = jax.random.PRNGKey(1)
    params = lm.init(key, cfg)
    opt = adamw(constant(1e-3))
    state = opt.init(params)

    def step(p, s, b):
        (l, m), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(pp, b, cfg), has_aux=True
        )(p)
        p2, s2 = opt.update(g, s, p, jnp.int32(0))
        return p2, s2, l

    batch = _batch(cfg, key)
    p2, s2, loss = jax.jit(step)(params, state, batch)
    assert np.isfinite(float(loss))
    # at least one parameter changed
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert changed
    # loss finite and grads flowed into deep leaves (embed)
    assert not np.allclose(
        np.asarray(params["embed"] if "embed" in params else jax.tree.leaves(params)[0], np.float32),
        np.asarray(p2["embed"] if "embed" in p2 else jax.tree.leaves(p2)[0], np.float32),
    )


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if not all_configs()[a].is_encoder]
)
def test_prefill_decode_matches_forward(arch):
    cfg = dataclasses.replace(
        all_configs()[arch].smoke(), param_dtype="float32",
        activation_dtype="float32",
    )
    key = jax.random.PRNGKey(2)
    params = lm.init(key, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :S]}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.img_tokens, cfg.d_model), jnp.float32
        )
    full, _ = lm.forward(params, batch, cfg, remat="none")
    plogits, cache = lm.prefill(params, batch, cfg, pad_to=S + 4)
    np.testing.assert_allclose(
        np.asarray(plogits), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4
    )
    dlogits, _ = lm.decode_step(
        params, {"token": tokens[:, S], "pos": jnp.int32(S), "cache": cache}, cfg
    )
    full2, _ = lm.forward(
        params, {**batch, "tokens": tokens[:, : S + 1]}, cfg, remat="none"
    )
    np.testing.assert_allclose(
        np.asarray(dlogits), np.asarray(full2[:, -1]), rtol=2e-4, atol=2e-4
    )


def test_shape_applicability_counts():
    """The assignment's skip bookkeeping: 31 runnable of 40 cells."""
    cells = runnable = 0
    for cfg in all_configs().values():
        if cfg.family == "recsys":
            continue
        for shape in ALL_SHAPES:
            cells += 1
            ok, why = shape_applicability(cfg, shape)
            runnable += ok
            if not ok:
                assert why
    assert cells == 40
    assert runnable == 31
