"""Compiled plan evaluator: equivalence with the reference objective.

The compiled fast path (:mod:`repro.core.planeval`) must agree with the
reference :func:`repro.core.netsim.topoopt_comm_time` to 1e-9 relative —
here it is pinned over random topologies, demands, jobsets, and degraded
fabrics — and the compiled search loops must return *identical* results to
the reference (pre-compiled) paths at fixed seeds.
"""

from __future__ import annotations

import random

import numpy as np
import pytest

from repro.core.alternating import (
    alternating_optimize,
    co_optimize_jobset,
    initial_topology,
)
from repro.core.netsim import (
    HardwareSpec,
    _routing_with_fallback,
    mp_flows,
    reference_comm_time,
)
from repro.core.simengine import topoopt_comm_time
from repro.core.planeval import (
    JobSetEvaluator,
    LRUCache,
    PlanEvaluator,
    plan_evaluator,
)
from repro.core.simengine import SimEngine
from repro.core.strategy_search import (
    Strategy,
    default_strategy,
    evaluate_jobset,
    mcmc_search,
    mcmc_search_jobset,
)
from repro.core.topology_finder import (
    remove_pair,
    repair_topology,
    topology_finder,
)
from repro.core.workloads import (
    BERT,
    DLRM,
    MOE_16E,
    JobSet,
    TenantJob,
    job_demand,
)

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)


def _random_demand(rng: random.Random, n: int):
    kind = rng.choice(["dp", "dlrm", "dlrm", "moe"])
    if kind == "dp":
        return job_demand(DLRM, n)
    if kind == "dlrm":
        hosts = tuple(sorted(rng.sample(range(n), rng.randint(1, max(1, n // 2)))))
        return job_demand(DLRM, n, table_hosts=hosts)
    return job_demand(MOE_16E, n, ep_group_size=rng.choice([2, 4, 8]))


def _assert_comm_close(topo, demand, ev=None):
    ev = ev or plan_evaluator(topo, HW)
    ref = topoopt_comm_time(topo, demand, HW)
    fast = ev.comm(demand)
    for key in ("comm_time", "bandwidth_tax"):
        assert fast[key] == pytest.approx(ref[key], rel=1e-9, abs=1e-12), key


# ---------------------------------------------------------------------------
# Randomized equivalence: compiled vs reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 13, 16])
def test_compiled_matches_reference_random_demands(n):
    rng = random.Random(n)
    base = job_demand(DLRM, n, table_hosts=tuple(range(0, n, 3)))
    topo = topology_finder(base, HW.degree)
    ev = plan_evaluator(topo, HW)
    for _ in range(12):
        # Cross-evaluation: demands the topology was never built for (the
        # MCMC probing pattern) exercise the fallback route cache.
        _assert_comm_close(topo, _random_demand(rng, n), ev)


def test_compiled_comm_time_is_bit_exact():
    """The full compiled evaluation matches the reference *to the bit* —
    the property that keeps fixed-seed MCMC ties aligned."""
    rng = random.Random(7)
    topo = topology_finder(job_demand(DLRM, 12, table_hosts=(0, 4, 9)),
                           HW.degree)
    ev = plan_evaluator(topo, HW)
    for _ in range(20):
        d = _random_demand(rng, 12)
        assert ev.comm_time(d) == reference_comm_time(topo, d, HW)


@pytest.mark.parametrize("degrade", ["remove", "repair"])
def test_compiled_matches_on_degraded_fabric(degrade):
    rng = random.Random(3)
    n = 12
    topo = topology_finder(job_demand(DLRM, n, table_hosts=(0, 3, 7)),
                           HW.degree)
    degraded = (
        remove_pair(topo, (0, 1)) if degrade == "remove"
        else repair_topology(topo, (0, 1))
    )
    # Degradation returns a *new* Topology: its evaluator compiles fresh
    # (no stale incidence/route caches from the healthy fabric).
    assert plan_evaluator(degraded, HW) is not plan_evaluator(topo, HW)
    for _ in range(8):
        d = _random_demand(rng, n)
        _assert_comm_close(degraded, d)
        _assert_comm_close(topo, d)  # healthy evaluator unaffected


def test_loads_delta_matches_full_evaluation():
    n = 14
    topo = topology_finder(job_demand(DLRM, n, table_hosts=(0, 5)), HW.degree)
    ev = plan_evaluator(topo, HW)
    rng = random.Random(11)
    old = _random_demand(rng, n)
    base = ev.loads(old)
    for _ in range(10):
        new = _random_demand(rng, n)
        delta = ev.pad(ev.loads_delta(base, old, new))
        full = ev.pad(ev.loads(new))
        scale = max(float(full.max()), 1.0)
        assert np.allclose(delta, full, rtol=1e-9, atol=1e-6 * scale)
        base, old = delta, new  # chain the lineage like the MCMC loop


def test_batched_comm_times_match_single():
    n = 12
    topo = topology_finder(job_demand(DLRM, n, table_hosts=(1, 6)), HW.degree)
    ev = plan_evaluator(topo, HW)
    rng = random.Random(5)
    demands = [_random_demand(rng, n) for _ in range(6)]
    batch = ev.comm_times(demands)
    single = np.array([ev.comm_time(d) for d in demands])
    assert np.allclose(batch, single, rtol=1e-12)


def test_plan_evaluator_memoized_on_topology():
    topo = initial_topology(8, 4)
    assert plan_evaluator(topo, HW) is plan_evaluator(topo, HW)
    other_hw = HardwareSpec(link_bandwidth=25e9, degree=4)
    assert plan_evaluator(topo, other_hw) is not plan_evaluator(topo, HW)


def test_simengine_compiled_facade_matches_reference():
    topo = initial_topology(10, 4)
    dem = job_demand(DLRM, 10, table_hosts=(2, 7))
    fast = SimEngine(HW).iteration_time(topo, dem, flops_per_iteration=1e15)
    ref = SimEngine(HW, compiled=False).iteration_time(
        topo, dem, flops_per_iteration=1e15
    )
    assert fast == pytest.approx(ref, rel=1e-9)


# ---------------------------------------------------------------------------
# Incremental jobset evaluation
# ---------------------------------------------------------------------------


def _jobset(n: int) -> JobSet:
    third = n // 3
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, third)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(third, 2 * third)),
                  name="bert"),
        TenantJob(spec=MOE_16E, servers=tuple(range(2 * third, n)),
                  name="moe"),
    ])


def test_jobset_evaluator_matches_reference_through_moves():
    """A propose/accept random walk stays within 1e-9 of the reference
    evaluate_jobset at every step."""
    n = 12
    js = _jobset(n)
    strategies = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(strategies), HW.degree,
                           pack="per_node")
    jse = JobSetEvaluator(js, topo, HW)
    obj, per_job = jse.set_strategies(strategies)
    rng = random.Random(2)
    for step in range(15):
        ref_obj, _, ref_per_job = evaluate_jobset(strategies, js, topo, HW)
        assert obj == pytest.approx(ref_obj, rel=1e-9)
        for label in per_job:
            assert per_job[label] == pytest.approx(
                ref_per_job[label], rel=1e-9
            )
        t = js.tenants[rng.randrange(len(js.tenants))]
        move = Strategy(
            mode="hybrid",
            table_hosts=tuple(sorted(rng.sample(range(t.k), 2))),
        ) if t.spec.n_tables else Strategy(
            mode="dp", ep_group_size=rng.choice([2, 4])
        )
        cand_obj, cand_per_job = jse.propose(t.label, move)
        cand = dict(strategies)
        cand[t.label] = move
        ref_cand = evaluate_jobset(cand, js, topo, HW)[0]
        assert cand_obj == pytest.approx(ref_cand, rel=1e-9)
        if step % 2 == 0:  # adopt every other move, like a real chain
            jse.accept()
            strategies, obj, per_job = cand, cand_obj, cand_per_job


def test_jobset_union_preserved():
    js = _jobset(12)
    strategies = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(strategies), HW.degree,
                           pack="per_node")
    jse = JobSetEvaluator(js, topo, HW)
    jse.set_strategies(strategies)
    union = jse.union()
    ref = js.union_for(strategies)
    assert union.sum_mp == pytest.approx(ref.sum_mp, rel=1e-12)
    assert union.sum_allreduce == pytest.approx(ref.sum_allreduce, rel=1e-12)


# ---------------------------------------------------------------------------
# Fixed-seed goldens: compiled search results identical to the reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 3, 5])
def test_mcmc_search_compiled_identical(seed):
    topo = initial_topology(16, 4)
    ref = mcmc_search(DLRM, topo, HW, iters=80, seed=seed, compiled=False)
    fast = mcmc_search(DLRM, topo, HW, iters=80, seed=seed, compiled=True)
    assert fast.strategy == ref.strategy
    assert fast.iter_time == pytest.approx(ref.iter_time, rel=1e-9)
    assert np.allclose(fast.history, ref.history, rtol=1e-9)


@pytest.mark.parametrize("seed", [0, 2])
def test_alternating_optimize_compiled_identical(seed):
    ref = alternating_optimize(DLRM, 16, HW, rounds=2, mcmc_iters=50,
                               seed=seed, compiled=False)
    fast = alternating_optimize(DLRM, 16, HW, rounds=2, mcmc_iters=50,
                                seed=seed, compiled=True)
    assert fast.strategy == ref.strategy
    assert fast.iter_time == pytest.approx(ref.iter_time, rel=1e-9)
    assert np.allclose(fast.rounds, ref.rounds, rtol=1e-9)


@pytest.mark.parametrize("seed", [0, 4])
def test_mcmc_search_jobset_compiled_identical(seed):
    js = _jobset(12)
    init = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(init), HW.degree, pack="per_node")
    ref = mcmc_search_jobset(js, topo, HW, iters=60, seed=seed,
                             compiled=False)
    fast = mcmc_search_jobset(js, topo, HW, iters=60, seed=seed,
                              compiled=True)
    assert fast.strategies == ref.strategies
    assert fast.iter_time == pytest.approx(ref.iter_time, rel=1e-9)
    assert np.allclose(fast.history, ref.history, rtol=1e-9)
    for label in ref.per_job:
        assert fast.per_job[label] == pytest.approx(
            ref.per_job[label], rel=1e-9
        )


def test_co_optimize_jobset_compiled_identical():
    js = JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, 4)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(4, 8)), name="bert"),
    ])
    ref = co_optimize_jobset(js, HW, rounds=2, mcmc_iters=30, seed=1,
                             compiled=False)
    fast = co_optimize_jobset(js, HW, rounds=2, mcmc_iters=30, seed=1,
                              compiled=True)
    assert fast.strategies == ref.strategies
    assert fast.iter_time == pytest.approx(ref.iter_time, rel=1e-9)


def test_batched_proposals_mode():
    """proposals_per_step > 1 runs a (documented) different chain but must
    produce a valid, competitive result."""
    topo = initial_topology(12, 4)
    base = mcmc_search(DLRM, topo, HW, iters=60, seed=0)
    batched = mcmc_search(DLRM, topo, HW, iters=30, seed=0,
                          proposals_per_step=4)
    assert batched.iter_time <= base.history[0]  # no worse than cold start
    js = _jobset(12)
    init = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo_js = topology_finder(js.union_for(init), HW.degree, pack="per_node")
    b = mcmc_search_jobset(js, topo_js, HW, iters=20, seed=0,
                           proposals_per_step=4)
    assert b.iter_time <= b.history[0]
    with pytest.raises(ValueError):
        mcmc_search(DLRM, topo, HW, iters=5, proposals_per_step=2,
                    compiled=False)


# ---------------------------------------------------------------------------
# Satellites: routing fallback memoization, LRU cache, vectorized flows
# ---------------------------------------------------------------------------


def test_routing_fallback_full_cache_hit_reuses_table():
    n = 10
    topo = topology_finder(job_demand(DLRM, n), HW.degree)
    # A pair the planned table never routed (probing pattern).
    flows = [(0, 7, 123.0), (3, 9, 5.0)]
    first = _routing_with_fallback(topo, flows)
    second = _routing_with_fallback(topo, flows)
    assert second is first  # memoized merged table, not a fresh deep copy
    # Routed-only flow lists short-circuit to the planned table itself.
    routed = [(s, t, 1.0) for (s, t) in list(topo.routing.routes)[:3]]
    assert _routing_with_fallback(topo, routed) is topo.routing
    # The merged table answers both planned and fallback pairs.
    assert first.get(0, 7)
    for s, t, _ in routed:
        assert first.get(s, t) == topo.routing.get(s, t)


def test_lru_cache_bounds_and_recency():
    cache = LRUCache(maxsize=3)
    for i in range(3):
        cache[i] = i * 10
    assert cache.get(0) == 0  # refresh 0
    cache[3] = 30  # evicts 1 (least recently used)
    assert 1 not in cache
    assert 0 in cache and 2 in cache and 3 in cache
    assert len(cache) == 3
    with pytest.raises(ValueError):
        LRUCache(maxsize=0)


def test_mp_flows_vectorized_form():
    dem = job_demand(DLRM, 8, table_hosts=(1, 5))
    flows = mp_flows(dem)
    assert len(flows) == int(np.count_nonzero(dem.mp))
    assert flows.total == pytest.approx(float(dem.mp.sum()))
    # Legacy tuple iteration still works (and yields python scalars).
    triples = list(flows)
    assert all(isinstance(s, int) and isinstance(b, float)
               for s, _, b in triples)
    as_dict = {(s, t): b for s, t, b in triples}
    srcs, dsts = np.nonzero(dem.mp)
    assert as_dict == {
        (int(s), int(t)): float(dem.mp[s, t]) for s, t in zip(srcs, dsts)
    }


def test_evaluate_jobset_compiled_flag_matches():
    js = _jobset(12)
    strategies = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(strategies), HW.degree,
                           pack="per_node")
    cache = LRUCache(64)
    ref = evaluate_jobset(strategies, js, topo, HW, _demand_cache=cache)
    fast = evaluate_jobset(strategies, js, topo, HW, _demand_cache=cache,
                           compiled=True)
    assert fast[0] == ref[0]  # bit-exact union pricing
    assert fast[2] == ref[2]


def test_empty_and_zero_demand():
    topo = initial_topology(6, 4)
    ev = plan_evaluator(topo, HW)
    from repro.core.demand import TrafficDemand

    empty = TrafficDemand(n=6)
    assert ev.comm(empty) == topoopt_comm_time(topo, empty, HW)
    assert ev.comm_time(empty) == 0.0


def test_demand_cache_size_env(monkeypatch):
    """REPRO_DEMAND_CACHE_SIZE tunes the default demand memo without edits;
    the explicit demand_cache kwarg still wins (it bypasses the default)."""
    from repro.core.strategy_search import DEMAND_CACHE_SIZE, demand_cache_size

    monkeypatch.delenv("REPRO_DEMAND_CACHE_SIZE", raising=False)
    assert demand_cache_size() == DEMAND_CACHE_SIZE
    monkeypatch.setenv("REPRO_DEMAND_CACHE_SIZE", "9")
    assert demand_cache_size() == 9
