"""Pallas kernels vs pure-jnp oracles (interpret=True), sweeping shapes and
dtypes per the deliverable requirements."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rglru_scan import rglru_scan

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype != np.float32 else dict(
        rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize(
    "B,H,KV,S,D,causal,window",
    [
        (2, 4, 4, 256, 64, True, 0),     # MHA causal
        (1, 8, 2, 256, 128, True, 0),    # GQA 4:1
        (2, 4, 1, 384, 64, True, 0),     # MQA
        (2, 4, 4, 256, 64, False, 0),    # bidirectional (encoder)
        (1, 4, 2, 512, 64, True, 128),   # sliding window (griffin)
        (1, 2, 2, 128, 32, True, 0),     # small dims
    ],
)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_flash_attention_vs_ref(B, H, KV, S, D, causal, window, dtype):
    q = jnp.array(RNG.standard_normal((B, H, S, D)), dtype)
    k = jnp.array(RNG.standard_normal((B, KV, S, D)), dtype)
    v = jnp.array(RNG.standard_normal((B, KV, S, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    expect = ref.ref_flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 256), (37, 53)])
def test_flash_attention_block_shapes(block_q, block_k):
    q = jnp.array(RNG.standard_normal((1, 2, 222, 64)), jnp.float32)
    k = jnp.array(RNG.standard_normal((1, 2, 222, 64)), jnp.float32)
    v = jnp.array(RNG.standard_normal((1, 2, 222, 64)), jnp.float32)
    out = flash_attention(
        q, k, v, causal=True, block_q=block_q, block_k=block_k, interpret=True
    )
    expect = ref.ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=3e-5, atol=3e-5
    )


@pytest.mark.parametrize(
    "B,L,DI,ST,block_d,chunk",
    [(2, 256, 64, 8, 32, 64), (1, 128, 128, 16, 128, 128), (3, 64, 32, 4, 16, 32)],
)
def test_mamba_scan_vs_ref(B, L, DI, ST, block_d, chunk):
    xc = jnp.array(RNG.standard_normal((B, L, DI)), jnp.float32)
    dt = jnp.array(RNG.uniform(0.001, 0.1, (B, L, DI)), jnp.float32)
    a = -jnp.array(RNG.uniform(0.5, 2.0, (DI, ST)), jnp.float32)
    b = jnp.array(RNG.standard_normal((B, L, ST)), jnp.float32)
    c = jnp.array(RNG.standard_normal((B, L, ST)), jnp.float32)
    d = jnp.array(RNG.standard_normal((DI,)), jnp.float32)
    y, h = mamba_scan(xc, dt, a, b, c, d, block_d=block_d, chunk=chunk,
                      interpret=True)
    yr, hr = ref.ref_mamba_scan(xc, dt, a, b, c, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("B,L,D,block_d,chunk", [(2, 256, 64, 32, 64), (1, 96, 48, 48, 32)])
def test_rglru_scan_vs_ref(B, L, D, block_d, chunk, dtype):
    a = jnp.array(RNG.uniform(0.1, 0.99, (B, L, D)), dtype)
    b = jnp.array(RNG.standard_normal((B, L, D)), dtype)
    h_all, h_fin = rglru_scan(a, b, block_d=block_d, chunk=chunk, interpret=True)
    hr_all, hr_fin = ref.ref_rglru_scan(a, b)
    np.testing.assert_allclose(
        np.asarray(h_all), np.asarray(hr_all), rtol=5e-3, atol=5e-3
    )
    np.testing.assert_allclose(
        np.asarray(h_fin), np.asarray(hr_fin), rtol=5e-3, atol=5e-3
    )


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize(
    "E,C,D,F,bc,bf,bd", [(4, 128, 256, 128, 64, 64, 128), (8, 64, 64, 256, 64, 128, 64)]
)
def test_moe_gmm_vs_ref(E, C, D, F, bc, bf, bd, dtype):
    x = jnp.array(RNG.standard_normal((E, C, D)), dtype)
    w = jnp.array(RNG.standard_normal((E, D, F)) / np.sqrt(D), dtype)
    o = moe_gmm(x, w, block_c=bc, block_f=bf, block_d=bd, interpret=True)
    orf = ref.ref_moe_gmm(x, w)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(orf, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("T,R,E,B,NNZ", [(3, 50, 16, 2, 4), (1, 10, 8, 4, 1), (5, 100, 32, 3, 7)])
def test_embedding_bag_vs_ref(T, R, E, B, NNZ):
    tables = jnp.array(RNG.standard_normal((T, R, E)), jnp.float32)
    idx = jnp.array(RNG.integers(0, R, (B, T, NNZ)), jnp.int32)
    out = embedding_bag(tables, idx, interpret=True)
    expect = ref.ref_embedding_bag(tables, idx)
    # Kernel sums the bag sequentially, the reference via XLA's tree reduce;
    # both in fp32, so they differ only by summation order: bounded by
    # ~NNZ ulps of the partial-sum magnitude, which an atol floor covers for
    # bags whose terms nearly cancel (|sum| << |terms|).
    atol = NNZ * np.finfo(np.float32).eps * float(np.abs(np.asarray(tables)).max())
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-6, atol=atol
    )


def test_xla_fallback_matches_kernel_mamba():
    """models.layers chunked-scan fallback == Pallas kernel semantics."""
    from repro.models.layers import chunked_linear_scan

    B, L, DI, ST = 1, 64, 16, 4
    xc = jnp.array(RNG.standard_normal((B, L, DI)), jnp.float32)
    dt = jnp.array(RNG.uniform(0.01, 0.1, (B, L, DI)), jnp.float32)
    a = -jnp.array(RNG.uniform(0.5, 2.0, (DI, ST)), jnp.float32)
    bm = jnp.array(RNG.standard_normal((B, L, ST)), jnp.float32)
    cm = jnp.array(RNG.standard_normal((B, L, ST)), jnp.float32)
    d = jnp.zeros((DI,), jnp.float32)
    decay = jnp.exp(dt[..., None] * a)
    drive = (dt * xc)[..., None] * bm[:, :, None, :]
    h_all, _ = chunked_linear_scan(decay, drive, jnp.zeros((B, DI, ST)), chunk=16)
    y_fallback = jnp.einsum("blds,bls->bld", h_all, cm)
    y_kernel, _ = mamba_scan(xc, dt, a, bm, cm, d, block_d=16, chunk=16,
                             interpret=True)
    np.testing.assert_allclose(
        np.asarray(y_fallback), np.asarray(y_kernel), rtol=1e-4, atol=1e-4
    )
