import networkx as nx
import numpy as np

from repro.core.simengine import ocs_topology


def test_highest_demand_gets_most_links():
    n = 8
    demand = np.ones((n, n))
    demand[0, 1] = 100.0
    g = ocs_topology(n, demand, degree=4)
    assert g.number_of_edges(0, 1) >= 2  # parallel links for the elephant


def test_degree_respected():
    n = 8
    rng = np.random.default_rng(0)
    demand = rng.random((n, n)) * 100
    g = ocs_topology(n, demand, degree=3)
    for v in range(n):
        assert g.out_degree(v) <= 3
        assert g.in_degree(v) <= 3


def test_connectivity_repair():
    # two cliques of demand, zero cross demand: repair must connect them
    n = 8
    demand = np.zeros((n, n))
    demand[:4, :4] = 10.0
    demand[4:, 4:] = 10.0
    np.fill_diagonal(demand, 0.0)
    g = ocs_topology(n, demand, degree=3, ensure_connected=True)
    assert nx.is_weakly_connected(nx.DiGraph(g))


def test_discounting_spreads_links():
    n = 6
    demand = np.ones((n, n)) * 10
    np.fill_diagonal(demand, 0.0)
    g = ocs_topology(n, demand, degree=3)
    # uniform demand with halving: links spread over many pairs
    pairs = {(a, b) for a, b in g.edges()}
    assert len(pairs) >= n  # not all parallel on one pair
