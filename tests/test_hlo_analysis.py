"""HLO analyzer: exactness on known programs + while-loop trip counting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_matmul_flops_exact():
    M, K, N = 64, 128, 256
    text = _compile(
        lambda x, w: x @ w,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32),
    )
    res = analyze_hlo(text)
    assert res["flops"] == pytest.approx(2 * M * K * N)


def test_scan_multiplies_trip_count():
    M, K = 32, 64
    L = 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=L)
        return out

    text = _compile(
        f,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, K), jnp.float32),
    )
    res = analyze_hlo(text)
    assert res["flops"] == pytest.approx(L * 2 * M * K * K)


def test_nested_scan():
    M, K = 16, 32

    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    text = _compile(
        f,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, K), jnp.float32),
    )
    res = analyze_hlo(text)
    assert res["flops"] == pytest.approx(15 * 2 * M * K * K)


def test_bytes_nonzero_and_reasonable():
    M = 512
    text = _compile(
        lambda x: jnp.tanh(x) * 2.0 + 1.0,
        jax.ShapeDtypeStruct((M, M), jnp.float32),
    )
    res = analyze_hlo(text)
    # fused elementwise: ~1 read + 1 write of the array
    assert 2 * M * M * 4 <= res["bytes"] <= 10 * M * M * 4


def test_collectives_on_synthetic_hlo():
    text = """
HloModule test

%cond (p: (s32[], f32[16])) -> pred[] {
  %p = (s32[], f32[16]) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body (p.1: (s32[], f32[16])) -> (s32[], f32[16]) {
  %p.1 = (s32[], f32[16]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%p.1), index=0
  %gte.2 = f32[16] get-tuple-element(%p.1), index=1
  %one = s32[] constant(1)
  %next = s32[] add(%gte.1, %one)
  %ar = f32[16]{0} all-reduce(%gte.2), to_apply=%add_comp
  ROOT %t = (s32[], f32[16]) tuple(%next, %ar)
}

ENTRY %main (x: f32[16]) -> f32[16] {
  %x = f32[16]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16]) tuple(%zero, %x)
  %w = (s32[], f32[16]) while(%init), condition=%cond, body=%body
  %res = f32[16]{0} get-tuple-element(%w), index=1
  ROOT %ag = f32[32]{0} all-gather(%res), dimensions={0}
}
"""
    res = analyze_hlo(text)
    # all-reduce: 12 trips x 2 x 64B = 1536; all-gather: 128B
    assert res["collectives_by_type"]["all-reduce"] == pytest.approx(12 * 2 * 64)
    assert res["collectives_by_type"]["all-gather"] == pytest.approx(128)
