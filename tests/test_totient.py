import math

import pytest

from repro.core.totient import (
    RingPermutation,
    coprimes,
    is_valid_ring,
    prime_coprimes,
    ring_edges,
    ring_order,
    totient_perms,
    totient_perms_grouped,
)


def test_coprimes_n12_matches_paper():
    # Paper §4.3: for n = 12, p in {1, 5, 7, 11}.
    assert coprimes(12) == [1, 5, 7, 11]


def test_coprimes_prime_n():
    # For prime n every 1 <= p < n is a generator (phi(p) = p - 1).
    assert len(coprimes(13)) == 12


def test_prime_coprimes_subset():
    ps = prime_coprimes(30)
    assert 1 in ps
    for p in ps[1:]:
        assert math.gcd(p, 30) == 1
        assert all(p % f for f in range(2, p)) and p >= 2
    assert set(ps) <= set([1] + coprimes(30))


@pytest.mark.parametrize("n", [2, 3, 8, 12, 16, 17, 60])
def test_every_coprime_stride_is_valid_ring(n):
    # Theorem 2: each coprime stride yields a Hamiltonian directed cycle.
    for p in coprimes(n):
        assert is_valid_ring(n, ring_edges(n, p)), (n, p)


def test_non_coprime_stride_rejected():
    with pytest.raises(ValueError):
        ring_order(12, 4)


@pytest.mark.parametrize("n", [8, 12, 16])
def test_rings_are_unique(n):
    # Theorem 2: distinct p -> distinct edge sets.
    seen = set()
    for p in coprimes(n):
        edges = frozenset(ring_edges(n, p))
        assert edges not in seen
        seen.add(edges)


def test_totient_perms_members_mapping():
    members = (3, 7, 11, 20, 42)
    ps = totient_perms(members, prime_only=False)
    assert ps.group == members
    for ring in ps.perms:
        order = ring.order()
        assert sorted(order) == sorted(members)
        edges = ring.edges()
        assert len(edges) == len(members)
        srcs = [a for a, _ in edges]
        assert sorted(srcs) == sorted(members)


def test_totient_perms_auto_prime_restriction():
    big = totient_perms(range(128))
    assert all(p == 1 or _is_prime(p) for p in big.strides)
    small = totient_perms(range(12))
    assert small.strides == [1, 5, 7, 11]


def _is_prime(x):
    return x >= 2 and all(x % f for f in range(2, int(math.isqrt(x)) + 1))


def test_totient_perms_grouped():
    sets = totient_perms_grouped(16, 4, prime_only=False)
    assert len(sets) == 4
    assert sets[0].group == (0, 1, 2, 3)
    assert sets[3].group == (12, 13, 14, 15)
    with pytest.raises(ValueError):
        totient_perms_grouped(10, 4)


def test_ring_permutation_edges_follow_stride():
    ring = RingPermutation(p=5, members=tuple(range(12)))
    for a, b in ring.edges():
        assert (a + 5) % 12 == b
