"""Int8 gradient compression: quantizer bounds + compressed allreduce
accuracy + error-feedback convergence (subprocess, 8 devices)."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from _subproc import run_with_devices
from repro.parallel.compression import dequantize_block, quantize_block

import pytest

# Multi-minute subprocess tests (fresh jax init per case); quick loop:
# python -m pytest -m "not slow"
pytestmark = pytest.mark.slow


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal(5000), jnp.float32)
    q, s, size = quantize_block(x, block=256)
    deq = dequantize_block(q, s)[:5000]
    err = np.abs(np.asarray(deq) - np.asarray(x))
    # per-block max-scale quantization: |err| <= scale/2 = max|x_block|/254
    blocks = np.asarray(x)
    assert err.max() <= np.abs(blocks).max() / 254 + 1e-7


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=3000), st.integers(min_value=8, max_value=512))
def test_quantize_shapes_property(n, block):
    rng = np.random.default_rng(n)
    x = jnp.array(rng.standard_normal(n), jnp.float32)
    q, s, size = quantize_block(x, block=block)
    assert q.shape[0] * q.shape[1] >= n
    assert q.shape[1] == block
    deq = dequantize_block(q, s)
    rel = np.abs(np.asarray(deq[:n]) - np.asarray(x))
    scale_bound = np.abs(np.asarray(x)).max() / 127 + 1e-7
    assert rel.max() <= scale_bound


def test_compressed_allreduce_close_to_exact():
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.parallel.compression import compressed_ring_all_reduce

mesh = jax.make_mesh((8,), ("x",))
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((8, 300)), jnp.float32)

def fn(v):
    out, res = compressed_ring_all_reduce(v, "x", p=3, block=64)
    return out

out = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
exact = np.asarray(x).sum(axis=0)
# per-hop int8 error is relative to the block max, so measure absolute error
# against the payload scale (near-zero sums make per-element ratios blow up).
scale = np.abs(np.asarray(x)).max()
err = np.abs(np.asarray(out)[0] - exact).max()
assert err < 0.1 * scale * 8, (err, scale)  # 2(n-1)/254 ~ 5.5% of max
print("PASS", err / scale)
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_error_feedback_converges_on_quadratic():
    """SGD with compressed gradients + error feedback must still drive a
    quadratic to its minimum (EF-SGD guarantee)."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map_compat
from repro.parallel.compression import Compressor
mesh = jax.make_mesh((8,), ("x",))
rng = np.random.default_rng(0)
target = jnp.array(rng.standard_normal(64), jnp.float32)
comp = Compressor(block=32)

def make_step():
    def step(w, residual, noise):
        g = (w - target) + 0.01 * noise[0]  # per-device noisy grad
        g_sync, new_res = comp.sync({"w": g}, {"w": residual[0]}, "x",
                                    strides=(1, 3))
        return w - 0.3 * g_sync["w"], new_res["w"][None]
    return jax.jit(shard_map_compat(step, mesh=mesh,
                                    in_specs=(P(), P("x"), P("x")),
                                    out_specs=(P(), P("x")),
                                    check_replication=False))

step = make_step()
w = jnp.zeros(64)
res = jnp.zeros((8, 64))
for i in range(60):
    noise = jnp.array(rng.standard_normal((8, 64)), jnp.float32)
    w, res = step(w, res, noise)
final = float(jnp.linalg.norm(w - target))
assert final < 0.05, final
print("PASS", final)
""",
        n_devices=8,
    )
    assert "PASS" in out
