import pytest

from repro.core.simengine import PROPAGATION_DELAY, FlowSimVec as FlowSim, Task


def _bw(links, bw=100.0):
    return {l: bw for l in links}


def test_single_flow_time():
    sim = FlowSim(_bw([(0, 1)], bw=100.0))
    res = sim.run([Task(tid=0, kind="flow", nbytes=1000.0, route=(0, 1))])
    assert res.makespan == pytest.approx(10.0 + PROPAGATION_DELAY, rel=1e-6)


def test_two_flows_share_link_fairly():
    sim = FlowSim(_bw([(0, 1)], bw=100.0))
    tasks = [
        Task(tid=0, kind="flow", nbytes=1000.0, route=(0, 1)),
        Task(tid=1, kind="flow", nbytes=1000.0, route=(0, 1)),
    ]
    res = sim.run(tasks)
    # each gets 50 B/s until both finish at t=20
    assert res.makespan == pytest.approx(20.0, rel=1e-3)


def test_disjoint_flows_parallel():
    sim = FlowSim(_bw([(0, 1), (2, 3)], bw=100.0))
    tasks = [
        Task(tid=0, kind="flow", nbytes=1000.0, route=(0, 1)),
        Task(tid=1, kind="flow", nbytes=2000.0, route=(2, 3)),
    ]
    res = sim.run(tasks)
    assert res.makespan == pytest.approx(20.0, rel=1e-3)
    assert res.finish_times[0] == pytest.approx(10.0, rel=1e-3)


def test_multi_hop_uses_both_links():
    sim = FlowSim(_bw([(0, 1), (1, 2)], bw=100.0))
    res = sim.run([Task(tid=0, kind="flow", nbytes=1000.0, route=(0, 1, 2))])
    # fluid model: rate limited to 100 on both links simultaneously
    assert res.makespan == pytest.approx(10.0, rel=1e-3)


def test_dependencies_serialize():
    sim = FlowSim(_bw([(0, 1)], bw=100.0))
    tasks = [
        Task(tid=0, kind="compute", duration=5.0),
        Task(tid=1, kind="flow", nbytes=1000.0, route=(0, 1), deps=(0,)),
        Task(tid=2, kind="compute", duration=2.0, deps=(1,)),
    ]
    res = sim.run(tasks)
    assert res.makespan == pytest.approx(17.0, rel=1e-3)
    assert res.finish_times[0] == pytest.approx(5.0)


def test_max_min_fairness_bottleneck():
    # flow A crosses (0,1); flows A and B share (1,2): B also alone on (1,2)?
    # A: 0->1->2, B: 1->2. Link (1,2) shared: each 50. A limited to 50 on (0,1) too.
    sim = FlowSim(_bw([(0, 1), (1, 2)], bw=100.0))
    tasks = [
        Task(tid=0, kind="flow", nbytes=500.0, route=(0, 1, 2)),
        Task(tid=1, kind="flow", nbytes=500.0, route=(1, 2)),
    ]
    res = sim.run(tasks)
    assert res.makespan == pytest.approx(10.0, rel=1e-2)


def test_compute_only():
    sim = FlowSim({})
    res = sim.run([Task(tid=0, kind="compute", duration=3.0)])
    assert res.makespan == pytest.approx(3.0)
