"""Property tests for the collective-schedule demand compilers
(repro.core.schedules): wire-byte conservation, degree-budget respect,
and byte-identity of the default ``ring`` schedule.

Runs under real hypothesis when installed, else the seeded shim
(tests/_hypothesis_compat.py) sweeps a deterministic example batch.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.demand import AllReduceGroup, TrafficDemand, demand_steps
from repro.core.schedules import (
    SCHEDULES,
    apply_schedule,
    get_schedule,
)
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, DLRM, MOE_16E, job_demand

COMPILED = [s for s in SCHEDULES if s != "ring"]


# ---------------------------------------------------------------------------
# Conservation: every schedule moves exactly 2 (k-1) M wire bytes
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    k=st.integers(min_value=2, max_value=33),
    name=st.sampled_from(COMPILED),
    nbytes=st.floats(min_value=1.0, max_value=1e9),
)
def test_pair_loads_conserve_wire_bytes(k, name, nbytes):
    members = tuple(range(100, 100 + k))  # arbitrary non-contiguous labels
    loads = get_schedule(name).pair_loads(members, nbytes)
    total = sum(loads.values())
    assert total == pytest.approx(2.0 * (k - 1) * nbytes, rel=1e-9)
    for (a, b), x in loads.items():
        assert a != b
        assert a in members and b in members
        assert x > 0.0


@settings(max_examples=40, deadline=None)
@given(k=st.integers(min_value=2, max_value=64))
def test_steps_never_exceed_ring(k):
    ring_steps = get_schedule("ring").steps(k)
    assert ring_steps == 2.0 * (k - 1)
    for name in COMPILED:
        s = get_schedule(name).steps(k)
        assert 0.0 < s <= ring_steps
        # Log-depth beats linear once the group is big enough (k=2 ties;
        # k=3 halving-doubling also ties: a 2-core plus the fold's 2 rounds).
        if k > 3:
            assert s < ring_steps


# ---------------------------------------------------------------------------
# apply_schedule: totals bookkeeping + steps semantics on random demands
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    name=st.sampled_from(COMPILED),
)
def test_apply_schedule_bookkeeping(seed, name):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 17))
    d = TrafficDemand(n=n)
    d.mp[:] = rng.uniform(0.0, 1e6, size=(n, n))
    np.fill_diagonal(d.mp, 0.0)
    n_groups = int(rng.integers(1, 4))
    for _ in range(n_groups):
        k = int(rng.integers(1, n + 1))
        members = tuple(int(v) for v in rng.choice(n, size=k, replace=False))
        d.allreduce.append(
            AllReduceGroup(members=members, nbytes=float(rng.uniform(0.0, 1e8)))
        )
    sched = get_schedule(name)
    out = apply_schedule(d, name)
    assert out is not d
    active = [g for g in d.allreduce if g.nbytes > 0.0 and len(g.members) > 1]
    expect_mp = d.sum_mp + sum(
        2.0 * (len(g.members) - 1) * g.nbytes for g in active
    )
    assert out.sum_mp == pytest.approx(expect_mp, rel=1e-9)
    # Compiled groups keep their members (connectivity ring) at zero bytes.
    assert [g.members for g in out.allreduce] == [
        g.members for g in d.allreduce
    ]
    for g_in, g_out in zip(d.allreduce, out.allreduce):
        if g_in.nbytes > 0.0 and len(g_in.members) > 1:
            assert g_out.nbytes == 0.0
        else:
            assert g_out.nbytes == g_in.nbytes
    # Latency rounds: the compiled schedule's steps, never worse than ring.
    if active:
        assert out.steps == max(
            float(sched.steps(len(g.members))) for g in active
        )
    assert demand_steps(out) <= demand_steps(d)
    # The input demand is untouched.
    assert d.steps == 0.0
    assert all(g.nbytes >= 0.0 for g in d.allreduce)


# ---------------------------------------------------------------------------
# Degree budgets: TopologyFinder still packs compiled demands feasibly
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    name=st.sampled_from(list(SCHEDULES)),
    degree=st.integers(min_value=3, max_value=6),
)
def test_topology_respects_degree_budget(seed, name, degree):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 13))
    d = TrafficDemand(n=n)
    d.allreduce.append(
        AllReduceGroup(members=tuple(range(n)), nbytes=float(rng.uniform(1e6, 1e9)))
    )
    k = int(rng.integers(2, n + 1))
    sub = tuple(int(v) for v in rng.choice(n, size=k, replace=False))
    d.allreduce.append(AllReduceGroup(members=sub, nbytes=float(rng.uniform(0, 1e8))))
    topo = topology_finder(apply_schedule(d, name), degree=degree)
    assert max(topo.out_degrees()) <= degree


# ---------------------------------------------------------------------------
# Ring schedule: byte-identical to the pre-schedule job_demand output
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    spec=st.sampled_from([BERT, DLRM, MOE_16E]),
    n=st.integers(min_value=4, max_value=16),
)
def test_ring_schedule_is_byte_identical(spec, n):
    base = job_demand(spec, n)
    ring = job_demand(spec, n, schedule="ring")
    assert np.array_equal(base.mp, ring.mp)
    assert base.allreduce == ring.allreduce
    assert base.steps == ring.steps == 0.0
    assert demand_steps(base) == demand_steps(ring)


def test_apply_schedule_ring_is_identity_object():
    d = job_demand(DLRM, 8, table_hosts=(0, 1))
    assert apply_schedule(d, "ring") is d
