"""Multi-tenant co-optimization invariants.

* Weighted fairness goldens: unit weights reproduce the PR-1 engine to
  1e-9; doubling one job's weight never slows that job; per-link rate
  allocations conserve capacity.
* JobSet: union demand equals the sum of per-job demands; placements are
  validated; remap embeds MP blocks exactly.
* Shared topology packing: per-tenant ring budgets respect the physical
  degree; idle servers stay reachable.
* JobSetController: place_arrival admission, departure, union replanning.
* Satellites: churn-proportional replan cost (edges_moved pricing),
  adaptive hysteresis (benefit-vs-cost skip + backoff), incremental
  degradation probe (bottleneck-set cache).
"""

import numpy as np
import pytest

from repro.core.alternating import co_optimize_jobset
from repro.core.demand import remap_demand, union_demand
from repro.core.netsim import HardwareSpec
from repro.core.online import (
    JobSetController,
    ReoptPolicy,
    TraceEvent,
    edge_churn,
    run_online_jobset,
)
from repro.core.simengine import (
    DeadlineFairness,
    LinkFailure,
    OCSPolicy,
    Scenario,
    SimEngine,
    SimJob,
    Task,
    WeightedFairness,
    _FlowState,
    _LinkTable,
    _max_min_rates,
)
from repro.core.workloads import (
    BERT,
    DLRM,
    MOE_16E,
    VGG16,
    JobSet,
    TenantJob,
    job_demand,
)

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)


def _flow_job(name, arrival, nbytes=1000.0, route=(0, 1)):
    return SimJob(
        name=name, arrival=arrival,
        tasks=[Task(tid=0, kind="flow", nbytes=nbytes, route=route)],
    )


def _jobset(n=12):
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, 5)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(5, 10)), weight=2.0,
                  name="bert"),
    ])


@pytest.fixture(scope="module")
def shared_plan():
    """One cheap shared-cluster plan reused by the controller tests."""
    return co_optimize_jobset(_jobset(), HW, rounds=2, mcmc_iters=20, seed=3)


# ---------------------------------------------------------------------------
# Weighted fairness goldens
# ---------------------------------------------------------------------------

GOLDEN_SCENARIOS = {
    "shared": lambda **kw: Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("a", 0.0), _flow_job("b", 5.0)],
        n=2, **kw,
    ),
    "failure_reroute": lambda **kw: Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=[_flow_job("j", 0.0, nbytes=1000.0, route=(0, 1))],
        failures=(LinkFailure(time=5.0, link=(0, 1)),),
        n=3, **kw,
    ),
    "ocs": lambda **kw: Scenario(
        links={}, n=4,
        jobs=[SimJob("o", [
            Task(tid=0, kind="flow", nbytes=1e6, route=(0, 3)),
            Task(tid=1, kind="flow", nbytes=1e6, route=(1, 2)),
        ])],
        reconfig=OCSPolicy(window=50e-3, latency=1e-3, degree=2,
                           link_bandwidth=1e6),
        **kw,
    ),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_SCENARIOS))
def test_unit_weights_reproduce_plain_engine(name):
    """weights=1 is the PR-1 engine, bit for bit (1e-9 in the assertion)."""
    make = GOLDEN_SCENARIOS[name]
    plain = SimEngine().run(make())
    weighted = SimEngine().run(make(fairness=WeightedFairness({})))
    assert weighted.makespan == pytest.approx(plain.makespan, rel=1e-9)
    for job, t in plain.job_finish.items():
        assert weighted.job_finish[job] == pytest.approx(t, rel=1e-9)
    assert weighted.delivered == plain.delivered
    assert weighted.finish_times == plain.finish_times


def test_weighted_shares_split_proportionally():
    """Two flows on one link with weights 3:1 run at 75/25 rates."""
    sc = Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("a", 0.0, nbytes=300.0),
              _flow_job("b", 0.0, nbytes=300.0)],
        n=2,
        fairness=WeightedFairness({"a": 3.0, "b": 1.0}),
    )
    r = SimEngine().run(sc)
    # a: 300 bytes at 75 B/s -> 4 s; b then finishes its remaining bytes
    # alone: 300 - 4*25 = 200 at 100 B/s -> 6 s total.
    assert r.job_makespans["a"] == pytest.approx(4.0, rel=1e-6)
    assert r.job_makespans["b"] == pytest.approx(6.0, rel=1e-6)


def test_doubling_a_weight_never_slows_that_job():
    def run(weight):
        sc = Scenario(
            links={(0, 1): 100.0},
            jobs=[_flow_job("a", 0.0, nbytes=500.0),
                  _flow_job("b", 0.0, nbytes=500.0)],
            n=2,
            fairness=WeightedFairness({"a": weight}),
        )
        return SimEngine().run(sc).job_makespans["a"]

    t1 = run(1.0)
    t2 = run(2.0)
    t4 = run(4.0)
    assert t2 <= t1 + 1e-12
    assert t4 <= t2 + 1e-12


def test_weighted_rates_conserve_link_capacity():
    """Randomized weighted progressive filling never oversubscribes a link
    and saturates every bottleneck some flow crosses."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        n_links = int(rng.integers(2, 8))
        caps = {(i, i + 1): float(rng.uniform(10, 100))
                for i in range(n_links)}
        table = _LinkTable(caps)
        flows = []
        for _ in range(int(rng.integers(1, 12))):
            a = int(rng.integers(0, n_links))
            b = int(rng.integers(a + 1, n_links + 1))
            route = tuple(range(a, b + 1))
            lids, cnts = table.indices_for(route)
            flows.append(_FlowState(
                task=Task(tid=0, kind="flow", nbytes=1.0, route=route),
                remaining=1.0, lids=lids, cnts=cnts, hops=len(route) - 1,
            ))
        weights = rng.uniform(0.1, 5.0, size=len(flows))
        rates = _max_min_rates(flows, table.cap, weights=weights)
        assert (rates >= 0).all()
        usage = np.zeros(table.cap.size)
        for f, r in zip(flows, rates):
            usage[f.lids] += r * f.cnts
        assert (usage <= table.cap * (1 + 1e-9)).all()
        # Max-min: every flow is stopped by some saturated link.
        for f, r in zip(flows, rates):
            assert r > 0
            slack = table.cap[f.lids] - usage[f.lids]
            assert slack.min() <= 1e-6 * table.cap[f.lids].max()


def test_deadline_fairness_ramps_weight():
    pol = DeadlineFairness(deadlines={"a": 10.0}, horizon=4.0, max_boost=8.0)
    assert pol.weight("a", 0.0) == 1.0  # far from deadline
    assert pol.weight("a", 8.0) == pytest.approx(4.5)  # halfway up the ramp
    assert pol.weight("a", 12.0) == 8.0  # past deadline: ceiling
    assert pol.weight("other", 0.0) == 1.0  # no deadline: base


# ---------------------------------------------------------------------------
# JobSet / union demand
# ---------------------------------------------------------------------------


def test_union_demand_equals_sum_of_per_job_demands():
    js = _jobset(n=12)
    demands = {
        "dlrm": job_demand(DLRM, 5, table_hosts=(0, 2)),
        "bert": job_demand(BERT, 5),
    }
    union = js.union(demands)
    assert union.n == 12
    assert union.sum_mp == pytest.approx(
        sum(d.sum_mp for d in demands.values()), rel=1e-12)
    assert union.sum_allreduce == pytest.approx(
        sum(d.sum_allreduce for d in demands.values()), rel=1e-12)
    # MP blocks land exactly on each tenant's placement.
    dlrm_block = union.mp[np.ix_(range(0, 5), range(0, 5))]
    np.testing.assert_allclose(dlrm_block, demands["dlrm"].mp)
    bert_block = union.mp[np.ix_(range(5, 10), range(5, 10))]
    np.testing.assert_allclose(bert_block, demands["bert"].mp)
    # Nothing lands off-placement.
    mask = np.zeros((12, 12), dtype=bool)
    mask[np.ix_(range(0, 5), range(0, 5))] = True
    mask[np.ix_(range(5, 10), range(5, 10))] = True
    assert union.mp[~mask].sum() == 0.0
    # AllReduce members relabelled into cluster space.
    assert {g.members for g in union.allreduce} == {
        (0, 1, 2, 3, 4), (5, 6, 7, 8, 9)}


def test_union_demand_merges_identical_groups():
    a = job_demand(VGG16, 4)
    u = union_demand([remap_demand(a, (0, 1, 2, 3), 4),
                      remap_demand(a, (0, 1, 2, 3), 4)], n=4)
    assert len(u.allreduce) == 1
    assert u.sum_allreduce == pytest.approx(2 * a.sum_allreduce)


def test_jobset_validation_rejects_overlap_and_duplicates():
    with pytest.raises(ValueError, match="overlaps"):
        JobSet(n=8, tenants=[
            TenantJob(spec=VGG16, servers=(0, 1, 2), name="a"),
            TenantJob(spec=BERT, servers=(2, 3), name="b"),
        ])
    with pytest.raises(ValueError, match="duplicate"):
        JobSet(n=8, tenants=[
            TenantJob(spec=VGG16, servers=(0, 1), name="a"),
            TenantJob(spec=BERT, servers=(2, 3), name="a"),
        ])
    with pytest.raises(ValueError, match="outside"):
        JobSet(n=4, tenants=[TenantJob(spec=VGG16, servers=(3, 4), name="a")])
    assert _jobset().free_servers() == {10, 11}


def test_remap_demand_validates_placement():
    d = job_demand(VGG16, 4)
    with pytest.raises(ValueError):
        remap_demand(d, (0, 1, 2), 8)  # wrong size
    with pytest.raises(ValueError):
        remap_demand(d, (0, 1, 2, 2), 8)  # repeated server
    with pytest.raises(ValueError):
        remap_demand(d, (0, 1, 2, 9), 8)  # outside cluster


# ---------------------------------------------------------------------------
# Shared topology packing
# ---------------------------------------------------------------------------


def test_shared_topology_packs_per_tenant_rings_within_degree(shared_plan):
    topo = shared_plan.topology
    assert max(topo.out_degrees()) <= HW.degree
    # Each tenant's dense AllReduce got at least one ring of its own.
    assert topo.rings.get((0, 1, 2, 3, 4))
    assert topo.rings.get((5, 6, 7, 8, 9))
    # Idle servers remain reachable (connectivity ring).
    import networkx as nx

    assert nx.is_strongly_connected(nx.DiGraph(topo.graph))


def test_cooptimize_jobset_respects_forbidden_pairs():
    plan = co_optimize_jobset(
        _jobset(), HW, rounds=1, mcmc_iters=10, seed=0,
        forbidden=((0, 1), (5, 6)),
    )
    banned = {(0, 1), (1, 0), (5, 6), (6, 5)}
    assert not banned & set(plan.topology.graph.edges())


def test_single_tenant_jobset_matches_single_job_shape():
    js = JobSet(n=8, tenants=[
        TenantJob(spec=VGG16, servers=tuple(range(8)), name="vgg16")])
    plan = co_optimize_jobset(js, HW, rounds=2, mcmc_iters=20, seed=0)
    assert set(plan.strategies) == {"vgg16"}
    assert np.isfinite(plan.iter_time) and plan.iter_time > 0
    assert plan.per_job["vgg16"] == pytest.approx(plan.iter_time)
    assert max(plan.topology.out_degrees()) <= HW.degree


# ---------------------------------------------------------------------------
# JobSetController: admission, departure, union replanning
# ---------------------------------------------------------------------------


def test_admit_places_on_free_servers_and_replans(shared_plan):
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy.reactive(replan_latency=1e-3),
        plan=shared_plan, seed=0,
    )
    free = ctrl.jobset.free_servers()
    servers, pause = ctrl.admit(VGG16, 2, name="vgg", now=0.0)
    assert set(servers) <= free and len(servers) == 2
    assert ctrl.n_replans == 1 and pause == pytest.approx(1e-3)
    assert "vgg" in ctrl.jobset.labels
    # The replanned shared topology budgets rings for the new tenant too.
    assert max(ctrl.topology.out_degrees()) <= HW.degree
    total = ctrl.depart("vgg", now=10.0)
    assert "vgg" not in ctrl.jobset.labels
    assert ctrl.n_replans == 2 and total == pytest.approx(1e-3)


def test_jobset_fail_forbids_pair_in_replanned_topology(shared_plan):
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3),
        plan=shared_plan, seed=0,
    )
    ctrl.fail((0, 2), now=0.0)
    assert ctrl.n_replans == 1
    dead = {(0, 2), (2, 0)}
    assert not dead & set(ctrl.topology.graph.edges())
    assert not dead & set(ctrl.links())


def test_run_online_jobset_reactive_beats_static_on_churn(shared_plan):
    trace = (
        TraceEvent(iteration=1, kind="arrive", job=MOE_16E, k=2, name="moe"),
        TraceEvent(iteration=2, kind="fail", link=(0, 3)),
        TraceEvent(iteration=3, kind="depart", name="bert"),
    )
    static = run_online_jobset(
        _jobset(), HW, policy=ReoptPolicy.never(), trace=trace,
        n_iters=5, seed=0, plan=shared_plan)
    reactive = run_online_jobset(
        _jobset(), HW, policy=ReoptPolicy.reactive(replan_latency=1e-3),
        trace=trace, n_iters=5, seed=0, plan=shared_plan)
    assert static.n_replans == 0
    assert reactive.n_replans >= 1
    assert len(static.iter_times) == len(reactive.iter_times) == 5
    assert reactive.total_time < static.total_time
    assert set(static.job_times) == {"dlrm", "bert", "moe"}


def test_failure_after_last_departure_keeps_incumbent(shared_plan):
    """Regression: a reactive controller whose jobset emptied must not try
    to optimize an empty set when a fiber later dies."""
    ctrl = JobSetController(
        _jobset(), hw=HW, policy=ReoptPolicy.reactive(replan_latency=1e-3),
        plan=shared_plan, seed=0,
    )
    ctrl.depart("dlrm", now=0.0)
    ctrl.depart("bert", now=1.0)
    assert not ctrl.jobset.tenants
    pause = ctrl.fail((0, 1), now=2.0)  # must not raise
    assert pause == 0.0
    assert (0, 1) in ctrl.dead


def test_admit_rejects_zero_servers(shared_plan):
    ctrl = JobSetController(
        _jobset(), hw=HW, policy=ReoptPolicy.never(), plan=shared_plan,
    )
    with pytest.raises(ValueError, match="k >= 1"):
        ctrl.admit(VGG16, 0, name="vgg")


def test_per_node_pack_respects_degree_one():
    """Regression: at degree=1 the reserved connectivity ring must be
    dropped, not allowed to overflow the single port."""
    from repro.core.topology_finder import topology_finder

    dem = remap_demand(job_demand(VGG16, 3), (0, 1, 2), 6)
    topo = topology_finder(dem, 1, pack="per_node")
    assert max(topo.out_degrees()) <= 1


def test_midrun_failure_recorded_even_when_jobset_empties():
    """Regression: a frac>0 failure queued in the same iteration as the last
    tenant's departure must still land on the fabric."""
    js = JobSet(n=6, tenants=[
        TenantJob(spec=VGG16, servers=(0, 1, 2), name="vgg")])
    plan = co_optimize_jobset(js, HW, rounds=1, mcmc_iters=8, seed=0)
    trace = (
        TraceEvent(iteration=1, kind="depart", name="vgg"),
        TraceEvent(iteration=1, kind="fail", link=(0, 1), frac=0.5),
    )
    r = run_online_jobset(js, HW, policy=ReoptPolicy.never(), trace=trace,
                          n_iters=3, seed=0, plan=plan)
    assert r.n_failures == 1
    assert r.iter_times[1] == 0.0  # empty iteration is instantaneous


def test_overhang_uses_last_applied_pause(shared_plan):
    """Regression: the pause tail charged past the last task finish must be
    the last *applied* PlanUpdate's pause, not reconstructed from a log that
    may end in a suppressed record."""
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, fiber_move_latency=1e-4),
        plan=shared_plan, seed=0,
    )
    ctrl.fail((0, 2), now=0.0)
    applied = [r for r in ctrl.log if r.replanned][-1]
    assert ctrl.last_pause == pytest.approx(1e-4 * applied.edges_moved)
    # A suppressed trigger appends a log record but leaves last_pause.
    ctrl.policy = ReoptPolicy(on_failure=True, fiber_move_latency=1e-4,
                              min_interval=100.0)
    ctrl.fail((1, 3), now=0.5)
    assert not ctrl.log[-1].replanned
    assert ctrl.last_pause == pytest.approx(1e-4 * applied.edges_moved)


# ---------------------------------------------------------------------------
# Satellite: churn-proportional replan cost
# ---------------------------------------------------------------------------


def test_edge_churn_counts_multiset_difference(shared_plan):
    topo = shared_plan.topology
    assert edge_churn(topo, topo) == 0
    from repro.core.topology_finder import remove_pair

    pair = next(iter(topo.graph.edges()))[:2]
    degraded = remove_pair(topo, (min(pair), max(pair)))
    # Degrading removes edges, so old -> degraded moves nothing new in...
    assert edge_churn(topo, degraded) == 0
    # ...but restoring them means re-patching exactly the removed fibers.
    assert edge_churn(degraded, topo) == topo.graph.number_of_edges() - \
        degraded.graph.number_of_edges()


def test_churn_proportional_pause_prices_per_moved_fiber(shared_plan):
    per_fiber = 1e-4
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, fiber_move_latency=per_fiber),
        plan=shared_plan, seed=0,
    )
    pause = ctrl.fail((0, 2), now=0.0)
    assert ctrl.n_replans == 1
    rec = [r for r in ctrl.log if r.replanned][-1]
    assert rec.edges_moved == ctrl.total_edges_moved
    assert pause == pytest.approx(per_fiber * rec.edges_moved)
    if rec.est_after <= rec.est_before:  # adopted a new plan
        assert rec.edges_moved >= 0
    # Fiber accounting surfaces in ScenarioResult via PlanUpdate.
    from repro.core.simengine import PlanUpdate

    eng = SimEngine(HW)

    class Once:
        fired = False

    from repro.core.simengine import ScenarioObserver

    class Swap(ScenarioObserver):
        def on_failure(self, view, link):
            if Once.fired:
                return None
            Once.fired = True
            return PlanUpdate(links=dict(view.links), pause=0.0,
                              edges_moved=7)

    r = eng.run(Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=[_flow_job("j", 0.0)],
        failures=(LinkFailure(time=1.0, link=(0, 2)),),
        n=3,
    ), observer=Swap())
    assert r.edges_moved == 7


def test_fiber_move_cost_prices_usd_per_moved_fiber():
    from repro.core.costmodel import (
        EXPECTED_FIBER,
        FIBER_MOVE_WEAR,
        PATCH_PANEL_PORT,
        fiber_move_cost,
    )

    assert fiber_move_cost(0) == 0.0
    one = fiber_move_cost(1)
    assert one == pytest.approx(
        FIBER_MOVE_WEAR * (2 * PATCH_PANEL_PORT + EXPECTED_FIBER))
    assert fiber_move_cost(10) == pytest.approx(10 * one)


def test_flat_pause_still_default(shared_plan):
    """fiber_move_latency=None keeps the pre-churn flat replan_latency."""
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=2e-3),
        plan=shared_plan, seed=0,
    )
    pause = ctrl.fail((0, 2), now=0.0)
    assert pause == pytest.approx(2e-3)


# ---------------------------------------------------------------------------
# Satellite: adaptive hysteresis (benefit-vs-cost gate + backoff)
# ---------------------------------------------------------------------------


def test_adaptive_gate_skips_unprofitable_replans(shared_plan):
    # An enormous per-fiber price makes every replan unprofitable; the gate
    # must skip (no pause, no plan swap) and back off the interval.
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, fiber_move_latency=1e6,
                           adaptive=True),
        plan=shared_plan, seed=0,
    )
    before = ctrl.topology
    pause = ctrl.fail((0, 2), now=0.0)
    assert pause == 0.0
    assert ctrl.n_replans == 0
    skipped = [r for r in ctrl.log if not r.replanned]
    assert skipped and np.isfinite(skipped[-1].est_after)
    assert ctrl._adaptive_interval > 0  # backed off
    # The incumbent (degraded in place) is still the live plan.
    assert ctrl.topology.graph.number_of_edges() <= \
        before.graph.number_of_edges()


def test_adaptive_gate_adopts_profitable_replans(shared_plan):
    # Free fiber moves: any probed win is profitable, gate must not block.
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, fiber_move_latency=0.0,
                           adaptive=True),
        plan=shared_plan, seed=0,
    )
    ctrl.fail((0, 2), now=0.0)
    assert ctrl.n_replans == 1
    assert ctrl._adaptive_interval == ctrl.policy.min_interval  # reset


def test_adaptive_backoff_suppresses_next_trigger(shared_plan):
    ctrl = JobSetController(
        _jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, fiber_move_latency=1e6,
                           adaptive=True),
        plan=shared_plan, seed=0,
    )
    ctrl.fail((0, 2), now=0.0)  # skipped, backs off
    gate = ctrl._adaptive_interval
    assert gate > 0
    n_log = len(ctrl.log)
    ctrl.fail((1, 3), now=gate / 2)  # inside the backoff window
    assert ctrl.n_replans == 0
    assert len(ctrl.log) == n_log + 1 and not ctrl.log[-1].replanned


# ---------------------------------------------------------------------------
# Satellite: incremental degradation probe
# ---------------------------------------------------------------------------


def test_probe_cache_reused_until_hot_link_touched(shared_plan):
    ctrl = JobSetController(
        _jobset(), hw=HW, policy=ReoptPolicy.never(), plan=shared_plan,
    )
    est = ctrl.estimated_iter_time()
    probes = ctrl.n_full_probes
    assert probes == 1
    assert ctrl.estimated_iter_time() == est  # cached, no new sim
    assert ctrl.n_full_probes == probes
    # A pair carrying no planned traffic (two idle servers) keeps the cache.
    ctrl.fail((10, 11), now=0.0)
    assert ctrl.estimated_iter_time() == est
    assert ctrl.n_full_probes == probes
    # A pair inside the hot set forces a full re-probe.
    hot = next(iter(ctrl._probe_cache[1]))
    ctrl.fail(hot, now=1.0)
    est2 = ctrl.estimated_iter_time()
    assert ctrl.n_full_probes == probes + 1
    assert est2 >= est


def test_probe_cache_invalidated_by_admission(shared_plan):
    ctrl = JobSetController(
        _jobset(), hw=HW, policy=ReoptPolicy.never(), plan=shared_plan,
    )
    ctrl.estimated_iter_time()
    probes = ctrl.n_full_probes
    ctrl.admit(VGG16, 2, name="vgg", now=0.0)  # never-policy: no replan
    ctrl.estimated_iter_time()
    assert ctrl.n_full_probes == probes + 1  # demand changed -> fresh probe
