import networkx as nx
import numpy as np
import pytest

from repro.core.demand import TrafficDemand, data_parallel_demand
from repro.core.topology_finder import (
    effective_diameter,
    repair_topology,
    topology_finder,
)
from repro.core.workloads import DLRM, job_demand


def test_pure_dp_allocates_all_degree_to_rings():
    dem = data_parallel_demand(16, 1e9)
    topo = topology_finder(dem, degree=4)
    assert topo.d_allreduce == 4
    assert topo.d_mp == 0
    # every node has out-degree exactly 4 (4 rings)
    assert set(topo.out_degrees()) == {4}
    strides = topo.ring_strides(tuple(range(16)))
    assert len(strides) == 4 and len(set(strides)) == 4


def test_degree_split_proportional():
    dem = TrafficDemand(n=8)
    dem.allreduce.append(
        __import__("repro.core.demand", fromlist=["AllReduceGroup"]).AllReduceGroup(
            members=tuple(range(8)), nbytes=1.0
        )
    )
    dem.add_all_to_all(range(8), 10.0)  # MP dominates
    topo = topology_finder(dem, degree=4)
    assert topo.d_allreduce >= 1  # line 2: at least one ring
    assert topo.d_mp >= 2  # most degree to MP


def test_pure_mp_still_connected():
    dem = TrafficDemand(n=8)
    dem.add_all_to_all(range(8), 5.0)
    topo = topology_finder(dem, degree=3)
    assert topo.d_allreduce == 1
    assert nx.is_strongly_connected(nx.DiGraph(topo.graph))


def test_dlrm_topology_serves_every_mp_pair():
    dem = job_demand(DLRM, 16, table_hosts=[0, 3, 8, 13])
    topo = topology_finder(dem, degree=4)
    srcs, dsts = np.nonzero(dem.mp)
    for s, t in zip(srcs.tolist(), dsts.tolist()):
        routes = topo.routing.get(int(s), int(t))
        assert routes, f"no route {s}->{t}"
        for r in routes:
            for a, b in zip(r.path[:-1], r.path[1:]):
                assert topo.graph.has_edge(a, b), f"route uses missing edge {a}->{b}"


def test_effective_diameter_bounded():
    dem = data_parallel_demand(64, 1e9)
    topo = topology_finder(dem, degree=4)
    d = effective_diameter(topo)
    assert 0 < d <= 2 * 4 * 64 ** (1 / 4)


def test_repair_swaps_mp_link_for_broken_ring():
    # Craft an MP-heavy demand so the degree split leaves MP links to donate.
    dem = TrafficDemand(n=16)
    from repro.core.demand import AllReduceGroup

    dem.allreduce.append(AllReduceGroup(members=tuple(range(16)), nbytes=1e6))
    dem.add_all_to_all(range(16), 1e6)
    topo = topology_finder(dem, degree=6)
    assert topo.d_mp > 0
    # break an allreduce ring edge
    ring = next(iter(topo.rings.values()))[0]
    u, v = ring.edges()[0]
    repaired = repair_topology(topo, (u, v))
    # repaired edge present again (donated from MP budget, §7)
    assert repaired.graph.has_edge(u, v)
    # network still strongly connected
    assert nx.is_strongly_connected(nx.DiGraph(repaired.graph))
    # no route uses a removed link
    for (s, t), routes in repaired.routing.routes.items():
        for r in routes:
            for a, b in zip(r.path[:-1], r.path[1:]):
                assert repaired.graph.has_edge(a, b)


def test_repair_mp_only_link_reroutes():
    dem = TrafficDemand(n=8)
    dem.add_all_to_all(range(8), 5.0)
    topo = topology_finder(dem, degree=4)
    mp_edges = [
        (a, b) for a, b, d in topo.graph.edges(data=True) if d.get("kind") == "mp"
    ]
    if not mp_edges:
        pytest.skip("no MP edges allocated")
    u, v = mp_edges[0]
    repaired = repair_topology(topo, (u, v))
    assert nx.is_strongly_connected(nx.DiGraph(repaired.graph))
