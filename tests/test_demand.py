"""Traffic-demand extraction, validated against the paper's §2.1 DLRM
example: 4 embedding tables (dim 512, 1e7 rows, fp32) on 16 servers.

* pure DP: "44 GB of AllReduce transfers" (ring moves 2(k-1)/k * M per node,
  M = 22 GB model) -> max per-node transfer ~44 GB.
* hybrid: max transfer drops to ~4 GB; each MP transfer is 32 MB
  (8192 batch x 512 cols x 8 B / 16 servers — paper App. D arithmetic).
"""

import numpy as np
import pytest

from repro.core.demand import (
    TrafficDemand,
    data_parallel_demand,
    dlrm_demand,
    moe_demand,
)


def test_paper_dlrm_pure_dp_44gb():
    model_bytes = 4 * 1e7 * 512 * 4  # 4 tables, fp32 ~ 82 GB? paper says 22GB
    # Paper's 22 GB total model => per-table bytes:
    model_bytes = 22e9
    dem = data_parallel_demand(16, model_bytes)
    ring_bytes = 2 * 15 / 16 * model_bytes
    assert ring_bytes == pytest.approx(44e9, rel=0.07)  # "44 GB AllReduce"


def test_paper_dlrm_hybrid_mp_32mb():
    # 16 servers x 8192 samples x 512 cols x 8 B / 16 servers = 32 MB / server
    act = 8192 * 512 * 8
    dem = dlrm_demand(16, dense_param_bytes=0.0, table_hosts=[0],
                      activation_bytes_per_host=act)
    assert dem.mp[0, 5] == pytest.approx(32e6, rel=0.05)
    # incast: gradient comes back
    assert dem.mp[5, 0] == pytest.approx(32e6, rel=0.05)


def test_dlrm_demand_structure():
    dem = dlrm_demand(8, 1e6, table_hosts=[0, 3], activation_bytes_per_host=100.0)
    assert len(dem.allreduce) == 1
    assert dem.allreduce[0].members == tuple(range(8))
    # broadcast from hosts to everyone else, incast back
    assert dem.mp[0, 1] == 100.0 and dem.mp[1, 0] == 100.0
    assert dem.mp[3, 5] == 100.0 and dem.mp[5, 3] == 100.0
    assert dem.mp[1, 2] == 0.0
    assert dem.mp[0, 0] == 0.0  # no self traffic


def test_moe_demand_groups():
    dem = moe_demand(
        8, 1e6, ep_groups=[range(0, 4), range(4, 8)], a2a_bytes_per_pair=10.0,
        expert_param_bytes=55.0,
    )
    # all-to-all only within groups
    assert dem.mp[0, 3] == 10.0
    assert dem.mp[0, 4] == 0.0
    # expert allreduce per group + global dense allreduce
    assert len(dem.allreduce) == 3
    sizes = sorted(g.nbytes for g in dem.allreduce)
    assert sizes == [55.0, 55.0, 1e6]


def test_sum_properties():
    dem = TrafficDemand(n=4)
    dem.add_broadcast(0, range(4), 5.0)
    dem.add_incast(range(4), 0, 7.0)
    assert dem.sum_mp == pytest.approx(3 * 5.0 + 3 * 7.0)
