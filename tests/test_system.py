"""End-to-end behaviour tests for the TopoOpt system.

The full paper pipeline on a small cluster: alternating co-optimization ->
topology -> JAX mesh ordering + multi-ring collectives -> a real training
run whose gradient sync rides the TotientPerms rings (subprocess, 8 devices).
"""

import numpy as np
import pytest

from _subproc import run_with_devices
from repro.core import (
    HardwareSpec,
    alternating_optimize,
    topology_finder,
)
from repro.core.simengine import (
    fat_tree_comm_time,
    ideal_switch_comm_time,
    topoopt_comm_time,
)
from repro.core.workloads import DLRM, job_demand

# Multi-minute subprocess tests (fresh jax init per case); quick loop:
# python -m pytest -m "not slow"
pytestmark = pytest.mark.slow


def test_cooptimization_beats_similar_cost_fat_tree():
    """Headline claim (Fig. 11d): TopoOpt's co-optimized plan beats the
    similar-cost Fat-tree (B' < B) on DLRM."""
    hw = HardwareSpec(link_bandwidth=12.5e9, degree=4)
    res = alternating_optimize(DLRM, n=16, hw=hw, rounds=3, mcmc_iters=100, seed=0)
    t_topo = topoopt_comm_time(res.topology, res.demand, hw)["comm_time"]
    t_ft = fat_tree_comm_time(res.demand, hw, bandwidth_fraction=0.35)
    assert t_ft > 1.5 * t_topo, (t_ft, t_topo)
    # and stays within ~2.5x of the ideal switch (paper: 1.3x for DLRM)
    t_ideal = ideal_switch_comm_time(res.demand, hw)
    assert t_topo < 2.5 * t_ideal


def test_end_to_end_train_on_topoopt_rings():
    """Train a small LM with the §6 trainer: gradient sync through
    multi-ring TotientPerms AllReduce on a TopoOpt-ordered mesh."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from repro.configs.base import get_config, ShapeSpec
from repro.core import topology_finder
from repro.core.demand import data_parallel_demand
from repro.core.device_order import topoopt_mesh
from repro.data.pipeline import DataSpec, batch_for_step
from repro.models import lm
from repro.optim import adamw, constant
from repro.train.steps import make_shardmap_dp_train_step

cfg = get_config("granite-8b").smoke()
shape = ShapeSpec("tiny", seq_len=32, global_batch=8, kind="train")

# 1. TopoOpt plan for an 8-node DP job, degree 3.
topo = topology_finder(data_parallel_demand(8, 1e9), degree=3)
strides = tuple(topo.ring_strides(tuple(range(8))))
assert len(strides) == 3

# 2. Mesh ordered for the primary ring; collectives ride all rings.
mesh = topoopt_mesh((8,), ("data",), allreduce_axis="data", stride=strides[0])
opt = adamw(constant(3e-3))
step = make_shardmap_dp_train_step(cfg, opt, mesh, axis_name="data",
                                   ring_strides=strides)

params = lm.init(jax.random.PRNGKey(0), cfg)
state = opt.init(params)
losses = []
spec = DataSpec(cfg=cfg, shape=shape, seed=0)
for i in range(15):
    batch = batch_for_step(spec, i)
    params, state, loss, _ = step(params, state, batch, jnp.int32(i), 0)
    losses.append(float(loss))
assert np.isfinite(losses).all()
assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
print("PASS", losses[0], losses[-1])
""",
        n_devices=8,
        timeout=900,
    )
    assert "PASS" in out


def test_dryrun_cell_smoke():
    """dryrun_cell compiles a smoke config train + decode cell on a (2,4)
    mesh and produces roofline terms."""
    out = run_with_devices(
        """
import jax, json
from repro.configs.base import get_config, ShapeSpec
from repro.parallel.sharding import ShardingPlan
from repro.launch.dryrun import dryrun_cell
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen3-moe-30b-a3b").smoke()
for shape in (ShapeSpec("t", 64, 8, "train"), ShapeSpec("d", 64, 8, "decode")):
    rec = dryrun_cell(cfg, shape, mesh, ShardingPlan())
    r = rec["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert rec["collectives"]["total_bytes"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
print("PASS")
""",
        n_devices=8,
        timeout=900,
    )
    assert "PASS" in out


def test_schedule_aware_gradient_sync_smoke():
    """make_shardmap_dp_train_step(schedule=...) trains with the searched
    collective kernel (halving-doubling / multi-tree) and reaches the same
    losses as the ring path (all three are psum-equivalent)."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from repro.configs.base import get_config, ShapeSpec
from repro.core.select_perms import schedule_strides
from repro.data.pipeline import DataSpec, batch_for_step
from repro.models import lm
from repro.optim import adamw, constant
from repro.train.steps import make_shardmap_dp_train_step

cfg = get_config("granite-8b").smoke()
shape = ShapeSpec("tiny", seq_len=32, global_batch=8, kind="train")
mesh = jax.make_mesh((8,), ("data",))
spec = DataSpec(cfg=cfg, shape=shape, seed=0)

ref = None
for sched in ("ring", "recursive_hd", "multi_tree"):
    strides = schedule_strides(8, sched, 2) or (1,)
    opt = adamw(constant(3e-3))
    step = make_shardmap_dp_train_step(cfg, opt, mesh, axis_name="data",
                                       ring_strides=strides, schedule=sched)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    losses = []
    for i in range(5):
        batch = batch_for_step(spec, i)
        params, state, loss, _ = step(params, state, batch, jnp.int32(i), 0)
        losses.append(float(loss))
    assert np.isfinite(losses).all(), (sched, losses)
    if ref is None:
        ref = losses
    else:
        assert np.allclose(losses, ref, rtol=1e-3, atol=1e-4), (sched, losses, ref)
print("PASS", ref[0], ref[-1])
""",
        n_devices=8,
    )
    assert "PASS" in out
