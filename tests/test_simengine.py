"""SimEngine: golden regression vs the legacy FlowSim, scenario semantics
(multi-job fairness, failures, stragglers, OCS epochs), and conservation."""

import heapq
from dataclasses import dataclass

import numpy as np
import pytest

from repro.core.netsim import HardwareSpec
from repro.core.simengine import (
    PROPAGATION_DELAY,
    FlowSimVec,
    LinkFailure,
    OCSPolicy,
    Scenario,
    SimEngine,
    SimJob,
    Task,
    iteration_tasks,
    links_from_topology,
)
from repro.core.topology_finder import topology_finder
from repro.core.workloads import BERT, DLRM, VGG16, job_demand

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)


# ---------------------------------------------------------------------------
# Frozen copy of the seed (pre-vectorization) FlowSim, kept verbatim as the
# behavioural reference for the golden tests.
# ---------------------------------------------------------------------------


@dataclass
class _LegacyFlowState:
    task: Task
    remaining: float
    rate: float = 0.0


class _LegacyFlowSim:
    def __init__(self, link_bandwidth):
        self.link_bw = dict(link_bandwidth)

    def _max_min_rates(self, flows):
        remaining_bw = dict(self.link_bw)
        unfrozen = [f for f in flows if f.task.route]
        for f in flows:
            f.rate = 0.0
        while unfrozen:
            link_users = {}
            for f in unfrozen:
                for link in zip(f.task.route[:-1], f.task.route[1:]):
                    link_users.setdefault(link, []).append(f)
            if not link_users:
                break
            bottleneck, users = min(
                link_users.items(),
                key=lambda kv: remaining_bw.get(kv[0], float("inf")) / len(kv[1]),
            )
            fair = remaining_bw.get(bottleneck, float("inf")) / len(users)
            for f in users:
                f.rate += fair
                for link in zip(f.task.route[:-1], f.task.route[1:]):
                    remaining_bw[link] = remaining_bw.get(link, float("inf")) - fair
            frozen_ids = {id(f) for f in users}
            unfrozen = [f for f in unfrozen if id(f) not in frozen_ids]

    def run(self, tasks, start_time=0.0):
        pending_deps = {t.tid: set(t.deps) for t in tasks}
        ready = [t for t in tasks if not t.deps]
        finish_times = {}
        active_flows = []
        compute_heap = []
        now = start_time

        def release(tid, t_done):
            finish_times[tid] = t_done
            out = []
            for t in tasks:
                if tid in pending_deps[t.tid]:
                    pending_deps[t.tid].discard(tid)
                    if not pending_deps[t.tid] and t.tid not in finish_times:
                        out.append(t)
            return out

        def admit(t):
            if t.kind == "compute":
                heapq.heappush(compute_heap, (now + t.duration, t.tid))
            else:
                active_flows.append(
                    _LegacyFlowState(task=t, remaining=max(t.nbytes, 1e-9))
                )

        for t in ready:
            admit(t)

        while active_flows or compute_heap:
            self._max_min_rates(active_flows)
            t_flow = float("inf")
            next_flow = None
            for f in active_flows:
                if f.rate > 0:
                    eta = now + f.remaining / f.rate + PROPAGATION_DELAY * (
                        len(f.task.route) - 1
                    )
                else:
                    eta = float("inf")
                if eta < t_flow:
                    t_flow, next_flow = eta, f
            t_comp = compute_heap[0][0] if compute_heap else float("inf")

            if t_comp == float("inf") and t_flow == float("inf"):
                for f in active_flows:
                    for nt in release(f.task.tid, now):
                        admit(nt)
                active_flows.clear()
                continue

            t_next = min(t_flow, t_comp)
            dt = t_next - now
            for f in active_flows:
                f.remaining = max(0.0, f.remaining - f.rate * dt)
            now = t_next

            newly = []
            if t_comp <= t_flow and compute_heap:
                _, tid = heapq.heappop(compute_heap)
                newly.extend(release(tid, now))
            else:
                active_flows.remove(next_flow)
                newly.extend(release(next_flow.task.tid, now))
            for t in newly:
                admit(t)

        class R:
            pass

        r = R()
        r.makespan = now - start_time
        r.finish_times = finish_times
        return r


def _dedicated_case(job, table_stride=None):
    th = range(0, 16, table_stride) if table_stride else None
    dem = job_demand(job, 16, table_hosts=th)
    topo = topology_finder(dem, 4)
    link_bw = links_from_topology(topo, HW)
    return link_bw, iteration_tasks(topo, dem)


# ---------------------------------------------------------------------------
# (a) Golden regression: legacy vs vectorized to 1e-9, plus pinned values
# computed from the seed implementation before the rewrite.
# ---------------------------------------------------------------------------

GOLDEN_MAKESPANS = {
    # Pinned from the seed FlowSim on 16-node d=4 dedicated clusters.
    "dlrm": 0.046197050349206334,
    "bert": 0.022864000000000065,
}


@pytest.mark.parametrize(
    "name,job,stride", [("dlrm", DLRM, 4), ("bert", BERT, None)]
)
def test_golden_dedicated_makespans(name, job, stride):
    link_bw, tasks = _dedicated_case(job, stride)
    res = FlowSimVec(link_bw).run(tasks)
    assert res.makespan == pytest.approx(GOLDEN_MAKESPANS[name], rel=1e-12, abs=0)


@pytest.mark.parametrize(
    "job,stride", [(DLRM, 4), (BERT, None), (VGG16, None)]
)
def test_vectorized_matches_legacy_on_dedicated(job, stride):
    link_bw, tasks = _dedicated_case(job, stride)
    new = FlowSimVec(link_bw).run(tasks)
    old = _LegacyFlowSim(link_bw).run(tasks)
    assert new.makespan == pytest.approx(old.makespan, rel=1e-9)
    assert new.finish_times.keys() == old.finish_times.keys()
    for tid, t in old.finish_times.items():
        assert new.finish_times[tid] == pytest.approx(t, rel=1e-9, abs=1e-12)


def test_vectorized_matches_legacy_on_task_graph():
    """Dependencies + unknown-capacity links + compute interleaving."""
    link_bw = {(0, 1): 100.0, (1, 2): 100.0, (0, 2): 50.0}
    tasks = [
        Task(tid=0, kind="flow", nbytes=1000.0, route=(0, 1, 2)),
        Task(tid=1, kind="flow", nbytes=500.0, route=(0, 2)),
        Task(tid=2, kind="compute", duration=3.0, deps=(0,)),
        Task(tid=3, kind="flow", nbytes=800.0, route=(2, 1), deps=(2,)),
    ]
    new = FlowSimVec(link_bw).run(tasks)
    old = _LegacyFlowSim(link_bw).run(tasks)
    assert new.makespan == pytest.approx(old.makespan, rel=1e-9)
    assert new.makespan == pytest.approx(13.000003999999999, rel=1e-12)


def test_vectorized_matches_legacy_randomized():
    rng = np.random.default_rng(7)
    n = 12
    link_bw = {}
    for i in range(n):
        for j in range(n):
            if i != j and rng.random() < 0.3:
                link_bw[(i, j)] = float(rng.integers(50, 200))
    nodes = sorted({a for a, _ in link_bw} | {b for _, b in link_bw})
    tasks = []
    for tid in range(40):
        if rng.random() < 0.25:
            tasks.append(
                Task(tid=tid, kind="compute", duration=float(rng.random() * 5))
            )
        else:
            a, b = rng.choice(nodes, size=2, replace=False)
            deps = ()
            if tid > 5 and rng.random() < 0.3:
                deps = (int(rng.integers(0, tid)),)
            tasks.append(
                Task(
                    tid=tid, kind="flow",
                    nbytes=float(rng.integers(100, 5000)),
                    route=(int(a), int(b)), deps=deps,
                )
            )
    new = FlowSimVec(link_bw).run(tasks)
    old = _LegacyFlowSim(link_bw).run(tasks)
    assert new.makespan == pytest.approx(old.makespan, rel=1e-9)


# ---------------------------------------------------------------------------
# (b) Scenario semantics
# ---------------------------------------------------------------------------


def _flow_job(name, arrival, nbytes=1000.0, route=(0, 1)):
    return SimJob(
        name=name, arrival=arrival,
        tasks=[Task(tid=0, kind="flow", nbytes=nbytes, route=route)],
    )


def test_multi_job_fair_sharing_with_staggered_arrivals():
    eng = SimEngine()
    sc = Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("a", 0.0), _flow_job("b", 5.0)],
        n=2,
    )
    r = eng.run(sc)
    # a: 5 s alone (500 B) + 10 s at half rate -> 15 s.
    # b: 10 s at half rate (500 B) + 5 s alone -> finishes at t=20.
    assert r.job_makespans["a"] == pytest.approx(15.0, rel=1e-5)
    assert r.job_finish["b"] == pytest.approx(20.0, rel=1e-5)
    assert r.makespan == pytest.approx(20.0, rel=1e-5)


def test_job_alone_is_faster_than_shared():
    eng = SimEngine()
    alone = eng.run(
        Scenario(links={(0, 1): 100.0}, jobs=[_flow_job("a", 0.0)], n=2)
    )
    shared = eng.run(
        Scenario(
            links={(0, 1): 100.0},
            jobs=[_flow_job("a", 0.0), _flow_job("b", 0.0)],
            n=2,
        )
    )
    assert shared.job_makespans["a"] > alone.job_makespans["a"]


def test_link_failure_reroutes_over_surviving_path():
    eng = SimEngine()
    sc = Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=[_flow_job("j", 0.0, nbytes=1000.0, route=(0, 1))],
        failures=(LinkFailure(time=5.0, link=(0, 1)),),
        n=3,
    )
    r = eng.run(sc)
    # 500 B delivered before the failure; the rest rides 0->2->1.
    assert not r.stalled
    assert r.makespan == pytest.approx(10.0, rel=1e-4)
    assert r.delivered["j"] == pytest.approx(1000.0)


def test_link_failure_without_alternative_stalls():
    eng = SimEngine()
    sc = Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("j", 0.0)],
        failures=(LinkFailure(time=5.0, link=(0, 1)),),
        n=2,
    )
    r = eng.run(sc)
    assert ("j", 0) in r.stalled


def test_straggler_skews_compute():
    eng = SimEngine()
    job = SimJob(
        name="s",
        tasks=[
            Task(tid=0, kind="compute", duration=2.0, node=0),
            Task(tid=1, kind="compute", duration=2.0, node=1),
        ],
    )
    r = eng.run(Scenario(links={}, jobs=[job], stragglers={1: 3.0}, n=2))
    assert r.finish_times[("s", 0)] == pytest.approx(2.0)
    assert r.finish_times[("s", 1)] == pytest.approx(6.0)
    assert r.makespan == pytest.approx(6.0)


def test_ocs_reconfig_after_compute_does_not_rewind_time():
    """A rebuild boundary that elapsed during a compute-only stretch fires
    immediately on flow admission instead of rewinding the clock."""
    eng = SimEngine()
    job = SimJob("c", [
        Task(tid=0, kind="compute", duration=1.0, node=0),
        Task(tid=1, kind="flow", nbytes=1e6, route=(0, 1), deps=(0,)),
    ])
    r = eng.run(Scenario(
        links={}, n=2, jobs=[job],
        reconfig=OCSPolicy(window=50e-3, latency=1e-3, degree=1,
                           link_bandwidth=1e6),
    ))
    assert r.finish_times[("c", 0)] == pytest.approx(1.0)
    # The flow starts only after the compute dependency: makespan covers
    # compute + ~1 s transfer + reconfiguration pauses, never less.
    assert r.finish_times[("c", 1)] > 1.0
    assert r.makespan >= 2.0
    assert r.delivered["c"] == pytest.approx(1e6)


def test_tree_times_compute_only_jobs():
    """No flows at all (pure-compute mix) must not crash the vectorized
    tree sweep."""
    from repro.core.demand import TrafficDemand

    eng = SimEngine(HW)
    out = eng.tree_times([VGG16], 32, 16, lambda job: TrafficDemand(n=16))
    assert out.shape == (1,)
    assert out[0] > 0  # compute time only


def test_ocs_reconfig_epochs_charge_latency():
    def make(latency):
        return Scenario(
            links={}, n=4,
            jobs=[SimJob("o", [
                Task(tid=0, kind="flow", nbytes=1e6, route=(0, 3)),
                Task(tid=1, kind="flow", nbytes=1e6, route=(1, 2)),
            ])],
            reconfig=OCSPolicy(
                window=50e-3, latency=latency, degree=2, link_bandwidth=1e6
            ),
        )

    eng = SimEngine()
    fast = eng.run(make(1e-4))
    slow = eng.run(make(10e-3))
    assert fast.n_reconfigs >= 1 and slow.n_reconfigs >= 1
    # Each epoch pauses traffic for the reconfiguration latency.
    assert slow.makespan > fast.makespan
    assert fast.delivered["o"] == pytest.approx(2e6)
    assert slow.delivered["o"] == pytest.approx(2e6)
    # Transfer itself is ~0.5 s (two parallel circuits per elephant pair);
    # pauses add ~n_reconfigs * latency on top.
    assert slow.makespan == pytest.approx(
        fast.makespan + (slow.n_reconfigs * 10e-3 - fast.n_reconfigs * 1e-4),
        rel=0.2,
    )


# ---------------------------------------------------------------------------
# (c) Conservation: delivered bytes == offered demand
# ---------------------------------------------------------------------------


def test_conservation_dedicated_iteration():
    dem = job_demand(DLRM, 16, table_hosts=range(0, 16, 4))
    topo = topology_finder(dem, 4)
    tasks = iteration_tasks(topo, dem)
    eng = SimEngine()
    r = eng.run(
        Scenario(
            links=links_from_topology(topo, HW),
            jobs=[SimJob("dlrm", tasks)],
            n=16,
        )
    )
    offered = sum(t.nbytes for t in tasks if t.kind == "flow")
    assert not r.stalled
    assert r.delivered["dlrm"] == pytest.approx(offered, rel=1e-12)
    # Every flow of the job finished.
    assert len(r.finish_times) == len(tasks)


def test_conservation_under_failure_and_sharing():
    eng = SimEngine()
    jobs = [
        _flow_job("a", 0.0, nbytes=2000.0, route=(0, 1)),
        _flow_job("b", 3.0, nbytes=1000.0, route=(0, 1)),
    ]
    sc = Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=jobs,
        failures=(LinkFailure(time=5.0, link=(0, 1)),),
        n=3,
    )
    r = eng.run(sc)
    assert not r.stalled
    assert r.delivered["a"] + r.delivered["b"] == pytest.approx(3000.0)


def test_scenario_requires_unique_job_names():
    eng = SimEngine()
    with pytest.raises(AssertionError):
        eng.run(
            Scenario(
                links={(0, 1): 1.0},
                jobs=[_flow_job("x", 0.0), _flow_job("x", 1.0)],
                n=2,
            )
        )


# ---------------------------------------------------------------------------
# Unknown (inf-capacity) links: explicit masking in the filling loop
# ---------------------------------------------------------------------------


def _mk_flows(routes, table):
    from repro.core.simengine import _FlowState

    flows = []
    for i, route in enumerate(routes):
        lids, cnts = table.indices_for(route)
        flows.append(
            _FlowState(
                task=Task(tid=i, kind="flow", nbytes=1000.0, route=route),
                remaining=1000.0,
                lids=lids,
                cnts=cnts,
                hops=len(route) - 1,
            )
        )
    return flows


def test_unknown_links_no_nan_and_methods_bitwise_identical():
    """Fabric with unknown links: the filling loop must not manufacture
    nans (the old ``inf - inf`` residual update), flows constrained only
    by unknown links run unconstrained, and heap == dense bit-for-bit."""
    from repro.core.simengine import _LinkTable, _max_min_rates

    # Known links (0,1), (1,2); routes also cross unknown (2,3), (3,4).
    table = _LinkTable({(0, 1): 100.0, (1, 2): 50.0})
    routes = [
        (0, 1, 2),  # both known links
        (0, 1),  # shares (0,1)
        (2, 3, 4),  # only unknown links -> unconstrained
        (1, 2, 3),  # known (1,2) + unknown (2,3)
    ]
    flows = _mk_flows(routes, table)
    dense = _max_min_rates(flows, table.cap, method="dense")
    heap = _max_min_rates(_mk_flows(routes, table), table.cap, method="heap")
    assert not np.isnan(dense).any() and not np.isnan(heap).any()
    assert np.isposinf(dense[2])  # unknown-only flow is unconstrained
    # Bottlenecks: (1,2) at 50/2 -> flows 0 and 3 get 25; then flow 1
    # takes the rest of (0,1).
    assert dense[0] == 25.0 and dense[3] == 25.0 and dense[1] == 75.0
    assert np.array_equal(dense, heap)


def test_unknown_only_fabric_completes():
    """A run whose every route crosses only unknown links finishes at
    propagation-delay time instead of tripping the deadlock path."""
    sim = FlowSimVec({(9, 10): 100.0})  # no route uses the known link
    tasks = [Task(tid=0, kind="flow", nbytes=5000.0, route=(0, 1, 2))]
    r = sim.run(tasks)
    assert r.makespan == pytest.approx(2 * PROPAGATION_DELAY)
    assert 0 in r.finish_times


def test_weighted_unknown_links_methods_agree():
    from repro.core.simengine import _LinkTable, _max_min_rates

    table = _LinkTable({(0, 1): 100.0, (1, 2): 50.0, (2, 0): 30.0})
    routes = [(0, 1, 2), (1, 2, 0), (2, 0, 1), (0, 1), (5, 6, 7)]
    weights = np.array([1.0, 2.5, 0.5, 1.0, 3.0])
    flows = _mk_flows(routes, table)
    dense = _max_min_rates(flows, table.cap, weights=weights, method="dense")
    heap = _max_min_rates(
        _mk_flows(routes, table), table.cap, weights=weights, method="heap"
    )
    assert not np.isnan(dense).any()
    assert np.array_equal(dense, heap)
