import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    available_steps,
    latest_step,
    load_checkpoint,
    prune_checkpoints,
    save_checkpoint,
)

# Multi-minute subprocess tests (fresh jax init per case); quick loop:
# python -m pytest -m "not slow"
pytestmark = pytest.mark.slow


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def _specs(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def test_roundtrip(tmp_path):
    params = _tree()
    opt = {"m": _tree(1)}
    save_checkpoint(str(tmp_path), 7, params, opt)
    step, p2, o2, manifest = load_checkpoint(str(tmp_path), _specs(params), _specs(opt))
    assert step == 7 and manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    params = _tree()
    for s in (10, 20, 30, 40):
        save_checkpoint(str(tmp_path), s, params)
    assert latest_step(str(tmp_path)) == 40
    prune_checkpoints(str(tmp_path), keep=2)
    assert available_steps(str(tmp_path)) == [30, 40]


def test_no_staging_dirs_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".staging")]
    assert not leftovers


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad_specs = {
        "a": jax.ShapeDtypeStruct((3, 3), jnp.float32),
        "nested": {
            "b": jax.ShapeDtypeStruct((10,), jnp.int32),
            "c": jax.ShapeDtypeStruct((), jnp.float32),
        },
    }
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(str(tmp_path), bad_specs)


def test_missing_dir():
    with pytest.raises(FileNotFoundError):
        load_checkpoint("/nonexistent/ckpts", {})


def test_elastic_restore_subprocess(tmp_path):
    """Write under 1 device, restore under 8 with target shardings —
    the elastic path (arrays saved global, re-placed on load)."""
    from _subproc import run_with_devices

    params = _tree()
    save_checkpoint(str(tmp_path), 5, params)
    out = run_with_devices(
        f"""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint.ckpt import load_checkpoint
mesh = jax.make_mesh((8,), ("data",))
specs = {{
    "a": jax.ShapeDtypeStruct((4, 8), jnp.float32),
    "nested": {{"b": jax.ShapeDtypeStruct((10,), jnp.int32),
               "c": jax.ShapeDtypeStruct((), jnp.float32)}},
}}
shardings = {{
    "a": NamedSharding(mesh, P(None, "data")),
    "nested": {{"b": NamedSharding(mesh, P()), "c": NamedSharding(mesh, P())}},
}}
step, p, _, _ = load_checkpoint(r"{tmp_path}", specs, param_shardings=shardings)
assert step == 5
assert len(p["a"].sharding.device_set) == 8
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out
