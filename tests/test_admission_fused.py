"""Fused admission co-search (candidate x temperature-ladder grid).

Three layers of evidence that the PR-10 grid kernel is the *same search*
when degenerate and a *faithful tempering search* when not:

* **Singleton-ladder regressions** — every search entry point
  (``mcmc_search``, ``mcmc_search_jobset``, ``alternating_optimize``,
  ``co_optimize_jobset``) run with ``backend="jax",
  temperatures=(t,)`` and one placement candidate must reproduce the PR-6
  flat-kernel path (``temperature=t``) decision-for-decision: strategies
  equal, ``iter_time`` exactly equal (both are NumPy re-prices of the
  same winner), histories equal to float noise.  ``backend="numpy"``
  rejects ``temperatures`` loudly; the NumPy goldens in
  ``tests/test_schedules.py`` / ``tests/test_planeval_jax.py`` stay
  byte-stable because that path never sees the ladder.
* **Property tests** (via ``tests/_hypothesis_compat``) — the swap pass
  permutes (state, energy) pairs within parity neighbors only; padded
  dummy links never contribute to any bottleneck (``pad_cap``-invariance,
  bitwise, device and reference); the fused grid kernel bitwise-matches
  the sequential per-cell NumPy replay (:func:`run_grid_reference`) on
  random degraded fabrics, and slicing one candidate out of the grid
  replays that candidate's cells bitwise.
* **Fused-path integration** — the fused ``co_optimize_jobset`` never
  adopts a worse plan than the sequential baseline at the same seed, its
  winner re-prices bit-exactly on the NumPy evaluator, and
  ``JobSetController.admit`` runs end-to-end under a ladder policy.
"""

import dataclasses
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.alternating import alternating_optimize, co_optimize_jobset
from repro.core.demand import data_parallel_demand
from repro.core.netsim import HardwareSpec
from repro.core.online import JobSetController, ReoptPolicy
from repro.core.planeval_jax import (
    DEFAULT_TEMPER_LADDER,
    ChainKernel,
    check_temper_ladder,
    default_temper_ladder,
    draw_grid_streams,
    draw_proposal_streams,
    draw_swap_streams,
    pack_jobset_grid,
    run_grid_reference,
    strategy_pool,
    _swap_pass_reference,
)
from repro.core.strategy_search import (
    default_strategy,
    evaluate_jobset,
    mcmc_search,
    mcmc_search_jobset,
)
from repro.core.topology_finder import remove_pair, topology_finder
from repro.core.workloads import BERT, DLRM, MOE_16E, JobSet, TenantJob

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)
N = 16


@pytest.fixture(scope="module")
def topo():
    return topology_finder(data_parallel_demand(N, 1e9), HW.degree)


@pytest.fixture(scope="module")
def jobset():
    return JobSet(n=N, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, 6)), weight=2.0,
                  name="dlrm0"),
        TenantJob(spec=BERT, servers=tuple(range(6, 12)), weight=1.0,
                  name="bert0"),
        TenantJob(spec=MOE_16E, servers=tuple(range(12, 16)), weight=0.5,
                  name="moe0"),
    ])


def _candidates(k: int) -> list[JobSet]:
    return [
        JobSet(n=N, tenants=[
            TenantJob(spec=DLRM, weight=2.0, name="dlrm0",
                      servers=tuple((s + off) % N for s in range(0, 6))),
            TenantJob(spec=BERT, weight=1.0, name="bert0",
                      servers=tuple((s + off) % N for s in range(6, 12))),
        ])
        for off in range(k)
    ]


# ---------------------------------------------------------------------------
# Ladder validation + env knob
# ---------------------------------------------------------------------------


def test_check_temper_ladder_accepts_ascending():
    assert check_temper_ladder([0.05, 0.1, 0.4]) == (0.05, 0.1, 0.4)
    assert check_temper_ladder((0.1,)) == (0.1,)
    # Equal neighbors are allowed (a swap between equal temps is a plain
    # exchange); only a descending ladder is rejected.
    assert check_temper_ladder((0.1, 0.1)) == (0.1, 0.1)


@pytest.mark.parametrize("bad", [
    (), (0.2, 0.1), (-0.1, 0.2), (0.0, 0.1),
    (0.1, float("inf")), (float("nan"),),
])
def test_check_temper_ladder_rejects(bad):
    with pytest.raises(ValueError):
        check_temper_ladder(bad)


def test_default_temper_ladder_env_knob(monkeypatch):
    assert default_temper_ladder() == DEFAULT_TEMPER_LADDER
    monkeypatch.setenv("REPRO_TEMPER_LADDER", "0.01, 0.1, 1.0")
    assert default_temper_ladder() == (0.01, 0.1, 1.0)
    monkeypatch.setenv("REPRO_TEMPER_LADDER", "1.0,0.5")
    with pytest.raises(ValueError):
        default_temper_ladder()


@pytest.mark.parametrize("entry", ["mcmc_search", "mcmc_search_jobset",
                                   "alternating", "co_optimize"])
def test_numpy_backend_rejects_temperatures(topo, jobset, entry):
    kw = dict(backend="numpy", temperatures=(0.05, 0.1))
    with pytest.raises(ValueError, match="backend"):
        if entry == "mcmc_search":
            mcmc_search(BERT, topo, HW, iters=5, **kw)
        elif entry == "mcmc_search_jobset":
            mcmc_search_jobset(jobset, topo, HW, iters=5, **kw)
        elif entry == "alternating":
            alternating_optimize(BERT, N, HW, rounds=1, mcmc_iters=5, **kw)
        else:
            co_optimize_jobset(jobset, HW, rounds=1, mcmc_iters=5, **kw)


# ---------------------------------------------------------------------------
# Singleton-ladder degeneracy: grid == flat PR-6 kernel, all entry points
# ---------------------------------------------------------------------------


def test_grid_streams_degenerate_to_flat_streams():
    # Cell (candidate 0, chain c, rung 0) IS draw_proposal_streams chain c.
    ft, fs, fu = draw_proposal_streams(9, 3, 20, 4, 8)
    gt, gs, gu = draw_grid_streams(9, 2, 3, 2, 20, 4, 8)
    assert np.array_equal(gt[0, :, 0], ft)
    assert np.array_equal(gs[0, :, 0], fs)
    assert np.array_equal(gu[0, :, 0], fu)
    # Every other cell is decorrelated from the anchor.
    assert not np.array_equal(gu[0, :, 1], fu)
    assert not np.array_equal(gu[1, :, 0], fu)
    # A singleton ladder draws no swap uniforms at all.
    assert draw_swap_streams(9, 2, 3, 1, 20).shape == (2, 3, 20, 0)


def test_mcmc_search_singleton_ladder_matches_flat(topo):
    kw = dict(iters=60, seed=2, backend="jax", chains=3, pool_size=24)
    flat = mcmc_search(BERT, topo, HW, temperature=0.1, **kw)
    grid = mcmc_search(BERT, topo, HW, temperatures=(0.1,), **kw)
    assert grid.strategy == flat.strategy
    assert grid.iter_time == flat.iter_time
    np.testing.assert_allclose(grid.history, flat.history, rtol=1e-12)


def test_mcmc_search_jobset_singleton_ladder_matches_flat(topo, jobset):
    kw = dict(iters=50, seed=4, backend="jax", chains=2, pool_size=16)
    flat = mcmc_search_jobset(jobset, topo, HW, temperature=0.1, **kw)
    grid = mcmc_search_jobset(jobset, topo, HW, temperatures=(0.1,), **kw)
    assert grid.strategies == flat.strategies
    assert grid.iter_time == flat.iter_time
    assert grid.per_job == flat.per_job
    np.testing.assert_allclose(grid.history, flat.history, rtol=1e-12)


def test_mcmc_search_jobset_singleton_decomposed(topo, jobset):
    kw = dict(iters=40, seed=6, backend="jax", chains=2, pool_size=16,
              objective="decomposed")
    flat = mcmc_search_jobset(jobset, topo, HW, temperature=0.1, **kw)
    grid = mcmc_search_jobset(jobset, topo, HW, temperatures=(0.1,), **kw)
    assert grid.strategies == flat.strategies
    assert grid.iter_time == flat.iter_time


def test_alternating_optimize_singleton_ladder_matches_flat():
    kw = dict(rounds=2, mcmc_iters=30, seed=3, backend="jax", chains=2,
              pool_size=16)
    flat = alternating_optimize(BERT, N, HW, **kw)
    grid = alternating_optimize(BERT, N, HW, temperatures=(0.1,), **kw)
    assert grid.strategy == flat.strategy
    assert grid.iter_time == flat.iter_time
    np.testing.assert_allclose(grid.rounds, flat.rounds, rtol=1e-12)


def test_co_optimize_jobset_singleton_ladder_matches_flat(jobset):
    # One candidate: the ladder routes through _co_optimize_single, the
    # grid kernel replays the flat kernel's decisions exactly.
    kw = dict(rounds=2, mcmc_iters=30, seed=5, backend="jax", chains=2,
              pool_size=16)
    flat = co_optimize_jobset(jobset, HW, **kw)
    grid = co_optimize_jobset(jobset, HW, temperatures=(0.1,), **kw)
    assert grid.strategies == flat.strategies
    assert grid.iter_time == flat.iter_time
    assert sorted(grid.topology.graph.edges()) == sorted(
        flat.topology.graph.edges()
    )
    np.testing.assert_allclose(grid.rounds, flat.rounds, rtol=1e-12)


# ---------------------------------------------------------------------------
# Property tests: swap pass, dummy-link padding, grid == reference
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=7),
    parity=st.integers(min_value=0, max_value=1),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_swap_pass_permutes_within_parity_pairs(m, parity, seed):
    rng = np.random.default_rng(seed)
    A = rng.integers(0, 9, size=(m, 3))
    cur = rng.uniform(0.1, 5.0, size=m)
    temps = np.sort(rng.uniform(0.01, 1.0, size=m))
    su = rng.uniform(0.0, 1.0, size=m // 2)
    A2, cur2 = _swap_pass_reference(A.copy(), cur.copy(), temps, su, parity)
    # The (state row, energy) pairing survives the pass: each rung either
    # kept its pair or exchanged it with its parity neighbor — nothing is
    # lost, duplicated, or torn apart.
    before = {(tuple(A[i]), cur[i]) for i in range(m)}
    after = {(tuple(A2[i]), cur2[i]) for i in range(m)}
    assert after == before
    for i in range(m):
        if not np.array_equal(A2[i], A[i]) or cur2[i] != cur[i]:
            j = i + 1 if (i - parity) % 2 == 0 else i - 1
            assert 0 <= j < m
            assert np.array_equal(A2[i], A[j]) and cur2[i] == cur[j]
    # Temps stay put (only states migrate up/down the ladder).
    if m == 1 or not len(su):
        assert np.array_equal(A2, A) and np.array_equal(cur2, cur)


def test_swap_pass_certain_accept_and_certain_reject():
    temps = np.array([0.05, 0.5])
    # Cold rung stuck high, hot rung found low: delta >> 0, exp -> +inf
    # side, any uniform accepts — the good state migrates down-ladder.
    A = np.array([[0], [1]])
    cur = np.array([5.0, 0.1])
    A2, cur2 = _swap_pass_reference(
        A.copy(), cur.copy(), temps, np.array([1.0 - 1e-12]), 0
    )
    assert cur2[0] == 0.1 and A2[0, 0] == 1
    # Reversed energies: delta << 0, exp(delta) ~ 6e-39, any ordinary
    # uniform rejects the swap.
    A = np.array([[0], [1]])
    cur = np.array([0.1, 5.0])
    A2, cur2 = _swap_pass_reference(
        A.copy(), cur.copy(), temps, np.array([0.5]), 0
    )
    assert cur2[0] == 0.1 and A2[0, 0] == 0


def _grid_fixture(seed, k_candidates=2, pool_size=8, dead=(), pad_cap=1.0,
                  pad_to=32):
    """A small packed grid over shifted two-tenant candidates."""
    cands = _candidates(k_candidates)
    init = {t.label: default_strategy(t.spec) for t in cands[0].tenants}
    pools = [
        strategy_pool(t.spec, t.k, pool_size, seed + i, init=init[t.label])
        for i, t in enumerate(cands[0].tenants)
    ]
    topos = []
    for js in cands:
        t = topology_finder(js.union_for(init), HW.degree, pack="per_node")
        for pair in dead:
            t = remove_pair(t, pair)
        topos.append(t)
    V, caps, comps, weights, steps, _ = pack_jobset_grid(
        cands, topos, HW, pools, pad_cap=pad_cap, pad_to=pad_to
    )
    return V, caps, comps, weights, steps


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=500),
    ladder=st.integers(min_value=1, max_value=4),
    objective=st.sampled_from(["union", "decomposed"]),
)
def test_grid_kernel_matches_sequential_reference(seed, ladder, objective):
    """The fused dispatch replays C x K x M sequential cells bitwise —
    including on degraded fabrics (dead fiber pairs removed pre-pack)."""
    rng = np.random.default_rng(seed)
    dead = [tuple(sorted(rng.choice(N, 2, replace=False)))
            for _ in range(rng.integers(0, 3))]
    V, caps, comps, weights, _ = _grid_fixture(seed, dead=dead)
    C, T, S, L = V.shape
    K, iters = 2, 12
    temps = np.sort(rng.uniform(0.02, 0.5, size=ladder))
    t_idx, s_idx, u = draw_grid_streams(seed, C, K, ladder, iters, T, S)
    su = draw_swap_streams(seed, C, K, ladder, iters)
    init_a = rng.integers(0, S, size=(C, T))

    kern = ChainKernel(V, caps, comps, weights, objective=objective)
    ba, bo, hist = kern.run_grid(init_a, temps, t_idx, s_idx, u, su)
    ra, ro, rhist = run_grid_reference(
        V, caps, comps, weights, 0.0, objective, init_a, temps,
        t_idx, s_idx, u, su,
    )
    assert np.array_equal(ba, ra)
    np.testing.assert_allclose(bo, ro, rtol=1e-12)
    np.testing.assert_allclose(hist, rhist, rtol=1e-12)

    # Fusion adds nothing: slicing one candidate out of the grid and
    # dispatching it alone reproduces that candidate's rows bitwise.
    solo = ChainKernel(V[1:2], caps[1:2], comps, weights,
                       objective=objective)
    sa, so, _ = solo.run_grid(init_a[1:2], temps, t_idx[1:2], s_idx[1:2],
                              u[1:2], su[1:2])
    assert np.array_equal(sa[0], ba[1])
    assert np.array_equal(so[0], bo[1])


@settings(max_examples=5, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=500),
    pad_cap=st.floats(min_value=0.5, max_value=200.0),
)
def test_dummy_links_never_contribute(seed, pad_cap):
    """Padding capacity is unobservable: zero load against any pad_cap > 0
    can neither win a bottleneck max nor activate in the decomposed
    objective — results are bitwise invariant, device and reference."""
    base = _grid_fixture(seed, pad_cap=1.0)
    varied = _grid_fixture(seed, pad_cap=pad_cap)
    V, caps, comps, weights, _ = base
    V2, caps2, _, _, _ = varied
    assert np.array_equal(V, V2)  # only dummy caps differ
    C, T, S, L = V.shape
    rng = np.random.default_rng(seed)
    ladder, K, iters = 3, 2, 10
    temps = np.array([0.05, 0.1, 0.3])
    t_idx, s_idx, u = draw_grid_streams(seed, C, K, ladder, iters, T, S)
    su = draw_swap_streams(seed, C, K, ladder, iters)
    init_a = rng.integers(0, S, size=(C, T))
    for objective in ("union", "decomposed"):
        a1, o1, h1 = ChainKernel(
            V, caps, comps, weights, objective=objective
        ).run_grid(init_a, temps, t_idx, s_idx, u, su)
        a2, o2, h2 = ChainKernel(
            V2, caps2, comps, weights, objective=objective
        ).run_grid(init_a, temps, t_idx, s_idx, u, su)
        assert np.array_equal(a1, a2)
        assert np.array_equal(o1, o2)
        assert np.array_equal(h1, h2)
        r1 = run_grid_reference(V, caps, comps, weights, 0.0, objective,
                                init_a, temps, t_idx, s_idx, u, su)
        r2 = run_grid_reference(V2, caps2, comps, weights, 0.0, objective,
                                init_a, temps, t_idx, s_idx, u, su)
        assert np.array_equal(r1[1], r2[1])


def test_pad_bucketing_only_widens_with_dummies():
    V8, caps8, *_ = _grid_fixture(0, pad_to=8)
    V64, caps64, *_ = _grid_fixture(0, pad_to=64)
    L8, L64 = V8.shape[3], V64.shape[3]
    assert L8 % 8 == 0 and L64 % 64 == 0 and L64 >= L8
    # The real prefix is identical; the extra width is pure dummy.
    assert np.array_equal(V64[..., :L8], V8)
    assert not V64[..., L8:].any()
    assert (caps64[:, L8:] == 1.0).all()


# ---------------------------------------------------------------------------
# Fused co-optimization end-to-end
# ---------------------------------------------------------------------------


def test_fused_co_optimize_not_worse_and_numpy_exact():
    cands = _candidates(4)
    kw = dict(rounds=2, mcmc_iters=40, seed=3, placement_candidates=cands,
              backend="jax", chains=4)
    seq = co_optimize_jobset(cands[0], HW, **kw)
    fused = co_optimize_jobset(
        cands[0], HW, temperatures=DEFAULT_TEMPER_LADDER, **kw
    )
    # Equal-or-better at the same fixed seed: the ladder explores a
    # superset of the single-temperature move space.
    assert fused.iter_time <= seq.iter_time * (1 + 1e-9)
    assert 0 <= fused.candidate_index < len(cands)
    assert fused.jobset is cands[fused.candidate_index]
    # The adopted number is always a NumPy re-price, never device math.
    repriced, _, per_job = evaluate_jobset(
        fused.strategies, fused.jobset, fused.topology, HW
    )
    assert repriced == fused.iter_time
    assert fused.per_job == per_job
    assert math.isfinite(fused.iter_time) and fused.iter_time > 0


def test_fused_co_optimize_seed_stable():
    cands = _candidates(4)
    kw = dict(rounds=2, mcmc_iters=25, seed=7, placement_candidates=cands,
              backend="jax", chains=2, temperatures=(0.05, 0.1, 0.2))
    a = co_optimize_jobset(cands[0], HW, **kw)
    b = co_optimize_jobset(cands[0], HW, **kw)
    assert a.strategies == b.strategies
    assert a.iter_time == b.iter_time
    assert a.candidate_index == b.candidate_index


def test_controller_admit_runs_fused_ladder():
    base = JobSet(n=N, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, 6)), weight=2.0,
                  name="dlrm"),
    ])
    policy = dataclasses.replace(
        ReoptPolicy.reactive(replan_latency=0.0, rounds=1, mcmc_iters=15),
        backend="jax", chains=2, candidates=4,
        temperatures=(0.05, 0.1, 0.2, 0.4),
    )
    ctrl = JobSetController(base, hw=HW, policy=policy, seed=2)
    out = ctrl.admit(BERT, 6, weight=1.0, name="bert", now=1.0)
    assert out is not None
    servers, _pause = out
    assert len(servers) == 6
    assert ctrl.plan is not None and ctrl.plan.iter_time > 0
    assert "bert" in ctrl.plan.strategies
    # The adopted plan re-prices bit-exactly on the NumPy path.
    repriced, _, _ = evaluate_jobset(
        ctrl.plan.strategies, ctrl.jobset, ctrl.plan.topology, HW
    )
    assert repriced == ctrl.plan.iter_time
