"""JAX planner backend: equivalence against the NumPy reference, plus
regression tests for the three PR-6 bugfixes.

* Batched demand pricing (:class:`repro.core.planeval_jax.JaxPlanEvaluator`)
  matches :meth:`PlanEvaluator.comm_time` per demand within the documented
  ``JAX_EQUIV_RTOL`` — on healthy and degraded fabrics, and on multi-tenant
  union demands.
* Batched MCMC chains (:class:`ChainKernel` through ``lax.scan``/``vmap``)
  make *exactly* the decisions of K sequential NumPy reference chains
  replaying the same pre-drawn proposal streams at fixed seeds
  (:func:`run_chains_reference`) — assignments equal, objectives within
  tolerance.
* ``backend="numpy"`` fixed-seed searches are byte-stable against the
  backend's introduction (goldens pinned below), and ``backend="jax"``
  returns NumPy-re-priced result values.
* Bugfix regressions: the ``objective="decomposed"`` jobset annealing
  (compiled == reference bit-exactly; heavy tenants shape the plan), the
  admission-time rebalance trigger (``rebalance_on_arrival``), and the
  ``screen_candidates`` pre-screen (byte-identical when disabled or
  non-binding; survivors keep original candidate indices).
"""

import numpy as np
import pytest

from repro.compat import ensure_x64
from repro.core.alternating import co_optimize_jobset
from repro.core.demand import data_parallel_demand
from repro.core.netsim import HardwareSpec
from repro.core.planeval import JobSetEvaluator, plan_evaluator
from repro.core.planeval_jax import (
    JAX_EQUIV_RTOL,
    ChainKernel,
    JaxPlanEvaluator,
    draw_proposal_streams,
    jax_plan_evaluator,
    pack_demand,
    run_chains_reference,
    strategy_pool,
)
from repro.core.online import JobSetController, ReoptPolicy
from repro.core.strategy_search import (
    default_strategy,
    evaluate_jobset,
    evaluate_jobset_decomposed,
    mcmc_search,
    mcmc_search_jobset,
    tenant_comm_times,
)
from repro.core.topology_finder import remove_pair, topology_finder
from repro.core.workloads import BERT, DLRM, MOE_16E, JobSet, TenantJob

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)
N = 16


@pytest.fixture(scope="module")
def topo():
    return topology_finder(data_parallel_demand(N, 1e9), HW.degree)


@pytest.fixture(scope="module")
def degraded(topo):
    return remove_pair(remove_pair(topo, (0, 1)), (3, 7))


@pytest.fixture(scope="module")
def jobset():
    return JobSet(n=N, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, 6)), weight=2.0,
                  name="dlrm0"),
        TenantJob(spec=BERT, servers=tuple(range(6, 12)), weight=1.0,
                  name="bert0"),
        TenantJob(spec=MOE_16E, servers=tuple(range(12, 16)), weight=0.5,
                  name="moe0"),
    ])


def test_ensure_x64_pins_float64():
    assert ensure_x64() is True
    import jax.numpy as jnp

    assert jnp.asarray(1.0).dtype == jnp.float64


# ---------------------------------------------------------------------------
# Batched demand pricing equivalence
# ---------------------------------------------------------------------------


def _random_demands(job, n, count, seed):
    pool = strategy_pool(job, n, count, seed)
    return [s.demand(job, n) for s in pool]


@pytest.mark.parametrize("fab", ["healthy", "degraded"])
def test_batched_pricing_matches_reference(topo, degraded, fab):
    t = topo if fab == "healthy" else degraded
    demands = _random_demands(DLRM, N, 20, seed=7)
    demands += _random_demands(MOE_16E, N, 10, seed=8)
    jax_times = jax_plan_evaluator(t, HW).comm_times(demands)
    ev = plan_evaluator(t, HW)
    ref = np.array([ev.comm_time(d) for d in demands])
    assert jax_times.shape == ref.shape
    rel = np.abs(jax_times - ref) / np.maximum(np.abs(ref), 1e-30)
    assert np.max(rel) <= JAX_EQUIV_RTOL


def test_pricing_matches_on_multitenant_unions(topo, jobset):
    # Union demands of several random per-tenant assignments.
    unions = []
    for seed in range(5):
        strategies = {
            t.label: strategy_pool(t.spec, t.k, 6, seed=seed + 11)[seed % 6]
            for t in jobset.tenants
        }
        unions.append(jobset.union_for(strategies))
    jax_times = jax_plan_evaluator(topo, HW).comm_times(unions)
    ev = plan_evaluator(topo, HW)
    ref = np.array([ev.comm_time(u) for u in unions])
    rel = np.abs(jax_times - ref) / np.maximum(np.abs(ref), 1e-30)
    assert np.max(rel) <= JAX_EQUIV_RTOL


def test_pack_demand_reproduces_scatter(topo):
    ev = plan_evaluator(topo, HW)
    d = default_strategy(DLRM).demand(DLRM, N)
    ids, shares = pack_demand(ev, d)
    loads = np.zeros(ev.n_links)
    np.add.at(loads, ids, shares)
    ref = ev.loads(d)
    assert np.allclose(loads, ref[: loads.size], rtol=1e-12, atol=0.0)


def test_jax_evaluator_comm_keeps_tax(topo):
    jev = JaxPlanEvaluator(topo, HW)
    d = default_strategy(DLRM).demand(DLRM, N)
    out = jev.comm(d)
    ref = plan_evaluator(topo, HW).comm(d)
    assert out["bandwidth_tax"] == ref["bandwidth_tax"]
    assert out["comm_time"] == pytest.approx(ref["comm_time"],
                                             rel=JAX_EQUIV_RTOL)


# ---------------------------------------------------------------------------
# Batched chains vs sequential NumPy reference chains
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("objective", ["union", "decomposed"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chain_kernel_matches_reference_chains(objective, seed):
    rs = np.random.RandomState(100 + seed)
    T, S, L, K, iters = 4, 12, 60, 6, 80
    V = rs.rand(T, S, L) * 1e9
    V[V < 0.4e9] = 0.0  # sparse activity so the decomposition has structure
    caps = rs.rand(L) * 12.5e9 + 1e9
    comps = rs.rand(T) * 0.01
    weights = rs.rand(T) * 2.0 + 0.25
    overlap = 0.3
    kernel = ChainKernel(V, caps, comps, weights, overlap=overlap,
                         objective=objective)
    t_idx, s_idx, u = draw_proposal_streams(seed, K, iters, T, S)
    temps = np.linspace(0.05, 0.5, K)
    init_a = np.zeros(T, dtype=np.int64)
    best_a, best_obj, hist = kernel.run(init_a, temps, t_idx, s_idx, u)
    ref_a, ref_obj, ref_hist = run_chains_reference(
        V, caps, comps, weights, overlap, objective, init_a, temps,
        t_idx, s_idx, u,
    )
    # Same chains: identical accept/reject decisions, hence assignments.
    assert np.array_equal(best_a, ref_a)
    assert np.allclose(best_obj, ref_obj, rtol=JAX_EQUIV_RTOL)
    assert np.allclose(hist, ref_hist, rtol=JAX_EQUIV_RTOL)


def test_strategy_pool_deterministic_and_padded():
    p1 = strategy_pool(DLRM, N, 16, seed=5)
    p2 = strategy_pool(DLRM, N, 16, seed=5)
    assert p1 == p2
    assert len(p1) == 16
    assert p1[0] == default_strategy(DLRM)
    init = p1[3]
    p3 = strategy_pool(DLRM, N, 16, seed=5, init=init)
    assert p3[0] == init
    # BERT has no tables/experts: only toggle_mode is reachable, so the
    # pool must pad by cycling instead of spinning forever.
    pb = strategy_pool(BERT, N, 8, seed=5)
    assert len(pb) == 8


# ---------------------------------------------------------------------------
# Backend wiring: numpy byte-stability, jax end-to-end
# ---------------------------------------------------------------------------


def test_numpy_backend_is_default_and_unchanged(topo):
    a = mcmc_search(DLRM, topo, HW, iters=40, seed=3)
    b = mcmc_search(DLRM, topo, HW, iters=40, seed=3, backend="numpy",
                    chains=1)
    assert a.strategy == b.strategy
    assert a.iter_time == b.iter_time
    assert a.history == b.history


def test_backend_validation(topo, jobset):
    with pytest.raises(ValueError):
        mcmc_search(DLRM, topo, HW, backend="tpu")
    with pytest.raises(ValueError):
        mcmc_search(DLRM, topo, HW, chains=3)  # chains>1 needs jax
    with pytest.raises(ValueError):
        mcmc_search_jobset(jobset, topo, HW, objective="nope")


@pytest.mark.parametrize("chains", [1, 4])
def test_jax_mcmc_search_end_to_end(topo, chains):
    res = mcmc_search(DLRM, topo, HW, iters=60, seed=3, backend="jax",
                      chains=chains, pool_size=16)
    # Result values are re-priced on the bit-exact NumPy path.
    ev = plan_evaluator(topo, HW)
    ref = mcmc_search(DLRM, topo, HW, iters=0, seed=0, init=res.strategy)
    assert res.iter_time == ref.iter_time
    assert len(res.history) == 61
    # More chains can only improve (or tie) the best-of-chains objective
    # because chain 0's stream is shared across both runs.
    one = mcmc_search(DLRM, topo, HW, iters=60, seed=3, backend="jax",
                      chains=1, pool_size=16)
    assert min(res.history) <= min(one.history) + 1e-15


@pytest.mark.parametrize("objective", ["union", "decomposed"])
def test_jax_jobset_end_to_end(topo, jobset, objective):
    res = mcmc_search_jobset(
        jobset, topo, HW, iters=50, seed=5, backend="jax", chains=3,
        pool_size=12, objective=objective,
    )
    assert set(res.strategies) == {t.label for t in jobset.tenants}
    if objective == "union":
        ref = evaluate_jobset(res.strategies, jobset, topo, HW,
                              compiled=True)[0]
    else:
        ref = evaluate_jobset_decomposed(res.strategies, jobset, topo,
                                         HW)[0]
    assert res.iter_time == ref
    assert set(res.per_job) == set(res.strategies)


def test_co_optimize_jobset_jax_backend(jobset):
    plan = co_optimize_jobset(jobset, HW, rounds=2, mcmc_iters=20, seed=1,
                              backend="jax", chains=2, pool_size=8)
    assert np.isfinite(plan.iter_time)
    assert set(plan.strategies) == {t.label for t in jobset.tenants}


def test_simengine_jax_backend(topo):
    from repro.core.simengine import SimEngine

    d = data_parallel_demand(N, 1e9)
    ref = SimEngine(HW).comm_time(topo, d)["comm_time"]
    jx = SimEngine(HW, backend="jax").comm_time(topo, d)["comm_time"]
    assert jx == pytest.approx(ref, rel=JAX_EQUIV_RTOL)
    with pytest.raises(ValueError):
        SimEngine(HW, backend="cuda")


# ---------------------------------------------------------------------------
# Bugfix a: objective="decomposed" jobset annealing
# ---------------------------------------------------------------------------


def test_decomposed_union_default_unchanged(topo, jobset):
    a = mcmc_search_jobset(jobset, topo, HW, iters=40, seed=5)
    b = mcmc_search_jobset(jobset, topo, HW, iters=40, seed=5,
                           objective="union")
    assert a.strategies == b.strategies
    assert a.iter_time == b.iter_time
    assert a.history == b.history


def test_decomposed_compiled_matches_reference(topo, jobset):
    kw = dict(iters=60, seed=7, objective="decomposed")
    c = mcmc_search_jobset(jobset, topo, HW, compiled=True, **kw)
    r = mcmc_search_jobset(jobset, topo, HW, compiled=False, **kw)
    # Bit-exact: both paths price identical vectors with identical
    # expressions, so fixed-seed chains make identical decisions.
    assert c.strategies == r.strategies
    assert c.iter_time == r.iter_time
    assert c.history == r.history


def test_decomposed_evaluator_matches_tenant_comm_times(topo, jobset):
    strategies = {t.label: default_strategy(t.spec) for t in jobset.tenants}
    jse = JobSetEvaluator(jobset, topo, HW)
    obj, per_job = jse.decomposed_objective_of(strategies)
    ref_obj, ref_per_job = evaluate_jobset_decomposed(
        strategies, jobset, topo, HW
    )
    assert obj == ref_obj
    assert per_job == ref_per_job
    # and the comm decomposition underneath is tenant_comm_times exactly
    comm = tenant_comm_times(strategies, jobset, topo, HW)
    assert set(comm) == set(per_job)


def test_decomposed_annealing_shapes_objective(topo, jobset):
    """The decomposed search optimizes its own objective at least as well
    as the union-annealed plan scores on it (the PR-5 gap: heavy tenants
    could not shape a union-annealed plan)."""
    kw = dict(iters=120, seed=3)
    u = mcmc_search_jobset(jobset, topo, HW, objective="union", **kw)
    d = mcmc_search_jobset(jobset, topo, HW, objective="decomposed", **kw)
    u_scored = evaluate_jobset_decomposed(u.strategies, jobset, topo, HW)[0]
    assert d.iter_time <= u_scored + 1e-12


# ---------------------------------------------------------------------------
# Bugfix b: admission-time rebalance (arriving tenant preempts)
# ---------------------------------------------------------------------------


def _rebalance_policy(**kw):
    return ReoptPolicy.reactive(
        max_migrations=1, migration_restart=0.0, payback_horizon=100.0,
        replan_latency=0.0, rounds=1, mcmc_iters=10, **kw,
    )


def test_admit_triggers_rebalance_when_enabled():
    base = JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=(0, 2, 4, 6), weight=0.1, name="cheap"),
    ])
    ctrl = JobSetController(
        base, hw=HW, policy=_rebalance_policy(rebalance_on_arrival=True),
        seed=2,
    )
    ctrl.admit(BERT, 4, weight=5.0, name="heavy", now=1.0)
    assert any(m.reason == "arrival" for m in ctrl.migrations)


def test_admit_no_rebalance_by_default():
    base = JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=(0, 2, 4, 6), weight=0.1, name="cheap"),
    ])
    ctrl = JobSetController(base, hw=HW, policy=_rebalance_policy(), seed=2)
    ctrl.admit(BERT, 4, weight=5.0, name="heavy", now=1.0)
    assert not any(m.reason == "arrival" for m in ctrl.migrations)


def test_admit_rebalance_skipped_without_migration_budget():
    base = JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=(0, 2, 4, 6), weight=0.1, name="cheap"),
    ])
    policy = ReoptPolicy.reactive(
        max_migrations=0, rebalance_on_arrival=True, replan_latency=0.0,
        rounds=1, mcmc_iters=10,
    )
    ctrl = JobSetController(base, hw=HW, policy=policy, seed=2)
    ctrl.admit(BERT, 4, weight=5.0, name="heavy", now=1.0)
    assert ctrl.migrations == []


# ---------------------------------------------------------------------------
# Bugfix c: screen_candidates pre-screen in co_optimize_jobset
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def placement_setup():
    base = JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=(0, 3, 6, 9), weight=1.0, name="d0"),
        TenantJob(spec=BERT, servers=(1, 4, 7, 10), weight=1.0, name="b0"),
    ])
    cands = [
        base,
        base.with_placement("d0", (0, 2, 3, 5)),
        base.with_placement("d0", (2, 5, 8, 11)),
        base.with_placement("d0", (0, 2, 6, 8)),
    ]
    return base, cands


def test_screening_disabled_is_byte_identical(placement_setup):
    base, cands = placement_setup
    kw = dict(rounds=2, mcmc_iters=15, seed=1, placement_candidates=cands)
    unscreened = co_optimize_jobset(base, HW, **kw)
    non_binding = co_optimize_jobset(base, HW, screen_candidates=len(cands),
                                     **kw)
    assert non_binding.candidate_index == unscreened.candidate_index
    assert non_binding.iter_time == unscreened.iter_time
    assert non_binding.strategies == unscreened.strategies
    assert non_binding.per_job == unscreened.per_job


def test_screening_keeps_original_candidate_indices(placement_setup):
    base, cands = placement_setup
    plan = co_optimize_jobset(base, HW, rounds=2, mcmc_iters=15, seed=1,
                              placement_candidates=cands,
                              screen_candidates=2)
    assert 0 <= plan.candidate_index < len(cands)
    # The winning plan's jobset must be the candidate at that index —
    # JobSetController._adopt_plan indexes the original candidate list.
    assert plan.jobset is cands[plan.candidate_index]


def test_screening_validation(placement_setup):
    base, cands = placement_setup
    with pytest.raises(ValueError):
        co_optimize_jobset(base, HW, placement_candidates=cands,
                           screen_candidates=0)


def test_policy_screen_candidates_threads_through():
    base = JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=(0, 2, 4, 6), weight=1.0, name="d0"),
    ])
    policy = ReoptPolicy.reactive(
        candidates=4, screen_candidates=2, replan_latency=0.0,
        rounds=1, mcmc_iters=10,
    )
    ctrl = JobSetController(base, hw=HW, policy=policy, seed=3)
    servers, _ = ctrl.admit(BERT, 4, weight=1.0, name="b0", now=1.0)
    assert len(servers) == 4
    assert ctrl.jobset.tenant("b0").servers == servers
