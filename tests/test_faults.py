"""Fault-injection subsystem: transient faults, correlated domains,
partition survival, and the hardened replan path.

* Construction-time validation: unknown ``TraceEvent`` kinds and
  repair-before-failure ``LinkFailure``\\ s raise instead of being skipped.
* Engine: transient repairs restore capacity byte-preservingly; partition
  survival accounts downtime / restarts / availability; checkpoint-restore
  restart costs block resumed jobs; the fault-free path carries no fault
  state.
* :class:`repro.core.faults.FaultModel`: seeded determinism, per-pair
  outage merging, correlated-domain atomicity, substream stability.
* Controller: ``repair`` restores the degraded incumbent in place,
  candidate plans are validated before adoption, optimizer crash storms
  exhaust a bounded retry budget and back off instead of wedging, and
  unhostable arrivals are refused gracefully.
* Property tests (hypothesis or the seeded shim): random transient storms
  conserve bytes, fail/repair interleavings keep degree budgets, and the
  heap and dense max-min fills stay bit-identical through fail -> repair
  round trips.
"""

from __future__ import annotations

import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.alternating import alternating_optimize
from repro.core.costmodel import (
    CHECKPOINT_RESTORE_BW,
    MIGRATION_RESTART_S,
    checkpoint_restart_s,
)
from repro.core.faults import FaultModel, server_domain, stride_domain
from repro.core.netsim import HardwareSpec
from repro.core.online import (
    JobSetController,
    ReoptController,
    ReoptPolicy,
    TraceEvent,
    place_arrival,
    run_online,
)
from repro.core.simengine import LinkFailure, Scenario, SimEngine, SimJob, Task
from repro.core.workloads import DLRM, VGG16, JobSet, TenantJob

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)


@pytest.fixture(scope="module")
def vgg_plan():
    return alternating_optimize(VGG16, 8, HW, rounds=1, mcmc_iters=10, seed=0)


def _flow_job(name, nbytes=1000.0, route=(0, 1)):
    return SimJob(name, [Task(tid=0, kind="flow", nbytes=nbytes, route=route)])


# ---------------------------------------------------------------------------
# Construction-time validation (satellite: no silently skipped events)
# ---------------------------------------------------------------------------


def test_trace_event_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown TraceEvent kind"):
        TraceEvent(iteration=0, kind="faii", link=(0, 1))
    with pytest.raises(ValueError, match="unknown TraceEvent kind"):
        TraceEvent(iteration=0, kind="Fail", link=(0, 1))


def test_trace_event_fail_and_repair_require_link():
    with pytest.raises(ValueError, match="requires a link"):
        TraceEvent(iteration=0, kind="fail")
    with pytest.raises(ValueError, match="requires a link"):
        TraceEvent(iteration=0, kind="repair")
    TraceEvent(iteration=0, kind="load")  # load/arrive/depart need no link


def test_link_failure_repair_must_follow_failure():
    with pytest.raises(ValueError, match="strictly after"):
        LinkFailure(time=5.0, link=(0, 1), repair_time=5.0)
    with pytest.raises(ValueError, match="strictly after"):
        LinkFailure(time=5.0, link=(0, 1), repair_time=4.0)
    LinkFailure(time=5.0, link=(0, 1), repair_time=5.0 + 1e-9)


# ---------------------------------------------------------------------------
# Engine: transient repair + partition survival
# ---------------------------------------------------------------------------


def test_transient_fault_byte_preserving_restore():
    """No surviving path: the flow waits out the outage, then finishes with
    its remaining bytes intact."""
    r = SimEngine().run(Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("j")],
        failures=(LinkFailure(time=5.0, link=(0, 1), repair_time=7.0),),
        n=2,
    ))
    assert r.delivered["j"] == 1000.0
    assert r.makespan == pytest.approx(12.0, rel=1e-5)
    assert r.downtime["j"] == pytest.approx(2.0, rel=1e-9)
    assert r.restarts == {"j": 1}
    assert r.availability("j") == pytest.approx(10.0 / 12.0, rel=1e-5)
    assert r.goodput["j"] == pytest.approx(1000.0 / r.makespan, rel=1e-9)


def test_transient_fault_with_detour_reroutes_then_restores():
    """A surviving detour carries the bytes during the outage; the repair
    re-paths multi-hop flows back."""
    r = SimEngine().run(Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=[_flow_job("j")],
        failures=(LinkFailure(time=5.0, link=(0, 1), repair_time=7.0),),
        n=3,
    ))
    assert not r.stalled
    assert r.delivered["j"] == 1000.0
    assert r.makespan == pytest.approx(10.0, rel=1e-5)  # detour at full rate
    assert r.downtime.get("j", 0.0) == 0.0  # never actually dark
    assert r.restarts == {}


def test_partition_survival_accounting():
    """Jobs inside a surviving component run degraded; cross-partition jobs
    stall, accrue downtime, and pay a checkpoint-restore restart."""
    links = {(0, 1): 100.0, (1, 0): 100.0, (1, 2): 100.0,
             (2, 1): 100.0, (2, 3): 100.0, (3, 2): 100.0}
    r = SimEngine().run(Scenario(
        links=links, n=4,
        jobs=[_flow_job("local", route=(0, 1)),
              _flow_job("cross", route=(1, 2))],
        failures=(LinkFailure(time=2.0, link=(1, 2), repair_time=6.0),),
        restart_s={"cross": 1.0},
    ))
    assert r.delivered == {"local": 1000.0, "cross": 1000.0}
    assert r.availability("local") == 1.0
    assert r.job_finish["local"] == pytest.approx(10.0, rel=1e-5)
    # 4 s dark (t=2..6) + 1 s checkpoint-restore restart pause.
    assert r.downtime == {"cross": pytest.approx(5.0, rel=1e-9)}
    assert r.restarts == {"cross": 1}
    assert r.job_finish["cross"] == pytest.approx(15.0, rel=1e-5)
    assert r.availability("cross") == pytest.approx(2.0 / 3.0, rel=1e-4)


def test_restart_cost_defaults_to_instant_resume():
    """Without Scenario.restart_s the restart is counted but free."""
    links = {(0, 1): 100.0, (1, 0): 100.0, (1, 2): 100.0,
             (2, 1): 100.0, (2, 3): 100.0, (3, 2): 100.0}
    r = SimEngine().run(Scenario(
        links=links, n=4,
        jobs=[_flow_job("cross", route=(1, 2))],
        failures=(LinkFailure(time=2.0, link=(1, 2), repair_time=6.0),),
    ))
    assert r.restarts == {"cross": 1}
    assert r.downtime["cross"] == pytest.approx(4.0, rel=1e-9)
    assert r.job_finish["cross"] == pytest.approx(14.0, rel=1e-5)


def test_fault_free_run_carries_no_fault_state():
    r = SimEngine().run(Scenario(
        links={(0, 1): 100.0}, jobs=[_flow_job("j")], n=2,
    ))
    assert r.downtime == {} and r.restarts == {}
    assert r.availability("j") == 1.0
    assert r.goodput["j"] == pytest.approx(1000.0 / r.makespan, rel=1e-9)


# ---------------------------------------------------------------------------
# Checkpoint-restore cost helper
# ---------------------------------------------------------------------------


def test_checkpoint_restart_s():
    assert checkpoint_restart_s(0.0) == MIGRATION_RESTART_S
    assert checkpoint_restart_s(CHECKPOINT_RESTORE_BW) == pytest.approx(
        MIGRATION_RESTART_S + 1.0)
    assert checkpoint_restart_s(1e9, checkpoint_bw=1e9, restart_s=2.0) == 3.0
    with pytest.raises(ValueError):
        checkpoint_restart_s(-1.0)


def test_jobset_restart_costs_match_helper():
    js = JobSet(n=6, tenants=[
        TenantJob(spec=DLRM, servers=(0, 1), name="d"),
        TenantJob(spec=VGG16, servers=(2, 3), name="v"),
    ])
    costs = js.restart_costs()
    assert costs == {
        "d": checkpoint_restart_s(DLRM.state_bytes),
        "v": checkpoint_restart_s(VGG16.state_bytes),
    }


# ---------------------------------------------------------------------------
# FaultModel: seeded storms
# ---------------------------------------------------------------------------

_PAIRS = ((0, 1), (1, 2), (2, 3), (0, 3))


def _model(seed=0, **kw):
    kw.setdefault("link_mtbf", 10.0)
    kw.setdefault("link_mttr", 2.0)
    return FaultModel(n=4, links=_PAIRS, seed=seed, **kw)


def test_fault_model_is_deterministic():
    a, b = _model(seed=5), _model(seed=5)
    assert a.link_failures(200.0) == b.link_failures(200.0)
    assert a.events(10, 5.0) == b.events(10, 5.0)
    assert _model(seed=6).link_failures(200.0) != a.link_failures(200.0)


def test_outages_are_merged_and_ordered():
    out = _model(seed=1, domains=[
        server_domain(1, _PAIRS, mtbf=15.0, mttr=3.0)]).outages(500.0)
    assert out, "a 500 s horizon at mtbf 10 must produce outages"
    for pair, ivals in out.items():
        assert pair == (min(pair), max(pair))
        for (t0, t1), nxt in zip(ivals, ivals[1:] + [None]):
            assert 0.0 <= t0 < t1
            if nxt is not None:
                assert t1 < nxt[0], f"overlap on {pair}"


def test_domain_fails_atomically():
    dom = server_domain(1, _PAIRS, mtbf=20.0, mttr=4.0)
    assert dom.links == ((0, 1), (1, 2))
    out = FaultModel(n=4, links=(), link_mtbf=None,
                     domains=[dom], seed=2).outages(300.0)
    assert set(out) == {(0, 1), (1, 2)}
    assert out[(0, 1)] == out[(1, 2)]  # one shared outage clock


def test_flap_substreams_stable_under_domain_changes():
    plain = _model(seed=3).outages(300.0)
    with_dom = _model(seed=3, domains=[
        server_domain(0, _PAIRS, mtbf=25.0, mttr=5.0)]).outages(300.0)
    # (1, 2) and (2, 3) touch no domain: their timelines must not shift.
    assert plain[(1, 2)] == with_dom[(1, 2)]
    assert plain[(2, 3)] == with_dom[(2, 3)]


def test_link_failures_are_transient_and_sorted():
    failures = _model(seed=4).link_failures(100.0)
    assert failures
    assert all(f.repair_time is not None and f.repair_time > f.time
               for f in failures)
    assert [f.time for f in failures] == sorted(f.time for f in failures)


def test_events_alternate_per_pair():
    events = _model(seed=7, domains=[
        stride_domain(4, 1, mtbf=30.0, mttr=3.0)]).events(40, 2.5)
    assert events and {ev.kind for ev in events} <= {"fail", "repair"}
    state: dict[tuple[int, int], str] = {}
    last_iter = -1
    for ev in events:
        assert ev.iteration >= 0
        assert state.get(ev.link, "repair") != ev.kind, (
            f"double {ev.kind} on {ev.link}"
        )
        state[ev.link] = ev.kind
        assert ev.iteration >= last_iter - 39  # quantized, clamped to run
        last_iter = max(last_iter, ev.iteration)
    assert all(kind == "repair" for kind in state.values()), (
        "every storm the driver sees must heal"
    )


def test_for_topology_uses_live_pairs(vgg_plan):
    fm = FaultModel.for_topology(vgg_plan.topology, link_mtbf=5.0)
    expected = {(min(a, b), max(a, b))
                for a, b in vgg_plan.topology.graph.edges()}
    assert set(fm.links) == expected and fm.n == vgg_plan.topology.n


def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(n=4, links=_PAIRS, link_mtbf=0.0)
    with pytest.raises(ValueError):
        FaultModel(n=4, links=_PAIRS, link_mtbf=1.0, link_mttr=-1.0)
    with pytest.raises(ValueError):
        server_domain(9, _PAIRS, mtbf=1.0, mttr=1.0)  # no incident links
    with pytest.raises(ValueError):
        stride_domain(4, 4, mtbf=1.0, mttr=1.0)
    with pytest.raises(ValueError):
        _model().events(10, 0.0)


# ---------------------------------------------------------------------------
# Controller: repair, validation, retry/backoff, refused admission
# ---------------------------------------------------------------------------


def _topo_pair(topo):
    a, b = next(iter(topo.graph.edges()))
    return (min(a, b), max(a, b))


def test_controller_repair_restores_incumbent(vgg_plan):
    ctrl = ReoptController(VGG16, 8, hw=HW, policy=ReoptPolicy.never(),
                           plan=vgg_plan)
    before_edges = sorted(ctrl.topology.graph.edges())
    before_links = dict(ctrl.links())
    pair = _topo_pair(ctrl.topology)
    assert ctrl.repair(pair) == 0.0  # repairing a live pair is a no-op

    ctrl.fail(pair)
    assert pair in ctrl.dead
    degraded = set(ctrl.topology.graph.edges())
    assert not degraded & {pair, (pair[1], pair[0])}
    assert pair not in ctrl.links()

    assert ctrl.repair(pair) == 0.0  # never-policy: no replan pause
    assert not ctrl.dead
    assert sorted(ctrl.topology.graph.edges()) == before_edges
    assert dict(ctrl.links()) == before_links
    a, b = pair
    assert ctrl.topology.routing.get(a, b), "direct route restored"


def test_validation_rejects_plan_on_dead_pair(vgg_plan):
    ctrl = ReoptController(
        VGG16, 8, hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3),
        plan=vgg_plan,
    )
    pair = _topo_pair(ctrl.topology)
    healthy = ctrl.plan  # still has edges on what is about to die
    ctrl._run_optimizer = lambda warm=True: healthy
    ctrl._estimate_plan = lambda res: 0.0  # force the would-adopt path

    pause = ctrl.fail(pair, now=0.0)
    assert pause == 0.0
    assert ctrl.n_rejected_plans == 1 and ctrl.n_replans == 0
    assert ctrl.log[-1].trigger == "failure:invalid"
    assert not ctrl.log[-1].replanned
    # Last-known-good (degraded incumbent + §7 repair) stays in force.
    assert not set(ctrl.topology.graph.edges()) & {pair, (pair[1], pair[0])}
    assert not ctrl.plan_violations(ctrl.topology)


def test_plan_violations_checks(vgg_plan):
    ctrl = ReoptController(VGG16, 8, hw=HW, policy=ReoptPolicy.never(),
                           plan=vgg_plan)
    assert ctrl.plan_violations(ctrl.topology) == []
    pair = _topo_pair(ctrl.topology)
    ctrl.dead.add(pair)
    bad = ctrl.plan_violations(vgg_plan.topology)
    assert any("dead pairs" in v for v in bad)


def test_optimizer_crash_storm_backs_off(vgg_plan):
    calls = []
    ctrl = ReoptController(
        VGG16, 8, hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3,
                           min_interval=0.0, replan_retries=1,
                           retry_backoff=2.0),
        plan=vgg_plan,
    )

    def boom(warm=True):
        calls.append(warm)
        raise RuntimeError("optimizer crashed")

    ctrl._run_optimizer = boom
    pairs = sorted({(min(a, b), max(a, b))
                    for a, b in ctrl.topology.graph.edges()})

    assert ctrl.fail(pairs[0], now=0.0) == 0.0
    assert len(calls) == 2  # 1 attempt + replan_retries retries
    assert ctrl.n_optimizer_errors == 2 and ctrl.n_replans == 0
    assert sum(r.trigger.endswith(":error") for r in ctrl.log) == 2

    # Storm inside the backoff window: the optimizer is NOT re-run.
    assert ctrl.fail(pairs[1], now=0.5) == 0.0
    assert len(calls) == 2
    assert ctrl.log[-1].trigger.endswith(":backoff")
    # The §7-degraded incumbent still took the cut.
    assert pairs[1] in ctrl.dead

    # Past the backoff: attempts resume, and the backoff doubles.
    assert ctrl.fail(pairs[2], now=3.0) == 0.0
    assert len(calls) == 4
    assert ctrl._backoff_until == pytest.approx(3.0 + 4.0)


def test_replan_deadline_discards_slow_attempts(vgg_plan):
    import time

    calls = []
    ctrl = ReoptController(
        VGG16, 8, hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3,
                           min_interval=0.0, replan_deadline=5e-3,
                           replan_retries=1),
        plan=vgg_plan,
    )
    healthy = ctrl.plan

    def slow(warm=True):
        calls.append(warm)
        time.sleep(0.02)  # always over the 5 ms deadline
        return healthy

    ctrl._run_optimizer = slow
    ctrl.fail(_topo_pair(ctrl.topology), now=0.0)
    # First attempt discarded for overrunning; the last permitted attempt
    # keeps its (late) result rather than returning nothing.  That result
    # then flows through normal replan processing — where validation
    # rejects it, since the stale healthy plan still routes the dead pair.
    assert len(calls) == 2
    assert ctrl.n_optimizer_errors == 1
    assert sum(r.trigger.endswith(":deadline") for r in ctrl.log) == 1
    assert ctrl.log[-1].trigger == "failure:invalid"
    assert ctrl.n_rejected_plans == 1 and ctrl.n_replans == 0


def test_place_arrival_require_hostable():
    split = {(0, 1): 1.0, (1, 0): 1.0, (2, 3): 1.0, (3, 2): 1.0}
    free = {0, 1, 2, 3}
    assert place_arrival(3, free, split, require_hostable=True) is None
    assert place_arrival(2, free, split, require_hostable=True) == (0, 1)
    # Singleton jobs have no network demand: always hostable.
    assert place_arrival(1, free, split, require_hostable=True) is not None
    # Connectivity may transit busy servers (4 is not free).
    via_busy = {(0, 4): 1.0, (4, 0): 1.0, (4, 3): 1.0, (3, 4): 1.0}
    assert place_arrival(2, {0, 3}, via_busy, require_hostable=True) == (0, 3)
    # Connected fabric: the flag is a no-op (bit-identical placement).
    ring = {}
    for i in range(4):
        ring[(i, (i + 1) % 4)] = 1.0
        ring[((i + 1) % 4, i)] = 1.0
    assert (place_arrival(3, free, ring, require_hostable=True)
            == place_arrival(3, free, ring))


def test_admit_refuses_unhostable_arrival(monkeypatch):
    jobset = JobSet(n=6, tenants=[
        TenantJob(spec=VGG16, servers=(0, 1), name="v")])
    ctrl = JobSetController(jobset, hw=HW, policy=ReoptPolicy.never())
    monkeypatch.setattr(ctrl, "links", lambda: {
        (2, 3): 1.0, (3, 2): 1.0, (4, 5): 1.0, (5, 4): 1.0})

    assert ctrl.admit(DLRM, 3, name="d", now=4.25) is None
    assert ctrl.refused == [(4.25, "d")]
    assert all(t.label != "d" for t in ctrl.jobset.tenants)

    servers, pause = ctrl.admit(DLRM, 2, name="d2", now=5.0)
    assert servers == (2, 3) and pause == 0.0
    # k > free servers is still a hard caller error, not a refusal.
    with pytest.raises(ValueError, match="only"):
        ctrl.admit(DLRM, 5, name="d3")


def test_run_online_repair_event(vgg_plan):
    pair = _topo_pair(vgg_plan.topology)
    trace = (TraceEvent(iteration=1, kind="fail", link=pair),
             TraceEvent(iteration=2, kind="repair", link=pair))
    base = run_online(VGG16, 8, hw=HW, policy=ReoptPolicy.never(),
                      n_iters=4, plan=vgg_plan)
    faulted = run_online(VGG16, 8, hw=HW, policy=ReoptPolicy.never(),
                         trace=trace, n_iters=4, plan=vgg_plan)
    assert faulted.n_failures == 1
    assert faulted.iter_times[0] == pytest.approx(base.iter_times[0],
                                                  rel=1e-9)
    # Degraded iteration can only be slower; the repaired fabric (restored
    # capacity, detours kept until the next replan) can only be faster.
    assert faulted.iter_times[1] >= base.iter_times[1] * (1 - 1e-9)
    assert faulted.iter_times[2] <= faulted.iter_times[1] * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Property tests (hypothesis or the seeded shim)
# ---------------------------------------------------------------------------


def _random_storm_scenario(data):
    n = data.draw(st.integers(min_value=4, max_value=7))
    links = {}
    ring = []
    for i in range(n):
        pair = (i, (i + 1) % n)
        ring.append((min(pair), max(pair)))
        links[pair] = 100.0
        links[pair[::-1]] = 100.0
    jobs = []
    for j in range(data.draw(st.integers(min_value=1, max_value=3))):
        src = data.draw(st.integers(min_value=0, max_value=n - 1))
        dst = (src + data.draw(st.integers(min_value=1, max_value=n - 1))) % n
        nbytes = float(data.draw(st.integers(min_value=100, max_value=5000)))
        jobs.append(_flow_job(f"j{j}", nbytes=nbytes, route=(src, dst)))
    failures = []
    used = set()
    for _ in range(data.draw(st.integers(min_value=0, max_value=3))):
        pair = ring[data.draw(st.integers(min_value=0, max_value=n - 1))]
        if pair in used:
            continue  # one transient interval per pair keeps merges trivial
        used.add(pair)
        t0 = data.draw(st.floats(min_value=0.0, max_value=30.0))
        dur = data.draw(st.floats(min_value=0.1, max_value=20.0))
        failures.append(LinkFailure(time=t0, link=pair,
                                    repair_time=t0 + dur))
    failures.sort(key=lambda f: (f.time, f.link))
    restart = {jobs[0].name: data.draw(st.floats(min_value=0.0,
                                                 max_value=3.0))}
    return Scenario(links=links, jobs=jobs, n=n,
                    failures=tuple(failures), restart_s=restart)


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_random_transient_storms_conserve_bytes(data):
    """Every fault is transient, so every byte is eventually delivered —
    exactly the fault-free run's delivery."""
    sc = _random_storm_scenario(data)
    calm = Scenario(links=dict(sc.links), jobs=sc.jobs, n=sc.n)
    r_storm = SimEngine().run(sc)
    r_calm = SimEngine().run(calm)
    assert not r_storm.stalled
    assert r_storm.delivered == r_calm.delivered
    assert np.isfinite(r_storm.makespan)
    for job in r_storm.downtime:
        assert 0.0 <= r_storm.availability(job) <= 1.0


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_heap_dense_identical_through_fail_repair(data):
    """The heap and dense max-min fills stay bit-identical through
    fail -> repair round trips (capacity snapshots restore exactly)."""
    sc = _random_storm_scenario(data)
    results = {}
    for method in ("heap", "dense"):
        os.environ["REPRO_MAXMIN_METHOD"] = method
        try:
            results[method] = SimEngine().run(Scenario(
                links=dict(sc.links), jobs=sc.jobs, n=sc.n,
                failures=sc.failures, restart_s=dict(sc.restart_s)))
        finally:
            os.environ.pop("REPRO_MAXMIN_METHOD", None)
    h, d = results["heap"], results["dense"]
    assert h.makespan == d.makespan  # bit-identical, no tolerance
    assert h.job_finish == d.job_finish
    assert h.delivered == d.delivered
    assert h.downtime == d.downtime and h.restarts == d.restarts


_PROP_PLAN = None


def _prop_plan():
    global _PROP_PLAN
    if _PROP_PLAN is None:
        _PROP_PLAN = alternating_optimize(VGG16, 8, HW, rounds=1,
                                          mcmc_iters=10, seed=0)
    return _PROP_PLAN


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_fail_repair_interleavings_keep_degree_budget(data):
    """Any interleaving of fails and repairs keeps the incumbent inside
    the degree budget with no dead-pair edges; repairing everything
    restores the original edge multiset bit for bit."""
    plan = _prop_plan()
    ctrl = ReoptController(VGG16, 8, hw=HW, policy=ReoptPolicy.never(),
                           plan=plan)
    original = sorted(ctrl.topology.graph.edges())
    budget = ctrl.topology.degree + 1
    pairs = sorted({(min(a, b), max(a, b))
                    for a, b in ctrl.topology.graph.edges()})
    for _ in range(data.draw(st.integers(min_value=1, max_value=8))):
        pair = pairs[data.draw(st.integers(min_value=0,
                                           max_value=len(pairs) - 1))]
        if data.draw(st.integers(min_value=0, max_value=1)) and ctrl.dead:
            pair = sorted(ctrl.dead)[0]
            ctrl.repair(pair)
        else:
            ctrl.fail(pair)
        g = ctrl.topology.graph
        degs = [d for _, d in g.out_degree()]
        assert max(degs, default=0) <= budget
        for dead in ctrl.dead:
            assert not g.has_edge(*dead) and not g.has_edge(dead[1], dead[0])
            assert dead not in ctrl.links()
    for pair in sorted(ctrl.dead):
        ctrl.repair(pair)
    assert sorted(ctrl.topology.graph.edges()) == original
