"""Appendix C: shard scheduling with look-ahead pre-provisioning, plus the
pluggable (topology-aware) placement policies."""

import pytest

from repro.core.scheduler import (
    FLIP_S,
    PATCH_PANEL_RECONFIG_S,
    JobRequest,
    contiguous_fit,
    first_fit,
    mean_queueing_overhead,
    simulate,
)


def _burst(n_jobs, size=16, duration=3600.0, gap=0.0):
    return [
        JobRequest(jid=i, arrival_s=i * gap, n_servers=size, duration_s=duration)
        for i in range(n_jobs)
    ]


def test_lookahead_hides_reconfiguration():
    jobs = _burst(4, size=16, duration=600.0, gap=1000.0)
    with_la = simulate(64, jobs, lookahead=True)
    without = simulate(64, jobs, lookahead=False)
    # plenty of free servers: look-ahead jobs start after one reconfig worth
    # of provisioning (hidden while idle) + flip; single-plane always pays.
    for r in without:
        assert r.queueing_s >= PATCH_PANEL_RECONFIG_S
    assert mean_queueing_overhead(with_la) < mean_queueing_overhead(without)


def test_jobs_get_disjoint_shards():
    jobs = _burst(4, size=16, duration=1e6)  # all run concurrently
    recs = simulate(64, jobs, lookahead=True)
    seen = set()
    for r in recs:
        assert len(r.servers) == 16
        assert not (seen & set(r.servers)), "overlapping shards"
        seen |= set(r.servers)


def test_queueing_when_cluster_full():
    jobs = _burst(3, size=32, duration=100.0)
    recs = simulate(64, jobs, lookahead=True)
    # first two fit; the third waits for a finish.
    starts = sorted(r.start_s for r in recs)
    assert starts[2] >= min(r.end_s for r in recs[:2]) - 1e-6


def test_all_jobs_complete():
    jobs = _burst(10, size=16, duration=50.0, gap=10.0)
    recs = simulate(48, jobs, lookahead=True)
    assert all(r.end_s > r.start_s >= r.req.arrival_s for r in recs)


# ---------------------------------------------------------------------------
# Placement policies
# ---------------------------------------------------------------------------


def test_first_fit_picks_lowest_ids():
    assert first_fit({9, 3, 7, 1}, 2) == (1, 3)


def test_contiguous_fit_best_fit_block():
    free = set(range(0, 4)) | {8, 9} | set(range(12, 16))
    # Smallest adequate run wins: the 2-run at 8.
    assert contiguous_fit(free, 2) == (8, 9)
    # Two 4-runs fit; ties break toward the lower start.
    assert contiguous_fit(free, 4) == (0, 1, 2, 3)


def test_contiguous_fit_gathers_when_fragmented():
    free = {0, 1, 4, 5, 6, 9}
    chosen = contiguous_fit(free, 5)
    assert len(chosen) == 5 and set(chosen) <= free
    assert {4, 5, 6} <= set(chosen)  # largest fragment used first


def test_simulate_with_contiguous_placement():
    jobs = _burst(4, size=16, duration=1e6)
    recs = simulate(64, jobs, lookahead=True, placement="contiguous")
    seen = set()
    for r in recs:
        ids = sorted(r.servers)
        assert ids == list(range(ids[0], ids[0] + 16))  # one solid block
        assert not (seen & set(ids))
        seen |= set(ids)


def test_simulate_with_callable_placement():
    calls = []

    def reversed_fit(free, k):
        calls.append(k)
        return tuple(sorted(free, reverse=True)[:k])

    recs = simulate(32, _burst(2, size=8, duration=10.0),
                    placement=reversed_fit)
    assert calls and all(len(r.servers) == 8 for r in recs)
    assert 31 in recs[0].servers
