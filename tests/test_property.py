"""Hypothesis property tests on system invariants."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.routing import coin_change_mod
from repro.core.select_perms import coin_change_diameter, select_permutations
from repro.core.topology_finder import topology_finder
from repro.core.totient import coprimes, is_valid_ring, ring_edges, totient_perms
from repro.core.demand import TrafficDemand, AllReduceGroup
from repro.models.layers import chunked_linear_scan


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=2, max_value=200))
def test_totient_rings_always_valid(n):
    """Invariant (Theorem 2): every coprime stride is a Hamiltonian cycle."""
    for p in coprimes(n)[:8]:
        assert is_valid_ring(n, ring_edges(n, p))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=96),
    d=st.integers(min_value=1, max_value=6),
)
def test_coin_change_covers_group(n, d):
    """Invariant: routing over SelectPermutations strides reaches every node."""
    sel = select_permutations(totient_perms(range(n), prime_only=False), d)
    strides = [r.p for r in sel]
    if not strides:
        return
    bt = coin_change_mod(n, strides)
    assert set(bt) == set(range(1, n))
    # route lengths bounded by diameter
    diam = coin_change_diameter(n, strides)
    assert max(len(v) for v in bt.values()) == diam


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=32),
    degree=st.integers(min_value=1, max_value=6),
    ar_bytes=st.floats(min_value=1.0, max_value=1e9),
    mp_scale=st.floats(min_value=0.0, max_value=1e8),
    seed=st.integers(min_value=0, max_value=99),
)
def test_topology_finder_degree_invariant(n, degree, ar_bytes, mp_scale, seed):
    """Invariant: no node exceeds its interface budget; network connected."""
    rng = np.random.default_rng(seed)
    dem = TrafficDemand(n=n)
    dem.allreduce.append(AllReduceGroup(members=tuple(range(n)), nbytes=ar_bytes))
    mp = rng.random((n, n)) * mp_scale
    np.fill_diagonal(mp, 0.0)
    dem.mp = mp
    topo = topology_finder(dem, degree)
    assert topo.d_allreduce + topo.d_mp == degree
    assert topo.d_allreduce >= 1
    assert max(topo.out_degrees()) <= degree + 1  # ceil rounding slack
    import networkx as nx

    assert nx.is_strongly_connected(nx.DiGraph(topo.graph))


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=3),
    l=st.integers(min_value=1, max_value=65),
    d=st.integers(min_value=1, max_value=8),
    chunk=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=999),
)
def test_chunked_scan_equals_sequential(b, l, d, chunk, seed):
    """Invariant: chunked associative scan == plain sequential recurrence for
    any (shape, chunk) combination including non-dividing chunks."""
    rng = np.random.default_rng(seed)
    a = jnp.array(rng.uniform(0.2, 0.95, (b, l, d)), jnp.float32)
    drv = jnp.array(rng.standard_normal((b, l, d)), jnp.float32)
    h0 = jnp.array(rng.standard_normal((b, d)), jnp.float32)
    h_all, h_last = chunked_linear_scan(a, drv, h0, chunk=chunk)
    # sequential reference
    h = np.asarray(h0).copy()
    outs = []
    for t in range(l):
        h = np.asarray(a)[:, t] * h + np.asarray(drv)[:, t]
        outs.append(h.copy())
    ref = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_all), ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), ref[:, -1], rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(min_value=2, max_value=50),
    cols=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=99),
)
def test_embedding_bag_property(rows, cols, seed):
    from repro.kernels.embedding_bag import embedding_bag
    from repro.kernels.ref import ref_embedding_bag

    rng = np.random.default_rng(seed)
    tables = jnp.array(rng.standard_normal((2, rows, 8)), jnp.float32)
    idx = jnp.array(rng.integers(0, rows, (1, 2, cols)), jnp.int32)
    out = embedding_bag(tables, idx, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_embedding_bag(tables, idx)), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# Fleet-scale pricing (ISSUE 8): sparse fast paths == dense reference, bitwise
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=9),
    n_flows=st.integers(min_value=1, max_value=12),
    degraded=st.sampled_from([False, True]),
    weighted=st.sampled_from([False, True]),
    seed=st.integers(min_value=0, max_value=9999),
)
def test_maxmin_heap_bitwise_matches_dense(n, n_flows, degraded, weighted, seed):
    """Event-queue progressive filling == the dense reference, bit for bit,
    on random fabrics — including degraded fabrics (routes over unknown
    links) and weighted fairness."""
    from repro.core.simengine import Task, _FlowState, _LinkTable, _max_min_rates

    rng = np.random.default_rng(seed)
    pairs = [(i, j) for i in range(n) for j in range(n) if i != j]
    keep = max(1, int(len(pairs) * (0.4 if degraded else 0.9)))
    sel = rng.choice(len(pairs), size=keep, replace=False)
    table = _LinkTable({pairs[i]: float(rng.uniform(1.0, 100.0)) for i in sel})

    def mk_flows():
        flows = []
        rs = np.random.default_rng(seed + 1)
        for t in range(n_flows):
            k = int(rs.integers(2, min(n, 4) + 1))
            route = tuple(int(v) for v in rs.choice(n, size=k, replace=False))
            lids, cnts = table.indices_for(route)
            flows.append(_FlowState(
                task=Task(tid=t, kind="flow", nbytes=1e3, route=route),
                remaining=1e3, lids=lids, cnts=cnts, hops=len(route) - 1,
            ))
        return flows

    w = rng.uniform(0.25, 4.0, size=n_flows) if weighted else None
    dense = _max_min_rates(mk_flows(), table.cap, weights=w, method="dense")
    heap = _max_min_rates(mk_flows(), table.cap, weights=w, method="heap")
    assert not np.isnan(dense).any()
    assert np.array_equal(dense, heap)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(min_value=6, max_value=14),
    degraded=st.sampled_from([False, True]),
    seed=st.integers(min_value=0, max_value=999),
)
def test_planeval_sparse_pricing_bitwise_matches_dense(n, degraded, seed):
    """CSR/segment-sum PlanEvaluator pricing == the dense-incidence path,
    bit for bit: comm_time, loads, and the loads_delta move fast path."""
    import random as pyrandom

    from repro.core.netsim import HardwareSpec
    from repro.core.planeval import PlanEvaluator
    from repro.core.topology_finder import remove_pair, topology_finder
    from repro.core.workloads import DLRM, MOE_16E, job_demand

    hw = HardwareSpec(link_bandwidth=12.5e9, degree=4)
    rng = pyrandom.Random(seed)
    topo = topology_finder(
        job_demand(DLRM, n, table_hosts=tuple(range(0, n, 3))), hw.degree
    )
    if degraded:
        topo = remove_pair(topo, (0, 1))
    sparse = PlanEvaluator(topo, hw)  # sparse by default
    dense = PlanEvaluator(topo, hw, sparse_min_nodes_=1 << 30)
    assert sparse._sparse and not dense._sparse

    def rand_demand():
        if rng.random() < 0.5:
            hosts = tuple(sorted(rng.sample(range(n), rng.randint(1, n // 2))))
            return job_demand(DLRM, n, table_hosts=hosts)
        return job_demand(MOE_16E, n, ep_group_size=rng.choice([2, 4]))

    prev = None
    for _ in range(4):
        d = rand_demand()
        assert sparse.comm_time(d) == dense.comm_time(d)
        ls, ld = sparse.loads(d), dense.loads(d)
        assert np.array_equal(ls, ld)
        if prev is not None:
            assert np.array_equal(
                sparse.loads_delta(sparse.loads(prev), prev, d),
                dense.loads_delta(dense.loads(prev), prev, d),
            )
        prev = d


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=20),
    n_tenants=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=999),
)
def test_union_embedded_bitwise_matches_dense_union(n, n_tenants, seed):
    """Incremental (COO-embedded) union demand == remap-then-union dense
    reference: same matrix bits, same merged groups, same steps."""
    import random as pyrandom

    from repro.core.demand import remap_demand, union_demand, union_embedded
    from repro.core.workloads import BERT, DLRM, job_demand

    rng = pyrandom.Random(seed)
    parts = []
    for _ in range(n_tenants):
        k = rng.randint(2, max(2, n // 2))
        servers = tuple(rng.sample(range(n), k))
        spec = rng.choice([BERT, DLRM])
        d = job_demand(spec, k) if spec is BERT else job_demand(
            spec, k, table_hosts=tuple(range(0, k, 2))
        )
        parts.append((d, servers))

    ref = union_demand([remap_demand(d, s, n) for d, s in parts], n)
    fast = union_embedded(parts, n)
    assert np.array_equal(ref.mp, fast.mp)
    assert ref.steps == fast.steps
    assert [(g.members, g.nbytes) for g in ref.allreduce] == [
        (g.members, g.nbytes) for g in fast.allreduce
    ]
