"""Hypothesis property tests on system invariants."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.routing import coin_change_mod
from repro.core.select_perms import coin_change_diameter, select_permutations
from repro.core.topology_finder import topology_finder
from repro.core.totient import coprimes, is_valid_ring, ring_edges, totient_perms
from repro.core.demand import TrafficDemand, AllReduceGroup
from repro.models.layers import chunked_linear_scan


@settings(max_examples=60, deadline=None)
@given(n=st.integers(min_value=2, max_value=200))
def test_totient_rings_always_valid(n):
    """Invariant (Theorem 2): every coprime stride is a Hamiltonian cycle."""
    for p in coprimes(n)[:8]:
        assert is_valid_ring(n, ring_edges(n, p))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=96),
    d=st.integers(min_value=1, max_value=6),
)
def test_coin_change_covers_group(n, d):
    """Invariant: routing over SelectPermutations strides reaches every node."""
    sel = select_permutations(totient_perms(range(n), prime_only=False), d)
    strides = [r.p for r in sel]
    if not strides:
        return
    bt = coin_change_mod(n, strides)
    assert set(bt) == set(range(1, n))
    # route lengths bounded by diameter
    diam = coin_change_diameter(n, strides)
    assert max(len(v) for v in bt.values()) == diam


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=32),
    degree=st.integers(min_value=1, max_value=6),
    ar_bytes=st.floats(min_value=1.0, max_value=1e9),
    mp_scale=st.floats(min_value=0.0, max_value=1e8),
    seed=st.integers(min_value=0, max_value=99),
)
def test_topology_finder_degree_invariant(n, degree, ar_bytes, mp_scale, seed):
    """Invariant: no node exceeds its interface budget; network connected."""
    rng = np.random.default_rng(seed)
    dem = TrafficDemand(n=n)
    dem.allreduce.append(AllReduceGroup(members=tuple(range(n)), nbytes=ar_bytes))
    mp = rng.random((n, n)) * mp_scale
    np.fill_diagonal(mp, 0.0)
    dem.mp = mp
    topo = topology_finder(dem, degree)
    assert topo.d_allreduce + topo.d_mp == degree
    assert topo.d_allreduce >= 1
    assert max(topo.out_degrees()) <= degree + 1  # ceil rounding slack
    import networkx as nx

    assert nx.is_strongly_connected(nx.DiGraph(topo.graph))


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=3),
    l=st.integers(min_value=1, max_value=65),
    d=st.integers(min_value=1, max_value=8),
    chunk=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=999),
)
def test_chunked_scan_equals_sequential(b, l, d, chunk, seed):
    """Invariant: chunked associative scan == plain sequential recurrence for
    any (shape, chunk) combination including non-dividing chunks."""
    rng = np.random.default_rng(seed)
    a = jnp.array(rng.uniform(0.2, 0.95, (b, l, d)), jnp.float32)
    drv = jnp.array(rng.standard_normal((b, l, d)), jnp.float32)
    h0 = jnp.array(rng.standard_normal((b, d)), jnp.float32)
    h_all, h_last = chunked_linear_scan(a, drv, h0, chunk=chunk)
    # sequential reference
    h = np.asarray(h0).copy()
    outs = []
    for t in range(l):
        h = np.asarray(a)[:, t] * h + np.asarray(drv)[:, t]
        outs.append(h.copy())
    ref = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_all), ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), ref[:, -1], rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(min_value=2, max_value=50),
    cols=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=99),
)
def test_embedding_bag_property(rows, cols, seed):
    from repro.kernels.embedding_bag import embedding_bag
    from repro.kernels.ref import ref_embedding_bag

    rng = np.random.default_rng(seed)
    tables = jnp.array(rng.standard_normal((2, rows, 8)), jnp.float32)
    idx = jnp.array(rng.integers(0, rows, (1, 2, cols)), jnp.int32)
    out = embedding_bag(tables, idx, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_embedding_bag(tables, idx)), rtol=1e-5
    )
