"""Cost model (§5.2): validate the paper's headline cost ratios."""

import pytest

from repro.core.costmodel import (
    ClusterSpec,
    cost_equivalent_bandwidth_fraction,
    cost_report,
    expander_cost,
    fat_tree_cost,
    ideal_switch_cost,
    sipml_cost,
    topoopt_cost,
)


def test_ideal_vs_topoopt_ratio_about_3x():
    # Paper: "the ratio of Ideal Switch's cost to TOPOOPT's cost is 3.2x on
    # average"; at 4,394 servers the ratio is 3.0-3.6x.
    ratios = []
    for n in (128, 432, 1024, 4394):
        spec = ClusterSpec(n_servers=n, degree=4, link_gbps=100)
        ratios.append(ideal_switch_cost(spec) / topoopt_cost(spec))
    avg = sum(ratios) / len(ratios)
    assert 2.2 <= avg <= 4.5, ratios


def test_ocs_vs_patch_panel_ratio():
    # Paper: OCS-based TopoOpt is 1.33x the patch-panel build on average.
    spec = ClusterSpec(n_servers=432, degree=4, link_gbps=100)
    ratio = topoopt_cost(spec, use_ocs=True) / topoopt_cost(spec, use_ocs=False)
    assert 1.15 <= ratio <= 1.6, ratio


def test_cost_ordering():
    spec = ClusterSpec(n_servers=128, degree=4, link_gbps=100)
    rep = cost_report(spec)
    # Expander cheapest (no optical layer); SiP-ML and Ideal most expensive.
    assert rep["expander"] < rep["topoopt_patch"]
    assert rep["ideal_switch"] > rep["topoopt_patch"]
    assert rep["sipml"] > rep["topoopt_patch"]
    assert rep["oversub_fat_tree"] < rep["ideal_switch"]


def test_cost_equivalent_fraction_in_range():
    spec = ClusterSpec(n_servers=128, degree=4, link_gbps=100)
    frac = cost_equivalent_bandwidth_fraction(spec)
    assert 0.05 < frac < 1.0
    # fat tree at that fraction costs ~ topoopt
    assert fat_tree_cost(spec, bandwidth_fraction=frac) == pytest.approx(
        topoopt_cost(spec), rel=0.15
    )


def test_costs_scale_with_n():
    small = ClusterSpec(n_servers=128, degree=4)
    big = ClusterSpec(n_servers=1024, degree=4)
    assert topoopt_cost(big) > 6 * topoopt_cost(small)
    assert expander_cost(big) == pytest.approx(8 * expander_cost(small))
