"""Collective-schedule co-optimization: the schedule axis of the strategy
search (repro.core.schedules threaded through mcmc_search / jobset search /
alternating), pinned three ways:

* **HEAD goldens** — with no ``schedules`` argument and ``link_latency=0``
  every search entry point must reproduce the exact pre-schedule results
  (fixed seeds, hardcoded values captured before the schedule axis landed).
* **Compiled == reference** — schedule-tagged demands price bit-identically
  on the compiled planner and the reference fluid model, healthy and
  degraded, with the (α, β) latency term on.
* **Error paths** — unknown schedules, non-coprime strides, degenerate
  groups all fail loudly.
"""

import numpy as np
import pytest

from repro.core.alternating import (
    alternating_optimize,
    co_optimize_jobset,
    initial_topology,
)
from repro.core.demand import demand_steps
from repro.core.netsim import HardwareSpec, compute_time, reference_comm_time
from repro.core.simengine import iteration_time
from repro.core.planeval import plan_evaluator
from repro.core.schedules import SCHEDULES, get_schedule, validate_hd_group
from repro.core.select_perms import schedule_strides
from repro.core.strategy_search import (
    Strategy,
    default_strategy,
    mcmc_search,
    mcmc_search_jobset,
)
from repro.core.topology_finder import remove_pair, topology_finder
from repro.core.workloads import (
    BERT,
    DLRM,
    MOE_16E,
    JobSet,
    TenantJob,
    job_demand,
)

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)
# α > 0 turns the latency term on; big enough to matter at these scales.
HW_LAT = HardwareSpec(link_bandwidth=12.5e9, degree=4, link_latency=2e-5)
ALL = ("ring", "recursive_hd", "multi_tree")


def _jobset12() -> JobSet:
    return JobSet(n=12, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, 4))),
        TenantJob(spec=BERT, servers=tuple(range(4, 8))),
        TenantJob(spec=MOE_16E, servers=tuple(range(8, 12))),
    ])


# ---------------------------------------------------------------------------
# HEAD goldens: the ring default is byte-identical to the pre-schedule tree
# ---------------------------------------------------------------------------


def test_golden_mcmc_search_ring_default():
    res = mcmc_search(DLRM, initial_topology(16, 4), HW, iters=60, seed=0)
    assert res.strategy == Strategy(
        mode="hybrid", table_hosts=(2, 3, 4, 6, 7, 14, 15), ep_group_size=0
    )
    assert res.strategy.schedule == "ring"
    assert res.iter_time == 0.04776528704703296


def test_golden_alternating_optimize_ring_default():
    res = alternating_optimize(DLRM, 16, HW, rounds=2, mcmc_iters=40, seed=0)
    assert res.strategy == Strategy(
        mode="hybrid", table_hosts=(2, 3, 4, 6, 7, 14, 15), ep_group_size=0
    )
    assert res.iter_time == float.fromhex("0x1.874b113808acdp-5")


def test_golden_mcmc_search_jobset_ring_default():
    res = mcmc_search_jobset(
        _jobset12(), initial_topology(12, 4), HW, iters=40, seed=0
    )
    assert res.strategies == {
        "dlrm": Strategy(mode="dp"),
        "bert": Strategy(mode="dp"),
        "moe16": Strategy(mode="dp", ep_group_size=2),
    }
    assert all(s.schedule == "ring" for s in res.strategies.values())
    assert res.iter_time == 0.006020768047407407


def test_golden_co_optimize_jobset_ring_default():
    res = co_optimize_jobset(_jobset12(), HW, rounds=2, mcmc_iters=30, seed=1)
    assert res.strategies == {
        "dlrm": Strategy(mode="hybrid", table_hosts=(2,)),
        "bert": Strategy(mode="dp"),
        "moe16": Strategy(mode="dp", ep_group_size=2),
    }
    assert res.iter_time == float.fromhex("0x1.82292122132c0p-4")


def test_singleton_schedules_tuple_matches_none():
    """schedules=("ring",) adds no proposal move, so the RNG stream — and
    every result byte — matches the default search exactly."""
    topo = initial_topology(12, 4)
    base = mcmc_search(DLRM, topo, HW, iters=50, seed=7)
    same = mcmc_search(DLRM, topo, HW, iters=50, seed=7, schedules=("ring",))
    assert same.strategy == base.strategy
    assert same.iter_time == base.iter_time
    assert same.history == base.history
    js = _jobset12()
    topo_js = initial_topology(12, 4)
    b = mcmc_search_jobset(js, topo_js, HW, iters=30, seed=3)
    s = mcmc_search_jobset(js, topo_js, HW, iters=30, seed=3,
                           schedules=("ring",))
    assert s.strategies == b.strategies
    assert s.iter_time == b.iter_time
    assert s.history == b.history


# ---------------------------------------------------------------------------
# Compiled == reference on schedule-tagged demands (healthy + degraded)
# ---------------------------------------------------------------------------


def _schedule_demands(n: int) -> list:
    out = []
    for name in ALL:
        out.append(job_demand(BERT, n, schedule=name))
        out.append(job_demand(MOE_16E, n, ep_group_size=4, schedule=name))
        out.append(
            job_demand(DLRM, n, table_hosts=(0, 3), schedule=name)
        )
    return out


@pytest.mark.parametrize("degrade", [False, True])
def test_compiled_pricing_bit_identical_with_latency(degrade):
    n = 8
    topo = topology_finder(job_demand(DLRM, n, table_hosts=(0, 3)), HW.degree)
    if degrade:
        topo = remove_pair(topo, (0, 1))
    ev = plan_evaluator(topo, HW_LAT)
    for d in _schedule_demands(n):
        fast = ev.comm_time(d)
        ref = reference_comm_time(topo, d, HW_LAT)
        assert fast == ref  # bit-identical: max_rel_err == 0
        assert fast > 0.0


def test_jax_batched_pricing_matches_with_latency():
    from repro.core.planeval_jax import JAX_EQUIV_RTOL, jax_plan_evaluator

    n = 8
    topo = topology_finder(job_demand(DLRM, n, table_hosts=(0, 3)), HW.degree)
    jev = jax_plan_evaluator(topo, HW_LAT)
    demands = _schedule_demands(n)
    batch = jev.comm_times(demands)
    ev = plan_evaluator(topo, HW_LAT)
    single = np.asarray([ev.comm_time(d) for d in demands])
    assert np.allclose(batch, single, rtol=JAX_EQUIV_RTOL)


@pytest.mark.parametrize("seed", [0, 3])
def test_mcmc_search_with_schedules_compiled_identical(seed):
    topo = initial_topology(8, 4)
    ref = mcmc_search(MOE_16E, topo, HW_LAT, iters=60, seed=seed,
                      schedules=ALL, compiled=False)
    fast = mcmc_search(MOE_16E, topo, HW_LAT, iters=60, seed=seed,
                       schedules=ALL, compiled=True)
    assert fast.strategy == ref.strategy
    assert fast.iter_time == pytest.approx(ref.iter_time, rel=1e-9)
    assert np.allclose(fast.history, ref.history, rtol=1e-9)
    assert ref.strategy.schedule in ALL


@pytest.mark.parametrize("objective", ["union", "decomposed"])
def test_mcmc_search_jobset_with_schedules_compiled_identical(objective):
    js = _jobset12()
    init = {t.label: default_strategy(t.spec) for t in js.tenants}
    topo = topology_finder(js.union_for(init), HW.degree, pack="per_node")
    ref = mcmc_search_jobset(js, topo, HW_LAT, iters=40, seed=2,
                             schedules=ALL, objective=objective,
                             compiled=False)
    fast = mcmc_search_jobset(js, topo, HW_LAT, iters=40, seed=2,
                              schedules=ALL, objective=objective,
                              compiled=True)
    assert fast.strategies == ref.strategies
    assert fast.iter_time == pytest.approx(ref.iter_time, rel=1e-9)
    assert np.allclose(fast.history, ref.history, rtol=1e-9)
    for label in ref.per_job:
        assert fast.per_job[label] == pytest.approx(
            ref.per_job[label], rel=1e-9
        )


def test_jax_backend_with_schedules_repriced_on_numpy():
    """backend="jax" explores a schedule-widened pool; the winner's
    iter_time must equal the bit-exact NumPy pricing of that strategy."""
    n = 8
    topo = initial_topology(n, 4)
    res = mcmc_search(MOE_16E, topo, HW_LAT, iters=40, seed=0,
                      backend="jax", schedules=ALL, pool_size=24)
    assert res.strategy.schedule in ALL
    ev = plan_evaluator(topo, HW_LAT)
    demand = res.strategy.demand(MOE_16E, n)
    comp = compute_time(
        MOE_16E.flops_per_sample * MOE_16E.batch_per_gpu * n, n, HW_LAT
    )
    assert res.iter_time == iteration_time(ev.comm_time(demand), comp)


def test_chain_kernel_latency_matches_reference():
    """ChainKernel's trailing (steps, alpha) params agree with the
    sequential NumPy replay to reassociation level."""
    from repro.core.planeval_jax import (
        ChainKernel,
        draw_proposal_streams,
        run_chains_reference,
    )

    rng = np.random.default_rng(0)
    T, S, L = 3, 6, 10
    V = rng.uniform(0.0, 1.0, size=(T, S, L))
    V[V < 0.3] = 0.0
    caps = rng.uniform(0.5, 2.0, size=L)
    comps = rng.uniform(0.1, 0.5, size=T)
    weights = rng.uniform(0.5, 2.0, size=T)
    steps = rng.integers(2, 30, size=(T, S)).astype(np.float64)
    alpha = 1e-2
    t_idx, s_idx, u = draw_proposal_streams(5, 4, 25, T, S)
    init_a = np.zeros(T, dtype=np.int64)
    temps = np.full(4, 0.1)
    for objective in ("union", "decomposed"):
        kernel = ChainKernel(V, caps, comps, weights, overlap=0.3,
                             objective=objective, steps=steps, alpha=alpha)
        best_a, best, hist = kernel.run(init_a, temps, t_idx, s_idx, u)
        ref_a, ref_best, ref_hist = run_chains_reference(
            V, caps, comps, weights, 0.3, objective, init_a, temps,
            t_idx, s_idx, u, steps=steps, alpha=alpha,
        )
        assert np.array_equal(best_a, ref_a), objective
        assert np.allclose(best, ref_best, rtol=1e-9)
        assert np.allclose(hist, ref_hist, rtol=1e-9)


def test_reopt_policy_threads_schedules():
    """ReoptPolicy.schedules reaches the replan optimizer: a controller
    with the full schedule tuple plans successfully and its strategy
    carries a valid schedule tag."""
    from repro.core.online import ReoptController, ReoptPolicy

    ctrl = ReoptController(
        MOE_16E, 8, hw=HW_LAT,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3,
                           schedules=ALL),
    )
    plan = ctrl.ensure_plan()
    assert plan.strategy.schedule in ALL
    ctrl.fail((0, 1), now=0.0)
    assert ctrl.strategy.schedule in ALL


# ---------------------------------------------------------------------------
# Error paths: unknown schedules, bad strides, degenerate groups
# ---------------------------------------------------------------------------


def test_unknown_schedule_errors():
    with pytest.raises(ValueError, match="unknown collective schedule"):
        get_schedule("butterfly")
    with pytest.raises(ValueError, match="unknown collective schedule"):
        job_demand(BERT, 8, schedule="butterfly")
    with pytest.raises(ValueError, match="unknown collective schedule"):
        mcmc_search(BERT, initial_topology(8, 4), HW, iters=1,
                    schedules=("ring", "butterfly"))
    with pytest.raises(ValueError, match="unknown schedule family"):
        schedule_strides(8, "butterfly")


def test_stride_validation_errors():
    from repro.core.collectives import _mod_inverse, multi_ring_all_reduce
    from repro.core.totient import ring_order

    with pytest.raises(ValueError, match="not coprime"):
        _mod_inverse(2, 8)  # gcd(2, 8) = 2: no ring
    with pytest.raises(ValueError, match="not a ring"):
        ring_order(8, 4)
    with pytest.raises(ValueError, match="at least one ring stride"):
        multi_ring_all_reduce(np.zeros(4), "x", ())
    with pytest.raises(ValueError, match="at least one tree stride"):
        from repro.core.collectives import multi_tree_all_reduce

        multi_tree_all_reduce(np.zeros(4), "x", ())


def test_degenerate_group_errors():
    with pytest.raises(ValueError, match=">= 2"):
        validate_hd_group(1)  # n=1 "group" has nothing to halve
    with pytest.raises(ValueError, match=">= 2"):
        get_schedule("multi_tree").pair_loads((5,), 100.0)
    with pytest.raises(TypeError, match="not compiled"):
        get_schedule("ring").pair_loads((0, 1), 1.0)
    # Schedule stride families: empty below 2 ranks, never above.
    assert schedule_strides(1, "recursive_hd") == ()
    assert schedule_strides(1, "multi_tree") == ()
    assert schedule_strides(8, "recursive_hd") == (1, 2, 4)


def test_compiled_demand_keeps_connectivity_ring():
    """apply_schedule leaves a zero-byte group so the TopologyFinder still
    reserves a ring over the members (the schedule's pinned pairs then ride
    matched direct links)."""
    d = job_demand(BERT, 8, schedule="recursive_hd")
    assert [g.nbytes for g in d.allreduce] == [0.0]
    assert d.allreduce[0].members == tuple(range(8))
    assert demand_steps(d) == 6.0  # 2 log2(8) rounds vs ring's 14
    topo = topology_finder(d, 4)
    assert max(topo.out_degrees()) <= 4


def test_topoopt_psum_fn_picks_searched_schedule():
    """Runtime kernel selection follows the searched ``Strategy.schedule``:
    the trainer no longer always rings (ROADMAP smaller item)."""
    from dataclasses import replace

    from jax import lax

    from repro.core.collectives import (
        multi_ring_all_reduce,
        multi_tree_all_reduce,
        recursive_hd_all_reduce,
        topoopt_psum_fn,
    )

    # Pre-schedule behavior is the default: strides ring, no strides psum.
    assert topoopt_psum_fn((1, 3), "x").func is multi_ring_all_reduce
    assert topoopt_psum_fn((), "x").func is lax.psum

    # A searched strategy carrying the HD schedule drives the HD kernel.
    s = replace(default_strategy(BERT), schedule="recursive_hd")
    fn = topoopt_psum_fn((1, 2, 4), "x", schedule=s.schedule, group_size=8)
    assert fn.func is recursive_hd_all_reduce

    # The strict HD kernel cannot run a non-power-of-two group: selection
    # folds back to the ring family (what the demand compiler does with
    # straggler nodes), never raising at trace time.
    fn = topoopt_psum_fn((1, 5), "x", schedule="recursive_hd", group_size=6)
    assert fn.func is multi_ring_all_reduce

    # Multi-tree takes the TotientPerms ring orders as tree seeds.
    strides = schedule_strides(8, "multi_tree", 2)
    fn = topoopt_psum_fn(strides, "x", schedule="multi_tree", group_size=8)
    assert fn.func is multi_tree_all_reduce
    assert fn.keywords["strides"] == strides
    assert topoopt_psum_fn((), "x", schedule="multi_tree").func is lax.psum

    with pytest.raises(ValueError, match="unknown collective schedule"):
        topoopt_psum_fn((1,), "x", schedule="butterfly")
