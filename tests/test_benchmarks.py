"""Benchmark harness smoke tests (reduced parameters) + paper-claim checks
that the full runs validate at scale."""

import pytest


def test_cost_bench():
    from benchmarks import bench_cost

    rows = bench_cost.run()
    assert len(rows) >= 4
    for row in rows:
        assert row["ideal_switch"] > row["topoopt_patch"]


def test_alltoall_bench_tax_grows_with_degree_drop():
    from benchmarks import bench_alltoall

    rows = bench_alltoall.run(batches=(128,), degrees=(4, 8))
    tax = {r["name"]: r["bandwidth_tax"] for r in rows}
    # higher degree -> lower forwarding tax (Fig. 13)
    assert tax["alltoall_d8_bs128"] < tax["alltoall_d4_bs128"]


def test_pathlen_bench_degree_effect():
    from benchmarks import bench_pathlen

    rows = bench_pathlen.run(degrees=(4, 8))
    mp = {r["name"]: r["mean_path"] for r in rows}
    # Fig. 14: mean path length drops substantially from d=4 to d=8
    assert mp["pathlen_d8"] < mp["pathlen_d4"]
    assert mp["pathlen_d4"] < 8.0


def test_dedicated_bench_single_model():
    from benchmarks import bench_dedicated

    rows = bench_dedicated.run(models=("vgg16",), bandwidths=(100,),
                               mcmc_iters=20)
    row = rows[0]
    # similar-cost fat-tree is slower than TopoOpt; ideal >= TopoOpt comm.
    assert row["fat_tree_s"] > row["topoopt_s"]
    assert row["fat_tree_paper_s"] > row["fat_tree_s"] * 0.99


def test_shared_bench_ratio_grows_with_load():
    from benchmarks import bench_shared

    rows = bench_shared.run(loads=(0.2, 1.0))
    r20 = float(rows[0]["fat_tree_mean"] / rows[0]["topoopt_mean"])
    r100 = float(rows[1]["fat_tree_mean"] / rows[1]["topoopt_mean"])
    assert r100 > r20 > 1.0
