"""Benchmark harness smoke tests (reduced parameters) + paper-claim checks
that the full runs validate at scale."""

import pytest


def test_cost_bench():
    from benchmarks import bench_cost

    rows = bench_cost.run()
    assert len(rows) >= 4
    for row in rows:
        assert row["ideal_switch"] > row["topoopt_patch"]


def test_alltoall_bench_tax_grows_with_degree_drop():
    from benchmarks import bench_alltoall

    rows = bench_alltoall.run(batches=(128,), degrees=(4, 8))
    tax = {r["name"]: r["bandwidth_tax"] for r in rows}
    # higher degree -> lower forwarding tax (Fig. 13)
    assert tax["alltoall_d8_bs128"] < tax["alltoall_d4_bs128"]


def test_pathlen_bench_degree_effect():
    from benchmarks import bench_pathlen

    rows = bench_pathlen.run(degrees=(4, 8))
    mp = {r["name"]: r["mean_path"] for r in rows}
    # Fig. 14: mean path length drops substantially from d=4 to d=8
    assert mp["pathlen_d8"] < mp["pathlen_d4"]
    assert mp["pathlen_d4"] < 8.0


def test_dedicated_bench_single_model():
    from benchmarks import bench_dedicated

    rows = bench_dedicated.run(models=("vgg16",), bandwidths=(100,),
                               mcmc_iters=20)
    row = rows[0]
    # similar-cost fat-tree is slower than TopoOpt; ideal >= TopoOpt comm.
    assert row["fat_tree_s"] > row["topoopt_s"]
    assert row["fat_tree_paper_s"] > row["fat_tree_s"] * 0.99


def test_shared_bench_ratio_grows_with_load():
    from benchmarks import bench_shared

    rows = bench_shared.run(loads=(0.2, 1.0))
    r20 = float(rows[0]["fat_tree_mean"] / rows[0]["topoopt_mean"])
    r100 = float(rows[1]["fat_tree_mean"] / rows[1]["topoopt_mean"])
    assert r100 > r20 > 1.0


def test_multitenant_bench_smoke(tmp_path, monkeypatch):
    """Shared-fabric reactive re-optimization must beat the static shared
    plan on the 3-job churn trace, and weighting a tenant must not slow it."""
    from benchmarks import bench_multitenant

    monkeypatch.chdir(tmp_path)  # perf record lands in a scratch dir
    rows = bench_multitenant.run(smoke=True)
    by_name = {r["name"]: r for r in rows}
    churn = by_name["multitenant_churn"]
    assert churn["static_s"] > churn["reactive_s"]
    assert churn["reactive_replans"] >= 1
    assert churn["edges_moved"] >= 1
    weighted = by_name["multitenant_weighted"]
    assert weighted["dlrm_weighted_s"] <= weighted["dlrm_unweighted_s"] * (
        1 + 1e-9
    )
    assert (tmp_path / "experiments" / "bench"
            / "BENCH_multitenant.json").exists()


def test_planner_bench_smoke(tmp_path, monkeypatch):
    """The compiled evaluator must agree with the reference objective and
    leave fixed-seed search results unchanged; candidate pricing must be
    dramatically faster even at smoke sizes."""
    from benchmarks import bench_planner

    monkeypatch.chdir(tmp_path)  # perf record lands in a scratch dir
    rows = bench_planner.run(smoke=True)
    by_name = {r["name"].rsplit("_n", 1)[0]: r for r in rows}
    cand = by_name["planner_candidate_evals"]
    assert cand["max_rel_err"] <= 1e-9
    assert cand["speedup"] > 5.0  # full run tracks ~35x; smoke is smaller
    assert by_name["planner_alternating"]["identical"]
    assert by_name["planner_replan"]["identical"]
    assert (tmp_path / "experiments" / "bench"
            / "BENCH_planner.json").exists()


def test_collectives_sched_bench_smoke(tmp_path, monkeypatch):
    """Searched collective schedules must beat ring-only on the
    latency-dominated arms, keep ring on the bandwidth-dominated arm, and
    price bit-identically on the compiled and reference paths."""
    from benchmarks import bench_collectives_sched

    monkeypatch.chdir(tmp_path)  # perf record lands in a scratch dir
    rows = bench_collectives_sched.run(smoke=True)
    by_name = {r["name"].rsplit("_n", 1)[0]: r for r in rows}
    assert by_name["sched_small_bert"]["comm_win"] >= 1.2
    assert by_name["sched_small_bert"]["schedule"] != "ring"
    assert by_name["sched_jobset"]["comm_win"] >= 1.2
    assert by_name["sched_jobset"]["flipped"]
    assert by_name["sched_dlrm_bandwidth"]["schedule"] == "ring"
    assert max(r["max_rel_err"] for r in rows) == 0.0
    assert (tmp_path / "experiments" / "bench"
            / "BENCH_collectives_sched.json").exists()


def test_fleet_bench_smoke(tmp_path, monkeypatch):
    """Sparse fleet pricing must clear its gates even at smoke sizes:
    >= 10x candidate pricing and >= 5x replans vs the forced-dense
    baseline at 256 nodes, bitwise identity at seed sizes, and a
    512-node / 200-tenant churn trace completing."""
    from benchmarks import bench_fleet

    monkeypatch.chdir(tmp_path)  # perf record lands in a scratch dir
    rows = bench_fleet.run(smoke=True)
    by_name = {r["name"]: r for r in rows}
    assert by_name["fleet_candidate_pricing"]["speedup"] >= 10.0
    assert by_name["fleet_replan"]["speedup"] >= 5.0
    fleet = by_name["fleet_churn"]
    assert fleet["n"] == 512 and fleet["n_tenants"] == 200
    assert fleet["events_per_s"] > 0
    assert (tmp_path / "experiments" / "bench"
            / "BENCH_fleet.json").exists()


def test_admission_jax_bench_smoke(tmp_path, monkeypatch):
    """The fused candidate x ladder admission co-search must clear the
    >= 3x end-to-end bar at smoke sizes, never regress plan quality vs
    the sequential baseline at the same seed, and the module's own
    asserts pin the NumPy-exact re-price of the winner."""
    from benchmarks import bench_admission_jax

    monkeypatch.chdir(tmp_path)  # perf record lands in a scratch dir
    rows = bench_admission_jax.run(smoke=True)
    row = rows[0]
    assert row["speedup"] >= bench_admission_jax.MIN_ADMISSION_SPEEDUP
    assert row["fused_iter_time"] <= row["seq_iter_time"] * (1 + 1e-9)
    assert row["candidates"] >= 4 and row["ladder"] >= 4
    assert (tmp_path / "experiments" / "bench"
            / "BENCH_admission_jax.json").exists()
