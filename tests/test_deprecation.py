"""Deprecation shims: legacy entry points warn and forward to simengine.

* Every subsumed name on ``netsim`` / ``ocs_reconfig`` / ``packetsim``
  emits a :class:`DeprecationWarning` on attribute access and resolves to
  the same object the warning points at (``repro.core.simengine``).
* The blessed ``simengine`` surface — and plain imports of the shim
  modules themselves — stay warning-free, so tier-1 runs clean.
"""

from __future__ import annotations

import sys
import warnings

import pytest

from repro.core import netsim, ocs_reconfig, packetsim, simengine


@pytest.mark.parametrize("name", [
    "topoopt_comm_time",
    "ideal_switch_comm_time",
    "fat_tree_comm_time",
    "iteration_time",
])
def test_netsim_shims_warn_and_forward(name):
    with pytest.warns(DeprecationWarning, match="repro.core.simengine"):
        legacy = getattr(netsim, name)
    assert legacy is getattr(simengine, name)


@pytest.mark.parametrize("name", [
    "ocs_topology",
    "RECONFIG_WINDOW",
    "RECONFIG_LATENCY",
])
def test_ocs_reconfig_shims_warn_and_forward(name):
    with pytest.warns(DeprecationWarning, match="repro.core.simengine"):
        legacy = getattr(ocs_reconfig, name)
    blessed = getattr(simengine, name)
    assert legacy is blessed or legacy == blessed


@pytest.mark.parametrize("name", [
    "PROPAGATION_DELAY",
    "FlowSimVec",
    "SimResult",
    "Task",
])
def test_packetsim_shims_warn_and_forward(name):
    with pytest.warns(DeprecationWarning, match="simengine"):
        legacy = getattr(packetsim, name)
    blessed = getattr(simengine, name)
    assert legacy is blessed or legacy == blessed


def test_packetsim_flowsim_is_flowsimvec_subclass():
    with pytest.warns(DeprecationWarning):
        cls = packetsim.FlowSim
    assert issubclass(cls, simengine.FlowSimVec)
    with pytest.warns(DeprecationWarning):
        assert packetsim.FlowSim is cls  # lazy class is built once


def test_packetsim_links_of_warns():
    import networkx as nx

    g = nx.MultiDiGraph()
    g.add_edge(0, 1)
    g.add_edge(0, 1)
    with pytest.warns(DeprecationWarning):
        links_of = packetsim.links_of
    assert links_of(g) == {(0, 1): 2.0}


@pytest.mark.parametrize("module", [netsim, ocs_reconfig, packetsim])
def test_unknown_attribute_still_raises(module):
    with pytest.raises(AttributeError):
        module.definitely_not_a_thing


def test_simengine_surface_warning_free():
    """The blessed re-export home must never warn."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for name in [
            "topoopt_comm_time", "ideal_switch_comm_time",
            "fat_tree_comm_time", "iteration_time", "RECONFIG_WINDOW",
            "RECONFIG_LATENCY", "ocs_topology", "PROPAGATION_DELAY",
            "FlowSimVec", "SimResult", "Task", "SimEngine", "Scenario",
        ]:
            getattr(simengine, name)


@pytest.mark.slow
def test_core_imports_warning_free():
    """Importing every repro.core module (fresh interpreter) must not
    trip any deprecation shim — internal consumers all moved to the
    private aliases / simengine re-exports."""
    from _subproc import run_with_devices

    run_with_devices(
        """
import pkgutil, warnings, importlib
warnings.simplefilter("error", DeprecationWarning)
import repro.core
for m in pkgutil.iter_modules(repro.core.__path__):
    importlib.import_module(f"repro.core.{m.name}")
print("clean")
""",
        n_devices=1,
    )


def test_this_process_has_no_shim_side_effects():
    """Accessing the shims above must not have mutated the blessed
    modules: the simengine names still resolve without warnings."""
    assert "repro.core.simengine" in sys.modules
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        simengine.topoopt_comm_time
        simengine.iteration_time
