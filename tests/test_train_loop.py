"""Fault-tolerant training loop: loss decreases, checkpoint/restart resumes
at the exact step, data pipeline is restart-deterministic."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step
from repro.configs.base import ShapeSpec, get_config
from repro.data.pipeline import DataSpec, Prefetcher, batch_for_step
from repro.models import lm
from repro.optim import adamw, cosine
from repro.parallel.sharding import ShardingPlan
from repro.train.loop import InjectedFailure, train

SMOKE = get_config("granite-8b").smoke()
SHAPE = ShapeSpec("tiny", seq_len=32, global_batch=4, kind="train")


def _mesh():
    return jax.make_mesh((1,), ("data",))


def test_loss_decreases():
    res = train(
        SMOKE, SHAPE, adamw(cosine(3e-3, 60, warmup=3)), ShardingPlan(fsdp=False),
        _mesh(), total_steps=25, ckpt_dir=None, log_every=100, logger=lambda *a: None,
    )
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first, (first, last)


def test_failure_injection_and_resume(tmp_path):
    opt = adamw(cosine(1e-3, 60, warmup=3))
    plan = ShardingPlan(fsdp=False)
    with pytest.raises(InjectedFailure):
        train(SMOKE, SHAPE, opt, plan, _mesh(), total_steps=20,
              ckpt_dir=str(tmp_path), ckpt_every=5, fail_at=12,
              log_every=100, logger=lambda *a: None)
    assert latest_step(str(tmp_path)) == 10  # last periodic ckpt before crash

    res = train(SMOKE, SHAPE, opt, plan, _mesh(), total_steps=20,
                ckpt_dir=str(tmp_path), ckpt_every=5,
                log_every=100, logger=lambda *a: None)
    assert res.final_step == 20
    # resumed: only steps 10..20 were run this time
    assert len(res.losses) == 10
    assert latest_step(str(tmp_path)) == 20


def test_data_pipeline_deterministic():
    spec = DataSpec(cfg=SMOKE, shape=SHAPE, seed=3)
    b1 = batch_for_step(spec, 17)
    b2 = batch_for_step(spec, 17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_for_step(spec, 18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_prefetcher_order_and_close():
    spec = DataSpec(cfg=SMOKE, shape=SHAPE, seed=0)
    pf = Prefetcher(spec, start_step=5, depth=2)
    try:
        for expect in (5, 6, 7):
            step, batch = pf.next()
            assert step == expect
            ref = batch_for_step(spec, expect)
            np.testing.assert_array_equal(batch["tokens"], ref["tokens"])
    finally:
        pf.close()


def test_process_sharded_batches():
    spec0 = DataSpec(cfg=SMOKE, shape=SHAPE, seed=0, process_index=0, process_count=2)
    spec1 = DataSpec(cfg=SMOKE, shape=SHAPE, seed=0, process_index=1, process_count=2)
    b0 = batch_for_step(spec0, 0)
    b1 = batch_for_step(spec1, 0)
    assert b0["tokens"].shape[0] == 2 and b1["tokens"].shape[0] == 2
