import pytest

from repro.core.alternating import alternating_optimize, evaluate, initial_topology
from repro.core.netsim import HardwareSpec
from repro.core.strategy_search import Strategy, mcmc_search
from repro.core.workloads import CANDLE, DLRM, PAPER_JOBS, job_demand


HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)


def test_dlrm_prefers_hybrid():
    # §2.1: hybrid placement beats pure DP for DLRM (44 GB -> 4 GB transfers).
    topo = initial_topology(16, 4)
    dp_time = evaluate(Strategy(mode="dp"), topo, DLRM, HW)
    res = mcmc_search(DLRM, topo, HW, iters=120, seed=3)
    assert res.strategy.mode == "hybrid"
    assert res.iter_time < dp_time


def test_candle_stays_data_parallel():
    # §5.3: "the best parallelization strategy for CANDLE ... is mostly data
    # parallel" — CANDLE has no tables so hybrid isn't even reachable.
    topo = initial_topology(16, 4)
    res = mcmc_search(CANDLE, topo, HW, iters=60, seed=0)
    assert res.strategy.mode == "dp"


def test_alternating_improves_or_matches_naive():
    # Co-optimization must beat the strategy search on the initial topology.
    naive = mcmc_search(DLRM, initial_topology(16, 4), HW, iters=100, seed=1)
    co = alternating_optimize(DLRM, 16, HW, rounds=3, mcmc_iters=100, seed=1)
    assert co.iter_time <= naive.iter_time * 1.001


def test_alternating_converges():
    res = alternating_optimize(DLRM, 16, HW, rounds=6, mcmc_iters=60, seed=0)
    assert len(res.rounds) <= 6
    assert res.iter_time > 0
    assert res.topology.n == 16


def test_mcmc_history_monotone_best():
    topo = initial_topology(16, 4)
    res = mcmc_search(DLRM, topo, HW, iters=80, seed=5)
    assert res.iter_time <= res.history[0]


def test_all_paper_jobs_have_demand():
    for name, job in PAPER_JOBS.items():
        dem = job_demand(job, 16)
        assert dem.sum_allreduce > 0, name
