"""DLRM JAX model (the paper's flagship workload): forward shapes, training
convergence, kernel-vs-model lookup equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import dlrm


CFG = dlrm.DLRMConfig(n_tables=4, rows_per_table=100, embed_dim=16,
                      dense_features=13, bottom_mlp=(32, 16), top_mlp=(32, 1))


def _batch(key, B=64):
    kd, ks, kl = jax.random.split(key, 3)
    return {
        "dense": jax.random.normal(kd, (B, CFG.dense_features)),
        "sparse": jax.random.randint(ks, (B, CFG.n_tables), 0, CFG.rows_per_table),
        "label": jax.random.bernoulli(kl, 0.5, (B,)).astype(jnp.float32),
    }


def test_forward_shape():
    params = dlrm.init(jax.random.PRNGKey(0), CFG)
    b = _batch(jax.random.PRNGKey(1))
    out = dlrm.forward(params, b["dense"], b["sparse"], CFG)
    assert out.shape == (64,)
    assert np.isfinite(np.asarray(out)).all()


def test_training_learns_separable_labels():
    params = dlrm.init(jax.random.PRNGKey(0), CFG)
    key = jax.random.PRNGKey(42)
    batch = _batch(key, B=256)
    # make labels depend on a sparse feature -> learnable
    batch["label"] = (batch["sparse"][:, 0] % 2).astype(jnp.float32)

    from repro.optim import adamw, constant

    opt = adamw(constant(5e-3), weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        (l, m), g = jax.value_and_grad(
            lambda pp: dlrm.loss_fn(pp, batch, CFG), has_aux=True
        )(p)
        p2, s2 = opt.update(g, s, p, i)
        return p2, s2, l

    losses = []
    for i in range(60):
        params, state, loss = step(params, state, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < 0.25, losses[::10]


def test_lookup_matches_embedding_bag_kernel():
    from repro.kernels.embedding_bag import embedding_bag

    params = dlrm.init(jax.random.PRNGKey(0), CFG)
    b = _batch(jax.random.PRNGKey(3), B=8)
    # model gather (one index per table) == kernel with NNZ=1
    emb_model = jnp.einsum(
        "tbe->bte",
        params["tables"][jnp.arange(CFG.n_tables)[:, None], b["sparse"].T],
    )
    idx = b["sparse"][:, :, None]
    emb_kernel = embedding_bag(params["tables"], idx, interpret=True)
    np.testing.assert_allclose(
        np.asarray(emb_model), np.asarray(emb_kernel), rtol=1e-6
    )
