"""Online re-optimization invariants.

* Golden: a ``ReoptPolicy.never()`` controller attached as observer leaves
  every PR-1 SimEngine scenario bit-identical (makespans to 1e-9).
* Replanned topologies respect the degree budget and avoid dead pairs.
* Flow bytes are conserved across a mid-run plan swap.
* Trigger semantics: hysteresis, periodic scheduling, degradation baseline.
* Warm start: incumbent ring strides survive a replan when still valid.
"""

import numpy as np
import pytest

from repro.core.alternating import alternating_optimize
from repro.core.netsim import HardwareSpec
from repro.core.online import (
    ReoptController,
    ReoptPolicy,
    TraceEvent,
    place_arrival,
    run_online,
)
from repro.core.simengine import (
    LinkFailure,
    OCSPolicy,
    Scenario,
    SimEngine,
    SimJob,
    Task,
    iteration_tasks,
)
from repro.core.topology_finder import remove_pair, topology_finder
from repro.core.workloads import DLRM, VGG16, job_demand

HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)


@pytest.fixture(scope="module")
def dlrm_plan():
    """One cheap co-optimized plan shared by every test in the module."""
    return alternating_optimize(DLRM, 8, HW, rounds=2, mcmc_iters=20, seed=2)


def _never_controller(n=4):
    return ReoptController(VGG16, n, hw=HW, policy=ReoptPolicy.never())


def _flow_job(name, arrival, nbytes=1000.0, route=(0, 1)):
    return SimJob(
        name=name, arrival=arrival,
        tasks=[Task(tid=0, kind="flow", nbytes=nbytes, route=route)],
    )


# ---------------------------------------------------------------------------
# Golden: never-policy == PR 1 engine
# ---------------------------------------------------------------------------

GOLDEN_SCENARIOS = {
    "shared": lambda: Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("a", 0.0), _flow_job("b", 5.0)],
        n=2,
    ),
    "failure_reroute": lambda: Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=[_flow_job("j", 0.0, nbytes=1000.0, route=(0, 1))],
        failures=(LinkFailure(time=5.0, link=(0, 1)),),
        n=3,
    ),
    "ocs": lambda: Scenario(
        links={}, n=4,
        jobs=[SimJob("o", [
            Task(tid=0, kind="flow", nbytes=1e6, route=(0, 3)),
            Task(tid=1, kind="flow", nbytes=1e6, route=(1, 2)),
        ])],
        reconfig=OCSPolicy(window=50e-3, latency=1e-3, degree=2,
                           link_bandwidth=1e6),
    ),
    "stragglers": lambda: Scenario(
        links={}, n=2, stragglers={1: 3.0},
        jobs=[SimJob("s", [
            Task(tid=0, kind="compute", duration=2.0, node=0),
            Task(tid=1, kind="compute", duration=2.0, node=1),
        ])],
    ),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_SCENARIOS))
def test_never_policy_reproduces_plain_engine(name):
    make = GOLDEN_SCENARIOS[name]
    plain = SimEngine().run(make())
    ctrl = _never_controller(n=4)
    observed = SimEngine().run(make(), observer=ctrl)
    assert observed.makespan == pytest.approx(plain.makespan, rel=1e-9)
    assert observed.n_replans == 0
    assert observed.job_finish.keys() == plain.job_finish.keys()
    for job, t in plain.job_finish.items():
        assert observed.job_finish[job] == pytest.approx(t, rel=1e-9)
    assert observed.delivered == plain.delivered
    assert ctrl.n_replans == 0


def test_never_policy_golden_shared_values():
    """Pin the PR-1 numbers themselves, not just the diff."""
    r = SimEngine().run(GOLDEN_SCENARIOS["shared"](), observer=_never_controller())
    assert r.job_makespans["a"] == pytest.approx(15.0, rel=1e-5)
    assert r.job_finish["b"] == pytest.approx(20.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Replanned topology invariants
# ---------------------------------------------------------------------------


def test_replan_respects_degree_and_dead_pairs(dlrm_plan):
    ctrl = ReoptController(
        DLRM, 8, hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3),
        plan=dlrm_plan,
    )
    ctrl.fail((0, 1), now=0.0)
    ctrl.fail((2, 5), now=1.0)
    assert ctrl.n_replans == 2
    topo = ctrl.topology
    assert max(topo.out_degrees()) <= HW.degree
    dead = {(0, 1), (1, 0), (2, 5), (5, 2)}
    assert not dead & set(topo.graph.edges()), "replanned topology uses dead pair"
    assert not dead & set(ctrl.links()), "live links include dead pair"


def test_forbidden_pairs_excluded_by_topology_finder():
    dem = job_demand(DLRM, 8, table_hosts=(0, 4))
    topo = topology_finder(dem, 4, forbidden=[(0, 1), (3, 7)])
    banned = {(0, 1), (1, 0), (3, 7), (7, 3)}
    assert not banned & set(topo.graph.edges())
    assert max(topo.out_degrees()) <= 4


def test_warm_start_keeps_surviving_strides():
    from repro.core.totient import ring_edges

    dem = job_demand(VGG16, 8)
    cold = topology_finder(dem, 4)
    members = tuple(range(8))
    warm = topology_finder(dem, 4, warm_start=cold)
    assert warm.ring_strides(members) == cold.ring_strides(members)
    # Forbid a pair: every incumbent stride whose ring avoids it must be
    # retained by the warm-started search.
    warm2 = topology_finder(dem, 4, forbidden=[(0, 1)], warm_start=cold)

    def uses_pair(p):
        return any({a, b} == {0, 1} for a, b in ring_edges(8, p))

    survivors = [p for p in cold.ring_strides(members) if not uses_pair(p)]
    assert survivors, "fixture must leave some incumbent strides valid"
    for p in survivors:
        assert p in warm2.ring_strides(members), f"stride {p} not retained"
    assert (0, 1) not in set(warm2.graph.edges())


def test_remove_pair_drops_links_and_reroutes():
    dem = job_demand(DLRM, 8, table_hosts=(0, 4))
    topo = topology_finder(dem, 4)
    degraded = remove_pair(topo, (0, 1))
    assert not {(0, 1), (1, 0)} & set(degraded.graph.edges())
    for rs in degraded.routing.routes.values():
        for r in rs:
            assert (0, 1) not in zip(r.path[:-1], r.path[1:])
            assert (1, 0) not in zip(r.path[:-1], r.path[1:])


# ---------------------------------------------------------------------------
# Conservation across a mid-run plan swap
# ---------------------------------------------------------------------------


def test_byte_conservation_across_midrun_replan(dlrm_plan):
    ctrl = ReoptController(
        DLRM, 8, hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3),
        plan=dlrm_plan,
    )
    tasks = iteration_tasks(ctrl.topology, ctrl.demand)
    offered = sum(t.nbytes for t in tasks if t.kind == "flow")
    # Pick a pair the plan actually uses so the failure bites mid-run.
    link = next(iter(ctrl.links()))
    sc = Scenario(
        links=ctrl.links(),
        jobs=[SimJob("dlrm", tasks)],
        failures=(LinkFailure(time=1e-4, link=link),),
        n=8,
    )
    r = SimEngine(HW).run(sc, observer=ctrl)
    assert r.n_replans == 1
    assert ctrl.n_replans == 1
    assert not r.stalled
    assert r.delivered["dlrm"] == pytest.approx(offered, rel=1e-12)
    assert len(r.finish_times) == len(tasks)
    # The replan pause is charged inside the run.
    assert r.replan_times and 0 <= r.replan_times[0] <= r.makespan


# ---------------------------------------------------------------------------
# Trigger semantics
# ---------------------------------------------------------------------------


def test_scheduled_checks_cannot_stall_a_dead_simulation():
    """Regression: an unroutable flow plus a periodic check schedule must
    stall-finish (one rescue check allowed), not spin the engine forever."""
    from repro.core.simengine import PlanUpdate, ScenarioObserver

    class Probe(ScenarioObserver):
        def __init__(self, rescue_links=None):
            self.checks = 0
            self.rescue_links = rescue_links

        def next_check(self, now):
            return now + 0.5  # always another check scheduled

        def on_check(self, view):
            self.checks += 1
            if self.rescue_links is not None:
                return PlanUpdate(links=self.rescue_links)
            return None

    def scenario():
        return Scenario(
            links={(0, 1): 100.0},
            jobs=[_flow_job("j", 0.0, nbytes=1000.0, route=(0, 1))],
            failures=(LinkFailure(time=1.0, link=(0, 1)),),
            n=2,
        )

    silent = Probe()
    r = SimEngine().run(scenario(), observer=silent)
    assert ("j", 0) in r.stalled  # terminated, flow reported stalled
    assert silent.checks >= 1  # the rescue check was offered

    # A rescuing observer reconnects the fabric and the flow completes.
    rescuer = Probe(rescue_links={(0, 1): 100.0})
    r2 = SimEngine().run(scenario(), observer=rescuer)
    assert not r2.stalled
    assert r2.delivered["j"] == pytest.approx(1000.0)


def test_unreachable_failure_events_do_not_hang_the_engine():
    """Regression: a LinkFailure at a non-finite time can never fire; it must
    not keep the event loop's while-condition alive after a stall-finish."""
    r = SimEngine().run(Scenario(
        links={(0, 1): 100.0},
        jobs=[_flow_job("j", 0.0, nbytes=1000.0, route=(0, 1))],
        failures=(LinkFailure(time=1.0, link=(0, 1)),
                  LinkFailure(time=float("inf"), link=(0, 1))),
        n=2,
    ))
    assert ("j", 0) in r.stalled


def test_run_online_disconnected_fabric_with_midrun_failure_terminates():
    """Regression: frac>0 failures used to schedule at frac*inf when the
    probe saw a disconnected fabric, hanging the engine."""
    plan = alternating_optimize(VGG16, 2, HW, rounds=1, mcmc_iters=5, seed=0)
    trace = (TraceEvent(iteration=0, kind="fail", link=(0, 1)),
             TraceEvent(iteration=1, kind="fail", link=(0, 1), frac=0.5))
    r = run_online(VGG16, 2, HW, policy=ReoptPolicy.never(), trace=trace,
                   n_iters=3, seed=0, plan=plan)
    assert len(r.iter_times) == 3  # completed, did not hang


def test_hysteresis_suppresses_back_to_back_replans(dlrm_plan):
    ctrl = ReoptController(
        DLRM, 8, hw=HW,
        policy=ReoptPolicy(on_failure=True, min_interval=10.0,
                           replan_latency=1e-3),
        plan=dlrm_plan,
    )
    ctrl.fail((0, 1), now=0.0)
    ctrl.fail((2, 5), now=0.5)  # within min_interval: suppressed
    ctrl.fail((3, 6), now=20.0)  # outside: replans again
    assert ctrl.n_replans == 2
    suppressed = [r for r in ctrl.log if not r.replanned]
    assert len(suppressed) == 1 and suppressed[0].trigger == "failure"
    # All three pairs are still dead regardless of replan decisions.
    assert ctrl.dead == {(0, 1), (2, 5), (3, 6)}


def test_periodic_schedule_advances_past_fires():
    pol = ReoptPolicy.periodic(period=0.5)
    assert pol.check_period == 0.5
    ctrl = _never_controller()
    assert ctrl.next_check(0.0) == np.inf  # never-policy: no checks
    assert ReoptPolicy.never().check_period is None


def test_degradation_baseline_pinned_at_adoption(dlrm_plan):
    ctrl = ReoptController(
        DLRM, 8, hw=HW,
        policy=ReoptPolicy.degradation(threshold=1.25, check_interval=0.05,
                                       replan_latency=1e-3),
        plan=dlrm_plan,
    )
    healthy = ctrl.baseline
    # Kill a pair the plan uses: the probe estimate must exceed the baseline.
    link = next(iter(ctrl.links()))
    ctrl.fail(link, now=0.0)  # degradation policy: records, no replan
    assert ctrl.n_replans == 0
    assert ctrl.baseline == healthy
    assert ctrl.estimated_iter_time() > healthy


# ---------------------------------------------------------------------------
# run_online driver
# ---------------------------------------------------------------------------


def test_run_online_reactive_beats_static_under_failures(dlrm_plan):
    trace = (
        TraceEvent(iteration=1, kind="fail", link=(0, 1)),
        TraceEvent(iteration=2, kind="fail", link=(2, 5), frac=0.5),
    )
    static = run_online(DLRM, 8, HW, policy=ReoptPolicy.never(),
                        trace=trace, n_iters=5, seed=0, plan=dlrm_plan)
    reactive = run_online(DLRM, 8, HW, policy=ReoptPolicy(replan_latency=1e-3),
                          trace=trace, n_iters=5, seed=0, plan=dlrm_plan)
    assert static.n_replans == 0
    assert reactive.n_replans >= 1
    assert reactive.n_failures == static.n_failures == 2
    assert len(static.iter_times) == len(reactive.iter_times) == 5
    assert reactive.total_time < static.total_time


def test_run_online_never_trace_free_is_flat(dlrm_plan):
    r = run_online(DLRM, 8, HW, policy=ReoptPolicy.never(), trace=(),
                   n_iters=3, seed=0, plan=dlrm_plan)
    assert r.n_replans == 0 and r.n_failures == 0
    assert r.iter_times[0] == pytest.approx(r.iter_times[-1], rel=1e-9)
    assert r.total_time == pytest.approx(sum(r.iter_times), rel=1e-12)


def test_run_online_load_shift_triggers_arrival_replan(dlrm_plan):
    trace = (TraceEvent(iteration=1, kind="load", job=VGG16),)
    r = run_online(DLRM, 8, HW,
                   policy=ReoptPolicy.reactive(replan_latency=1e-3),
                   trace=trace, n_iters=3, seed=0, plan=dlrm_plan)
    assert r.n_replans >= 1
    assert r.log[0].trigger == "arrival"


# ---------------------------------------------------------------------------
# Topology-aware placement
# ---------------------------------------------------------------------------


def test_place_arrival_prefers_connected_servers():
    links = {(0, 1): 1.0, (1, 2): 1.0, (0, 2): 1.0, (4, 5): 1.0}
    chosen = place_arrival(3, set(range(8)), links)
    assert chosen == (0, 1, 2)


def test_place_arrival_avoids_failed_island():
    # Nodes 0-3 form a clique; 4-7 have no surviving capacity at all.
    links = {(a, b): 1.0 for a in range(4) for b in range(4) if a < b}
    chosen = place_arrival(4, set(range(8)), links)
    assert chosen == (0, 1, 2, 3)


def test_place_arrival_requires_enough_free():
    with pytest.raises(ValueError):
        place_arrival(3, {0, 1}, {})


def test_place_arrival_zero_request_is_empty():
    assert place_arrival(0, {0, 1}, {(0, 1): 1.0}) == ()


def test_disconnected_probe_estimates_unusable():
    """A fabric whose surviving links cannot carry the demand must probe as
    unusable (inf), not as instantly-stall-finished (fast)."""
    plan = alternating_optimize(VGG16, 2, HW, rounds=1, mcmc_iters=5, seed=0)
    ctrl = ReoptController(VGG16, 2, hw=HW, policy=ReoptPolicy.never(),
                           plan=plan)
    healthy = ctrl.estimated_iter_time()
    assert np.isfinite(healthy) and healthy > 0
    ctrl.fail((0, 1), now=0.0)  # the only pair: fabric fully disconnected
    assert ctrl.estimated_iter_time() == np.inf
