"""Executable documentation.

Every fenced ``python`` code block in README.md and docs/simengine.md runs
here, with ``DeprecationWarning`` promoted to an error — documentation that
drifts from the code (or from the pinned dependency versions) fails CI
instead of rotting silently.  Blocks within one file share a namespace, so
later snippets may build on earlier imports (doctest-style).
"""

from __future__ import annotations

import re
import warnings
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "docs/simengine.md"]
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks(rel: str) -> list[str]:
    return FENCE.findall((ROOT / rel).read_text())


@pytest.mark.parametrize("rel", DOC_FILES)
def test_doc_snippets_execute(rel):
    blocks = _python_blocks(rel)
    assert blocks, f"no ```python snippets found in {rel}"
    ns: dict = {"__name__": f"docsnippet_{rel.replace('/', '_')}"}
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for i, src in enumerate(blocks):
            code = compile(src, f"{rel}[snippet {i}]", "exec")
            exec(code, ns)  # asserts inside the snippets are the checks


def test_docs_cover_all_benchmarks():
    """The README results table must list every registered bench."""
    from benchmarks.run import BENCHES

    readme = (ROOT / "README.md").read_text()
    for bench, _ in BENCHES:
        assert f"`{bench}`" in readme, f"README bench table misses {bench}"
