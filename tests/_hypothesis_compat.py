"""Hermetic stand-in for ``hypothesis`` so property tests run everywhere.

When the real ``hypothesis`` package is installed it is used unchanged.
Otherwise a minimal shim provides the subset this repo's tests need —
``given``/``settings`` decorators and ``st.integers/floats/lists/
sampled_from/data`` strategies — backed by seeded numpy sampling, so the
property tests still sweep a deterministic batch of random examples
instead of being skipped.

Usage in tests::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A sampler: ``example(rng)`` draws one value."""

        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _DataObject:
        """Interactive draws inside a test body (``st.data()``)."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_ignored):
            span = (min_value, max_value)

            def sample(rng):
                # Bias toward the bounds now and then, like hypothesis does.
                r = rng.random()
                if r < 0.05:
                    return float(span[0])
                if r < 0.10:
                    return float(span[1])
                return float(rng.uniform(span[0], span[1]))

            return _Strategy(sample)

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)

            def sample(rng):
                return pool[int(rng.integers(len(pool)))]

            return _Strategy(sample)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def sample(rng):
                size = int(rng.integers(min_size, max_size + 1))
                if not unique:
                    return [elements.example(rng) for _ in range(size)]
                out, seen = [], set()
                for _ in range(50 * max(size, 1)):
                    if len(out) >= size:
                        break
                    v = elements.example(rng)
                    if v not in seen:
                        seen.add(v)
                        out.append(v)
                return out

            return _Strategy(sample)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        def deco(fn):
            fn._hc_max_examples = max_examples
            return fn

        return deco

    import inspect

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_hc_max_examples", _DEFAULT_MAX_EXAMPLES)
                base_seed = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng((base_seed, i))
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs)
                    except Exception as e:  # noqa: BLE001 - re-raise annotated
                        raise AssertionError(
                            f"property falsified on example {i}: "
                            f"args={args!r} kwargs={kwargs!r}"
                        ) from e

            # pytest must see a zero-arg test, not the wrapped signature
            # (it would demand fixtures for the strategy parameters).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
