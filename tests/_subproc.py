"""Run a snippet in a subprocess with N forced host devices.

Multi-device collective tests must not pollute the main pytest process
(jax locks the device count at first init), so each runs in its own python.
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
