import numpy as np
import pytest

from repro.core.demand import TrafficDemand, data_parallel_demand
from repro.core.fabrics import expander_topology, generic_comm_time, sipml_ring_topology
from repro.core.netsim import HardwareSpec, compute_time
from repro.core.simengine import (
    fat_tree_comm_time,
    ideal_switch_comm_time,
    iteration_time,
    topoopt_comm_time,
)
from repro.core.topology_finder import topology_finder


HW = HardwareSpec(link_bandwidth=12.5e9, degree=4)  # 100 Gbps


def test_ideal_switch_allreduce_time():
    dem = data_parallel_demand(16, 1e9)
    t = ideal_switch_comm_time(dem, HW)
    expected = 2 * 15 / 16 * 1e9 / (4 * 12.5e9)
    assert t == pytest.approx(expected)


def test_topoopt_matches_ideal_for_pure_dp():
    # d rings at B each == one pipe at d*B for ring AllReduce.
    dem = data_parallel_demand(16, 1e9)
    topo = topology_finder(dem, degree=4)
    t = topoopt_comm_time(topo, dem, HW)
    assert t["comm_time"] == pytest.approx(ideal_switch_comm_time(dem, HW), rel=1e-6)
    assert t["bandwidth_tax"] == 1.0


def test_fat_tree_slower_at_reduced_bandwidth():
    dem = data_parallel_demand(16, 1e9)
    t_ideal = ideal_switch_comm_time(dem, HW)
    t_ft = fat_tree_comm_time(dem, HW, bandwidth_fraction=0.35)
    assert t_ft == pytest.approx(t_ideal / 0.35)


def test_mp_forwarding_incurs_tax():
    dem = TrafficDemand(n=16)
    dem.add_all_to_all(range(16), 1e6)
    dem.allreduce.append(
        __import__("repro.core.demand", fromlist=["AllReduceGroup"]).AllReduceGroup(
            members=tuple(range(16)), nbytes=1.0
        )
    )
    topo = topology_finder(dem, degree=4)
    t = topoopt_comm_time(topo, dem, HW)
    assert t["bandwidth_tax"] > 1.0  # multi-hop forwarding


def test_iteration_time_overlap():
    assert iteration_time(2.0, 3.0, overlap=0.0) == 5.0
    assert iteration_time(2.0, 3.0, overlap=1.0) == 3.0
    assert iteration_time(2.0, 3.0, overlap=0.5) == 4.0


def test_compute_time():
    hw = HardwareSpec(compute_flops=100.0, compute_efficiency=0.5)
    assert compute_time(1000.0, 2, hw) == pytest.approx(10.0)


def test_expander_topology_regular():
    topo = expander_topology(16, 4, seed=1)
    assert set(topo.out_degrees()) == {4}
    dem = data_parallel_demand(16, 1e9)
    t = generic_comm_time(topo, dem, HW)
    assert t > 0


def test_sipml_ring_neighbors():
    topo = sipml_ring_topology(8, 4)
    assert topo.graph.has_edge(0, 1) and topo.graph.has_edge(0, 7)
    assert topo.graph.has_edge(0, 2) and topo.graph.has_edge(0, 6)
    assert not topo.graph.has_edge(0, 4)
