"""Sharding rules: divisibility sanitation + full-leaf coverage for every
assigned architecture (subprocess with a (2, 4) mesh)."""

from _subproc import run_with_devices

import pytest

# Multi-minute subprocess tests (fresh jax init per case); quick loop:
# python -m pytest -m "not slow"
pytestmark = pytest.mark.slow


def test_param_specs_cover_all_archs():
    out = run_with_devices(
        """
import jax
from jax.sharding import PartitionSpec as P
from repro.configs.base import all_configs
from repro.models import lm
from repro.parallel.sharding import ShardingPlan, param_spec_tree, sanitize

mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = ShardingPlan(fsdp=True)
for name, cfg in all_configs().items():
    if cfg.family == "recsys":
        continue
    small = cfg.smoke()
    specs = lm.param_specs(small)
    tree = param_spec_tree(specs, plan, mesh)
    flat_specs = jax.tree.leaves(specs)
    flat_shard = jax.tree_util.tree_leaves(tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_specs) == len(flat_shard), name
    n_sharded = 0
    for leaf, spec in zip(flat_specs, flat_shard):
        assert len(spec) <= len(leaf.shape), (name, leaf.shape, spec)
        for dim, ax in zip(leaf.shape, list(spec) + [None] * 9):
            if ax is None:
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else __import__("math").prod(mesh.shape[a] for a in ax)
            assert dim % size == 0, (name, leaf.shape, spec)
            n_sharded += 1
    assert n_sharded > 0, f"{name}: nothing sharded"
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_sanitize_drops_indivisible():
    out = run_with_devices(
        """
import jax
from jax.sharding import PartitionSpec as P
from repro.parallel.sharding import sanitize

mesh = jax.make_mesh((2, 4), ("data", "model"))
# 122753 is prime (minicpm vocab): model axis must be dropped.
s = sanitize(P("model", "data"), (122753, 64), mesh)
assert s == P(None, "data"), s
s2 = sanitize(P(("data", "model"), None), (16, 7), mesh)
assert s2 == P(("data", "model"), None)
s3 = sanitize(P(("data", "model"), None), (12, 7), mesh)
assert s3 == P(None, None)
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_batch_and_cache_specs():
    out = run_with_devices(
        """
import jax
from jax.sharding import PartitionSpec as P
from repro.configs.base import all_configs, input_specs, DECODE_32K, TRAIN_4K, shape_applicability
from repro.parallel.sharding import ShardingPlan, batch_spec_tree
mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = ShardingPlan()
for name, cfg in all_configs().items():
    if cfg.family == "recsys":
        continue
    for shape in (TRAIN_4K, DECODE_32K):
        ok, _ = shape_applicability(cfg, shape)
        if not ok:
            continue
        b = input_specs(cfg, shape)
        tree = batch_spec_tree(b, cfg, plan, mesh)
        leaves = jax.tree.leaves(b)
        specs = jax.tree_util.tree_leaves(tree, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves) == len(specs), name
        # tokens/batch leaves must shard batch over data
        flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=lambda x: isinstance(x, P))[0]
        for path, spec in flat:
            names = [str(k.key) for k in path if hasattr(k, "key")]
            if names and names[-1] in ("tokens", "token"):
                assert spec[0] is not None, (name, shape.name, names, spec)
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out
