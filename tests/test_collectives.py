"""Multi-ring TotientPerms collectives vs lax.psum (8 fake devices,
subprocess-isolated)."""

from _subproc import run_with_devices

import pytest

# Multi-minute subprocess tests (fresh jax init per case); quick loop:
# python -m pytest -m "not slow"
pytestmark = pytest.mark.slow


def test_ring_and_multiring_allreduce_match_psum():
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.core.collectives import ring_all_reduce, multi_ring_all_reduce

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 13, dtype=jnp.float32).reshape(8, 13)
ref = jax.jit(shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x")))(x)
for strides in [(1,), (3,), (5,), (7,), (1, 3), (1, 3, 5), (1, 3, 5, 7)]:
    fn = (lambda ss: lambda v: multi_ring_all_reduce(v, "x", ss))(strides)
    out = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    assert np.allclose(out, ref), strides
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_all_to_all_ring_matches_transpose():
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.core.collectives import all_to_all_ring

mesh = jax.make_mesh((8,), ("x",))
y = jnp.arange(8 * 8 * 4, dtype=jnp.float32).reshape(8, 8, 4)
for p in (1, 3, 5):
    fn = (lambda pp: lambda v: all_to_all_ring(v[0], "x", pp)[None])(p)
    out = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(y)
    assert np.allclose(out, np.transpose(np.asarray(y), (1, 0, 2))), p
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_reduce_scatter_owns_correct_segment():
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.core.collectives import ring_reduce_scatter

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
out = jax.jit(shard_map(lambda v: ring_reduce_scatter(v, "x", 3), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x")))(x)
full = np.asarray(x).sum(axis=0)
n, seg = 8, 2
padded = full.reshape(n, seg)
inv = pow(3, -1, 8)
got = np.asarray(out).reshape(8, seg)
for dev in range(8):
    pos = (dev * inv) % 8
    assert np.allclose(got[dev], padded[(pos + 1) % 8]), dev
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_int_exactness_of_multiring():
    """AllReduce of integers must be exact regardless of ring count."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.core.collectives import multi_ring_all_reduce

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 11, dtype=jnp.int32).reshape(8, 11)
out = jax.jit(shard_map(lambda v: multi_ring_all_reduce(v, "x", (1, 3, 5)),
                        mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
assert np.array_equal(np.asarray(out)[0], np.asarray(x).sum(0))
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_recursive_hd_allreduce_matches_psum():
    """Halving-doubling AllReduce == lax.psum, exact on integers, plus the
    odd-size ValueError (the runtime kernel keeps the strict power-of-two
    form the demand compiler folds around)."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.core.collectives import recursive_hd_all_reduce

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 13, dtype=jnp.float32).reshape(8, 13)
ref = jax.jit(shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x")))(x)
out = jax.jit(shard_map(lambda v: recursive_hd_all_reduce(v, "x"),
                        mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
assert np.allclose(out, ref)

xi = jnp.arange(8 * 11, dtype=jnp.int32).reshape(8, 11)
outi = jax.jit(shard_map(lambda v: recursive_hd_all_reduce(v, "x"),
                         mesh=mesh, in_specs=P("x"), out_specs=P("x")))(xi)
assert np.array_equal(np.asarray(outi)[0], np.asarray(xi).sum(0))

# Odd-size groups are a host-visible ValueError, not silent corruption.
mesh6 = jax.make_mesh((6,), ("y",), devices=jax.devices()[:6])
x6 = jnp.arange(6 * 4, dtype=jnp.float32).reshape(6, 4)
try:
    jax.jit(shard_map(lambda v: recursive_hd_all_reduce(v, "y"),
                      mesh=mesh6, in_specs=P("y"), out_specs=P("y")))(x6)
except ValueError as e:
    assert "power-of-two" in str(e)
else:
    raise SystemExit("expected ValueError for group of 6")
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_multi_tree_allreduce_matches_psum():
    """Multi-tree AllReduce == lax.psum for 1/2/3-tree splits, exact on
    integers (the runtime form of the ``multi_tree`` schedule)."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.core.collectives import multi_tree_all_reduce

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 13, dtype=jnp.float32).reshape(8, 13)
ref = jax.jit(shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                        in_specs=P("x"), out_specs=P("x")))(x)
for strides in [(1,), (1, 3), (1, 3, 5)]:
    fn = (lambda ss: lambda v: multi_tree_all_reduce(v, "x", ss))(strides)
    out = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    assert np.allclose(out, ref), strides

xi = jnp.arange(8 * 11, dtype=jnp.int32).reshape(8, 11)
outi = jax.jit(shard_map(lambda v: multi_tree_all_reduce(v, "x", (1, 3)),
                         mesh=mesh, in_specs=P("x"), out_specs=P("x")))(xi)
assert np.array_equal(np.asarray(outi)[0], np.asarray(xi).sum(0))
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out


def test_device_order_mesh():
    out = run_with_devices(
        """
import jax, numpy as np
from repro.core.device_order import permuted_axis_order, topoopt_mesh
order = permuted_axis_order(8, 3)
assert sorted(order) == list(range(8))
assert order[1] == 3  # position j holds device (j * p) % n

mesh = topoopt_mesh((8,), ("data",), allreduce_axis="data", stride=3)
ids = [d.id for d in mesh.devices.flat]
assert ids == order, (ids, order)
print("PASS")
""",
        n_devices=8,
    )
    assert "PASS" in out
