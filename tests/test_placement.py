"""Placement co-search + churn-priced migration invariants.

* ``place_arrival`` vectorization: bit-identical to the dict-walk
  reference (randomized fabrics, exactly-representable capacities).
* ``place_candidates``: greedy seed first, distinct valid placements.
* Placement co-search: ``placement_candidates=[jobset]`` reproduces the
  no-candidates path bit for bit; candidate plans beat-or-match greedy on
  randomized fragmented fabrics; ``admit(candidates=k)`` adopts the
  winning placement and ``candidates=1`` stays on the greedy path.
* Golden equivalence: ``candidates=1, max_migrations=0`` run is
  bit-identical to the plain reactive run (the PR-3/4 behaviour).
* Migration: ``migration_cost`` pricing, rebalance invariants (disjoint
  placements, tenant shapes preserved, expensive state stays pinned),
  capacity conservation across a migration ``PlanUpdate``.
* Satellites: ``rebase_demand`` placement rebase, per-tenant comm
  decomposition, deadline-aware replanning.
"""

import random

import numpy as np
import pytest

from repro.core.alternating import co_optimize_jobset
from repro.core.costmodel import (
    CHECKPOINT_RESTORE_BW,
    FIBER_MOVE_S,
    MIGRATION_RESTART_S,
    migration_cost,
)
from repro.core.demand import rebase_demand, remap_demand
from repro.core.netsim import HardwareSpec
from repro.core.online import (
    JobSetController,
    ReoptPolicy,
    TraceEvent,
    place_arrival,
    place_candidates,
    run_online_jobset,
)
from repro.core.simengine import (
    DeadlineFairness,
    LinkFailure,
    MigrationRecord,
    PlanUpdate,
    Scenario,
    ScenarioObserver,
    SimEngine,
    SimJob,
    Task,
)
from repro.core.strategy_search import (
    default_strategy,
    evaluate_jobset,
    tenant_comm_times,
)
from repro.core.workloads import (
    BERT,
    DLRM,
    MOE_16E,
    VGG16,
    JobSet,
    TenantJob,
    job_demand,
    placement_diff,
)

HW = HardwareSpec(link_bandwidth=12.5e9, degree=3)


def _fragmented_jobset(n=12):
    """DLRM/BERT interleaved at stride 3: scattered free pool."""
    return JobSet(n=n, tenants=[
        TenantJob(spec=DLRM, servers=tuple(range(0, n, 3)), name="dlrm"),
        TenantJob(spec=BERT, servers=tuple(range(1, n, 3)), name="bert"),
    ])


@pytest.fixture(scope="module")
def frag_plan():
    return co_optimize_jobset(_fragmented_jobset(), HW, rounds=2,
                              mcmc_iters=20, seed=1)


# ---------------------------------------------------------------------------
# place_arrival vectorization: bit-identical to the dict reference
# ---------------------------------------------------------------------------


def _place_arrival_reference(k, free, links):
    """The pre-vectorization dict-walk implementation, verbatim."""
    free = set(free)
    if k > len(free):
        raise ValueError(f"need {k} servers, only {len(free)} free")
    if k == 0:
        return ()
    cap_to = {v: {} for v in free}
    for (a, b), c in links.items():
        if a in free and b in free and c > 0:
            cap_to[a][b] = cap_to[a].get(b, 0.0) + c
            cap_to[b][a] = cap_to[b].get(a, 0.0) + c
    seed = min(free, key=lambda v: (-sum(cap_to.get(v, {}).values()), v))
    chosen = [seed]
    pool = free - {seed}
    while len(chosen) < k:
        nxt = min(pool, key=lambda v: (
            -sum(cap_to.get(v, {}).get(u, 0.0) for u in chosen),
            -sum(cap_to.get(v, {}).values()),
            v,
        ))
        chosen.append(nxt)
        pool.discard(nxt)
    return tuple(sorted(chosen))


def test_place_arrival_matches_reference_on_random_fabrics():
    """Bit-identical to the dict walk even for capacities whose float sums
    are order-sensitive (0.1, 0.7, random()): the vectorized totals replay
    the reference's neighbor first-touch summation order."""
    rng = random.Random(7)
    for _ in range(150):
        n = rng.randrange(4, 24)
        free = set(rng.sample(range(n), rng.randrange(2, n)))
        links = {}
        for _ in range(rng.randrange(3, 50)):
            a, b = rng.randrange(n), rng.randrange(n)
            if a != b:
                links[(a, b)] = links.get((a, b), 0.0) + rng.choice(
                    [0.1, 0.7, 1 / 3, rng.random(), rng.randrange(1, 32) / 4]
                )
        k = rng.randrange(1, len(free) + 1)
        assert place_arrival(k, free, links) == \
            _place_arrival_reference(k, free, links)


def test_place_arrival_edge_cases_unchanged():
    links = {(0, 1): 1.0, (1, 2): 1.0, (0, 2): 1.0, (4, 5): 1.0}
    assert place_arrival(3, set(range(8)), links) == (0, 1, 2)
    assert place_arrival(0, {0, 1}, links) == ()
    with pytest.raises(ValueError):
        place_arrival(3, {0, 1}, {})


# ---------------------------------------------------------------------------
# place_candidates
# ---------------------------------------------------------------------------


def test_place_candidates_greedy_first_distinct_and_valid():
    links = {(0, 1): 1.0, (1, 2): 1.0, (0, 2): 1.0, (4, 5): 1.0, (5, 7): 1.0}
    free = set(range(8))
    cands = place_candidates(3, free, links, n=4)
    assert cands[0] == place_arrival(3, free, links)
    assert len(cands) == len(set(cands))
    for p in cands:
        assert len(p) == 3 and set(p) <= free
    assert 1 < len(cands) <= 4


def test_place_candidates_n1_is_greedy_only():
    links = {(0, 1): 1.0}
    assert place_candidates(2, {0, 1, 2}, links, n=1) == \
        [place_arrival(2, {0, 1, 2}, links)]


def test_place_candidates_validates_like_place_arrival():
    with pytest.raises(ValueError):
        place_candidates(4, {0, 1}, {}, n=3)
    assert place_candidates(0, {0, 1}, {}, n=3) == [()]


# ---------------------------------------------------------------------------
# Placement co-search: plan-level equivalence + dominance
# ---------------------------------------------------------------------------


def test_single_candidate_reproduces_plain_path_bitwise(frag_plan):
    js = _fragmented_jobset()
    plan = co_optimize_jobset(js, HW, rounds=2, mcmc_iters=20, seed=1,
                              placement_candidates=[js])
    assert plan.iter_time == frag_plan.iter_time
    assert plan.strategies == frag_plan.strategies
    assert plan.per_job == frag_plan.per_job
    assert sorted(plan.topology.graph.edges()) == \
        sorted(frag_plan.topology.graph.edges())
    assert plan.candidate_index == 0
    assert plan.jobset is js


def test_placement_candidates_validate():
    js = _fragmented_jobset()
    with pytest.raises(ValueError, match="non-empty"):
        co_optimize_jobset(js, HW, rounds=1, mcmc_iters=5,
                           placement_candidates=[])
    other = JobSet(n=12, tenants=[
        TenantJob(spec=VGG16, servers=(0, 1), name="other")])
    with pytest.raises(ValueError, match="same tenant labels"):
        co_optimize_jobset(js, HW, rounds=1, mcmc_iters=5,
                           placement_candidates=[other])


def test_cosearch_never_worse_than_greedy_on_fragmented_fabrics():
    """Randomized: the winning candidate plan's objective is <= the greedy
    candidate's (greedy is always candidate 0, same seed)."""
    rng = random.Random(3)
    for trial in range(4):
        n = 12
        js = _fragmented_jobset(n)
        free = sorted(js.free_servers())
        dead = set()
        while len(dead) < 3:
            a, b = rng.sample(free, 2)
            dead.add((min(a, b), max(a, b)))
        links = {}  # degraded fabric: only what a healthy plan would give
        base = co_optimize_jobset(js, HW, rounds=1, mcmc_iters=10, seed=trial,
                                  forbidden=tuple(dead))
        k = 2
        from repro.core.simengine import links_from_topology

        links = links_from_topology(base.topology, HW)
        arrived = js.with_tenant(
            TenantJob(spec=MOE_16E, servers=tuple(free[:k]), name="moe"))
        cands = [
            js.with_tenant(TenantJob(spec=MOE_16E, servers=p, name="moe"))
            for p in place_candidates(k, set(free), links, n=4)
        ]
        greedy_plan = co_optimize_jobset(
            cands[0], HW, rounds=1, mcmc_iters=10, seed=trial,
            forbidden=tuple(dead))
        co_plan = co_optimize_jobset(
            arrived, HW, rounds=1, mcmc_iters=10, seed=trial,
            forbidden=tuple(dead), placement_candidates=cands)
        assert co_plan.iter_time <= greedy_plan.iter_time


def test_admit_cosearch_adopts_winning_candidate(frag_plan):
    js = _fragmented_jobset()
    ctrl = JobSetController(
        js, hw=HW,
        policy=ReoptPolicy.reactive(replan_latency=1e-3, candidates=4),
        plan=frag_plan, seed=0,
    )
    free = ctrl.jobset.free_servers()
    servers, pause = ctrl.admit(MOE_16E, 3, name="moe", now=0.0)
    assert set(servers) <= free and len(servers) == 3
    assert ctrl.n_replans == 1 and pause == pytest.approx(1e-3)
    # The resident set and the adopted plan agree on the placement.
    assert ctrl.jobset.tenant("moe").servers == servers
    assert ctrl.plan.jobset.tenant("moe").servers == servers
    ctrl.jobset.validate()  # disjointness holds after adoption


def test_admit_suppressed_replan_keeps_greedy_seed(frag_plan):
    js = _fragmented_jobset()
    ctrl = JobSetController(
        js, hw=HW,
        policy=ReoptPolicy.reactive(replan_latency=1e-3, candidates=4,
                                    min_interval=100.0),
        plan=frag_plan, seed=0,
    )
    ctrl.fail((0, 3), now=0.0)  # consume the hysteresis budget
    greedy = place_arrival(3, ctrl.jobset.free_servers(), ctrl.links())
    servers, pause = ctrl.admit(MOE_16E, 3, name="moe", now=1.0)
    assert servers == greedy and pause == 0.0
    assert ctrl._pending_candidates is None  # cleared even when suppressed


# ---------------------------------------------------------------------------
# Golden equivalence: candidates=1 / max_migrations=0 == plain reactive
# ---------------------------------------------------------------------------


def test_run_online_jobset_golden_equivalence(frag_plan):
    js = _fragmented_jobset()
    trace = (
        TraceEvent(iteration=0, kind="fail", link=(2, 5)),
        TraceEvent(iteration=1, kind="arrive", job=MOE_16E, k=3, name="moe"),
        TraceEvent(iteration=2, kind="depart", name="bert"),
    )
    plain = run_online_jobset(
        js, HW, policy=ReoptPolicy.reactive(replan_latency=1e-3),
        trace=trace, n_iters=4, seed=0, plan=frag_plan)
    explicit = run_online_jobset(
        js, HW,
        policy=ReoptPolicy.reactive(replan_latency=1e-3, candidates=1,
                                    max_migrations=0),
        trace=trace, n_iters=4, seed=0, plan=frag_plan)
    assert explicit.total_time == plain.total_time
    assert explicit.iter_times == plain.iter_times
    assert explicit.job_times == plain.job_times
    assert explicit.n_replans == plain.n_replans
    assert explicit.edges_moved == plain.edges_moved
    assert explicit.migrations == [] == plain.migrations
    assert sorted(explicit.final_plan.topology.graph.edges()) == \
        sorted(plain.final_plan.topology.graph.edges())


# ---------------------------------------------------------------------------
# Migration: pricing, rebalance invariants, engine PlanUpdate
# ---------------------------------------------------------------------------


def test_migration_cost_prices_components():
    assert migration_cost(0.0) == MIGRATION_RESTART_S
    assert migration_cost(2e10) == pytest.approx(
        MIGRATION_RESTART_S + 2e10 / CHECKPOINT_RESTORE_BW)
    assert migration_cost(0.0, edges_moved=3) == pytest.approx(
        MIGRATION_RESTART_S + 3 * FIBER_MOVE_S)
    assert migration_cost(1e9, 2, fiber_move_s=0.5, checkpoint_bw=1e9,
                          restart_s=1.0) == pytest.approx(1.0 + 1.0 + 1.0)
    with pytest.raises(ValueError):
        migration_cost(-1.0)


def test_state_bytes_counts_tables_and_experts():
    assert VGG16.state_bytes == VGG16.dense_bytes
    assert DLRM.state_bytes == pytest.approx(
        DLRM.dense_bytes + 64 * 1e7 * 128 * 4)
    moe_extra = 8 * 16 * 3 * 1024 * 2048 * 4
    assert MOE_16E.state_bytes == pytest.approx(
        MOE_16E.dense_bytes + moe_extra)


def test_rebalance_invariants(frag_plan):
    """An adopted migration keeps the JobSet well-formed: same tenants,
    same shard sizes, disjoint placements; records land on the controller."""
    js = _fragmented_jobset()
    ctrl = JobSetController(
        js, hw=HW,
        policy=ReoptPolicy.reactive(
            replan_latency=1e-3, max_migrations=2,
            payback_horizon=1e6, migration_restart=1e-6),
        plan=frag_plan, seed=0,
    )
    ctrl.admit(MOE_16E, 3, name="moe", now=0.0)
    before = {t.label: t.k for t in ctrl.jobset.tenants}
    pause = ctrl.depart("bert", now=1.0)  # wires rebalance in
    assert pause >= 0.0
    after = ctrl.jobset
    after.validate()  # disjoint placements survive any migration
    assert {t.label: t.k for t in after.tenants} == \
        {k: v for k, v in before.items() if k != "bert"}
    for rec in ctrl.migrations:
        assert isinstance(rec, MigrationRecord)
        assert rec.reason == "departure"
        assert len(rec.src) == len(rec.dst)
        assert rec.cost > 0.0
        if rec.adopted:
            assert rec.est_after <= rec.est_before
            assert after.tenant(rec.tenant).servers == rec.dst


def test_rebalance_rejects_expensive_state(frag_plan):
    """With a realistic restart floor every move is unprofitable over a
    short horizon: rebalance must reject (records kept) and leave
    placements untouched."""
    js = _fragmented_jobset()
    ctrl = JobSetController(
        js, hw=HW,
        policy=ReoptPolicy.reactive(
            replan_latency=1e-3, max_migrations=2,
            payback_horizon=1.0, migration_restart=MIGRATION_RESTART_S),
        plan=frag_plan, seed=0,
    )
    placements = {t.label: t.servers for t in ctrl.jobset.tenants}
    update = ctrl.rebalance(now=0.0, reason="departure")
    assert update is None
    assert {t.label: t.servers for t in ctrl.jobset.tenants} == placements
    assert all(not m.adopted for m in ctrl.migrations)


def test_rebalance_not_suppressed_by_plain_min_interval(frag_plan):
    """Regression: depart() replans (stamping last_replan) right before it
    chains rebalance — a plain min_interval hysteresis must not swallow
    the rebalance it was wired to.  Only an active adaptive backoff may."""
    js = _fragmented_jobset()
    ctrl = JobSetController(
        js, hw=HW,
        policy=ReoptPolicy.reactive(
            replan_latency=1e-3, min_interval=100.0, max_migrations=2,
            payback_horizon=1e6, migration_restart=1e-6),
        plan=frag_plan, seed=0,
    )
    ctrl.fail((0, 3), now=0.0)  # stamps last_replan at t=0
    ctrl.rebalance(now=1.0, reason="departure")  # inside min_interval
    assert ctrl.migrations  # decisions were taken, not gated away
    # An adopted migration keeps log and counter in correspondence.
    assert sum(1 for r in ctrl.log if r.replanned) == ctrl.n_replans
    # Active adaptive backoff, by contrast, does suppress.
    backed = JobSetController(
        js, hw=HW,
        policy=ReoptPolicy.reactive(
            fiber_move_latency=1e6, adaptive=True, max_migrations=2,
            payback_horizon=1e6, migration_restart=1e-6),
        plan=frag_plan, seed=0,
    )
    backed.fail((0, 3), now=0.0)  # adaptive skip: backs off the interval
    assert backed._adaptive_interval > backed.policy.min_interval
    n_before = len(backed.migrations)
    assert backed.rebalance(now=1e-6, reason="departure") is None
    assert len(backed.migrations) == n_before  # gated: no decisions taken


def test_rebalance_disabled_is_noop(frag_plan):
    ctrl = JobSetController(
        _fragmented_jobset(), hw=HW, policy=ReoptPolicy.never(),
        plan=frag_plan, seed=0,
    )
    assert ctrl.rebalance(now=0.0) is None
    assert ctrl.migrations == []
    assert ctrl.n_replans == 0


def test_migration_planupdate_conserves_bytes_and_reports_records():
    """A mid-run migration PlanUpdate behaves like any fabric swap: flows
    keep their remaining bytes, the pause is charged, and the records
    surface in ScenarioResult.migrations."""
    rec = MigrationRecord(time=1.0, tenant="j", src=(0,), dst=(2,),
                          cost=2.0, adopted=True, reason="departure")

    class Migrate(ScenarioObserver):
        fired = False

        def on_failure(self, view, link):
            if Migrate.fired:
                return None
            Migrate.fired = True
            return PlanUpdate(
                links={(0, 2): 100.0, (2, 1): 100.0},
                pause=2.0, label="rebalance:departure", edges_moved=2,
                migrations=(rec,),
            )

    r = SimEngine().run(Scenario(
        links={(0, 1): 100.0, (0, 2): 100.0, (2, 1): 100.0},
        jobs=[SimJob("j", [
            Task(tid=0, kind="flow", nbytes=1000.0, route=(0, 1))])],
        failures=(LinkFailure(time=5.0, link=(0, 1)),),
        n=3,
    ), observer=Migrate())
    assert r.migrations == (rec,)
    assert r.edges_moved == 2
    assert r.delivered["j"] == pytest.approx(1000.0)
    # 5 s direct + 2 s pause + 500 B over the 2-hop detour at 100 B/s.
    assert r.makespan == pytest.approx(12.0, rel=1e-6)
    assert not r.stalled


def test_run_online_jobset_reports_migrations(frag_plan):
    js = _fragmented_jobset()
    trace = (
        TraceEvent(iteration=0, kind="fail", link=(2, 5)),
        TraceEvent(iteration=0, kind="fail", link=(5, 8)),
        TraceEvent(iteration=1, kind="arrive", job=MOE_16E, k=3, name="moe"),
        TraceEvent(iteration=2, kind="depart", name="bert"),
    )
    r = run_online_jobset(
        js, HW,
        policy=ReoptPolicy.reactive(
            replan_latency=1e-3, candidates=4, max_migrations=2,
            payback_horizon=1e6, migration_restart=1e-6),
        trace=trace, n_iters=4, seed=0, plan=frag_plan)
    assert r.n_migrations == sum(1 for m in r.migrations if m.adopted)
    final = {t.label for t in r.final_jobset.tenants}
    assert final == {"dlrm", "moe"}
    r.final_jobset.validate()


# ---------------------------------------------------------------------------
# Satellite: rebase_demand (placement rebase without union rebuild)
# ---------------------------------------------------------------------------


def test_rebase_demand_equals_remap_at_new_placement():
    d = job_demand(DLRM, 4, table_hosts=(0, 2))
    old = (1, 3, 5, 7)
    new = (0, 2, 4, 6)
    a = rebase_demand(remap_demand(d, old, 8), old, new)
    b = remap_demand(d, new, 8)
    np.testing.assert_array_equal(a.mp, b.mp)
    assert [(g.members, g.nbytes) for g in a.allreduce] == \
        [(g.members, g.nbytes) for g in b.allreduce]


def test_rebase_demand_validates():
    d = remap_demand(job_demand(VGG16, 2), (0, 1), 4)
    with pytest.raises(ValueError):
        rebase_demand(d, (0, 1), (2,))  # size mismatch
    with pytest.raises(ValueError):
        rebase_demand(d, (0, 1), (2, 2))  # repeat
    with pytest.raises(ValueError):
        rebase_demand(d, (0, 1), (2, 9))  # outside


def test_placement_diff_and_with_placement():
    js = _fragmented_jobset()
    moved = js.with_placement("bert", (2, 5, 8, 11))
    diff = placement_diff(js, moved)
    assert set(diff) == {"bert"}
    assert diff["bert"] == (js.tenant("bert").servers, (2, 5, 8, 11))
    assert placement_diff(js, js) == {}
    # Departures/arrivals are not migrations.
    assert placement_diff(js, js.without("bert")) == {}
    with pytest.raises(KeyError):
        js.with_placement("nope", (0,))
    with pytest.raises(ValueError):  # overlap rejected by validation
        js.with_placement("bert", js.tenant("dlrm").servers)


# ---------------------------------------------------------------------------
# Satellite: per-tenant comm-time decomposition
# ---------------------------------------------------------------------------


def test_tenant_comm_times_decomposition(frag_plan):
    js = _fragmented_jobset()
    strategies = {t.label: default_strategy(t.spec) for t in js.tenants}
    obj, union, per_job, per_comm = evaluate_jobset(
        strategies, js, frag_plan.topology, HW, decompose=True)
    assert set(per_comm) == {"dlrm", "bert"}
    from repro.core.planeval import plan_evaluator

    union_comm = plan_evaluator(frag_plan.topology, HW).comm_time(union)
    for label, own in per_comm.items():
        assert 0.0 < own
        # A tenant's own weighted-share time never exceeds the union time
        # scaled by the contention it actually sees.
        assert own <= union_comm * sum(t.weight for t in js.tenants) + 1e-12
    # The objective is identical with and without decomposition.
    obj2, _, per_job2 = evaluate_jobset(
        strategies, js, frag_plan.topology, HW)
    assert obj == obj2 and per_job == per_job2


def test_tenant_comm_alone_equals_union_time():
    js = JobSet(n=8, tenants=[
        TenantJob(spec=VGG16, servers=tuple(range(8)), name="vgg")])
    plan = co_optimize_jobset(js, HW, rounds=1, mcmc_iters=5, seed=0)
    per_comm = tenant_comm_times(plan.strategies, js, plan.topology, HW)
    from repro.core.planeval import plan_evaluator

    ev = plan_evaluator(plan.topology, HW)
    union = js.union_for(plan.strategies)
    assert per_comm["vgg"] == pytest.approx(ev.comm_time(union), rel=1e-12)


def test_plan_reports_per_job_comm(frag_plan):
    assert set(frag_plan.per_job_comm) == {"dlrm", "bert"}
    assert all(v >= 0 for v in frag_plan.per_job_comm.values())


# ---------------------------------------------------------------------------
# Satellite: deadline-aware replanning
# ---------------------------------------------------------------------------


def test_deadline_policy_scales_replan_weights(frag_plan):
    pol = DeadlineFairness(deadlines={"bert": 1.0}, horizon=2.0,
                           max_boost=8.0)
    ctrl = JobSetController(
        _fragmented_jobset(), hw=HW, policy=ReoptPolicy.reactive(),
        plan=frag_plan, seed=0, deadline_policy=pol,
    )
    scaled = ctrl._opt_jobset(ctrl.jobset, now=1.0)  # at the deadline
    weights = {t.label: t.weight for t in scaled.tenants}
    assert weights["bert"] == pytest.approx(pol.weight("bert", 1.0))
    assert weights["dlrm"] == pytest.approx(1.0)
    assert pol.weight("bert", 1.0) > 4.0  # deep into the ramp
    # The engine-side fairness prices the same weight * urgency product —
    # static tenant weights are not discarded by the deadline policy.
    fair = ctrl.fairness()
    assert fair.time_varying
    assert fair.weight("bert", 1.0) == pytest.approx(
        ctrl.jobset.tenant("bert").weight * pol.weight("bert", 1.0))
    assert fair.weight("dlrm", 1.0) == pytest.approx(
        ctrl.jobset.tenant("dlrm").weight * pol.weight("dlrm", 1.0))
    # Without a deadline policy the jobset passes through untouched.
    plain = JobSetController(_fragmented_jobset(), hw=HW,
                             policy=ReoptPolicy.never(), plan=frag_plan)
    assert plain._opt_jobset(plain.jobset, now=1.0) is plain.jobset


def test_deadline_replan_matches_manually_scaled_jobset(frag_plan):
    """A deadline-aware replan is exactly a replan of the urgency-scaled
    JobSet: same seed, same warm start, same plan."""
    from dataclasses import replace

    from repro.core.topology_finder import remove_pair

    pol = DeadlineFairness(deadlines={"bert": 0.5}, horizon=1.0,
                           max_boost=8.0)
    now, pair = 0.25, (0, 3)
    ctrl = JobSetController(
        _fragmented_jobset(), hw=HW,
        policy=ReoptPolicy(on_failure=True, replan_latency=1e-3),
        plan=frag_plan, seed=0, deadline_policy=pol,
    )
    warm_strategies = ctrl.strategies()
    degraded = remove_pair(ctrl.topology, pair)
    ctrl.fail(pair, now=now)
    assert ctrl.n_replans == 1
    scaled = JobSet(n=12, tenants=[
        replace(t, weight=t.weight * pol.weight(t.label, now))
        for t in _fragmented_jobset().tenants
    ])
    manual = co_optimize_jobset(
        scaled, HW, rounds=ctrl.policy.rounds,
        mcmc_iters=ctrl.policy.mcmc_iters, seed=ctrl.seed + 1,
        warm_topology=degraded, warm_strategies=warm_strategies,
        forbidden=(pair,),
    )
    applied = [r for r in ctrl.log if r.replanned][-1]
    if applied.est_after <= applied.est_before:  # plan adopted
        assert ctrl.plan.strategies == manual.strategies
        assert sorted(ctrl.plan.topology.graph.edges()) == \
            sorted(manual.topology.graph.edges())
