import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, apply_updates, constant, cosine, sgd_momentum, wsd


def test_adamw_converges_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw(constant(0.1), weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        g = {"w": p["w"] - target}
        return opt.update(g, s, p, i)

    for i in range(200):
        params, state = step(params, state, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_adamw_bf16_master_copy():
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    opt = adamw(constant(1e-3))
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    p2, s2 = opt.update(g, state, params, jnp.int32(0))
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["master"]["w"].dtype == jnp.float32
    # master tracks more precision than bf16 params
    assert not np.allclose(np.asarray(s2["master"]["w"]), 0.0)


def test_sgd_momentum_converges():
    target = jnp.array([0.5, -0.5])
    params = {"w": jnp.zeros(2)}
    opt = sgd_momentum(constant(0.05), momentum=0.9)
    state = opt.init(params)
    for i in range(300):
        g = {"w": params["w"] - target}
        params, state = opt.update(g, state, params, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_wsd_schedule_shape():
    fn = wsd(1.0, total_steps=1000, warmup_frac=0.01, decay_frac=0.1)
    warm = float(fn(jnp.int32(0)))
    stable = float(fn(jnp.int32(500)))
    decayed = float(fn(jnp.int32(999)))
    assert warm < stable  # warming up
    assert stable == pytest.approx(1.0)
    assert decayed < 0.1  # decay tail


def test_cosine_schedule_monotone_after_warmup():
    fn = cosine(1.0, total_steps=100, warmup=10)
    vals = [float(fn(jnp.int32(s))) for s in range(100)]
    assert vals[10] >= vals[50] >= vals[99]
    assert vals[99] >= 0.099  # final_frac floor


def test_apply_updates_preserves_dtype():
    p = {"w": jnp.zeros(3, jnp.bfloat16)}
    u = {"w": jnp.ones(3, jnp.float32)}
    out = apply_updates(p, u)
    assert out["w"].dtype == jnp.bfloat16
