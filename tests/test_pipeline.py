"""GPipe pipeline parallelism over ppermute (4 stages, subprocess)."""

from _subproc import run_with_devices

import pytest

# Multi-minute subprocess tests (fresh jax init per case); quick loop:
# python -m pytest -m "not slow"
pytestmark = pytest.mark.slow


def test_gpipe_matches_sequential():
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from repro.parallel.pipeline import make_gpipe_step

S, M, MB, D = 4, 6, 8, 16
mesh = jax.make_mesh((S,), ("pipe",))
rng = np.random.default_rng(0)
# one layer per stage: y = tanh(x @ W_s)
Ws = jnp.array(rng.standard_normal((S, D, D)) / np.sqrt(D), jnp.float32)
x = jnp.array(rng.standard_normal((M, MB, D)), jnp.float32)

def stage_fn(w, h):
    return jnp.tanh(h @ w)

step = make_gpipe_step(stage_fn, mesh, "pipe")
outs = step(Ws, x)

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ Ws[s])
assert np.allclose(np.asarray(outs), np.asarray(ref), atol=1e-5), \
    np.abs(np.asarray(outs) - np.asarray(ref)).max()
print("PASS")
""",
        n_devices=4,
    )
    assert "PASS" in out


def test_gpipe_bubble_schedule_lengths():
    """Every microbatch index must be produced exactly once (no bubble
    corruption) for several (M, S) combinations."""
    out = run_with_devices(
        """
import jax, numpy as np
import jax.numpy as jnp
from repro.parallel.pipeline import make_gpipe_step
for M in (1, 2, 5):
    S, MB, D = 4, 4, 8
    mesh = jax.make_mesh((S,), ("pipe",))
    rng = np.random.default_rng(M)
    Ws = jnp.array(rng.standard_normal((S, D, D)) / np.sqrt(D), jnp.float32)
    x = jnp.array(rng.standard_normal((M, MB, D)), jnp.float32)
    step = make_gpipe_step(lambda w, h: jnp.tanh(h @ w), mesh, "pipe")
    outs = np.asarray(step(Ws, x))
    ref = np.asarray(x)
    for s in range(S):
        ref = np.tanh(ref @ np.asarray(Ws[s]))
    assert np.allclose(outs, ref, atol=1e-5), M
print("PASS")
""",
        n_devices=4,
    )
    assert "PASS" in out
