"""Prototype reproduction (§6): train the paper's DLRM at testbed scale and
measure the impact of all-to-all traffic, mirroring Fig. 21.

Trains a small DLRM in JAX (embedding tables + dot interaction) while the
network layer estimates per-iteration comm time on (a) the TopoOpt plan,
(b) Switch-100G (ideal) and (c) Switch-25G, across batch sizes.

    PYTHONPATH=src python examples/dlrm_testbed.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HardwareSpec, topology_finder
from repro.core.simengine import ideal_switch_comm_time, topoopt_comm_time
from repro.core.workloads import DLRM, job_demand
from repro.models import dlrm
from repro.optim import adamw, constant


def train_small_dlrm(steps: int = 80) -> float:
    cfg = dlrm.DLRMConfig(n_tables=8, rows_per_table=512, embed_dim=32)
    params = dlrm.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(constant(3e-3), weight_decay=0.0)
    state = opt.init(params)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(p, s, batch, i):
        (l, _), g = jax.value_and_grad(
            lambda pp: dlrm.loss_fn(pp, batch, cfg), has_aux=True
        )(p)
        p2, s2 = opt.update(g, s, p, i)
        return p2, s2, l

    losses = []
    for i in range(steps):
        sparse = rng.integers(0, cfg.rows_per_table, (128, cfg.n_tables))
        batch = {
            "dense": jnp.array(rng.standard_normal((128, cfg.dense_features)),
                               jnp.float32),
            "sparse": jnp.array(sparse, jnp.int32),
            "label": jnp.array(sparse[:, 0] % 2, jnp.float32),
        }
        params, state, loss = step(params, state, batch, jnp.int32(i))
        losses.append(float(loss))
    print(f"DLRM training: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses[-1]


def network_study() -> None:
    n, d = 12, 4  # the paper's 12-server testbed, degree 4
    print(f"\n{n}-server testbed, d={d} (Fig. 21 style):")
    print(f"{'batch':>6} {'a2a/ar':>7} {'topoopt':>9} {'sw100':>9} {'sw25':>9} {'tax':>5}")
    for bs in (64, 128, 256, 512):
        job = DLRM.with_batch(bs)
        dem = job_demand(job, n, table_hosts=range(0, n, 3))
        topo = topology_finder(dem, d)
        hw100 = HardwareSpec(link_bandwidth=25e9 / 8, degree=d)  # 4 x 25G
        res = topoopt_comm_time(topo, dem, hw100)
        t_sw100 = ideal_switch_comm_time(dem, HardwareSpec(link_bandwidth=100e9 / 8, degree=1))
        t_sw25 = ideal_switch_comm_time(dem, HardwareSpec(link_bandwidth=25e9 / 8, degree=1))
        ratio = dem.sum_mp / max(dem.sum_allreduce, 1e-9)
        print(
            f"{bs:6d} {ratio:7.2f} {res['comm_time']*1e3:8.2f}m "
            f"{t_sw100*1e3:8.2f}m {t_sw25*1e3:8.2f}m {res['bandwidth_tax']:5.2f}"
        )


if __name__ == "__main__":
    final = train_small_dlrm()
    assert final < 0.6, "DLRM training failed to learn"
    network_study()
