"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
full TopoOpt pipeline —

1. TopologyFinder plans the rings for an 8-way data-parallel job,
2. the JAX mesh is reordered so the primary ring is physically contiguous,
3. gradient sync runs over the multi-ring TotientPerms AllReduce (§6),
4. checkpoints every 50 steps; restart-safe.

Run with 8 fake devices (CPU):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python examples/train_lm_topoopt.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec, get_config
from repro.core import topology_finder
from repro.core.demand import data_parallel_demand
from repro.core.device_order import topoopt_mesh
from repro.checkpoint.ckpt import latest_step, load_checkpoint, save_checkpoint
from repro.data.pipeline import DataSpec, batch_for_step
from repro.models import lm
from repro.optim import adamw, cosine
from repro.train.steps import make_shardmap_dp_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=8)
    ap.add_argument("--schedule", default="ring",
                    choices=["ring", "recursive_hd", "multi_tree"],
                    help="collective schedule for gradient sync "
                         "(normally the searched Strategy.schedule)")
    args = ap.parse_args()

    n_dev = jax.device_count()
    # ~100M params: vocab 32k x d_model + layers.
    cfg = dataclasses.replace(
        get_config("granite-8b"),
        n_layers=args.n_layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=32, d_ff=args.d_model * 4, vocab=32768,
        param_dtype="float32", activation_dtype="float32",
    )
    shape = ShapeSpec("example", seq_len=128, global_batch=n_dev * 2, kind="train")

    # --- TopoOpt plan: degree-3 rings for the DP AllReduce -----------------
    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree.leaves(lm.param_specs(cfg))
    )
    print(f"model: {n_params/1e6:.1f}M params on {n_dev} devices")
    topo = topology_finder(data_parallel_demand(n_dev, n_params * 4), degree=3)
    strides = tuple(topo.ring_strides(tuple(range(n_dev))))
    print(f"TotientPerms ring strides: {strides}")

    mesh = topoopt_mesh((n_dev,), ("data",), allreduce_axis="data",
                        stride=strides[0] if strides else 1)
    opt = adamw(cosine(3e-3, args.steps))
    step_fn = make_shardmap_dp_train_step(
        cfg, opt, mesh, axis_name="data", ring_strides=strides or (1,),
        schedule=args.schedule,
    )

    start = 0
    params = state = None
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        p_specs = lm.param_specs(cfg)
        o_specs = jax.eval_shape(opt.init, p_specs)
        start, params, state, _ = load_checkpoint(args.ckpt_dir, p_specs, o_specs)
        print(f"resumed from step {start}")
    if params is None:
        params = lm.init(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)

    spec = DataSpec(cfg=cfg, shape=shape, seed=0)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = batch_for_step(spec, step)
        params, state, loss, _ = step_fn(params, state, batch, jnp.int32(step), 0)
        if step % 20 == 0:
            dt = (time.perf_counter() - t0) / max(step - start, 1)
            print(f"step {step:4d} loss {float(loss):.4f} ({dt*1e3:.0f} ms/step)")
        if args.ckpt_dir and (step + 1) % 50 == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params, state)
    print(f"final loss: {float(loss):.4f}")


if __name__ == "__main__":
    main()
