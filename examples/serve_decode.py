"""Serve a small model with batched requests: continuous prefill+decode with
a KV cache, reporting tokens/s — exercises the serving path used by the
decode_32k / long_500k dry-run cells.

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-9b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--decode-steps", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode path")
    rng = np.random.default_rng(0)
    params = lm.init(jax.random.PRNGKey(0), cfg)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.array(
            rng.standard_normal((B, cfg.img_tokens, cfg.d_model)),
            jnp.dtype(cfg.activation_dtype),
        )

    max_len = S + args.decode_steps
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cfg, pad_to=max_len))
    decode = jax.jit(lambda p, b: lm.decode_step(p, b, cfg))

    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    tok = jnp.argmax(logits, axis=-1)

    t0 = time.perf_counter()
    n_tokens = 0
    for i in range(args.decode_steps - 1):
        logits, cache = decode(
            params, {"token": tok, "pos": jnp.int32(S + i), "cache": cache}
        )
        tok = jnp.argmax(logits, axis=-1)
        n_tokens += B
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"{cfg.name} (smoke): {n_tokens} tokens in {dt:.2f}s "
          f"= {n_tokens / dt:.1f} tok/s (batch {B})")


if __name__ == "__main__":
    main()
