"""Quickstart: co-optimize topology + parallelization for a DLRM job, then
inspect the TopoOpt plan.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import HardwareSpec, alternating_optimize
from repro.core.simengine import (
    fat_tree_comm_time,
    ideal_switch_comm_time,
    topoopt_comm_time,
)
from repro.core.topology_finder import effective_diameter
from repro.core.workloads import DLRM


def main() -> None:
    n, degree = 16, 4
    hw = HardwareSpec(link_bandwidth=100e9 / 8, degree=degree)

    print(f"Co-optimizing DLRM on {n} servers, degree {degree}, 100 Gbps ...")
    res = alternating_optimize(DLRM, n=n, hw=hw, rounds=3, mcmc_iters=150, seed=0)

    print(f"\nstrategy: {res.strategy.mode}")
    if res.strategy.table_hosts:
        print(f"embedding-table hosts: {res.strategy.table_hosts}")
    print(f"estimated iteration time: {res.iter_time * 1e3:.2f} ms")

    topo = res.topology
    print(f"\ntopology: d_AllReduce={topo.d_allreduce} d_MP={topo.d_mp}")
    for members, rings in topo.rings.items():
        print(f"  AllReduce group of {len(members)}: strides "
              f"{[r.p for r in rings]} (TotientPerms)")
    print(f"  effective diameter: {effective_diameter(topo)}")

    t = topoopt_comm_time(topo, res.demand, hw)
    print(f"  comm time: {t['comm_time']*1e3:.2f} ms, "
          f"bandwidth tax: {t['bandwidth_tax']:.2f}")

    t_ideal = ideal_switch_comm_time(res.demand, hw)
    t_ft = fat_tree_comm_time(res.demand, hw, bandwidth_fraction=0.35)
    print(f"\nvs ideal switch : {t['comm_time'] / t_ideal:.2f}x its comm time")
    print(f"vs similar-cost fat-tree: {t_ft / t['comm_time']:.2f}x faster")


if __name__ == "__main__":
    main()
