"""Architecture configs + input shapes.

One :class:`ArchConfig` covers every assigned family; per-arch files
instantiate the exact published configuration and register it.  ``smoke()``
returns the reduced same-family config used by CPU tests; full configs are
only ever lowered via ShapeDtypeStructs (no allocation).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable

import jax.numpy as jnp
from jax import ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned LM shape set (identical for all 10 archs, with per-family
# skips recorded in shape_applicability()).
TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # VLM (cross-attention image layers; frontend stubbed)
    cross_attn_every: int = 0  # every k-th layer is a cross-attn block
    img_tokens: int = 0
    # Hybrid (RG-LRU + local attention)
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    tail_pattern: tuple[str, ...] = ()
    attn_window: int = 0  # sliding window for local attention
    lru_width: int = 0
    # SSM (mamba1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0
    # Encoder-only (audio): no causal mask, no decode shapes
    is_encoder: bool = False
    # Schedule hint (minicpm uses WSD)
    schedule: str = "cosine"
    # Numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model if self.family == "ssm" else (
            self.lru_width or self.d_model
        )

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=16,
            d_ff=128,
            vocab=256,
        )
        if self.family == "moe":
            # capacity 4.0 => dropless at smoke scale (keeps prefill/decode
            # numerically identical to the full forward).
            small.update(n_experts=4, top_k=2, d_ff=32, capacity_factor=4.0)
        if self.family == "vlm":
            small.update(cross_attn_every=2, img_tokens=8, n_layers=4)
        if self.family == "hybrid":
            small.update(lru_width=64, attn_window=16, n_layers=5,
                         tail_pattern=("rec", "rec"))
        if self.family == "ssm":
            small.update(ssm_state=8, dt_rank=8, n_layers=2)
        if self.n_kv_heads == self.n_heads:
            small["n_kv_heads"] = small["n_heads"]
        if self.n_kv_heads == 1:
            small["n_kv_heads"] = 1
        return replace(self, **small)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from importlib import import_module

    for mod in (
        "minicpm_2b",
        "deepseek_coder_33b",
        "granite_8b",
        "granite_34b",
        "qwen3_moe_30b_a3b",
        "qwen3_moe_235b_a22b",
        "llama32_vision_11b",
        "recurrentgemma_9b",
        "falcon_mamba_7b",
        "hubert_xlarge",
        "dlrm",
    ):
        import_module(f"repro.configs.{mod}")


# ---------------------------------------------------------------------------
# Shape applicability (skips recorded in DESIGN.md §4)
# ---------------------------------------------------------------------------


def shape_applicability(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch, shape) cell."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch: no autoregressive decode step"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid")
        if not sub_quadratic:
            return False, "pure full-attention arch: O(L^2) at 524k infeasible"
    return True, ""


def runnable_cells() -> list[tuple[ArchConfig, ShapeSpec]]:
    cells = []
    for cfg in all_configs().values():
        if cfg.family == "recsys":  # paper's DLRM: separate shape system
            continue
        for shape in ALL_SHAPES:
            ok, _ = shape_applicability(cfg, shape)
            if ok:
                cells.append((cfg, shape))
    return cells


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs; no allocation) — DESIGN.md §5
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Model inputs for one cell as ShapeDtypeStructs.

    train:   tokens (B, S) i32 (+ image_embeds / frames for vlm/audio)
    prefill: tokens (B, S) i32
    decode:  token (B,) i32, pos () i32, cache pytree (family-specific)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.activation_dtype)

    if cfg.family == "audio":
        batch = {
            "frames": ShapeDtypeStruct((B, S, cfg.d_model), act),
            "labels": ShapeDtypeStruct((B, S), i32),
        }
        return batch

    batch: dict = {"tokens": ShapeDtypeStruct((B, S), i32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = ShapeDtypeStruct((B, cfg.img_tokens, cfg.d_model), act)

    if shape.kind == "decode":
        batch = {
            "token": ShapeDtypeStruct((B,), i32),
            "pos": ShapeDtypeStruct((), i32),
            "cache": cache_specs(cfg, B, S),
        }
        if cfg.family == "vlm":
            batch["image_embeds"] = ShapeDtypeStruct(
                (B, cfg.img_tokens, cfg.d_model), act
            )
    return batch


def cache_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """Decoding state for one model (stacked over layers)."""
    act = jnp.dtype(cfg.activation_dtype)
    hd = cfg.hd

    if cfg.family == "ssm":
        return {
            "conv": ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.d_conv - 1, cfg.d_inner), act
            ),
            "ssm": ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state), jnp.float32
            ),
        }
    if cfg.family == "hybrid":
        n_blocks = cfg.n_layers // len(cfg.block_pattern) if cfg.block_pattern else 0
        n_rec_main = n_blocks * sum(1 for k in cfg.block_pattern if k == "rec")
        n_attn = n_blocks * sum(1 for k in cfg.block_pattern if k == "attn")
        n_rec_tail = sum(1 for k in cfg.tail_pattern if k == "rec")
        window = min(cfg.attn_window, seq_len)
        return {
            "lru": ShapeDtypeStruct(
                (n_rec_main + n_rec_tail, batch, cfg.d_inner), jnp.float32
            ),
            "conv": ShapeDtypeStruct(
                (n_rec_main + n_rec_tail, batch, 3, cfg.d_inner), act
            ),
            "k": ShapeDtypeStruct((n_attn, batch, cfg.n_kv_heads, window, hd), act),
            "v": ShapeDtypeStruct((n_attn, batch, cfg.n_kv_heads, window, hd), act),
        }
    # dense / moe / vlm transformers: full KV cache per self-attn layer.
    n_self = cfg.n_layers
    specs = {}
    if cfg.family == "vlm" and cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        specs["xk"] = ShapeDtypeStruct(
            (n_cross, batch, cfg.n_kv_heads, cfg.img_tokens, hd), act
        )
        specs["xv"] = ShapeDtypeStruct(
            (n_cross, batch, cfg.n_kv_heads, cfg.img_tokens, hd), act
        )
    specs["k"] = ShapeDtypeStruct((n_self, batch, cfg.n_kv_heads, seq_len, hd), act)
    specs["v"] = ShapeDtypeStruct((n_self, batch, cfg.n_kv_heads, seq_len, hd), act)
    return specs
