"""Granite-34B-Code [arXiv:2405.04324; hf] — dense llama-arch, MQA (kv=1)."""

from .base import ArchConfig, register

GRANITE_34B = register(
    ArchConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab=49152,
        head_dim=128,
        source="arXiv:2405.04324",
    )
)
