"""RecurrentGemma-9B [arXiv:2402.19427; unverified] — Griffin: RG-LRU
recurrent blocks + local (sliding-window) attention, pattern 2 recurrent : 1
attention.  38 layers = 12 x (rec, rec, attn) + (rec, rec) tail.
"""

from .base import ArchConfig, register

RECURRENTGEMMA_9B = register(
    ArchConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab=256000,
        head_dim=256,
        block_pattern=("rec", "rec", "attn"),
        tail_pattern=("rec", "rec"),
        attn_window=2048,
        lru_width=4096,
        source="arXiv:2402.19427",
    )
)
