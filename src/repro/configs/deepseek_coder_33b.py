"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — dense llama-arch, GQA kv=8."""

from .base import ArchConfig, register

DEEPSEEK_CODER_33B = register(
    ArchConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab=32256,
        head_dim=128,
        source="arXiv:2401.14196",
    )
)
