"""Qwen3-235B-A22B [hf:Qwen/Qwen3-235B-A22B] — MoE, 128 experts top-8."""

from .base import ArchConfig, register

QWEN3_MOE_235B = register(
    ArchConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,  # per-expert ffn width
        vocab=151936,
        head_dim=128,
        n_experts=128,
        top_k=8,
        source="hf:Qwen/Qwen3-30B-A3B (235B sibling)",
    )
)
