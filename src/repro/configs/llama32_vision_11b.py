"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Transformer backbone only: every 5th layer is a cross-attention block over
precomputed patch embeddings (modality frontend is a stub; ``input_specs``
provides (B, img_tokens, d_model) embeddings directly).
"""

from .base import ArchConfig, register

LLAMA32_VISION_11B = register(
    ArchConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=128256,
        head_dim=128,
        cross_attn_every=5,  # 8 cross-attn blocks of 40 layers
        img_tokens=1601,  # 1 CLS + 40x40 patches
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
)
