"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE, 128 experts top-8."""

from .base import ArchConfig, register

QWEN3_MOE_30B = register(
    ArchConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=768,  # per-expert ffn width
        vocab=151936,
        head_dim=128,  # hf config head_dim (decoupled from d_model/n_heads)
        n_experts=128,
        top_k=8,
        source="hf:Qwen/Qwen3-30B-A3B",
    )
)
