"""Falcon-Mamba-7B [arXiv:2410.05355; unverified] — pure Mamba-1 SSM,
attention-free; d_inner = 2 * d_model, ssm_state = 16."""

from .base import ArchConfig, register

FALCON_MAMBA_7B = register(
    ArchConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=65024,
        ssm_state=16,
        d_conv=4,
        expand=2,
        dt_rank=256,  # d_model / 16
        source="arXiv:2410.05355",
    )
)
