"""Granite-8B-Code [arXiv:2405.04324; hf] — dense llama-arch, GQA kv=8."""

from .base import ArchConfig, register

GRANITE_8B = register(
    ArchConfig(
        name="granite-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=49152,
        head_dim=128,
        source="arXiv:2405.04324",
    )
)
