"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, WSD schedule."""

from .base import ArchConfig, register

MINICPM_2B = register(
    ArchConfig(
        name="minicpm-2b",
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab=122753,
        head_dim=64,
        tie_embeddings=True,
        schedule="wsd",
        source="arXiv:2404.06395",
    )
)
