"""HuBERT-XLarge [arXiv:2106.07447; unverified] — encoder-only audio
transformer (w2v2 arch).  Modality frontend stubbed: ``input_specs`` provides
precomputed frame embeddings (B, frames, d_model); targets are masked-frame
cluster ids over a 504-way codebook."""

from .base import ArchConfig, register

HUBERT_XLARGE = register(
    ArchConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab=504,
        head_dim=80,
        is_encoder=True,
        source="arXiv:2106.07447",
    )
)
