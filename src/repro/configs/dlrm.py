"""DLRM (paper §2.1/§5, List 1) — the paper's flagship workload.  Used by the
examples and benchmarks (recsys family: its shapes are batch-only, outside
the LM shape grid)."""

from .base import ArchConfig, register

DLRM_PAPER = register(
    ArchConfig(
        name="dlrm-paper",
        family="recsys",
        n_layers=8,  # dense stack
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=4096,  # feature-layer width
        vocab=0,
        source="paper List 1 (§5.3); github.com/facebookresearch/dlrm",
    )
)
