"""Step builders: jit-able train_step / serve_step with sharding attached.

Two execution styles:

* ``pjit`` (default, used by the dry-run and the big-mesh path): the step is
  written in global terms; GSPMD inserts the collectives implied by the
  sharding plan (FSDP all-gathers, gradient reduce-scatters, EP all-to-all).
* ``shard_map_dp`` (examples/tests): explicit data-parallel trainer whose
  gradient sync is the paper's multi-ring TotientPerms AllReduce
  (core.collectives), matching the NCCL integration of §6.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec, cache_specs, input_specs
from ..core.collectives import topoopt_psum_fn
from ..models import lm
from ..optim import Optimizer
from ..parallel.act_sharding import ActivationPolicy, set_policy
from ..parallel.sharding import (
    ShardingPlan,
    batch_sharding,
    opt_state_sharding,
    param_sharding,
)


def install_activation_policy(plan: ShardingPlan, mesh: Mesh) -> None:
    """GSPMD hints: batch-over-data activations (see parallel.act_sharding)."""
    set_policy(
        ActivationPolicy(
            dp=plan.dp_axes(mesh),
            tp="model" if "model" in mesh.axis_names else None,
            seq="model" if plan.seq_parallel else None,
        )
    )


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, plan: ShardingPlan):
    """Global-semantics train step (pjit style)."""

    def train_step(params, opt_state, batch, step):
        def loss(p):
            return lm.loss_fn(
                p, batch, cfg, remat=plan.remat, loss_chunk=plan.loss_chunk
            )

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        new_params, new_state = optimizer.update(grads, opt_state, params, step)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        metrics = dict(metrics, loss=total, grad_norm=gnorm)
        return new_params, new_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, shape: ShapeSpec):
    if shape.kind == "prefill":
        def serve_step(params, batch):
            return lm.prefill(params, batch, cfg)
        return serve_step

    def serve_step(params, batch):
        return lm.decode_step(params, batch, cfg)

    return serve_step


def shapes_of(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def jit_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    plan: ShardingPlan,
    mesh: Mesh,
    donate: bool = True,
):
    """jit(train_step) with in/out shardings derived from the plan.

    Returns (jitted_fn, (param_specs, opt_specs, batch_fn)) where batch_fn
    maps a ShapeSpec to that cell's batch ShapeDtypeStructs."""
    install_activation_policy(plan, mesh)
    p_specs = lm.param_specs(cfg)
    o_specs = jax.eval_shape(optimizer.init, p_specs)
    p_sh = param_sharding(p_specs, plan, mesh)
    o_sh = opt_state_sharding(o_specs, plan, mesh)

    step_fn = make_train_step(cfg, optimizer, plan)

    def batch_sh(shape: ShapeSpec):
        b = input_specs(cfg, shape)
        return batch_sharding(b, cfg, plan, mesh)

    jitted = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, None, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (p_specs, o_specs, p_sh, o_sh, batch_sh)


# ---------------------------------------------------------------------------
# shard_map data-parallel trainer with TotientPerms multi-ring gradient sync
# ---------------------------------------------------------------------------


def make_shardmap_dp_train_step(
    cfg: ArchConfig,
    optimizer: Optimizer,
    mesh: Mesh,
    axis_name: str = "data",
    ring_strides: tuple[int, ...] = (1,),
    compressor=None,
    schedule: str = "ring",
):
    """The §6 trainer: per-device microbatch, local grads, gradient sync via
    the collective schedule the co-optimizer searched (``Strategy.schedule``):
    multi-ring TotientPerms AllReduce by default, recursive halving-doubling
    or multi-tree when the plan says so (optionally int8-compressed — the
    compressor path is ring-only and ignores ``schedule``).

    Params/opt-state replicated; batch sharded on ``axis_name``.
    ``compressor``: parallel.compression.Compressor or None.
    """
    n = mesh.shape[axis_name]
    sync = topoopt_psum_fn(
        tuple(ring_strides), axis_name, schedule=schedule, group_size=n
    )

    def step(params, opt_state, batch, step_idx, residual):
        def loss(p):
            return lm.loss_fn(p, batch, cfg, remat="full")

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)

        if compressor is not None:
            # residual leaves carry a leading device axis (sharded state).
            local_res = jax.tree.map(lambda r: r[0], residual)
            grads, new_res = compressor.sync(
                grads, local_res, axis_name, ring_strides
            )
            residual = jax.tree.map(lambda r: r[None], new_res)
        else:
            grads = jax.tree.map(lambda g: sync(g) / n, grads)
        new_params, new_state = optimizer.update(grads, opt_state, params, step_idx)
        total = jax.lax.pmean(total, axis_name)
        return new_params, new_state, total, residual

    from ..compat import shard_map_compat

    rep = P()
    sharded = P(axis_name)
    smapped = shard_map_compat(
        step,
        mesh=mesh,
        in_specs=(rep, rep, sharded, rep, sharded if compressor else rep),
        out_specs=(rep, rep, rep, sharded if compressor else rep),
        check_replication=False,
    )
    return jax.jit(smapped)


def init_compressor_residual(compressor, params, mesh, axis_name="data"):
    """Per-device residual state: leaves (n_devices, *param.shape)."""
    n = mesh.shape[axis_name]
    import jax.numpy as jnp

    return jax.tree.map(
        lambda p: jnp.zeros((n, *p.shape), jnp.float32), params
    )
