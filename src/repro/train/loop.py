"""Fault-tolerant training loop.

- checkpoint every N steps (atomic), resume from latest on start,
- deterministic stateless data pipeline (restart-safe),
- straggler detection: per-step wall time vs running median; slow steps are
  counted and surfaced (on a real pod this feeds the backup-worker /
  TopoOpt link-repair path),
- failure injection hook for tests (``fail_at``) proving restart works.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import latest_step, load_checkpoint, prune_checkpoints, save_checkpoint
from ..configs.base import ArchConfig, ShapeSpec
from ..data.pipeline import DataSpec, Prefetcher
from ..models import lm
from ..optim import Optimizer
from ..parallel.sharding import ShardingPlan
from .steps import jit_train_step


class InjectedFailure(RuntimeError):
    pass


@dataclass
class TrainResult:
    final_step: int
    losses: list[float] = field(default_factory=list)
    straggler_steps: int = 0
    restarts: int = 0


def train(
    cfg: ArchConfig,
    shape: ShapeSpec,
    optimizer: Optimizer,
    plan: ShardingPlan,
    mesh,
    total_steps: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    seed: int = 0,
    fail_at: int | None = None,
    straggler_factor: float = 3.0,
    log_every: int = 10,
    logger=print,
) -> TrainResult:
    jitted, (p_specs, o_specs, p_sh, o_sh, _) = jit_train_step(
        cfg, optimizer, plan, mesh, donate=True
    )

    start_step = 0
    params = opt_state = None
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        start_step, params, opt_state, _ = load_checkpoint(
            ckpt_dir, p_specs, o_specs,
            param_shardings=p_sh, opt_shardings=o_sh,
        )
        logger(f"[loop] resumed from step {start_step}")

    if params is None:
        with mesh:
            params = jax.jit(
                lambda: lm.init(jax.random.PRNGKey(seed), cfg),
                out_shardings=p_sh,
            )()
            opt_state = jax.jit(optimizer.init, out_shardings=o_sh)(params)

    data = Prefetcher(DataSpec(cfg=cfg, shape=shape, seed=seed), start_step)
    result = TrainResult(final_step=start_step)
    step_times: list[float] = []

    try:
        step = start_step
        while step < total_steps:
            got_step, batch = data.next()
            assert got_step == step, f"pipeline desync {got_step} != {step}"
            t0 = time.perf_counter()
            with mesh:
                params, opt_state, metrics = jitted(
                    params, opt_state, batch, jnp.int32(step)
                )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            step_times.append(dt)
            med = float(np.median(step_times[-50:]))
            if len(step_times) > 5 and dt > straggler_factor * med:
                result.straggler_steps += 1
                logger(f"[loop] straggler at step {step}: {dt:.3f}s vs median {med:.3f}s")

            result.losses.append(loss)
            if step % log_every == 0:
                logger(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.1f} ms)")

            step += 1
            result.final_step = step

            if ckpt_dir and step % ckpt_every == 0:
                save_checkpoint(ckpt_dir, step, params, opt_state)
                prune_checkpoints(ckpt_dir, keep=3)

            if fail_at is not None and step == fail_at:
                raise InjectedFailure(f"injected failure at step {step}")
    finally:
        data.close()

    if ckpt_dir:
        save_checkpoint(ckpt_dir, result.final_step, params, opt_state)
    return result
