"""Int8 gradient compression with error feedback.

The wire payload of the ring AllReduce is int8 + per-block fp32 scales (4x
less traffic than fp32, 2x less than bf16 — directly visible in the HLO
collective bytes of the dry-run).  Quantization errors are accumulated into a
local residual and re-injected on the next step (error feedback), which keeps
SGD convergence (Karimireddy et al., EF-signSGD).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from ..core.collectives import _mod_inverse, _ring_perm


def quantize_block(x, block: int = 1024):
    """x: flat fp array -> (int8 codes, fp32 scales (nb,), padded_len)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], flat.size


def dequantize_block(q, scale):
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)


def compressed_ring_all_reduce(
    x: jax.Array, axis_name: str, p: int = 1, block: int = 1024
):
    """Ring AllReduce whose every hop carries int8 codes + scales.

    Per-hop requantization error is kept locally and returned as a residual
    with x's shape.  Returns (allreduced_approx, residual)."""
    n = axis_size(axis_name)
    shape = x.shape
    if n == 1:
        return x, jnp.zeros_like(x)
    inv_p = _mod_inverse(p, n)
    perm = _ring_perm(n, p)
    pos = (lax.axis_index(axis_name) * inv_p) % n

    flat = x.reshape(-1).astype(jnp.float32)
    seg = -(-flat.size // n)
    seg = -(-seg // block) * block  # segment multiple of block
    pad = seg * n - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    acc = flat.reshape(n, seg)
    err = jnp.zeros_like(acc)

    def seg_at(arr, idx):
        return lax.dynamic_index_in_dim(arr, idx % n, axis=0, keepdims=False)

    # Reduce-scatter with per-hop quantization.
    for t in range(n - 1):
        send_idx = (pos - t) % n
        recv_idx = (pos - t - 1) % n
        payload = seg_at(acc, send_idx)
        q, s, _ = quantize_block(payload, block)
        deq = dequantize_block(q, s)[: payload.size]
        err = lax.dynamic_update_index_in_dim(
            err, seg_at(err, send_idx) + (payload - deq), send_idx % n, axis=0
        )
        rq = lax.ppermute(q, axis_name, perm)
        rs = lax.ppermute(s, axis_name, perm)
        received = dequantize_block(rq, rs)[: payload.size]
        acc = lax.dynamic_update_index_in_dim(
            acc, seg_at(acc, recv_idx) + received, recv_idx % n, axis=0
        )

    # All-gather phase: quantize the reduced segment once, rotate int8.
    own_idx = (pos + 1) % n
    own = seg_at(acc, own_idx)
    q, s, _ = quantize_block(own, block)
    deq = dequantize_block(q, s)[: own.size]
    err = lax.dynamic_update_index_in_dim(
        err, seg_at(err, own_idx) + (own - deq), own_idx % n, axis=0
    )
    acc = lax.dynamic_update_index_in_dim(acc, deq, own_idx % n, axis=0)
    for t in range(n - 1):
        send_idx = (pos + 1 - t) % n
        recv_idx = (pos - t) % n
        payload = seg_at(acc, send_idx)
        q, s, _ = quantize_block(payload, block)
        rq = lax.ppermute(q, axis_name, perm)
        rs = lax.ppermute(s, axis_name, perm)
        received = dequantize_block(rq, rs)[: payload.size]
        acc = lax.dynamic_update_index_in_dim(acc, received, recv_idx % n, axis=0)

    out = acc.reshape(-1)[: flat.size - pad if pad else flat.size]
    res = err.reshape(-1)[: flat.size - pad if pad else flat.size]
    return out.reshape(shape).astype(x.dtype), res.reshape(shape).astype(jnp.float32)


@dataclass(frozen=True)
class Compressor:
    block: int = 1024

    def init_residual(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def sync(self, grads, residual, axis_name: str, strides=(1,)):
        """Error-feedback compressed gradient sync.  Returns
        (mean_grads, new_residual)."""
        n = axis_size(axis_name)
        strides = tuple(strides) or (1,)
        leaves, treedef = jax.tree.flatten(grads)
        res_leaves = treedef.flatten_up_to(residual)
        outs, new_res = [], []
        for i, (g, r) in enumerate(zip(leaves, res_leaves)):
            p = strides[i % len(strides)]
            g_fed = g.astype(jnp.float32) + r
            summed, err = compressed_ring_all_reduce(
                g_fed, axis_name, p=p, block=self.block
            )
            outs.append((summed / n).astype(g.dtype))
            new_res.append(err)
        return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_res)
