"""Sharding plans -> PartitionSpec trees.

DP/FSDP over the ``data`` (and ``pod``) axes, TP/EP over ``model``; sequence
dims of long caches shard over ``model`` (flash-decoding style).  Every spec
is sanitized against actual divisibility (e.g. minicpm's prime vocab 122753
cannot shard over 16 — the rule falls back to the next dim) so a single rule
set covers all 10 architectures.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


@dataclass(frozen=True)
class ShardingPlan:
    """How a job is laid out on the mesh (the Comp x Comm plane choice)."""

    fsdp: bool = True          # ZeRO-3: shard params/opt-state over data axes
    zero1: bool = False        # ZeRO-1: replicate params, shard opt state
    seq_parallel: bool = False  # shard activation sequence dim over "model"
    # TopoOpt integration: collective schedule from the co-optimizer
    # (the searched ``Strategy.schedule`` family plus its ring strides).
    ring_strides: tuple[int, ...] = ()
    schedule: str = "ring"
    remat: str = "full"
    loss_chunk: int = 0

    def dp_axes(self, mesh: Mesh):
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if len(axes) > 1 else (axes[0] if axes else None)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes whose size does not divide the corresponding dim."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, axes in zip(shape, dims):
        if axes is None:
            out.append(None)
            continue
        if _axis_size(mesh, axes) == 0 or d % _axis_size(mesh, axes) != 0:
            out.append(None)
        else:
            out.append(axes)
    return P(*out)


# --- parameter rules --------------------------------------------------------

# (context, name) -> base spec expressed with symbolic axes:
#   "tp"   -> "model"; "fsdp" -> data axes (if plan.fsdp)
# base rank = len(spec); extra leading dims (layer stacking) -> None.
_PARAM_RULES: list[tuple[tuple[str, ...], tuple]] = [
    (("embed",), ("tp", "fsdp")),
    (("lm_head",), ("fsdp", "tp")),
    (("moe", "router"), ("fsdp", None)),
    (("moe", "wg"), ("tp", "fsdp", None)),
    (("moe", "wu"), ("tp", "fsdp", None)),
    (("moe", "wd"), ("tp", None, "fsdp")),
    (("wq",), ("fsdp", "tp")),
    (("wk",), ("fsdp", "tp")),
    (("wv",), ("fsdp", "tp")),
    (("wo",), ("tp", "fsdp")),
    (("wg",), ("fsdp", "tp")),
    (("wu",), ("fsdp", "tp")),
    (("wd",), ("tp", "fsdp")),
    (("w1",), ("fsdp", "tp")),
    (("w2",), ("tp", "fsdp")),
    (("w_in",), ("fsdp", "tp")),
    (("w_x",), ("fsdp", "tp")),
    (("w_y",), ("fsdp", "tp")),
    (("w_xdbc",), ("tp", None)),
    (("w_dt",), (None, "tp")),
    (("w_input_gate",), ("tp", None)),
    (("w_rec_gate",), ("tp", None)),
    (("w_out",), ("tp", "fsdp")),
    (("conv_w",), (None, "tp")),
    (("conv_b",), ("tp",)),
    (("a_log",), ("tp", None)),
    (("d_skip",), ("tp",)),
    (("b_dt",), ("tp",)),
    (("lambda_p",), ("tp",)),
    (("tables",), (None, "tp", None)),
]


def _path_names(path) -> tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return tuple(names)


def _resolve(sym, plan: ShardingPlan, mesh: Mesh, for_params: bool):
    if sym == "tp":
        return "model" if "model" in mesh.axis_names else None
    if sym == "fsdp":
        if for_params and not plan.fsdp:
            return None
        return plan.dp_axes(mesh)
    return sym


def param_spec_tree(param_shapes, plan: ShardingPlan, mesh: Mesh,
                    for_params: bool = True):
    """PartitionSpec tree for a parameter pytree (of ShapeDtypeStructs)."""

    def one(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        for key, base in _PARAM_RULES:
            if len(key) == 1:
                hit = names and names[-1] == key[0]
            else:
                hit = len(names) >= 2 and names[-2:] == key
            if hit:
                extra = len(shape) - len(base)
                if extra < 0:
                    continue
                resolved = tuple(
                    _resolve(s, plan, mesh, for_params) for s in base
                )
                return sanitize(P(*([None] * extra), *resolved), shape, mesh)
        # Default: replicate small leaves; fsdp-shard anything big on its
        # largest dim as a fallback.
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, param_shapes)


def param_sharding(param_shapes, plan: ShardingPlan, mesh: Mesh,
                   for_params: bool = True):
    specs = param_spec_tree(param_shapes, plan, mesh, for_params=for_params)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def opt_state_sharding(param_shapes, plan: ShardingPlan, mesh: Mesh):
    """Optimizer moments follow the parameters; under ZeRO-1 the moments are
    sharded over data even when the params are replicated."""
    if plan.zero1:
        plan = ShardingPlan(
            fsdp=True, zero1=True, seq_parallel=plan.seq_parallel,
            ring_strides=plan.ring_strides, schedule=plan.schedule,
            remat=plan.remat,
            loss_chunk=plan.loss_chunk,
        )
        return param_sharding(param_shapes, plan, mesh, for_params=True)
    return param_sharding(param_shapes, plan, mesh, for_params=True)


# --- batch / cache rules -----------------------------------------------------


def batch_spec_tree(batch_shapes, cfg: ArchConfig, plan: ShardingPlan,
                    mesh: Mesh):
    dp = plan.dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    seq = tp if plan.seq_parallel else None

    def cache_spec(name: str, shape):
        if name in ("ssm",):  # (L, B, DI, ST)
            return sanitize(P(None, dp, tp, None), shape, mesh)
        if name in ("conv",):  # (L, B, W, DI)
            return sanitize(P(None, dp, None, tp), shape, mesh)
        if name in ("lru",):  # (L, B, DI)
            return sanitize(P(None, dp, tp), shape, mesh)
        if name in ("k", "v", "xk", "xv"):  # (L, B, KV, S, D)
            # Batch over dp, cache sequence over model (flash-decoding).
            return sanitize(P(None, dp, None, tp, None), shape, mesh)
        return P(*([None] * len(shape)))

    def one(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        name = names[-1] if names else ""
        if "cache" in names:
            return cache_spec(name, shape)
        if name in ("tokens", "labels"):  # (B, S)
            return sanitize(P(dp, seq), shape, mesh)
        if name == "frames":  # (B, S, D)
            return sanitize(P(dp, seq, None), shape, mesh)
        if name == "image_embeds":  # (B, T, D)
            return sanitize(P(dp, None, None), shape, mesh)
        if name == "token":  # (B,)
            return sanitize(P(dp), shape, mesh)
        if name == "pos":
            return P()
        if name in ("dense", "sparse", "label"):
            return sanitize(P(dp, *([None] * (len(shape) - 1))), shape, mesh)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def batch_sharding(batch_shapes, cfg: ArchConfig, plan: ShardingPlan,
                   mesh: Mesh):
    specs = batch_spec_tree(batch_shapes, cfg, plan, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
