"""Activation sharding constraints (GSPMD hints).

Without explicit constraints the partitioner may satisfy an FSDP-sharded
weight contraction by *replicating the batch* and all-reducing activations
(observed: f32[256,4096,896] activation all-reduces in the granite-8b HLO —
see EXPERIMENTS.md §Perf iteration 0).  Constraining activations to
batch-over-data at block boundaries forces the intended schedule: all-gather
the (small) layer weights, keep activations sharded.

The policy is process-global (models are pure functions of (params, batch));
step builders install it before lowering.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ActivationPolicy:
    dp: tuple | str | None  # axes for the batch dim
    tp: str | None  # axis for feature/head dims
    seq: str | None = None  # axis for the sequence dim (sequence parallelism)


_POLICY: ActivationPolicy | None = None


def set_policy(policy: ActivationPolicy | None) -> None:
    global _POLICY
    _POLICY = policy


def get_policy() -> ActivationPolicy | None:
    return _POLICY


def constrain(x, kind: str):
    """Apply a sharding constraint by activation kind.

    kinds: 'btd' (batch, seq, features), 'bd' (batch, features),
    'btf' (batch, seq, sharded features), 'ecd' (expert, capacity, features).
    No-op when no policy is installed (pure single-device use).
    """
    pol = _POLICY
    if pol is None:
        return x
    if kind == "btd":
        spec = P(pol.dp, pol.seq, None)
    elif kind == "bd":
        spec = P(pol.dp, None)
    elif kind == "btf":
        spec = P(pol.dp, pol.seq, pol.tp)
    elif kind == "ecd":
        spec = P(pol.tp, None, None)
    elif kind == "nd":  # flattened token dim (B*S or N*K, features)
        spec = P(pol.dp, None)
    else:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        # outside a mesh context (e.g. plain CPU tests) — no-op
        return x
