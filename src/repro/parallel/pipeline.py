"""Pipeline parallelism: GPipe-style microbatch schedule over a ``pipe``
mesh axis using ``lax.ppermute`` stage handoffs (shard_map style).

Stages hold disjoint layer slices (params sharded P("pipe") on the stacked
layer dim).  The schedule runs ``n_micro + n_stages - 1`` ticks; at each
tick every stage applies its layers to its current activation and hands the
result to the next stage.  Bubble fraction = (S-1)/(M+S-1), the classic
GPipe trade-off — the paper's PP point-to-point edges are exactly the MP
transfers TopologyFinder's Blossom matching serves with direct links.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size


def gpipe_forward(stage_fn, stage_params, microbatches, axis_name: str = "pipe"):
    """Run microbatches through the pipeline.

    stage_fn: (stage_params, x) -> y, applied by every stage (params differ).
    stage_params: this stage's parameters (inside shard_map).
    microbatches: (M, mb, ...) — every stage receives the full array; only
      stage 0 consumes it.
    Returns (M, mb, ...) outputs, valid on the LAST stage (zeros elsewhere).
    """
    S = axis_size(axis_name)
    sid = lax.axis_index(axis_name)
    M = microbatches.shape[0]
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    carry = jnp.zeros_like(microbatches[0])
    outs = jnp.zeros_like(microbatches)

    for t in range(M + S - 1):
        mb = microbatches[min(t, M - 1)]
        x = jnp.where(sid == 0, mb, carry)
        active_in = (t < M) | (sid > 0)
        y = stage_fn(stage_params, x)
        # last stage's result for microbatch (t - S + 1)
        if t >= S - 1:
            idx = t - S + 1
            write = (sid == S - 1) & (idx < M)
            outs = outs.at[idx].set(jnp.where(write, y, outs[idx]))
        carry = lax.ppermute(y, axis_name, fwd_perm)
        del active_in
    return outs


def make_gpipe_step(stage_fn, mesh, axis_name: str = "pipe"):
    """jit(shard_map(...)) wrapper: params sharded over the stage axis,
    microbatches replicated in, outputs gathered from the last stage."""
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map_compat

    S = mesh.shape[axis_name]

    def run(params_stacked, microbatches):
        # params_stacked: (S, ...) stage-major; shard_map slices one stage.
        local = jax.tree.map(lambda p: p[0], params_stacked)
        outs = gpipe_forward(stage_fn, local, microbatches, axis_name)
        # outs are zero except on the last stage: psum broadcasts them.
        return lax.psum(outs, axis_name)

    smapped = shard_map_compat(
        run, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
        check_replication=False,
    )
    return jax.jit(smapped)
