"""Model implementation options (the §Perf hillclimbing levers).

Set process-globally before tracing, like act_sharding.  The baseline
(paper-faithful naive implementations) is the default; the dry-run's
``--tag optimized`` runs flip these.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelOptions:
    # "naive": materialize (S, T) scores.  "chunked": flash-style online
    # softmax over KV chunks (XLA path; the Pallas kernel is the TPU path).
    attention_impl: str = "naive"
    attention_chunk: int = 1024
    # "assoc": associative-scan tree (materializes (B, L, D, ST) per chunk).
    # "assoc_ckpt": recompute the tree in bwd.  "seq": sequential scan.
    scan_impl: str = "assoc"
    scan_chunk: int = 256
    # constrain MoE dispatch buffers to expert-parallel sharding
    moe_constrain: bool = False
    # constrain MoE token gathers to batch sharding
    moe_gather_constrain: bool = False
    # norm statistics in fp32 but elementwise scaling in the activation
    # dtype (halves residual-stream HBM traffic; MaxText-style)
    lowp_norm: bool = False


_OPTS = ModelOptions()


def set_options(opts: ModelOptions | None) -> None:
    global _OPTS
    _OPTS = opts or ModelOptions()


def get_options() -> ModelOptions:
    return _OPTS


def with_options(**kw) -> ModelOptions:
    return replace(ModelOptions(), **kw)
