from .adamw import Optimizer, adamw, apply_updates, sgd_momentum
from .schedule import constant, cosine, linear_warmup, wsd

__all__ = [
    "Optimizer",
    "adamw",
    "apply_updates",
    "constant",
    "cosine",
    "linear_warmup",
    "sgd_momentum",
    "wsd",
]
