"""LR schedules.  minicpm-2b trains with WSD (warmup-stable-decay,
arXiv:2404.06395); others default to cosine."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def linear_warmup(lr: float, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.float32(lr) * jnp.minimum(1.0, (s + 1) / max(warmup, 1))

    return fn


def cosine(lr: float, total_steps: int, warmup: int = 100, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * warm * cos

    return fn


def wsd(lr: float, total_steps: int, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, long constant plateau, short
    exponential-ish (linear here) decay tail."""
    warmup = max(1, int(total_steps * warmup_frac))
    decay_start = int(total_steps * (1 - decay_frac))

    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / warmup)
        decay_prog = jnp.clip(
            (s - decay_start) / max(total_steps - decay_start, 1), 0.0, 1.0
        )
        decay = 1.0 - (1.0 - final_frac) * decay_prog
        return jnp.float32(lr) * warm * decay

    return fn
