"""Optimizers in pure JAX (no optax dependency).

State layout mirrors params so the sharding specs of parameters transfer
directly to the moments (ZeRO via parallel.sharding.opt_state_sharding).
When params are bf16, an fp32 master copy is kept in the state.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def _needs_master(p):
    return p.dtype in (jnp.bfloat16, jnp.float16)


def adamw(
    lr_fn: Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    master_fp32: bool = True,
) -> Optimizer:
    def init(params):
        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        if master_fp32:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32) if _needs_master(p) else p, params
            )
        return state

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t
        source = state.get("master", params)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            pf = p.astype(jnp.float32)
            step_vec = mh / (jnp.sqrt(vh) + eps) + weight_decay * pf
            new_p = pf - lr * step_vec
            return m, v, new_p

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(source)
        flat_orig = treedef.flatten_up_to(params)

        new_m, new_v, new_master, new_params = [], [], [], []
        for g, m, v, p, orig in zip(flat_g, flat_m, flat_v, flat_p, flat_orig):
            m2, v2, p2 = upd(g, m, v, p)
            new_m.append(m2)
            new_v.append(v2)
            new_master.append(p2 if _needs_master(orig) else p2.astype(orig.dtype))
            new_params.append(p2.astype(orig.dtype))

        new_state = {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
        }
        if "master" in state:
            new_state["master"] = jax.tree.unflatten(treedef, new_master)
        return jax.tree.unflatten(treedef, new_params), new_state

    return Optimizer(init=init, update=update)


def sgd_momentum(
    lr_fn: Callable[[jax.Array], jax.Array], momentum: float = 0.9
) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return m, (p.astype(jnp.float32) - lr * m).astype(p.dtype)

        pairs = jax.tree.map(upd, grads, state["mom"], params)
        mom = jax.tree.map(lambda x: x[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_p = jax.tree.map(lambda x: x[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mom": mom}

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
