"""Distributed checkpointing: atomic npz shards + manifest, with *elastic*
re-sharding on load (a checkpoint written under one mesh restores under any
other mesh/plan — arrays are saved in global form and re-placed with the
target sharding).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import numpy as np


# dtypes numpy cannot serialize natively (ml_dtypes): stored as a bit-view
# with a "::dtype" tag appended to the key.
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path
        )
        arr = np.asarray(leaf)
        name = arr.dtype.name
        if name in _VIEW_DTYPES:
            flat[f"{key}::{name}"] = arr.view(_VIEW_DTYPES[name])
        else:
            flat[key] = arr
    return flat


def _unflatten_like(spec_tree, flat: dict[str, np.ndarray]):
    import ml_dtypes

    by_key = {}
    for key, arr in flat.items():
        if "::" in key:
            key, name = key.rsplit("::", 1)
            arr = arr.view(np.dtype(getattr(ml_dtypes, name)))
        by_key[key] = arr

    def one(path, spec):
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path
        )
        arr = by_key[key]
        if tuple(arr.shape) != tuple(spec.shape):
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != expected {spec.shape}"
            )
        return arr.astype(spec.dtype)

    return jax.tree_util.tree_map_with_path(one, spec_tree)


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None,
                    extra: dict | None = None) -> str:
    """Atomic write: stage into a tmp dir, fsync, rename to step-NNNN."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".staging-", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        if opt_state is not None:
            np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
        manifest = {
            "step": step,
            "time": time.time(),
            "has_opt_state": opt_state is not None,
            **(extra or {}),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step-") and os.path.exists(
            os.path.join(ckpt_dir, name, "manifest.json")
        ):
            steps.append(int(name.split("-")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(
    ckpt_dir: str,
    param_specs,
    opt_specs=None,
    step: int | None = None,
    param_shardings=None,
    opt_shardings=None,
):
    """Load (optionally a specific step) and, if shardings are given, place
    leaves onto devices with the *target* sharding — elastic restore onto a
    different mesh shape / chip count works because arrays are global."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    with np.load(os.path.join(d, "params.npz")) as z:
        params = _unflatten_like(param_specs, dict(z))
    opt_state = None
    if opt_specs is not None and manifest.get("has_opt_state"):
        with np.load(os.path.join(d, "opt_state.npz")) as z:
            opt_state = _unflatten_like(opt_specs, dict(z))

    if param_shardings is not None:
        params = jax.tree.map(jax.device_put, params, param_shardings)
    if opt_state is not None and opt_shardings is not None:
        opt_state = jax.tree.map(jax.device_put, opt_state, opt_shardings)
    return step, params, opt_state, manifest


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step-{s:08d}"), ignore_errors=True)
