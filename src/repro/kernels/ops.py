"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
where the kernels compile to Mosaic.  The XLA fallbacks live in
models/layers.py; these wrappers are the TPU fast path.
"""

from __future__ import annotations

import jax

from .embedding_bag import embedding_bag
from .flash_attention import flash_attention
from .moe_gmm import moe_gmm
from .mamba_scan import mamba_scan
from .rglru_scan import rglru_scan


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, causal=True, window=0, **kw):
    kw.setdefault("interpret", _default_interpret())
    return flash_attention(q, k, v, causal=causal, window=window, **kw)


def selective_scan(xc, dt, a, b, c, d_skip, **kw):
    kw.setdefault("interpret", _default_interpret())
    return mamba_scan(xc, dt, a, b, c, d_skip, **kw)


def lru_scan(a, b, **kw):
    kw.setdefault("interpret", _default_interpret())
    return rglru_scan(a, b, **kw)


def grouped_matmul(x, w, **kw):
    kw.setdefault("interpret", _default_interpret())
    return moe_gmm(x, w, **kw)


def bag_lookup(tables, indices, **kw):
    kw.setdefault("interpret", _default_interpret())
    return embedding_bag(tables, indices, **kw)
