"""Mamba-1 selective scan as a Pallas TPU kernel.

TPU adaptation (not a port of the CUDA kernel): grid = (batch, d_inner
blocks, time chunks) with the chunk axis innermost and sequential — the SSM
state h (block_d, d_state) persists in VMEM scratch across chunk grid steps,
so the (B, L, D, N) decay/drive tensors are never materialized in HBM (the
XLA fallback in models/layers.py materializes them per chunk).  Inputs are
streamed HBM->VMEM per (chunk, d-block); the inner time loop is VPU work
over (block_d, d_state) registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(xc_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref,
                 y_ref, hout_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...]  # (blk, ST) — A matrix (negative)
    dskip = dskip_ref[...]  # (blk,)

    def step(t, h):
        x_t = xc_ref[0, t, :].astype(jnp.float32)  # (blk,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (blk,)
        bv = b_ref[0, t, :].astype(jnp.float32)  # (ST,)
        cv = c_ref[0, t, :].astype(jnp.float32)  # (ST,)
        decay = jnp.exp(dt_t[:, None] * a)  # (blk, ST)
        drive = (dt_t * x_t)[:, None] * bv[None, :]
        h = decay * h + drive
        y_t = jnp.sum(h * cv[None, :], axis=1) + dskip * x_t
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "chunk", "interpret")
)
def mamba_scan(
    xc: jax.Array,  # (B, L, DI) post-conv activations
    dt: jax.Array,  # (B, L, DI) fp32 softplus'd step sizes
    a: jax.Array,  # (DI, ST) negative state matrix
    b: jax.Array,  # (B, L, ST)
    c: jax.Array,  # (B, L, ST)
    d_skip: jax.Array,  # (DI,)
    block_d: int = 512,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B, L, DI) fp32, h_final (B, DI, ST) fp32)."""
    B, L, DI = xc.shape
    ST = a.shape[1]
    block_d = min(block_d, DI)
    chunk = min(chunk, L)
    assert DI % block_d == 0 and L % chunk == 0
    grid = (B, DI // block_d, L // chunk)

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((block_d, ST), lambda bi, di, ci: (di, 0)),
            pl.BlockSpec((1, chunk, ST), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, ST), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((block_d,), lambda bi, di, ci: (di,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, block_d, ST), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, DI), jnp.float32),
            jax.ShapeDtypeStruct((B, DI, ST), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ST), jnp.float32)],
        interpret=interpret,
    )(xc, dt, a, b, c, d_skip)
