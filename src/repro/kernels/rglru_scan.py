"""RG-LRU linear-recurrence scan (Griffin) as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, elementwise over the channel dim.  Grid =
(batch, channel blocks, time chunks), chunk axis innermost/sequential with
the carry in VMEM scratch — identical scheduling to the Mamba kernel but a
pure VPU elementwise recurrence (no state dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, y_ref, hout_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        h = a_ref[0, t, :].astype(jnp.float32) * h + b_ref[0, t, :].astype(
            jnp.float32
        )
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def rglru_scan(
    a: jax.Array,  # (B, L, D) decay in (0, 1)
    b: jax.Array,  # (B, L, D) gated drive
    block_d: int = 512,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (h_all (B, L, D) fp32, h_final (B, D) fp32)."""
    B, L, D = a.shape
    block_d = min(block_d, D)
    chunk = min(chunk, L)
    assert D % block_d == 0 and L % chunk == 0
    grid = (B, D // block_d, L // chunk)

    kernel = functools.partial(_lru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ci: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(a, b)
