"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_flash_attention(q, k, v, causal=True, window=0):
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kj <= qi
    if window > 0:
        m &= kj > qi - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def ref_mamba_scan(xc, dt, a, b, c, d_skip):
    """Sequential-scan oracle.  Shapes as kernels.mamba_scan."""
    B, L, DI = xc.shape
    ST = a.shape[1]

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs
        decay = jnp.exp(dt_t[:, :, None] * a[None])  # (B, DI, ST)
        drive = (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        h = decay * h + drive
        y = jnp.einsum("bds,bs->bd", h, c_t) + d_skip * x_t
        return h, y

    h0 = jnp.zeros((B, DI, ST), jnp.float32)
    xs = (
        jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last


def ref_rglru_scan(a, b):
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h0 = jnp.zeros(a.shape[::2][:1] + a.shape[2:], jnp.float32)
    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    xs = (
        jnp.moveaxis(a.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
    )
    h_last, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), h_last


def ref_moe_gmm(x, w):
    return jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def ref_embedding_bag(tables, indices):
    """tables: (T, R, E); indices: (B, T, NNZ) -> (B, T, E)."""
    T = tables.shape[0]
    gathered = tables[jnp.arange(T)[None, :, None], indices]  # (B, T, NNZ, E)
    return gathered.sum(axis=2)
