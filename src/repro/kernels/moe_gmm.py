"""Grouped (per-expert) matmul for MoE as a Pallas TPU kernel.

Computes out[e] = x[e] @ w[e] for E experts with MXU-aligned blocking:
grid = (E, C/bc, F/bf, D/bd) with the contraction (D) axis innermost and a
fp32 accumulator in VMEM scratch; weights/activations stream HBM->VMEM one
(bc x bd) / (bd x bf) tile per step.  This is the dispatch-side compute of
the capacity-based MoE in models/layers.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def moe_gmm(
    x: jax.Array,  # (E, C, D) dispatched tokens
    w: jax.Array,  # (E, D, F) expert weights
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    grid = (E, C // block_c, F // block_f, D // block_d)

    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, ci, fi, ki: (e, ci, ki)),
            pl.BlockSpec((1, block_d, block_f), lambda e, ci, fi, ki: (e, ki, fi)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_c, block_f), lambda e, ci, fi, ki: (e, ci, fi)
        ),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
