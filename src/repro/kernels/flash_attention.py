"""Flash attention (GQA, causal / sliding-window / full) as a Pallas TPU
kernel.

Design (TPU-native, not a CUDA port): grid = (batch, q_heads, q_blocks,
kv_blocks) with the kv axis innermost and *sequential* (online-softmax
carry lives in VMEM scratch across kv grid steps).  Block shapes are MXU
aligned (multiples of 128 on the matmul dims); K/V blocks stream HBM->VMEM
per grid step, so VMEM holds O(Bq*d + Bk*d + Bq*Bk) — independent of
sequence length.  Causal blocks above the diagonal are masked via in-block
iota (they still occupy grid steps; production TPU kernels skip them with a
grid transform — measured as a §Perf iteration).

GQA: kv head index = q head // (H // KV) through the K/V index_maps — no
K/V replication in HBM.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int, block_q: int, block_k: int,
    seq_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (block_q, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_cur = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,  # (B, KV, Sk, D)
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, KV, Sk, _ = k.shape
    assert H % KV == 0, "GQA requires H % KV == 0"
    group = H // KV
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # Pad sequence dims to block multiples (out-of-bounds block reads are
    # undefined; padded keys are masked via seq_len inside the kernel).
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    grid = (B, H, pl.cdiv(Sq_p, block_q), pl.cdiv(Sk_p, block_k))

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=Sk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)[:, :, :Sq, :]
