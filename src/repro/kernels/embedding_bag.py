"""DLRM embedding-bag lookup as a Pallas TPU kernel.

out[b, t] = sum_j table[t, idx[b, t, j]] — multi-hot embedding-bag over T
tables.  TPU-native design: indices are *scalar-prefetched*
(PrefetchScalarGridSpec) so the BlockSpec index_map itself selects the table
row to DMA per grid step — the gather is expressed as data-dependent block
fetches, the canonical TPU pattern for embedding lookups (no scatter/gather
unit on TPU).  Accumulation over the NNZ axis happens in the revisited
output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, row_ref, o_ref):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, 0, :] += row_ref[0, 0, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(
    tables: jax.Array,  # (T, R, E) stacked embedding tables
    indices: jax.Array,  # (B, T, NNZ) int32 row ids
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, T, E) bag sums."""
    T, R, E = tables.shape
    B, T2, NNZ = indices.shape
    assert T == T2

    def table_map(b, t, j, idx_ref):
        return (t, idx_ref[b, t, j], 0)

    def out_map(b, t, j, idx_ref):
        return (b, t, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, T, NNZ),
        in_specs=[pl.BlockSpec((1, 1, E), table_map)],
        out_specs=pl.BlockSpec((1, 1, E), out_map),
    )
    # Accumulate in fp32 regardless of table dtype (the revisited output
    # block is the accumulator, so its dtype is the accumulation dtype).
    acc_dtype = jnp.promote_types(tables.dtype, jnp.float32)
    out = pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, E), acc_dtype),
        interpret=interpret,
    )(indices, tables)
    return out.astype(tables.dtype)
