"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

compute    = HLO_FLOPs   / (chips x 197 TFLOP/s bf16)
memory     = HLO_bytes   / (chips x 819 GB/s HBM)
collective = coll_bytes  / (chips x 50 GB/s/link x links-used)

``cost_analysis()`` FLOPs/bytes on an SPMD program are per-device; we report
both per-device and whole-job numbers.  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) with D = tokens processed per step.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

from ..configs.base import ArchConfig, ShapeSpec
from .mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16


def param_counts(cfg: ArchConfig) -> dict:
    """(total, expert, embedding) parameter counts from the init specs."""
    from ..models import lm

    specs = lm.param_specs(cfg)
    total = expert = embed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        names = [str(k.key) for k in path if hasattr(k, "key")]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "moe" in names and names[-1] in ("wg", "wu", "wd"):
            expert += n
        if names and names[-1] in ("embed", "lm_head"):
            embed += n
    return {"total": total, "expert": expert, "embedding": embed}


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N*D (training) / 2*N*D (inference), N = active non-embedding params."""
    counts = param_counts(cfg)
    n_active = counts["total"] - counts["embedding"]
    if cfg.n_experts:
        n_active -= counts["expert"] * (1.0 - cfg.top_k / cfg.n_experts)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * n_active * tokens


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float  # whole job
    hlo_bytes: float  # whole job
    collective_bytes: float  # per-device program
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-optimistic step time."""
        denom = self.step_time_s * self.chips * PEAK_FLOPS_BF16
        return self.model_flops / denom if denom else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "step_time_s": self.step_time_s,
            "mfu": self.mfu,
            "chips": self.chips,
        }


def roofline(
    hlo_analysis: dict,
    coll_bytes_per_dev: float,
    chips: int,
    cfg: ArchConfig,
    shape: ShapeSpec,
    links_used: int = 4,
) -> RooflineTerms:
    # Per-device numbers from the trip-count-aware HLO analyzer
    # (launch.hlo_analysis — XLA's cost_analysis counts loop bodies once).
    flops_dev = float(hlo_analysis.get("flops", 0.0))
    bytes_dev = float(hlo_analysis.get("bytes", 0.0))
    return RooflineTerms(
        compute_s=flops_dev / PEAK_FLOPS_BF16,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll_bytes_per_dev / (ICI_LINK_BW * links_used),
        hlo_flops=flops_dev * chips,
        hlo_bytes=bytes_dev * chips,
        collective_bytes=coll_bytes_per_dev,
        model_flops=model_flops(cfg, shape),
        chips=chips,
    )
