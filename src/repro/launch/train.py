"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt

On this CPU container use ``--smoke`` (reduced config).  On a pod, drop
``--smoke`` and pass ``--mesh single|multi`` to train the full config on the
production mesh with the plan flags below.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import ShapeSpec, get_config
from repro.optim import adamw, cosine, wsd
from repro.parallel.sharding import ShardingPlan
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser(description="TopoOpt training driver")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["cpu", "single", "multi"], default="cpu")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    shape = ShapeSpec("cli", args.seq_len, args.global_batch, "train")

    if args.mesh == "cpu":
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        plan = ShardingPlan(fsdp=False, remat=args.remat)
    else:
        from .mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        plan = ShardingPlan(
            fsdp=not args.no_fsdp, seq_parallel=args.seq_parallel,
            remat=args.remat,
        )

    sched = (wsd if cfg.schedule == "wsd" else cosine)(args.lr, args.steps)
    res = train(
        cfg, shape, adamw(sched), plan, mesh,
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, fail_at=args.fail_at,
    )
    print(
        f"done: step={res.final_step} loss {res.losses[0]:.4f} -> "
        f"{res.losses[-1]:.4f} stragglers={res.straggler_steps}"
    )


if __name__ == "__main__":
    main()
