"""HLO-text analysis for the roofline (§Roofline).

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — a
scan-over-layers program under-reports FLOPs/bytes by ~n_layers.  This
analyzer walks the optimized HLO with a per-computation symbol table and:

* multiplies while-body costs by the loop trip count (recovered from the
  largest integer constant in the loop-condition computation),
* counts dot FLOPs as 2 * prod(result) * prod(lhs contracting dims),
* counts HBM bytes at fusion boundaries (operands + result of every
  top-level op; fusion internals excluded — approximates post-fusion HBM
  traffic far better than the CPU backend's per-op "bytes accessed"),
* sums collective payloads: all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, with all-reduce counted 2x (ring ~
  reduce-scatter + all-gather of the payload).

All numbers are for the per-device SPMD program.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_SKIP_BYTES = (
    "while", "call", "conditional", "tuple", "get-tuple-element",
    "parameter", "constant", "bitcast", "after-all", "opt-barrier",
    "optimization-barrier", "iota", "partition-id", "replica-id",
)

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<result>\((?:[^()]|\([^)]*\))*\)|\S+)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<operands>.*)$"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_WHILE_BC = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_WHILE_CB = re.compile(r"body=%?([\w.\-]+),\s*condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shapes_in(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> float:
    total = 0
    for dtype, dims in _shapes_in(type_str):
        total += math.prod(dims) * _DTYPE_BYTES[dtype]
    return float(total)


def _clean(line: str) -> str:
    for marker in (", metadata=", ", backend_config=", ", frontend_attributes="):
        idx = line.find(marker)
        if idx >= 0:
            line = line[:idx]
    return line


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes_: float = 0.0
    coll: dict = field(default_factory=lambda: defaultdict(float))
    whiles: list = field(default_factory=list)
    calls: list = field(default_factory=list)
    max_const: int = 0
    symbols: dict = field(default_factory=dict)  # op name -> result type str


def _operand_args(operands: str) -> list[str]:
    """Names of %operands up to the closing paren of the op's argument list."""
    depth = 1
    end = len(operands)
    for i, ch in enumerate(operands):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(operands[:end])


_UPDATE_OPS = ("dynamic-update-slice", "scatter", "select-and-scatter")
_SLICE_OPS = ("dynamic-slice", "slice", "gather")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")


def analyze_hlo(hlo_text: str) -> dict:
    # ---- pass 1: split into computations, record lines + root opcodes -----
    comps: dict[str, _Comp] = {}
    comp_lines: dict[str, list[str]] = {}
    comp_root: dict[str, str] = {}
    cur: _Comp | None = None
    entry: str | None = None

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if line.endswith("{"):
            h = _COMP_HEADER.match(line.strip())
            if h:
                cur = _Comp(name=h.group(2))
                comps[cur.name] = cur
                comp_lines[cur.name] = []
                if h.group(1):
                    entry = cur.name
                continue
        if cur is None or not line.strip():
            continue
        stripped = _clean(line)
        comp_lines[cur.name].append(stripped)
        if stripped.lstrip().startswith("ROOT"):
            m = _DEF_RE.match(stripped)
            if m:
                comp_root[cur.name] = m.group("opcode")

    # ---- pass 2: per-computation costs with fusion-root knowledge ---------
    for cname, lines in comp_lines.items():
        cur = comps[cname]
        for stripped in lines:
            for c in _CONST_RE.findall(stripped):
                cur.max_const = max(cur.max_const, int(c))
            m = _DEF_RE.match(stripped)
            if not m:
                continue
            name, result = m.group("name"), m.group("result")
            opcode, operands = m.group("opcode"), m.group("operands")
            cur.symbols[name] = result
            base = opcode.replace("-start", "").replace("-done", "")

            if base in _COLLECTIVE_FACTORS and not opcode.endswith("-done"):
                payload = _type_bytes(result)
                if opcode.endswith("-start"):
                    payload /= 2.0  # tuple holds (in, out) aliases
                cur.coll[base] += _COLLECTIVE_FACTORS[base] * payload

            if opcode == "while":
                mm = _WHILE_BC.search(stripped) or _WHILE_CB.search(stripped)
                if mm:
                    if "condition=" in stripped and stripped.index("condition=") < stripped.index("body="):
                        cur.whiles.append((mm.group(2), mm.group(1)))
                    else:
                        cur.whiles.append((mm.group(1), mm.group(2)))
                continue
            if opcode in ("call", "conditional"):
                for cm in re.findall(
                    r"(?:to_apply|branch_computations?)=\{?%?([\w.\-]+)", stripped
                ):
                    cur.calls.append(cm)
                continue
            if opcode.endswith("-done"):
                continue

            if opcode == "dot":
                res_shapes = _shapes_in(result)
                out_elems = math.prod(res_shapes[0][1]) if res_shapes else 0
                mm = _LHS_CONTRACT.search(stripped)
                contract = (
                    [int(x) for x in mm.group(1).split(",") if x] if mm else []
                )
                args = _operand_args(operands)
                k = 1
                if args and args[0] in cur.symbols:
                    lshapes = _shapes_in(cur.symbols[args[0]])
                    if lshapes:
                        ldims = lshapes[0][1]
                        for c in contract:
                            if c < len(ldims):
                                k *= ldims[c]
                cur.flops += 2.0 * out_elems * k

            if base in _SKIP_BYTES:
                continue

            # effective opcode: fusions inherit their root's access pattern.
            eff = opcode
            if opcode == "fusion":
                cm = _CALLS_RE.search(stripped)
                if cm:
                    eff = comp_root.get(cm.group(1), "fusion")

            res_b = _type_bytes(result)
            op_bytes = [
                _type_bytes(cur.symbols.get(a, ""))
                for a in _operand_args(operands)
            ]
            big = max(op_bytes, default=0.0)
            others = sum(op_bytes) - big
            if eff in _UPDATE_OPS and big >= res_b * 0.99:
                # in-place update into an aliased buffer: move only the
                # update (read) + updated region (write).
                total = 2.0 * others
            elif eff in _SLICE_OPS and big >= 4 * max(res_b + others, 1.0):
                # small read out of a big buffer.
                total = 2.0 * (res_b + others)
            else:
                total = res_b + sum(op_bytes)
            cur.bytes_ += total

    memo: dict[str, dict] = {}

    def total(name: str) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}}
        memo[name] = {"flops": 0.0, "bytes": 0.0, "coll": {}}  # cycle guard
        agg_coll = defaultdict(float, comp.coll)
        flops, bytes_ = comp.flops, comp.bytes_
        for body, cond in comp.whiles:
            trips = max(comps.get(cond, _Comp("")).max_const, 1)
            inner = total(body)
            flops += trips * inner["flops"]
            bytes_ += trips * inner["bytes"]
            for op, b in inner["coll"].items():
                agg_coll[op] += trips * b
        for callee in comp.calls:
            inner = total(callee)
            flops += inner["flops"]
            bytes_ += inner["bytes"]
            for op, b in inner["coll"].items():
                agg_coll[op] += b
        memo[name] = {"flops": flops, "bytes": bytes_, "coll": dict(agg_coll)}
        return memo[name]

    if entry is None:
        entry = next(iter(comps), None)
    res = total(entry) if entry else {"flops": 0.0, "bytes": 0.0, "coll": {}}
    return {
        "flops": res["flops"],
        "bytes": res["bytes"],
        "collective_bytes": float(sum(res["coll"].values())),
        "collectives_by_type": {k: float(v) for k, v in res["coll"].items()},
    }


def parse_collectives(hlo_text: str) -> dict:
    res = analyze_hlo(hlo_text)
    return {
        "total_bytes": res["collective_bytes"],
        "by_type": res["collectives_by_type"],
    }


def collective_bytes(compiled_or_text) -> dict:
    text = (
        compiled_or_text
        if isinstance(compiled_or_text, str)
        else compiled_or_text.as_text()
    )
    return parse_collectives(text)
