import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# ShapeDtypeStruct inputs (no allocation), record memory/cost analyses and
# collective bytes for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).
# ---------------------------------------------------------------------------

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import (
    ALL_SHAPES,
    ArchConfig,
    ShapeSpec,
    all_configs,
    get_config,
    input_specs,
    shape_applicability,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline
from repro.models import lm
from repro.optim import adamw, constant
from repro.parallel.sharding import (
    ShardingPlan,
    batch_sharding,
    opt_state_sharding,
    param_sharding,
)
from repro.train.steps import make_serve_step, make_train_step


def plan_from_args(args, cfg: ArchConfig, shape: ShapeSpec) -> ShardingPlan:
    return ShardingPlan(
        fsdp=not args.no_fsdp,
        seq_parallel=args.seq_parallel,
        remat=args.remat,
        loss_chunk=args.loss_chunk,
    )


def options_from_args(args):
    from repro.parallel.options import ModelOptions

    return ModelOptions(
        attention_impl=args.attention,
        attention_chunk=args.attention_chunk,
        scan_impl=args.scan,
        scan_chunk=args.scan_chunk,
        moe_constrain=args.moe_constrain,
        moe_gather_constrain=args.moe_gather_constrain,
        lowp_norm=args.lowp_norm,
    )


def dryrun_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, plan: ShardingPlan) -> dict:
    """Lower+compile one cell; returns the §Dry-run record."""
    from repro.train.steps import install_activation_policy

    install_activation_policy(plan, mesh)
    chips = mesh.devices.size
    batch_specs = input_specs(cfg, shape)
    b_sh = batch_sharding(batch_specs, cfg, plan, mesh)
    p_specs = lm.param_specs(cfg)
    p_sh = param_sharding(p_specs, plan, mesh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            optimizer = adamw(constant(3e-4))
            o_specs = jax.eval_shape(optimizer.init, p_specs)
            o_sh = opt_state_sharding(o_specs, plan, mesh)
            step_fn = make_train_step(cfg, optimizer, plan)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(
                p_specs, o_specs, batch_specs,
                jax.ShapeDtypeStruct((), jax.numpy.int32),
            )
        else:
            serve = make_serve_step(cfg, shape)
            if shape.kind == "decode":
                cache_sh = b_sh
                jitted = jax.jit(
                    serve, in_shardings=(p_sh, b_sh),
                    out_shardings=(None, b_sh["cache"]),
                    donate_argnums=(1,),
                )
            else:
                jitted = jax.jit(serve, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_specs, batch_specs)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(ma, k):
                mem[k] = getattr(ma, k)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else cost_list[0]
    hlo = analyze_hlo(compiled.as_text())
    terms = roofline(hlo, hlo["collective_bytes"], chips, cfg, shape)

    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "chips": int(chips),
        "plan": {
            "fsdp": plan.fsdp,
            "seq_parallel": plan.seq_parallel,
            "remat": plan.remat,
            "loss_chunk": plan.loss_chunk,
        },
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": mem,
        "xla_cost": {k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost},
        "hlo": {
            "flops_per_dev": hlo["flops"],
            "bytes_per_dev": hlo["bytes"],
        },
        "collectives": {
            "total_bytes": hlo["collective_bytes"],
            "by_type": hlo["collectives_by_type"],
        },
        "roofline": terms.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="TopoOpt multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--attention", default="naive", choices=["naive", "chunked"])
    ap.add_argument("--attention-chunk", type=int, default=1024)
    ap.add_argument("--scan", default="assoc",
                    choices=["assoc", "assoc_ckpt", "seq"])
    ap.add_argument("--moe-constrain", action="store_true")
    ap.add_argument("--moe-gather-constrain", action="store_true")
    ap.add_argument("--lowp-norm", action="store_true")
    ap.add_argument("--scan-chunk", type=int, default=256)
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    from repro.parallel.options import set_options

    set_options(options_from_args(args))

    configs = all_configs()
    archs = [get_config(args.arch)] if args.arch else [
        c for c in configs.values() if c.family != "recsys"
    ]
    shapes = [s for s in ALL_SHAPES if args.shape is None or s.name == args.shape]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for cfg in archs:
        for shape in shapes:
            ok, why = shape_applicability(cfg, shape)
            if not ok:
                print(f"SKIP  {cfg.name} x {shape.name}: {why}", flush=True)
                n_skip += 1
                continue
            for mesh_name, mesh in meshes:
                plan = plan_from_args(args, cfg, shape)
                tag = f"{cfg.name}_{shape.name}_{mesh_name}_{args.tag}"
                try:
                    rec = dryrun_cell(cfg, shape, mesh, plan)
                    rec["mesh_name"] = mesh_name
                    rec["tag"] = args.tag
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(
                        f"OK    {tag}: compile={rec['compile_s']:.1f}s "
                        f"dominant={r['dominant']} "
                        f"compute={r['compute_s']*1e3:.2f}ms "
                        f"mem={r['memory_s']*1e3:.2f}ms "
                        f"coll={r['collective_s']*1e3:.2f}ms "
                        f"useful={r['useful_fraction']:.2f} mfu={r['mfu']:.3f}",
                        flush=True,
                    )
                    n_ok += 1
                except Exception:
                    print(f"FAIL  {tag}", flush=True)
                    traceback.print_exc()
                    n_fail += 1
    print(f"dry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
