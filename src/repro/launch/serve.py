"""Serving driver: prefill a batch of prompts, then decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
        --prompt-len 32 --decode-steps 16 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser(description="TopoOpt serving driver")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")

    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.array(
            rng.standard_normal((B, cfg.img_tokens, cfg.d_model)),
            jnp.dtype(cfg.activation_dtype),
        )

    max_len = S + args.decode_steps
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, cfg, pad_to=max_len))
    decode = jax.jit(lambda p, b: lm.decode_step(p, b, cfg))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = jnp.argmax(logits, axis=-1)
    generated = [tokens]
    t0 = time.perf_counter()
    for i in range(args.decode_steps - 1):
        logits, cache = decode(
            params, {"token": tokens, "pos": jnp.int32(S + i), "cache": cache}
        )
        tokens = jnp.argmax(logits, axis=-1)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in generated], axis=1)
    print(f"prefill: {B}x{S} in {t_prefill*1e3:.1f} ms")
    print(
        f"decode: {len(generated)} steps in {t_decode*1e3:.1f} ms "
        f"({t_decode / max(len(generated)-1, 1) * 1e3:.2f} ms/token)"
    )
    print("generated ids (first seq):", out[0][:16])


if __name__ == "__main__":
    main()
