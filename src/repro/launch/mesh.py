"""Production meshes.

Functions (never module-level constants) so importing this module does not
touch jax device state — only the dry-run forces 512 host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods of
    256 as (pod=2, data=16, model=16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis (EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_LINK_BW = 50e9  # bytes/s per link
ICI_LINKS_PER_CHIP = 4
