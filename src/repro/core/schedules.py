"""Collective schedules as demand compilers (ROADMAP item 2).

TopoOpt's fluid model prices every AllReduce as a *ring* schedule:
``2 (k-1)/k * M`` per ring link over ``2 (k-1)`` latency rounds.  That is
bandwidth-optimal but latency-pessimal — small-message groups and MoE
expert AllReduces pay ``O(k)`` rounds when ``O(log k)`` schedules exist at
equal wire bytes (Zhao et al., "Efficient Direct-Connect Topologies for
Collective Communications", arXiv 2202.03356).

A :class:`CollectiveSchedule` compiles an :class:`~repro.core.demand.AllReduceGroup`
into

* **pair loads** — pinned (src, dst, bytes) MP demand entries the
  TopologyFinder can Blossom-match direct links onto, and
* a **step count** — the schedule's serial round count, priced by the
  ``(α, β)`` cost model as ``hw.link_latency * steps`` on top of the fluid
  bandwidth bottleneck (β term).

Every schedule conserves total wire bytes: an AllReduce of ``M`` bytes over
``k`` members moves exactly ``2 (k-1) M`` bytes regardless of schedule —
the invariant ``tests/test_schedule_properties.py`` pins.

``"ring"`` compiles to the identity (the group stays mutable AllReduce
demand), so the default is byte-identical to the pre-schedule code path.
"""

from __future__ import annotations

from .demand import AllReduceGroup, TrafficDemand
from .select_perms import schedule_strides
from .totient import ring_order

SCHEDULES = ("ring", "recursive_hd", "multi_tree")

PairLoads = dict[tuple[int, int], float]


def _pow2_floor(k: int) -> int:
    """Largest power of two <= k (k >= 1)."""
    return 1 << (k.bit_length() - 1)


class CollectiveSchedule:
    """One AllReduce schedule: a demand compiler plus an (α, β) cost shape.

    ``pair_loads(members, nbytes)`` returns the pinned per-pair wire bytes;
    ``steps(k)`` the serial latency rounds (the α multiplier).  ``ring``
    overrides neither — it stays uncompiled ring-AllReduce demand.
    """

    name: str = "?"

    def pair_loads(self, members: tuple[int, ...], nbytes: float) -> PairLoads:
        raise NotImplementedError

    def steps(self, k: int) -> float:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{type(self).__name__}()"


class RingSchedule(CollectiveSchedule):
    """Ring AllReduce — the identity compile: the group stays a mutable
    :class:`AllReduceGroup` (any ring permutation serves it), costing
    ``2 (k-1)/k * M`` per ring link over ``2 (k-1)`` rounds."""

    name = "ring"

    def pair_loads(self, members: tuple[int, ...], nbytes: float) -> PairLoads:
        raise TypeError("ring schedule is not compiled to pinned pairs")

    def steps(self, k: int) -> float:
        return 2.0 * (k - 1) if k > 1 else 0.0


class RecursiveHDSchedule(CollectiveSchedule):
    """Recursive halving-doubling: reduce-scatter by recursive halving over
    power-of-two exchange distances, then allgather by recursive doubling.

    The ``p2 = 2^L <= k`` core runs ``2 L`` rounds; a non-power-of-two group
    folds the ``k - p2`` extras in (full-vector pre/post exchange, +2
    rounds).  Round ``r`` pairs core rank ``i`` with ``i XOR 2^r`` carrying
    ``M / 2^r`` combined (RS ``M/2^(r+1)`` + AG ``M/2^(r+1)``) — total wire
    bytes ``2 (p2-1) M + 2 (k-p2) M = 2 (k-1) M``, same as ring.
    """

    name = "recursive_hd"

    def pair_loads(self, members: tuple[int, ...], nbytes: float) -> PairLoads:
        k = len(members)
        validate_hd_group(k)
        p2 = _pow2_floor(k)
        loads: PairLoads = {}

        def add(a: int, b: int, x: float) -> None:
            loads[(a, b)] = loads.get((a, b), 0.0) + x

        # Fold: extras hand their full vector to a core partner and get the
        # finished result back.
        for j in range(k - p2):
            extra, partner = members[p2 + j], members[j]
            add(extra, partner, nbytes)
            add(partner, extra, nbytes)
        # Halving-doubling core over the first p2 members.
        for r, dist in enumerate(schedule_strides(p2, "recursive_hd")):
            share = nbytes / float(1 << r)
            for i in range(p2):
                add(members[i], members[i ^ dist], share)
        return loads

    def steps(self, k: int) -> float:
        if k < 2:
            return 0.0
        p2 = _pow2_floor(k)
        return 2.0 * (p2.bit_length() - 1) + (2.0 if k > p2 else 0.0)


class MultiTreeSchedule(CollectiveSchedule):
    """Multi-tree AllReduce: the vector splits across ``n_trees`` balanced
    binary reduce+broadcast trees, each rooted on a different TotientPerms
    ring order (Algorithm 3 selects the seeding strides) so tree edges
    spread over distinct node pairs.

    Each tree carries ``M / n_trees`` up its ``k-1`` edges and back down —
    total wire bytes ``2 (k-1) M``, same as ring, in ``2 floor(log2 k)``
    rounds.
    """

    name = "multi_tree"
    n_trees = 2

    def pair_loads(self, members: tuple[int, ...], nbytes: float) -> PairLoads:
        k = len(members)
        strides = schedule_strides(k, "multi_tree", self.n_trees)
        if not strides:
            raise ValueError(f"multi_tree needs a group of >= 2, got {k}")
        share = nbytes / float(len(strides))
        loads: PairLoads = {}

        def add(a: int, b: int, x: float) -> None:
            loads[(a, b)] = loads.get((a, b), 0.0) + x

        for p in strides:
            order = [members[i] for i in ring_order(k, p)]
            for i in range(1, k):
                parent, child = order[(i - 1) // 2], order[i]
                add(child, parent, share)  # reduce up
                add(parent, child, share)  # broadcast down
        return loads

    def steps(self, k: int) -> float:
        return 2.0 * (k.bit_length() - 1) if k > 1 else 0.0


def validate_hd_group(k: int) -> int:
    """Halving-doubling group-size check: needs >= 2 ranks; returns the
    power-of-two core size ``p2`` (non-power-of-two sizes fold, they do not
    fail).  Raises ``ValueError`` on degenerate groups — the negative-test
    hook for n=1 groups."""
    if k < 2:
        raise ValueError(
            f"recursive halving-doubling needs a group of >= 2, got {k}"
        )
    return _pow2_floor(k)


_REGISTRY: dict[str, CollectiveSchedule] = {
    s.name: s for s in (RingSchedule(), RecursiveHDSchedule(), MultiTreeSchedule())
}


def get_schedule(name: str) -> CollectiveSchedule:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown collective schedule {name!r}: expected one of {SCHEDULES}"
        ) from None


def apply_schedule(demand: TrafficDemand, schedule: str = "ring") -> TrafficDemand:
    """Compile a demand's AllReduce groups under one schedule.

    ``"ring"`` returns ``demand`` unchanged (same object — the byte-identical
    default).  Other schedules pin each active group's traffic as MP pair
    loads, keep a zero-byte group in place (the TopologyFinder still
    reserves its connectivity ring), and raise ``demand.steps`` to the
    schedule's round count.  Zero-byte or singleton groups pass through.
    """
    sched = get_schedule(schedule)
    if sched.name == "ring":
        return demand
    out = TrafficDemand(n=demand.n, mp=demand.mp.copy(), steps=demand.steps)
    groups: list[AllReduceGroup] = []
    for g in demand.allreduce:
        k = len(g.members)
        if g.nbytes <= 0.0 or k < 2:
            groups.append(g)
            continue
        for (a, b), x in sched.pair_loads(g.members, g.nbytes).items():
            out.mp[a, b] += x
        groups.append(AllReduceGroup(members=g.members, nbytes=0.0))
        out.steps = max(out.steps, float(sched.steps(k)))
    out.allreduce = groups
    return out
