"""Online re-optimization: dynamic TopoOpt reacting to failures and load
shifts (ROADMAP "online re-optimization" + "topology-aware job placement").

The offline pipeline (:func:`repro.core.alternating.alternating_optimize`)
computes one (strategy, topology, routing) plan and assumes the cluster never
changes.  :class:`repro.core.simengine.SimEngine` already models the events
that make such a plan stale — fiber failures, job arrivals/departures,
stragglers — so this module closes the loop:

* :class:`ReoptPolicy` — *when* to re-optimize: on failure, on job
  arrival/departure (load shifts), periodically, or when a degradation probe
  sees the estimated iteration time exceed a tracked baseline, all gated by a
  hysteresis ``min_interval``.
* :class:`ReoptController` — *how*: a
  :class:`~repro.core.simengine.ScenarioObserver` that pauses the fluid
  simulation (an OCS-style ``replan_latency`` stall), re-runs the alternating
  optimizer **warm-started from the incumbent plan** against the surviving
  fiber pairs and resident job, and resumes in-flight flows on the new
  topology/routes via a :class:`~repro.core.simengine.PlanUpdate`.  When no
  replan triggers it still maintains the paper's §7 quick fix
  (:func:`~repro.core.topology_finder.repair_topology`) as the static
  operator's incumbent.
* :func:`run_online` — an iteration-granularity driver: each training
  iteration's flows are regenerated from the *current* plan, a
  failure/load-shift trace is injected (at iteration boundaries or
  mid-iteration through the engine's failure events), and the policy decides
  between static repair and reactive replanning.  ``benchmarks/bench_online.py``
  compares the two.
* :func:`place_arrival` — topology-aware placement of newly arriving jobs:
  pick the free servers with the most surviving pairwise capacity instead of
  the lowest ids.

Multi-tenant shared fabrics (ROADMAP "extend to multi-job shared fabrics"):
:class:`JobSetController` holds the resident
:class:`~repro.core.workloads.JobSet` instead of a single job — it
re-optimizes the *union* demand via
:func:`~repro.core.alternating.co_optimize_jobset` on arrival / departure /
failure, admits arrivals through :func:`place_arrival`, and probes with
per-tenant flow graphs under the set's weighted fairness.
:func:`run_online_jobset` drives a churn trace (jobs arriving, departing,
fibers dying) against it; ``benchmarks/bench_multitenant.py`` compares
static vs reactive shared plans.

Placement as a co-optimization axis (ROADMAP "placement co-search" +
"preemption / migration"): on a shared fabric the fourth coupled dimension
is *where each tenant sits*.  :func:`place_candidates` generates diverse
candidate server sets for an arrival (greedy-capacity seed first, then
contiguous / spread / anti-affinity variants);
``JobSetController.admit(candidates=k)`` — or ``ReoptPolicy.candidates`` —
threads them through the replan, which scores every candidate with the
full alternating loop and adopts the best *plan including placement*
(``candidates=1`` is byte-identical to the greedy-then-replan path).  After
a departure, :meth:`JobSetController.rebalance` proposes migrating up to
``ReoptPolicy.max_migrations`` resident tenants into the freed capacity,
each move priced by :func:`repro.core.costmodel.migration_cost`
(checkpoint-restore seconds + churn-priced fiber moves) and adopted only
when the probed amortized win clears the price;
:class:`~repro.core.simengine.MigrationRecord`\\ s land in run results and
``ScenarioResult.migrations``.  ``benchmarks/bench_placement.py`` shows
co-searched admission + rebalancing beating greedy-then-replan on a
fragmented churn trace.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

import numpy as np

from .alternating import (
    CoOptResult,
    JobSetPlan,
    alternating_optimize,
    co_optimize_jobset,
)
from .costmodel import MIGRATION_RESTART_S, migration_cost
from .demand import remap_demand
from .netsim import HardwareSpec, compute_time
from .ocs_reconfig import _RECONFIG_LATENCY as RECONFIG_LATENCY
from .planeval import JobSetEvaluator
from .simengine import (
    DeadlineFairness,
    EngineView,
    FairnessPolicy,
    LinkFailure,
    MigrationRecord,
    PlanUpdate,
    Scenario,
    ScenarioObserver,
    SimEngine,
    SimJob,
    WeightedFairness,
    iteration_tasks,
    links_from_topology,
)
from .strategy_search import Strategy, default_strategy
from .topology_finder import Topology, remove_pair, restore_pair
from .workloads import JobSet, JobSpec, TenantJob

__all__ = [
    "ReoptPolicy",
    "ReoptController",
    "JobSetController",
    "TraceEvent",
    "OnlineRunResult",
    "JobSetRunResult",
    "run_online",
    "run_online_jobset",
    "place_arrival",
    "place_candidates",
    "edge_churn",
]


def edge_churn(old: Topology, new: Topology) -> int:
    """Fibers the patch panel must re-seat to turn ``old`` into ``new``:
    the directed-edge multiset difference (each graph edge is one physical
    port-to-port fiber; edges present in both plans stay patched)."""
    c_old = Counter(old.graph.edges())
    c_new = Counter(new.graph.edges())
    return int(sum((c_new - c_old).values()))


@dataclass(frozen=True)
class ReoptPolicy:
    """Trigger rules for online re-optimization.

    Any combination of triggers may be enabled:

    * ``on_failure`` — replan when a fiber pair dies.
    * ``on_arrival`` / ``on_departure`` — replan on load shifts (a job
      joining or leaving the fabric, or :func:`run_online` swapping the
      resident job's spec).
    * ``period`` — unconditional periodic replanning every ``period`` s.
    * ``degradation_threshold`` + ``check_interval`` — every
      ``check_interval`` s, estimate the incumbent's fluid iteration time on
      the (repaired) surviving fabric; replan when it exceeds
      ``degradation_threshold`` x the baseline recorded at plan adoption.

    ``min_interval`` is hysteresis: replans closer than this to the previous
    one are suppressed (failed triggers leave the static repair in place).
    Every applied replan charges ``replan_latency`` seconds of OCS-style
    traffic pause.

    Churn-proportional cost (``fiber_move_latency``): real patch panels
    charge per *moved fiber*, not a flat fee.  When set, an adopted replan's
    pause is ``fiber_move_latency * edges_moved`` (the directed-edge diff
    between incumbent and replanned topology, :func:`edge_churn`) and a
    replan that keeps the incumbent pauses nothing; ``None`` keeps the flat
    ``replan_latency`` (the pre-churn behaviour).  Constants to plug in live
    in :mod:`repro.core.costmodel` (``FIBER_MOVE_S``, ``OCS_FIBER_MOVE_S``).

    Adaptive hysteresis (``adaptive``): a triggered replan is *skipped* —
    no pause, no fabric change — when the probed marginal win over the
    degraded incumbent, amortized over ``payback_horizon`` iterations, is
    below its (churn-proportional) pause cost; each skip doubles the
    controller's effective ``min_interval`` (reset on the next adopted
    replan), so hopeless replanning backs off instead of burning pauses.

    ``probe_slack`` tunes the incremental degradation probe: after a full
    one-iteration flow probe the controller caches the estimate together
    with the link set whose planned utilization exceeds ``probe_slack`` x
    the bottleneck; later probes reuse the cached estimate until a failure
    touches that hot set (or the demand changes).  ``0.0`` = every loaded
    link is hot (reuse only across failures of unloaded pairs);
    ``~0.95`` = only near-bottleneck links invalidate.
    """

    on_failure: bool = True
    on_arrival: bool = False
    on_departure: bool = False
    period: float | None = None
    check_interval: float | None = None
    degradation_threshold: float | None = None
    min_interval: float = 0.0
    replan_latency: float = RECONFIG_LATENCY
    # Churn-proportional replan cost: seconds per moved fiber (None = flat).
    fiber_move_latency: float | None = None
    # Benefit-vs-cost replan gate + min_interval backoff.
    adaptive: bool = False
    payback_horizon: float = 8.0  # iterations a replan must amortize over
    # Incremental probe: bottleneck-set utilization threshold in [0, 1).
    probe_slack: float = 0.0
    # Placement co-search: candidate placements tried per admission
    # (:func:`place_candidates`); 1 = the greedy `place_arrival` path,
    # byte-identical to the pre-search behaviour.
    candidates: int = 1
    # Churn-priced tenant migration: how many resident tenants one
    # :meth:`JobSetController.rebalance` call may move (0 disables — no
    # rebalance ever runs, the pre-migration behaviour).  An adopted move
    # must clear its checkpoint-restore + fiber-churn cost
    # (:func:`repro.core.costmodel.migration_cost`) amortized over
    # ``payback_horizon`` iterations.
    max_migrations: int = 0
    # Per-migration drain/teardown/re-init floor in seconds (the
    # checkpoint-transfer and fiber components are priced per tenant and
    # per moved fiber on top of this).  Defaults to the cost model's
    # documented floor; simulations on sub-second iteration timescales
    # lower it explicitly (as the placement benchmark does).
    migration_restart: float = MIGRATION_RESTART_S
    # Warm-started optimizer budget per replan (smaller than offline: the
    # incumbent is already good, we only adapt it).
    rounds: int = 2
    mcmc_iters: int = 40
    # Candidate pricing inside the replan optimizer: the compiled plan
    # evaluator (repro.core.planeval) by default; False pins the reference
    # topoopt_comm_time path (fixed seeds must agree between the two).
    compiled: bool = True
    # Planner backend of the replan optimizer's inner MCMC: "jax" runs
    # ``chains`` batched on-device annealing chains per round
    # (repro.core.planeval_jax); "numpy" (default) is byte-stable against
    # its introduction.
    backend: str = "numpy"
    chains: int = 1
    # Multi-tenant annealing objective: "decomposed" prices each tenant's
    # own weighted-share comm time instead of charging everyone the union
    # bottleneck (see mcmc_search_jobset).  Default preserves goldens.
    objective: str = "union"
    # Admission-time preemption: an *arriving* tenant triggers the same
    # churn-priced rebalance pass a departure does (max_migrations > 0
    # required), displacing cheap residents when the migration-priced win
    # clears its cost.  Off by default — the pre-fix behaviour, where only
    # departures could rebalance.
    rebalance_on_arrival: bool = False
    # Pre-screen wide placement-candidate lists inside co_optimize_jobset:
    # only the k best candidates by the incremental evaluator pay the full
    # alternating loop (None = screen nothing, the pre-fix behaviour).
    screen_candidates: int | None = None
    # Collective-schedule search axis of the replan optimizer's inner MCMC
    # (repro.core.schedules): a tuple of schedule names the proposal kernel
    # may flip per AllReduce-bearing strategy, e.g. ("ring",
    # "recursive_hd", "multi_tree").  None / ("ring",) keeps the search
    # (and its RNG streams) byte-identical to the pre-schedule behaviour.
    schedules: tuple[str, ...] | None = None
    # Parallel-tempering ladder of the JAX grid kernel (ascending floats).
    # With backend="jax" and placement candidates this turns every
    # admission into the *fused* co-search: all screened candidates x the
    # ladder anneal in one device dispatch per alternating round
    # (repro.core.alternating._co_optimize_fused).  None keeps the flat
    # single-temperature chains; requires backend="jax" when set.
    temperatures: tuple[float, ...] | None = None
    # -- robustness hardening (fault storms) --------------------------------
    # Wall-clock budget in seconds for one warm optimizer run inside a
    # replan.  The optimizer is not interruptible, so the deadline is
    # checked post-hoc: an over-budget run is discarded and retried with a
    # bumped seed (the last permitted attempt's result is kept either way
    # rather than thrown away).  None disables the deadline.
    replan_deadline: float | None = None
    # Seed-bumped retries after an optimizer raise or deadline overrun
    # before the controller gives up on this trigger and keeps the
    # last-known-good plan (+ §7 repair).  Exhausting every attempt arms an
    # exponential backoff — base ``retry_backoff`` seconds (None: the max
    # of ``replan_latency``/``min_interval``/1 ms), doubling per
    # consecutive exhaustion — so a fault storm cannot wedge the controller
    # in a replan-crash loop.
    replan_retries: int = 2
    retry_backoff: float | None = None
    # Validate every candidate plan before adoption: per-node degree
    # budgets, no edge on a dead pair, per-node capacity conservation, and
    # tenant-ring connectivity on the *live* degraded fabric.  A plan that
    # fails a check the incumbent passes is rejected in favour of the
    # last-known-good plan + §7 repair.  Valid plans (everything a healthy
    # optimizer emits) adopt byte-identically to the unvalidated path.
    validate_plans: bool = True

    @classmethod
    def never(cls) -> "ReoptPolicy":
        """Static plan: no trigger ever fires (PR-1 engine semantics)."""
        return cls(on_failure=False, replan_latency=0.0)

    @classmethod
    def reactive(cls, min_interval: float = 0.0, **kw) -> "ReoptPolicy":
        """Replan on every failure and load shift (subject to hysteresis)."""
        return cls(on_failure=True, on_arrival=True, on_departure=True,
                   min_interval=min_interval, **kw)

    @classmethod
    def periodic(cls, period: float, **kw) -> "ReoptPolicy":
        return cls(on_failure=False, period=period, **kw)

    @classmethod
    def degradation(
        cls, threshold: float, check_interval: float, **kw
    ) -> "ReoptPolicy":
        return cls(on_failure=False, degradation_threshold=threshold,
                   check_interval=check_interval, **kw)

    @property
    def check_period(self) -> float | None:
        """Interval between observer checks, if any trigger needs them."""
        if self.period is not None:
            return self.period
        if (
            self.check_interval is not None
            and self.degradation_threshold is not None
        ):
            return self.check_interval
        return None


@dataclass
class ReplanRecord:
    """One controller decision, for logs and benchmarks."""

    time: float
    trigger: str  # "failure" | "arrival" | "departure" | "periodic" | ...
    replanned: bool
    est_before: float = float("nan")  # incumbent (repaired) iteration time
    est_after: float = float("nan")  # adopted plan's iteration time
    edges_moved: int = 0  # physical fiber churn of the adopted swap


class ReoptController(ScenarioObserver):
    """Couples :func:`alternating_optimize` into a running scenario.

    The controller tracks three things across events:

    * ``dead`` — fiber pairs that failed so far; every replanned topology is
      searched with these pairs ``forbidden``.
    * the **incumbent plan** (``plan``/``topology``/``demand``) — after a
      failure with no replan trigger, the incumbent topology is degraded in
      place (:func:`~repro.core.topology_finder.remove_pair`: dead pair
      gone, routes re-pathed over the survivors) — the plan a static
      operator keeps running; after a replan it is the freshly optimized
      plan, warm-started from the old one.
    * ``baseline`` — the one-iteration simulated makespan recorded when the
      incumbent was adopted, against which the degradation trigger compares.

    As a :class:`ScenarioObserver` it turns replans into
    :class:`PlanUpdate`s: new fabric links + a ``replan_latency`` pause, so
    in-flight flows resume (bytes preserved) on the new topology mid-run.
    A controller whose policy never triggers returns ``None`` from every
    hook, leaving the engine bit-identical to an observer-less run.
    """

    def __init__(
        self,
        job: JobSpec | None,
        n: int,
        hw: HardwareSpec | None = None,
        policy: ReoptPolicy | None = None,
        seed: int = 0,
        plan: CoOptResult | None = None,
    ):
        self.job = job
        self.n = n
        self.hw = hw or HardwareSpec()
        self.policy = policy or ReoptPolicy()
        self.seed = seed
        self.dead: set[tuple[int, int]] = set()
        self.n_replans = 0
        self.total_edges_moved = 0
        # Hardened replan path: retry nonce folded into the warm seed (0 on
        # first attempts — byte-identical to the pre-hardening seeds),
        # consecutive give-ups, and the backoff gate they arm.
        self._retry_nonce = 0
        self._replan_failures = 0
        self._backoff_until = -np.inf
        self.n_rejected_plans = 0  # plans refused by validation
        self.n_optimizer_errors = 0  # raises + deadline overruns survived
        # pair -> graph edges _note_dead removed, so repair() can restore
        # the incumbent fabric in place.
        self._cut_edges: dict[tuple[int, int], list] = {}
        # Pause of the most recent *applied* PlanUpdate (drivers charge the
        # tail of a pause that hangs past the last task finish).
        self.last_pause = 0.0
        self.last_replan = -np.inf
        self.log: list[ReplanRecord] = []
        self._plan: CoOptResult | None = plan
        self._topology: Topology | None = plan.topology if plan else None
        self._baseline: float | None = None
        self._probe_engine: SimEngine | None = None
        # Incremental degradation probe: (estimate, hot undirected pairs)
        # from the last full flow probe of the incumbent; reused until a
        # failure touches the hot set or the demand changes.
        self._probe_cache: tuple[float, frozenset] | None = None
        self.n_full_probes = 0
        # Adaptive hysteresis: effective min_interval, doubled per skipped
        # (benefit < cost) replan, reset on adoption.
        self._adaptive_interval = self.policy.min_interval
        # Global-clock time of the replan currently being computed; hooks
        # that need "now" inside _run_optimizer (deadline urgency) read it.
        self._replan_now = 0.0
        # Hook clock = engine-local time + clock_offset.  Drivers that run a
        # sequence of scenarios (run_online: one per training iteration) set
        # the offset so hysteresis spans scenario boundaries.
        self.clock_offset = 0.0
        # run_online admits one SimJob per iteration; those admissions are
        # not load shifts, so the driver mutes the arrival/departure hooks
        # and feeds genuine load shifts through set_job instead.
        self.suppress_job_hooks = False
        interval = self.policy.check_period
        # Global-clock time of the next periodic/degradation check.
        self._next_check_global = interval if interval is not None else np.inf

    # -- incumbent plan ------------------------------------------------------

    def _run_optimizer(self, warm: bool) -> CoOptResult:
        """One optimizer run against the current resident workload.
        Subclasses (:class:`JobSetController`) override this to optimize
        their own notion of "the resident job"."""
        if not warm:
            return alternating_optimize(
                self.job, self.n, self.hw,
                rounds=max(self.policy.rounds, 2),
                mcmc_iters=max(self.policy.mcmc_iters, 40),
                seed=self.seed,
                forbidden=tuple(self.dead),
                compiled=self.policy.compiled,
                backend=self.policy.backend,
                chains=self.policy.chains,
                schedules=self.policy.schedules,
                temperatures=self.policy.temperatures,
            )
        return alternating_optimize(
            self.job, self.n, self.hw,
            rounds=self.policy.rounds,
            mcmc_iters=self.policy.mcmc_iters,
            seed=self.seed + 1 + self.n_replans + 997 * self._retry_nonce,
            warm_topology=self.topology,
            warm_strategy=self.strategy,
            forbidden=tuple(self.dead),
            compiled=self.policy.compiled,
            backend=self.policy.backend,
            chains=self.policy.chains,
            schedules=self.policy.schedules,
            temperatures=self.policy.temperatures,
        )

    def ensure_plan(self) -> CoOptResult:
        """Cold-start the offline optimizer once, lazily (a controller whose
        policy never fires should cost nothing)."""
        if self._plan is None:
            self._plan = self._run_optimizer(warm=False)
            self._topology = self._plan.topology
        return self._plan

    @property
    def plan(self) -> CoOptResult:
        return self.ensure_plan()

    @property
    def topology(self) -> Topology:
        """The live physical plan: replanned, or incumbent + §7 repairs."""
        self.ensure_plan()
        assert self._topology is not None
        return self._topology

    @property
    def strategy(self) -> Strategy:
        return self.plan.strategy

    @property
    def demand(self):
        return self.strategy.demand(self.job, self.n)

    @property
    def baseline(self) -> float:
        """Iteration-time estimate the degradation trigger compares against.

        Established on first access (and re-pinned by every replan) — read it
        once while the fabric is still healthy when using the degradation
        trigger; :func:`run_online` does this before applying any trace."""
        if self._baseline is None:
            self.ensure_plan()
            self._baseline = self.estimated_iter_time()
        return self._baseline

    def links(self) -> dict[tuple[int, int], float]:
        """Directed link capacities of the current topology on the surviving
        fabric (dead pairs carry nothing, whatever the plan says)."""
        return self._links_for(self.topology)

    def _links_for(self, topo: Topology) -> dict[tuple[int, int], float]:
        caps = links_from_topology(topo, self.hw)
        for a, b in list(caps):
            if (min(a, b), max(a, b)) in self.dead:
                del caps[(a, b)]
        return caps

    def _probe_jobs(self, topo: Topology, strategy) -> list[SimJob]:
        """The one-iteration flow graph(s) the probe simulates; subclasses
        build one SimJob per tenant."""
        demand = strategy.demand(self.job, self.n)
        comp = compute_time(
            self.job.flops_per_sample * self.job.batch_per_gpu * self.n,
            self.n, self.hw,
        )
        return [SimJob("probe", iteration_tasks(topo, demand,
                                                compute_duration=comp))]

    def _probe_fairness(self) -> FairnessPolicy | None:
        return None

    def _probe_metric(self, res) -> float:
        """Scalar the probe optimizes for; subclasses weight per-job times."""
        return res.makespan

    def _hot_pairs(
        self, jobs: list[SimJob], links: dict[tuple[int, int], float]
    ) -> frozenset | None:
        """Undirected pairs whose planned utilization exceeds
        ``probe_slack`` x the bottleneck; failures outside this set cannot
        move the cached estimate.  Returns ``None`` — *every* failure
        invalidates — when any planned hop has no live link: the engine
        detours such flows over links the plan never names, so the hot set
        cannot be known from the plan alone."""
        # Vectorized hop accounting: encode every planned hop as a dense
        # pair id, sum bytes with one bincount, and look capacities up only
        # for the unique loaded links.
        hop_a: list[np.ndarray] = []
        hop_b: list[np.ndarray] = []
        hop_bytes: list[np.ndarray] = []
        for j in jobs:
            for t in j.tasks:
                if t.kind != "flow" or len(t.route) < 2:
                    continue
                r = np.asarray(t.route, dtype=np.int64)
                hop_a.append(r[:-1])
                hop_b.append(r[1:])
                hop_bytes.append(np.full(r.size - 1, t.nbytes))
        if not hop_a:
            return frozenset()
        a = np.concatenate(hop_a)
        b = np.concatenate(hop_b)
        ids = a * self.n + b
        uniq, inv = np.unique(ids, return_inverse=True)
        loads = np.bincount(inv, weights=np.concatenate(hop_bytes))
        pairs = [(int(i) // self.n, int(i) % self.n) for i in uniq]
        caps = np.asarray([links.get(p) or 0.0 for p in pairs])
        alive = caps > 0
        if np.any(~alive & (loads > 0)):
            return None  # detour-routed flow: hot set unknowable
        if not np.any(alive):
            return frozenset()
        util = np.zeros_like(loads)
        util[alive] = loads[alive] / caps[alive]
        thresh = self.policy.probe_slack * float(util.max())
        return frozenset(
            (min(p), max(p))
            for p, u, live in zip(pairs, util, alive)
            if live and u > thresh
        )

    def estimated_iter_time(
        self,
        topo: Topology | None = None,
        strategy=None,
    ) -> float:
        """One-iteration simulated makespan of ``strategy`` on ``topo``
        restricted to the surviving fabric (defaults: the incumbent).

        A flow-level probe rather than the fluid formula: the fluid model
        charges AllReduce rings by the *planned* ring edges, so it cannot see
        a dead ring link; the scenario engine re-routes those flows over the
        survivors and prices the resulting contention.

        Incumbent probes (both arguments defaulted) are cached together with
        the hot link set (:meth:`_hot_pairs`): failures that do not touch a
        hot link, and checks with no intervening change, reuse the cached
        estimate instead of re-simulating — the incremental probe that keeps
        shared multi-job scenarios cheap."""
        incumbent = topo is None and strategy is None
        if incumbent and self._probe_cache is not None:
            return self._probe_cache[0]
        topo = topo if topo is not None else self.topology
        strategy = strategy if strategy is not None else self.strategy
        jobs = self._probe_jobs(topo, strategy)
        links = self._links_for(topo)
        if self._probe_engine is None:
            self._probe_engine = SimEngine(self.hw)
        sc = Scenario(
            links=links, jobs=jobs, n=self.n, fairness=self._probe_fairness()
        )
        res = self._probe_engine.run(sc)
        self.n_full_probes += 1
        if res.stalled:
            # Unroutable demand stall-finishes instantly in the engine; a
            # disconnected fabric must probe as unusable, not as fast.
            est = float(np.inf)
        else:
            est = float(self._probe_metric(res))
        if incumbent:
            self._probe_cache = (est, self._hot_pairs(jobs, links))
        return est

    # -- mutations -----------------------------------------------------------

    def set_job(self, job: JobSpec, now: float = 0.0) -> float:
        """Load shift: the resident job's spec changes (new batch size, new
        tables, a different model).  Returns the pause charged (seconds) if
        the arrival trigger replanned."""
        self.job = job
        self._probe_cache = None  # demand changed: cached estimate is stale
        if self.policy.on_arrival:
            update = self._maybe_replan(now, "arrival")
            if update is not None:
                return update.pause
        return 0.0

    def _note_dead(self, pair: tuple[int, int]) -> None:
        """Record a dead pair and degrade the incumbent; the probe cache
        survives only when the pair is outside the cached hot link set
        (a ``None`` hot set means any failure invalidates)."""
        if self._probe_cache is not None and (
            self._probe_cache[1] is None or pair in self._probe_cache[1]
        ):
            self._probe_cache = None
        self.dead.add(pair)
        if self._topology is not None:
            # Snapshot what the cut takes out so a transient fault can be
            # healed in place (restore_pair) when the repair lands.
            g = self._topology.graph
            self._cut_edges[pair] = [
                (a, b, dict(data))
                for a, b in (pair, (pair[1], pair[0]))
                if g.has_edge(a, b)
                for data in g[a][b].values()
            ]
            self._topology = remove_pair(self._topology, pair)

    def _note_repaired(self, pair: tuple[int, int]) -> None:
        """A dead pair came back: lift the forbidden constraint, restore the
        incumbent's cut edges in place, and drop the probe cache (capacity
        improved, so any cached estimate is stale)."""
        self.dead.discard(pair)
        self._probe_cache = None
        edges = self._cut_edges.pop(pair, None)
        if edges and self._topology is not None:
            self._topology = restore_pair(self._topology, pair, edges)

    def fail(self, link: tuple[int, int], now: float = 0.0) -> float:
        """A node pair dies.  Always records the pair and degrades the
        incumbent (routes re-pathed over survivors); replans when the policy
        says so.  Returns the pause charged (seconds)."""
        pair = (min(link), max(link))
        if pair in self.dead:
            return 0.0
        self._note_dead(pair)
        if self.policy.on_failure:
            update = self._maybe_replan(now, "failure")
            if update is not None:
                return update.pause
        return 0.0

    def repair(self, link: tuple[int, int], now: float = 0.0) -> float:
        """A previously failed pair heals (transient fault over).  Always
        restores the incumbent's cut capacity; the failure trigger, if
        enabled, may additionally replan to reclaim the pair.  Returns the
        pause charged (seconds)."""
        pair = (min(link), max(link))
        if pair not in self.dead:
            return 0.0
        self._note_repaired(pair)
        if self.policy.on_failure:
            update = self._maybe_replan(now, "repair")
            if update is not None:
                return update.pause
        return 0.0

    def _replan_pause(self, edges_moved: int) -> float:
        """Churn-proportional pause when the policy prices per moved fiber,
        the flat ``replan_latency`` otherwise."""
        if self.policy.fiber_move_latency is not None:
            return self.policy.fiber_move_latency * edges_moved
        return self.policy.replan_latency

    def _adopt_plan(self, res) -> None:
        """Install ``res`` as the incumbent plan.  Subclasses extend this
        to sync plan provenance (an adopted candidate placement)."""
        self._plan = res
        self._topology = res.topology

    def _estimate_plan(self, res) -> float:
        """Probe a freshly optimized plan's one-iteration time.  Subclasses
        override to probe under the plan's own tenant placements."""
        return self.estimated_iter_time(
            topo=res.topology, strategy=res.strategy
        )

    def _retry_backoff_base(self) -> float:
        if self.policy.retry_backoff is not None:
            return self.policy.retry_backoff
        return max(self.policy.replan_latency, self.policy.min_interval, 1e-3)

    def _guarded_optimize(self, now: float, trigger: str):
        """Run the warm optimizer under the hardening policy: a post-hoc
        wall-clock deadline (``replan_deadline``) and bounded seed-bumped
        retries when it raises or overruns.  Returns the optimizer result,
        or ``None`` after exhausting every attempt — the caller then keeps
        the last-known-good plan (+ §7 repair) and the controller backs off
        exponentially, so a fault storm cannot wedge it in a replan-crash
        loop."""
        import time as _time

        deadline = self.policy.replan_deadline
        attempts = 1 + max(int(self.policy.replan_retries), 0)
        for attempt in range(attempts):
            self._retry_nonce = attempt
            t0 = _time.perf_counter()
            try:
                res = self._run_optimizer(warm=True)
            except Exception:
                self.n_optimizer_errors += 1
                self.log.append(ReplanRecord(
                    time=now, trigger=f"{trigger}:error", replanned=False))
                continue
            finally:
                self._retry_nonce = 0
            if (
                deadline is not None
                and _time.perf_counter() - t0 > deadline
                and attempt + 1 < attempts
            ):
                # Over budget with retry budget left: discard, try another
                # seed.  The last permitted attempt keeps its result —
                # better a late plan than none.
                self.n_optimizer_errors += 1
                self.log.append(ReplanRecord(
                    time=now, trigger=f"{trigger}:deadline", replanned=False))
                continue
            self._replan_failures = 0
            self._backoff_until = -np.inf
            return res
        self._replan_failures += 1
        self._backoff_until = now + self._retry_backoff_base() * (
            2 ** (self._replan_failures - 1)
        )
        self.last_replan = now
        return None

    def _required_groups(self) -> list[tuple[int, ...]]:
        """Server groups that must stay mutually reachable on the live
        fabric for the plan to be servable.  The single resident job spans
        every node; :class:`JobSetController` lists per-tenant placements."""
        return [tuple(range(self.n))] if self.job is not None else []

    def plan_violations(self, topo: Topology) -> list[str]:
        """Validate a candidate topology against the live degraded fabric.

        Checks: per-node degree budgets (with the +1 slack §7 repair
        donations get), no edge on a dead pair, per-node capacity
        conservation, and required-group connectivity on the surviving
        links.  Returns human-readable violations; empty means valid."""
        out: list[str] = []
        budget = topo.degree + 1
        outdeg = Counter(a for a, _ in topo.graph.edges())
        indeg = Counter(b for _, b in topo.graph.edges())
        worst_out = max(outdeg.values(), default=0)
        worst_in = max(indeg.values(), default=0)
        if worst_out > budget or worst_in > budget:
            out.append(
                f"degree budget exceeded: out={worst_out}/in={worst_in} "
                f"> {budget}"
            )
        on_dead = sorted({
            (min(a, b), max(a, b))
            for a, b in topo.graph.edges()
            if (min(a, b), max(a, b)) in self.dead
        })
        if on_dead:
            out.append(f"edges on dead pairs {on_dead[:4]}")
        links = self._links_for(topo)
        cap_budget = budget * self.hw.link_bandwidth * (1.0 + 1e-9)
        node_cap: dict[int, float] = {}
        for (a, _b), c in links.items():
            node_cap[a] = node_cap.get(a, 0.0) + c
        worst_cap = max(node_cap.values(), default=0.0)
        if worst_cap > cap_budget:
            out.append(
                f"capacity conservation violated: {worst_cap:.3g} B/s out "
                f"of one node > {cap_budget:.3g}"
            )
        groups = [g for g in self._required_groups() if len(g) > 1]
        if groups:
            import networkx as nx

            g = nx.DiGraph()
            g.add_nodes_from(range(self.n))
            g.add_edges_from(links.keys())
            comp_of: dict[int, int] = {}
            for ci, comp in enumerate(nx.strongly_connected_components(g)):
                for v in comp:
                    comp_of[v] = ci
            for grp in groups:
                if len({comp_of[v] for v in grp}) > 1:
                    out.append(
                        f"servers {tuple(grp)[:6]} split across fabric "
                        "partitions"
                    )
        return out

    def replan(self, now: float, trigger: str) -> PlanUpdate | None:
        """Re-run the alternating optimizer warm-started from the incumbent,
        forbidding dead pairs; adopt whichever of {new plan, degraded
        incumbent} probes faster.  Returns the PlanUpdate to apply — or
        ``None`` when the adaptive gate skips (the probed win would not pay
        for the churn-proportional pause), the optimizer kept failing
        (:meth:`_guarded_optimize`), or validation rejected the candidate
        (:meth:`plan_violations`) — in the latter two cases the
        last-known-good plan + §7 repair stays in force."""
        self._replan_now = now
        self.ensure_plan()
        est_before = self.estimated_iter_time()
        res = self._guarded_optimize(now, trigger)
        if res is None:
            return None
        est_new = self._estimate_plan(res)
        if self.policy.validate_plans and est_new <= est_before:
            # About to adopt: validate first.  A candidate that probes well
            # but breaks a fabric invariant (degree budget, dead-pair edge,
            # capacity conservation, tenant-ring connectivity) is refused
            # and the last-known-good incumbent + §7 repair stays in force.
            # (When the *incumbent* fails the same checks — e.g. the fabric
            # is genuinely partitioned — the est comparison decides, as
            # before.)  Candidates the est comparison would reject anyway
            # take the unvalidated keep-incumbent path below, unchanged.
            bad = self.plan_violations(res.topology)
            if bad and not self.plan_violations(self.topology):
                self.n_rejected_plans += 1
                self.last_replan = now
                self.log.append(ReplanRecord(
                    time=now, trigger=f"{trigger}:invalid", replanned=False,
                    est_before=est_before, est_after=est_new,
                ))
                return None
        adopt = est_new <= est_before
        edges_moved = edge_churn(self.topology, res.topology) if adopt else 0
        pause = self._replan_pause(edges_moved)
        if adopt and self.policy.adaptive:
            benefit = (est_before - est_new) * self.policy.payback_horizon
            if not np.isfinite(est_before):
                benefit = np.inf if np.isfinite(est_new) else 0.0
            if benefit < pause:
                # Skip: the win doesn't pay for the fiber moves.  No pause,
                # no fabric change; back off the effective min_interval so
                # hopeless triggers stop re-running the optimizer.
                self.last_replan = now
                self._adaptive_interval = max(
                    2 * self._adaptive_interval, pause, self.policy.min_interval
                )
                self.log.append(ReplanRecord(
                    time=now, trigger=trigger, replanned=False,
                    est_before=est_before, est_after=est_new,
                ))
                return None
        if adopt:
            self._adopt_plan(res)
            self._baseline = est_new
            self._probe_cache = None
            self._adaptive_interval = self.policy.min_interval
        else:
            # The warm search couldn't beat the degraded incumbent — keep it
            # (still counts as a replan: the pause was spent deciding) and
            # re-baseline so the degradation trigger doesn't fire forever.
            self._baseline = est_before
        self.n_replans += 1
        self.total_edges_moved += edges_moved
        self.last_replan = now
        self.last_pause = pause
        self.log.append(ReplanRecord(
            time=now, trigger=trigger, replanned=True,
            est_before=est_before, est_after=min(est_new, est_before),
            edges_moved=edges_moved,
        ))
        return PlanUpdate(
            links=self.links(),
            pause=pause,
            label=f"reopt:{trigger}",
            edges_moved=edges_moved,
        )

    def _maybe_replan(self, now: float, trigger: str) -> PlanUpdate | None:
        if now < self._backoff_until:
            # Optimizer-failure backoff: a storm of triggers while replans
            # keep raising/overrunning must not re-run the optimizer on
            # every event.
            self.log.append(ReplanRecord(
                time=now, trigger=f"{trigger}:backoff", replanned=False))
            return None
        gate = (
            self._adaptive_interval if self.policy.adaptive
            else self.policy.min_interval
        )
        if now - self.last_replan < gate:
            self.log.append(ReplanRecord(time=now, trigger=trigger,
                                         replanned=False))
            return None
        return self.replan(now, trigger)

    # -- ScenarioObserver hooks ---------------------------------------------

    def next_check(self, now: float) -> float:
        # The engine speaks scenario-local time; the schedule is global.
        return self._next_check_global - self.clock_offset

    def on_failure(
        self, view: EngineView, link: tuple[int, int]
    ) -> PlanUpdate | None:
        pair = (min(link), max(link))
        if pair in self.dead:
            return None
        self._note_dead(pair)
        if not self.policy.on_failure:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "failure")

    def on_repair(
        self, view: EngineView, link: tuple[int, int]
    ) -> PlanUpdate | None:
        pair = (min(link), max(link))
        if pair not in self.dead:
            return None
        self._note_repaired(pair)
        if not self.policy.on_failure:
            # Static operator: the engine already restored the capacity;
            # the healed incumbent simply resumes.
            return None
        return self._maybe_replan(view.now + self.clock_offset, "repair")

    def on_arrival(self, view: EngineView, job: SimJob) -> PlanUpdate | None:
        if not self.policy.on_arrival or self.suppress_job_hooks:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "arrival")

    def on_departure(self, view: EngineView, job_name: str) -> PlanUpdate | None:
        if not self.policy.on_departure or self.suppress_job_hooks:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "departure")

    def on_check(self, view: EngineView) -> PlanUpdate | None:
        interval = self.policy.check_period
        if interval is None:
            return None
        now = view.now + self.clock_offset
        self._next_check_global = now + interval
        if self.policy.period is not None:
            return self._maybe_replan(now, "periodic")
        # Degradation probe: estimated iteration time on the degraded
        # incumbent vs the baseline recorded at adoption.
        est = self.estimated_iter_time()
        if est > self.policy.degradation_threshold * self.baseline:
            return self._maybe_replan(now, "degradation")
        self.log.append(ReplanRecord(time=now, trigger="check",
                                     replanned=False, est_before=est))
        return None


# ---------------------------------------------------------------------------
# Multi-tenant controller: the resident workload is a JobSet
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _UrgencyWeightedFairness(FairnessPolicy):
    """Static per-tenant weights scaled by deadline urgency — the engine
    analogue of :meth:`JobSetController._opt_jobset`'s ``weight * urgency``
    replan objective, re-queried each rate recomputation as the clock
    approaches deadlines."""

    time_varying = True

    weights: dict[str, float] = field(default_factory=dict)
    deadline: DeadlineFairness = field(default_factory=DeadlineFairness)

    def weight(self, job: str, now: float) -> float:
        return self.weights.get(job, 1.0) * self.deadline.weight(job, now)


class JobSetController(ReoptController):
    """A :class:`ReoptController` whose resident workload is a whole
    :class:`~repro.core.workloads.JobSet` sharing one fabric.

    Replans re-optimize the *union* demand
    (:func:`~repro.core.alternating.co_optimize_jobset`, warm-started from
    the incumbent shared plan, dead pairs forbidden); probes simulate one
    iteration of every tenant contending under the set's weighted fairness;
    :meth:`admit` places arrivals on the surviving fabric via
    :func:`place_arrival` and :meth:`depart` frees a tenant's servers — both
    are load shifts the policy's arrival/departure triggers may answer with
    a replan.  Tenants admitted without a replan ride the incumbent fabric:
    their AllReduce bytes take a synthetic ring over their placement
    (``iteration_tasks(synth_missing_rings=True)``) until the next replan
    gives them real rings.
    """

    def __init__(
        self,
        jobset: JobSet,
        hw: HardwareSpec | None = None,
        policy: ReoptPolicy | None = None,
        seed: int = 0,
        plan: JobSetPlan | None = None,
        deadline_policy: DeadlineFairness | None = None,
    ):
        self.jobset = jobset
        # Deadline-aware replanning: when set, every replan's objective
        # weights each tenant by ``weight * deadline_policy.weight(label,
        # now)`` so a near-deadline tenant's traffic dominates the union
        # objective, and the engine runs the same policy as its bandwidth
        # fairness.  ``None`` keeps the static weighted objective.
        self.deadline_policy = deadline_policy
        # Candidate JobSets (greedy seed first) a replan should co-search;
        # set by :meth:`admit` around its _maybe_replan call.
        self._pending_candidates: list[JobSet] | None = None
        # Every migration decision rebalance() ever took (adopted or not).
        self.migrations: list[MigrationRecord] = []
        # Arrivals admit() turned away because no live fabric component
        # could host them: (time, label) records, in admission order.
        self.refused: list[tuple[float, str]] = []
        super().__init__(job=None, n=jobset.n, hw=hw, policy=policy,
                         seed=seed, plan=plan)

    # -- plan machinery ------------------------------------------------------

    def _opt_jobset(self, jobset: JobSet, now: float) -> JobSet:
        """The JobSet the optimizer should price: tenant weights scaled by
        deadline urgency at ``now`` (identity without a deadline policy)."""
        if self.deadline_policy is None:
            return jobset
        from dataclasses import replace as _replace

        return JobSet(n=jobset.n, tenants=[
            _replace(
                t,
                weight=t.weight * self.deadline_policy.weight(t.label, now),
            )
            for t in jobset.tenants
        ])

    def _run_optimizer(self, warm: bool) -> JobSetPlan:
        now = self._replan_now
        if not warm:
            return co_optimize_jobset(
                self._opt_jobset(self.jobset, now), self.hw,
                rounds=max(self.policy.rounds, 2),
                mcmc_iters=max(self.policy.mcmc_iters, 40),
                seed=self.seed,
                forbidden=tuple(self.dead),
                compiled=self.policy.compiled,
                objective=self.policy.objective,
                backend=self.policy.backend,
                chains=self.policy.chains,
                schedules=self.policy.schedules,
                temperatures=self.policy.temperatures,
            )
        candidates = None
        if self._pending_candidates is not None:
            candidates = [
                self._opt_jobset(js, now) for js in self._pending_candidates
            ]
        return co_optimize_jobset(
            self._opt_jobset(self.jobset, now), self.hw,
            rounds=self.policy.rounds,
            mcmc_iters=self.policy.mcmc_iters,
            seed=self.seed + 1 + self.n_replans + 997 * self._retry_nonce,
            warm_topology=self.topology,
            warm_strategies=self.strategies(),
            forbidden=tuple(self.dead),
            compiled=self.policy.compiled,
            placement_candidates=candidates,
            screen_candidates=self.policy.screen_candidates,
            objective=self.policy.objective,
            backend=self.policy.backend,
            chains=self.policy.chains,
            schedules=self.policy.schedules,
            temperatures=self.policy.temperatures,
        )

    def _adopt_plan(self, res) -> None:
        super()._adopt_plan(res)
        if self._pending_candidates is not None:
            # Sync the resident set to the winning candidate placement
            # (the *unscaled* JobSet — plan.jobset may carry urgency-scaled
            # weights).
            self.jobset = self._pending_candidates[res.candidate_index]
            self._probe_cache = None

    def _estimate_plan(self, res) -> float:
        if self._pending_candidates is None:
            return super()._estimate_plan(res)
        # Probe under the candidate's placements: the plan's flows live on
        # the candidate servers, not the incumbent greedy ones.
        saved = self.jobset
        self.jobset = self._pending_candidates[res.candidate_index]
        try:
            return self.estimated_iter_time(
                topo=res.topology, strategy=res.strategy
            )
        finally:
            self.jobset = saved

    def _maybe_replan(self, now: float, trigger: str) -> PlanUpdate | None:
        if not self.jobset.tenants:
            return None  # nothing to optimize for (e.g. failure after the
            # last tenant departed); keep the incumbent fabric as-is.
        return super()._maybe_replan(now, trigger)

    def _required_groups(self) -> list[tuple[int, ...]]:
        """Each multi-server tenant's ring must stay connected on the live
        fabric (single-server tenants have no network demand)."""
        return [t.servers for t in self.jobset.tenants if t.k > 1]

    def strategies(self) -> dict[str, Strategy]:
        """Per-tenant strategies of the incumbent plan, with cold defaults
        for tenants admitted after it was computed."""
        planned = dict(self.plan.strategies)
        return {
            t.label: planned.get(t.label) or default_strategy(t.spec)
            for t in self.jobset.tenants
        }

    @property
    def demand(self):
        """Cluster-level union demand of the resident set under the
        incumbent (default-extended) strategies."""
        return self.jobset.union_for(self.strategies())

    # -- probes --------------------------------------------------------------

    def _probe_jobs(self, topo: Topology, strategy) -> list[SimJob]:
        strategies = dict(strategy) if strategy else {}
        for t in self.jobset.tenants:
            strategies.setdefault(t.label, default_strategy(t.spec))
        jobs = []
        for t in self.jobset.tenants:
            dem = remap_demand(
                strategies[t.label].demand(t.spec, t.k), t.servers, self.n
            )
            comp = compute_time(t.flops_per_iteration, t.k, self.hw)
            jobs.append(SimJob(t.label, iteration_tasks(
                topo, dem, compute_duration=comp, synth_missing_rings=True,
            )))
        return jobs

    def _probe_fairness(self) -> FairnessPolicy | None:
        return self.fairness()

    def _probe_metric(self, res) -> float:
        """Weighted mean of per-job one-iteration makespans."""
        total = self.jobset.total_weight
        return sum(
            t.weight * res.job_makespans.get(t.label, 0.0)
            for t in self.jobset.tenants
        ) / total

    def iteration_jobs(self) -> list[SimJob]:
        """One SimJob per resident tenant (flows + compute) for the current
        plan — what :func:`run_online_jobset` feeds the engine each
        iteration."""
        return self._probe_jobs(self.topology, self.strategies())

    def fairness(self) -> FairnessPolicy:
        """The engine-side bandwidth policy: static tenant weights, scaled
        by deadline urgency when a deadline policy is set — the same
        ``weight * urgency`` product the replan objective prices
        (:meth:`_opt_jobset`), so simulated shares and the optimizer's view
        stay consistent."""
        if self.deadline_policy is not None:
            return _UrgencyWeightedFairness(
                weights=self.jobset.weights(), deadline=self.deadline_policy
            )
        return WeightedFairness(self.jobset.weights())

    # -- admission / departure ----------------------------------------------

    def admit(
        self,
        spec: JobSpec,
        k: int,
        weight: float = 1.0,
        name: str | None = None,
        now: float = 0.0,
        candidates: int | None = None,
    ) -> tuple[tuple[int, ...], float] | None:
        """Admit an arriving job: place it on ``k`` free servers, then let
        the arrival trigger replan the shared fabric.  Returns
        ``(servers, pause_seconds)`` — the servers the tenant ends up on —
        or ``None`` when free servers exist but no connected component of
        the live (degraded) fabric can host all ``k`` of them: the job is
        *refused* rather than admitted astride a partition it could never
        AllReduce across.  Refusals are recorded in :attr:`refused` as
        ``(now, label)`` so operators can re-admit after a repair.

        ``candidates`` (default: the policy's ``candidates``) switches the
        admission from greedy-then-replan to **placement co-search**: the
        diverse candidate placements of :func:`place_candidates` are each
        carried through the full replan
        (``co_optimize_jobset(placement_candidates=...)``) and the best
        full plan — placement included — is adopted.  ``candidates=1`` is
        the greedy :func:`place_arrival` path, byte-identical to the
        pre-search behaviour.  When the replan is suppressed (hysteresis,
        adaptive skip, or a policy without the arrival trigger) the tenant
        stays on the greedy seed placement.

        With ``policy.backend="jax"`` and ``policy.temperatures`` set, the
        candidate search runs **fused**: every screened placement
        candidate x the tempering ladder anneals in one device dispatch
        per alternating round
        (:func:`~repro.core.alternating.co_optimize_jobset` with
        ``temperatures=``), with the winner hand-off staying on-device
        between rounds — the wide-admission configuration
        ``benchmarks/bench_admission_jax.py`` gates at >= 3x the
        sequential per-candidate throughput."""
        if k < 1:
            raise ValueError(f"admit needs k >= 1 servers, got {k}")
        n_cand = self.policy.candidates if candidates is None else candidates
        label = name or spec.name
        free = self.jobset.free_servers()
        links = self.links()
        seed_placement = place_arrival(k, free, links, require_hostable=True)
        if seed_placement is None:
            self.refused.append((now, label))
            return None
        if n_cand <= 1:
            placements = [seed_placement]
        else:
            # Hostable seed first (bit-identical to place_candidates[0] on
            # a connected fabric), then the diverse variants it didn't pick.
            placements = [seed_placement] + [
                p for p in place_candidates(k, free, links, n=n_cand)
                if p != seed_placement
            ]
        base = self.jobset
        self.jobset = base.with_tenant(
            TenantJob(spec=spec, servers=placements[0], weight=weight,
                      name=label)
        )
        self._probe_cache = None
        pause = 0.0
        if self.policy.on_arrival:
            if len(placements) > 1:
                self._pending_candidates = [
                    base.with_tenant(TenantJob(
                        spec=spec, servers=p, weight=weight, name=label))
                    for p in placements
                ]
            try:
                update = self._maybe_replan(now, "arrival")
            finally:
                self._pending_candidates = None
            if update is not None:
                pause = update.pause
        if (
            self.policy.rebalance_on_arrival
            and self.policy.max_migrations > 0
            and self.jobset.tenants
        ):
            # Admission-time preemption (bugfix: rebalancing used to fire
            # only on departures): offer the post-admission fabric to every
            # resident — the arrival included — so a high-value newcomer
            # can displace cheap residents when the migration-priced win
            # clears its cost.
            update = self.rebalance(now + pause, reason="arrival")
            if update is not None:
                pause += update.pause
        return self.jobset.tenant(label).servers, pause

    def depart(self, label: str, now: float = 0.0) -> float:
        """A tenant finishes: free its servers; the departure trigger may
        compact the shared fabric, and a policy with ``max_migrations > 0``
        additionally offers the freed capacity to the remaining tenants
        (:meth:`rebalance`).  Returns the pause charged (seconds)."""
        self.jobset = self.jobset.without(label)
        self._probe_cache = None
        pause = 0.0
        if self.policy.on_departure:
            update = self._maybe_replan(now, "departure")
            if update is not None:
                pause += update.pause
        if self.policy.max_migrations > 0 and self.jobset.tenants:
            update = self.rebalance(now + pause, reason="departure")
            if update is not None:
                pause += update.pause
        return pause

    # -- churn-priced tenant migration ---------------------------------------

    def _migration_proposals(
        self, n_cand: int
    ) -> list[tuple[str, tuple[int, ...]]]:
        """Fast screen: per resident tenant, its best candidate placement
        by the weighted objective *on the incumbent topology* (incremental
        :class:`~repro.core.planeval.JobSetEvaluator` pricing with
        synthetic rings for virgin placements — no union rebuild, no
        optimizer run), returned ranked best-first.

        The screen is deliberately a *ranking*, not a gate: a placement the
        incumbent fabric serves badly can still win big once a replan
        rebuilds rings over it, so :meth:`rebalance` full-evaluates the
        ranked proposals in order instead of trusting the screen's absolute
        values."""
        strategies = self.strategies()
        jse = JobSetEvaluator(self.jobset, self.topology, self.hw,
                              synth_missing_rings=True)
        jse.set_strategies(strategies)
        links = self.links()
        free = self.jobset.free_servers()
        ranked: list[tuple[float, str, tuple[int, ...]]] = []
        for t in self.jobset.tenants:
            pool = free | set(t.servers)
            if t.k > len(pool):
                continue
            best: tuple[float, tuple[int, ...]] | None = None
            for servers in place_candidates(t.k, pool, links, n=n_cand):
                if set(servers) == set(t.servers):
                    continue
                obj = jse.objective_at(t.label, strategies[t.label], servers)
                if best is None or obj < best[0]:
                    best = (obj, servers)
            if best is not None:
                ranked.append((best[0], t.label, best[1]))
        ranked.sort(key=lambda r: (r[0], r[1]))
        jse.log_cache_stats("migration-screen")
        return [(label, servers) for _, label, servers in ranked]

    def rebalance(
        self,
        now: float = 0.0,
        reason: str = "departure",
        max_migrations: int | None = None,
        candidates: int | None = None,
    ) -> PlanUpdate | None:
        """Propose migrating up to ``max_migrations`` resident tenants to
        better placements, adopting each move only when its probed win
        clears its price.

        Per migration slot: rank every tenant's best candidate placement
        through the incremental evaluator on the incumbent topology
        (:meth:`_migration_proposals`), then carry the ranked proposals —
        best-screened first — through full warm-started replans on the
        moved JobSet until one is adopted (up to one replan per resident
        tenant: the screen deliberately ranks rather than gates, because
        the incumbent fabric undervalues virgin placements).  Each move is
        priced with :func:`repro.core.costmodel.migration_cost` — the
        policy's ``migration_restart`` floor plus the tenant's
        checkpoint-restore transfer
        (:attr:`~repro.core.workloads.JobSpec.state_bytes`) — plus the
        fiber churn of the topology swap priced exactly like a replan
        (``fiber_move_latency * edge_churn``, or the flat
        ``replan_latency``).  A move is adopted only when the probed
        per-iteration win, amortized over the policy's ``payback_horizon``,
        clears that cost; a slot in which every proposal is rejected backs
        off the adaptive interval (the same hysteresis replans use) and
        ends the pass.

        Returns a migration :class:`~repro.core.simengine.PlanUpdate`
        (fabric + summed pause + per-tenant
        :class:`~repro.core.simengine.MigrationRecord`\\ s) when at least
        one move was adopted, else ``None``.  Every decision — adopted or
        rejected — is appended to ``self.migrations``."""
        limit = (
            self.policy.max_migrations
            if max_migrations is None else max_migrations
        )
        if limit <= 0 or not self.jobset.tenants:
            return None
        # Only an active *adaptive backoff* suppresses rebalancing: a plain
        # min_interval must not swallow the rebalance that depart() chains
        # right after its own replan (which just stamped last_replan).  A
        # backed-off interval, by contrast, is evidence that recent fabric
        # changes did not pay for themselves.
        if (
            self.policy.adaptive
            and self._adaptive_interval > self.policy.min_interval
            and now - self.last_replan < self._adaptive_interval
        ):
            return None
        self._replan_now = now
        self.ensure_plan()
        n_cand = (
            candidates if candidates is not None
            else max(2, self.policy.candidates)
        )
        adopted: list[MigrationRecord] = []
        total_pause = 0.0
        total_churn = 0
        for _ in range(limit):
            proposals = self._migration_proposals(n_cand)
            if not proposals:
                break
            slot_adopted = False
            for label, servers in proposals:
                tenant = self.jobset.tenant(label)
                est_before = self.estimated_iter_time()
                trial = self.jobset.with_placement(label, servers)
                plan = co_optimize_jobset(
                    self._opt_jobset(trial, now), self.hw,
                    rounds=self.policy.rounds,
                    mcmc_iters=self.policy.mcmc_iters,
                    seed=self.seed + 1 + self.n_replans,
                    warm_topology=self.topology,
                    warm_strategies=self.strategies(),
                    forbidden=tuple(self.dead),
                    compiled=self.policy.compiled,
                    objective=self.policy.objective,
                    backend=self.policy.backend,
                    chains=self.policy.chains,
                    schedules=self.policy.schedules,
                    temperatures=self.policy.temperatures,
                )
                saved = self.jobset
                self.jobset = trial
                try:
                    est_after = self.estimated_iter_time(
                        topo=plan.topology, strategy=plan.strategies
                    )
                finally:
                    self.jobset = saved
                churn = edge_churn(self.topology, plan.topology)
                cost = migration_cost(
                    tenant.spec.state_bytes, edges_moved=0,
                    restart_s=self.policy.migration_restart,
                ) + self._replan_pause(churn)
                win = (est_before - est_after) * self.policy.payback_horizon
                if not np.isfinite(est_before):
                    win = np.inf if np.isfinite(est_after) else 0.0
                record = MigrationRecord(
                    time=now, tenant=label, src=tenant.servers, dst=servers,
                    est_before=est_before, est_after=est_after, cost=cost,
                    edges_moved=churn,
                    adopted=bool(est_after <= est_before and win >= cost),
                    reason=reason,
                )
                self.migrations.append(record)
                if not record.adopted:
                    continue
                self.jobset = trial
                self._adopt_plan(plan)
                self._baseline = est_after
                self._probe_cache = None
                self._adaptive_interval = self.policy.min_interval
                self.n_replans += 1
                self.total_edges_moved += churn
                self.last_replan = now
                # Keep the log/counter correspondence every replan path
                # maintains: one replanned record per n_replans bump.
                self.log.append(ReplanRecord(
                    time=now, trigger=f"rebalance:{reason}", replanned=True,
                    est_before=est_before, est_after=est_after,
                    edges_moved=churn,
                ))
                adopted.append(record)
                total_pause += cost
                total_churn += churn
                slot_adopted = True
                break
            if not slot_adopted:
                # Same backoff the adaptive replan gate uses: hopeless
                # rebalancing stops burning optimizer runs until the next
                # adopted change resets the interval.
                if self.policy.adaptive:
                    self._adaptive_interval = max(
                        2 * self._adaptive_interval,
                        self.policy.min_interval,
                    )
                break
        if not adopted:
            return None
        self.last_pause = total_pause
        update = PlanUpdate(
            links=self.links(),
            pause=total_pause,
            label=f"rebalance:{reason}",
            edges_moved=total_churn,
            migrations=tuple(adopted),
        )
        return update

    def set_job(self, job: JobSpec, now: float = 0.0) -> float:
        raise TypeError(
            "JobSetController has no single resident job; use admit/depart"
        )


# ---------------------------------------------------------------------------
# Iteration-granularity driver: static plan vs reactive replanning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceEvent:
    """One disruption in an online trace.

    ``kind="fail"``: the fiber pair ``link`` dies when iteration
    ``iteration`` starts (``frac=0``) or ``frac`` of the way through it.
    ``kind="repair"``: a previously failed ``link`` comes back at that
    iteration boundary (transient fault healed; the controller restores the
    fiber and may replan).
    ``kind="load"``: the resident job's spec becomes ``job`` (a load shift —
    bigger batch, more tables, a different model) at that iteration boundary.

    Multi-tenant traces (:func:`run_online_jobset`) additionally use
    ``kind="arrive"`` — job ``job`` joins on ``k`` servers with fairness
    ``weight`` under label ``name`` (placed by :func:`place_arrival`) — and
    ``kind="depart"`` — tenant ``name`` finishes and frees its servers.

    Unknown kinds raise :class:`ValueError` at construction — the drivers
    dispatch on ``kind``, and a typo'd kind used to be skipped silently.
    """

    KINDS = frozenset({"fail", "repair", "load", "arrive", "depart"})

    iteration: int
    kind: str  # "fail" | "repair" | "load" | "arrive" | "depart"
    link: tuple[int, int] | None = None
    frac: float = 0.0
    job: JobSpec | None = None
    k: int = 0
    weight: float = 1.0
    name: str | None = None

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(
                f"unknown TraceEvent kind {self.kind!r}; expected one of "
                f"{sorted(self.KINDS)}"
            )
        if self.kind in ("fail", "repair") and self.link is None:
            raise ValueError(
                f"TraceEvent(kind={self.kind!r}) requires a link"
            )


@dataclass
class OnlineRunResult:
    total_time: float
    iter_times: list[float] = field(default_factory=list)
    n_replans: int = 0
    n_failures: int = 0
    edges_moved: int = 0
    log: list[ReplanRecord] = field(default_factory=list)
    final_plan: CoOptResult | None = None


def run_online(
    job: JobSpec,
    n: int,
    hw: HardwareSpec | None = None,
    policy: ReoptPolicy | None = None,
    trace: tuple[TraceEvent, ...] = (),
    n_iters: int = 8,
    seed: int = 0,
    plan: CoOptResult | None = None,
    engine: SimEngine | None = None,
) -> OnlineRunResult:
    """Simulate ``n_iters`` training iterations under a disruption trace.

    Every iteration's flow graph is regenerated from the controller's
    *current* plan (so a replan changes the traffic of all later iterations,
    not just the routes of in-flight flows), then run through
    :meth:`SimEngine.run` with the controller attached as observer:
    mid-iteration failures hit the engine's failure event, the controller
    replans, and the engine swaps the fabric under the surviving flows.

    Pass ``policy=ReoptPolicy.never()`` for the static baseline — the same
    trace, but failures only get the paper's §7 repair — and share ``plan``
    between the two calls so both start from the identical offline optimum.
    """
    hw = hw or HardwareSpec()
    ctrl = ReoptController(job, n, hw=hw, policy=policy, seed=seed, plan=plan)
    ctrl.ensure_plan()
    if ctrl.policy.degradation_threshold is not None:
        ctrl.baseline  # pin the healthy-fabric baseline before disruptions
    # One SimJob per iteration: its admission is not a load shift.  Genuine
    # load shifts arrive through TraceEvent(kind="load") -> set_job below.
    ctrl.suppress_job_hooks = True
    eng = engine or SimEngine(hw)

    by_iter: dict[int, list[TraceEvent]] = {}
    for ev in trace:
        by_iter.setdefault(ev.iteration, []).append(ev)

    total = 0.0
    result = OnlineRunResult(total_time=0.0)
    for it in range(n_iters):
        mid_iter: list[TraceEvent] = []
        for ev in by_iter.get(it, ()):
            if ev.kind == "load" and ev.job is not None:
                total += ctrl.set_job(ev.job, now=total)
            elif ev.kind == "repair" and ev.link is not None:
                total += ctrl.repair(ev.link, now=total)
            elif ev.kind == "fail" and ev.link is not None:
                if ev.frac <= 0.0:
                    total += ctrl.fail(ev.link, now=total)
                    result.n_failures += 1
                else:
                    mid_iter.append(ev)

        cur_job = ctrl.job
        comp = compute_time(
            cur_job.flops_per_sample * cur_job.batch_per_gpu * n, n, hw
        )
        tasks = iteration_tasks(ctrl.topology, ctrl.demand,
                                compute_duration=comp)
        failures = []
        if mid_iter:  # probe only when a failure needs an in-iteration time
            est = ctrl.estimated_iter_time()
            if not np.isfinite(est):
                # Disconnected fabric: the iteration stall-finishes at t=0,
                # so land mid-iteration failures at the start.
                est = result.iter_times[-1] if result.iter_times else 0.0
            est = max(est, 1e-12)
            for ev in mid_iter:
                failures.append(LinkFailure(time=ev.frac * est, link=ev.link))
                result.n_failures += 1
        sc = Scenario(
            links=ctrl.links(),
            jobs=[SimJob(cur_job.name, tasks)],
            failures=tuple(sorted(failures, key=lambda f: f.time)),
            n=n,
        )
        ctrl.clock_offset = total  # hooks see the global training clock
        res = eng.run(sc, observer=ctrl)
        iter_time = res.makespan
        if res.replan_times:
            # A replan near the end of the iteration can leave part of its
            # pause hanging past the last task finish; charge the overhang
            # so reactive policies don't get the tail of the pause free.
            overhang = res.replan_times[-1] + ctrl.last_pause - res.makespan
            if overhang > 0:
                iter_time += overhang
        total += iter_time
        result.iter_times.append(iter_time)

    result.total_time = total
    result.n_replans = ctrl.n_replans
    result.edges_moved = ctrl.total_edges_moved
    result.log = ctrl.log
    result.final_plan = ctrl.plan
    return result


# ---------------------------------------------------------------------------
# Multi-tenant driver: a churn trace against a shared fabric
# ---------------------------------------------------------------------------


@dataclass
class JobSetRunResult:
    total_time: float
    iter_times: list[float] = field(default_factory=list)
    # Tenant -> sum of its per-iteration makespans while resident.
    job_times: dict[str, float] = field(default_factory=dict)
    n_replans: int = 0
    n_failures: int = 0
    edges_moved: int = 0
    log: list[ReplanRecord] = field(default_factory=list)
    # Every rebalance decision (adopted or rejected), in decision order.
    migrations: list[MigrationRecord] = field(default_factory=list)
    # Labels of arrivals the controller refused (no live fabric component
    # could host them), in admission order.
    refused: list[str] = field(default_factory=list)
    final_plan: JobSetPlan | None = None
    final_jobset: JobSet | None = None

    @property
    def n_migrations(self) -> int:
        return sum(1 for m in self.migrations if m.adopted)


def run_online_jobset(
    jobset: JobSet,
    hw: HardwareSpec | None = None,
    policy: ReoptPolicy | None = None,
    trace: tuple[TraceEvent, ...] = (),
    n_iters: int = 8,
    seed: int = 0,
    plan: JobSetPlan | None = None,
    engine: SimEngine | None = None,
) -> JobSetRunResult:
    """Simulate ``n_iters`` training iterations of a *shared* cluster under
    a churn trace: jobs arriving (placed via :func:`place_arrival`) and
    departing, fibers dying at or inside iteration boundaries.

    Each iteration regenerates one SimJob per resident tenant from the
    controller's current shared plan and runs them through
    :meth:`SimEngine.run` contending under the set's weighted fairness, with
    the :class:`JobSetController` attached as observer.  Pass
    ``policy=ReoptPolicy.never()`` for the static shared baseline and share
    ``plan`` so both operators start from the same offline optimum.

    Placement knobs ride the policy: ``candidates > 1`` co-searches each
    arrival's placement through the replan, and ``max_migrations > 0``
    lets departures trigger churn-priced rebalancing
    (:meth:`JobSetController.rebalance`) — every migration decision lands
    in ``JobSetRunResult.migrations``.
    """
    hw = hw or HardwareSpec()
    ctrl = JobSetController(jobset, hw=hw, policy=policy, seed=seed, plan=plan)
    ctrl.ensure_plan()
    if ctrl.policy.degradation_threshold is not None:
        ctrl.baseline  # pin the healthy-fabric baseline before disruptions
    ctrl.suppress_job_hooks = True
    eng = engine or SimEngine(hw)

    by_iter: dict[int, list[TraceEvent]] = {}
    for ev in trace:
        by_iter.setdefault(ev.iteration, []).append(ev)

    total = 0.0
    result = JobSetRunResult(total_time=0.0)
    for it in range(n_iters):
        mid_iter: list[TraceEvent] = []
        for ev in by_iter.get(it, ()):
            if ev.kind == "arrive" and ev.job is not None:
                admitted = ctrl.admit(
                    ev.job, ev.k, weight=ev.weight, name=ev.name, now=total,
                )
                if admitted is not None:
                    total += admitted[1]
            elif ev.kind == "depart" and ev.name:
                total += ctrl.depart(ev.name, now=total)
            elif ev.kind == "repair" and ev.link is not None:
                total += ctrl.repair(ev.link, now=total)
            elif ev.kind == "fail" and ev.link is not None:
                if ev.frac <= 0.0:
                    total += ctrl.fail(ev.link, now=total)
                    result.n_failures += 1
                else:
                    mid_iter.append(ev)

        if not ctrl.jobset.tenants:
            # No resident work: the iteration is instantaneous, but queued
            # mid-iteration failures still land on the fabric.
            for ev in mid_iter:
                total += ctrl.fail(ev.link, now=total)
                result.n_failures += 1
            result.iter_times.append(0.0)
            continue
        jobs = ctrl.iteration_jobs()
        failures = []
        if mid_iter:
            est = ctrl.estimated_iter_time()
            if not np.isfinite(est):
                est = result.iter_times[-1] if result.iter_times else 0.0
            est = max(est, 1e-12)
            for ev in mid_iter:
                failures.append(LinkFailure(time=ev.frac * est, link=ev.link))
                result.n_failures += 1
        sc = Scenario(
            links=ctrl.links(),
            jobs=jobs,
            failures=tuple(sorted(failures, key=lambda f: f.time)),
            n=jobset.n,
            fairness=ctrl.fairness(),
        )
        ctrl.clock_offset = total
        res = eng.run(sc, observer=ctrl)
        iter_time = res.makespan
        if res.replan_times:
            overhang = res.replan_times[-1] + ctrl.last_pause - res.makespan
            if overhang > 0:
                iter_time += overhang
        total += iter_time
        result.iter_times.append(iter_time)
        for name, ms in res.job_makespans.items():
            result.job_times[name] = result.job_times.get(name, 0.0) + ms

    result.total_time = total
    result.n_replans = ctrl.n_replans
    result.edges_moved = ctrl.total_edges_moved
    result.log = ctrl.log
    result.migrations = list(ctrl.migrations)
    result.refused = [label for _, label in ctrl.refused]
    result.final_plan = ctrl.plan
    result.final_jobset = ctrl.jobset
    return result


# ---------------------------------------------------------------------------
# Topology-aware placement of arriving jobs
# ---------------------------------------------------------------------------


def _free_capacity_matrix(
    free: set[int] | frozenset[int],
    links: dict[tuple[int, int], float],
) -> tuple[np.ndarray, np.ndarray, list[list[int]]]:
    """(sorted free server ids, symmetric free-to-free capacity matrix,
    per-row neighbor first-touch order).

    ``A[i, j]`` sums both directions of every live link between free
    servers ``i`` and ``j`` — the adjacency the greedy packer and every
    candidate generator scan, built once per call instead of rebuilding a
    nested dict per step.  ``touch_order[i]`` lists ``i``'s neighbor
    columns in the order they first appeared in ``links`` — the dict
    reference summed each server's capacities in exactly that order, and
    float addition is order-sensitive at the last ulp, so bit-identical
    tie-breaking must replay it."""
    ids = np.asarray(sorted(free), dtype=np.int64)
    index = {int(v): i for i, v in enumerate(ids)}
    m = ids.size
    a_mat = np.zeros((m, m), dtype=np.float64)
    touch_order: list[list[int]] = [[] for _ in range(m)]
    for (a, b), c in links.items():
        ia = index.get(a)
        ib = index.get(b)
        if ia is not None and ib is not None and c > 0:
            if a_mat[ia, ib] == 0.0:
                touch_order[ia].append(ib)
            if a_mat[ib, ia] == 0.0:
                touch_order[ib].append(ia)
            a_mat[ia, ib] += c
            a_mat[ib, ia] += c
    return ids, a_mat, touch_order


def _greedy_pack(
    ids: np.ndarray,
    a_mat: np.ndarray,
    k: int,
    allowed: np.ndarray,
    touch_order: list[list[int]],
) -> tuple[int, ...]:
    """Greedy capacity packing over the ``allowed`` subset of a prebuilt
    free-capacity matrix (the :func:`place_arrival` algorithm body).

    Total capacities are summed per row in ``touch_order`` — the dict
    reference's neighbor insertion order — because float addition is
    order-sensitive at the last ulp and a last-ulp difference can flip a
    tie-break.  Restricting to a subset reproduces a fresh build over that
    subset bit for bit: the reduced build's insertion order is the same
    subsequence of ``links``, and the capacity-toward-chosen vector
    accumulates one column per pick exactly like the reference's
    chosen-order walk (its zero addends for non-neighbors cannot change a
    float sum)."""
    sub = np.flatnonzero(allowed)
    sub_ids = ids[sub]
    sub_mat = a_mat[np.ix_(sub, sub)]
    total = np.zeros(sub.size, dtype=np.float64)
    for si, i in enumerate(sub):
        acc = 0.0
        for j in touch_order[i]:
            if allowed[j]:
                acc += a_mat[i, j]
        total[si] = acc
    # np.lexsort is stable ascending, last key primary; ids ascending break
    # full ties toward the lowest id exactly like the dict reference.
    seed = int(np.lexsort((sub_ids, -total))[0])
    chosen_mask = np.zeros(sub.size, dtype=bool)
    chosen_mask[seed] = True
    cap_chosen = sub_mat[:, seed].copy()
    for _ in range(k - 1):
        pool = np.flatnonzero(~chosen_mask)
        order = np.lexsort(
            (sub_ids[pool], -total[pool], -cap_chosen[pool])
        )
        nxt = int(pool[order[0]])
        chosen_mask[nxt] = True
        cap_chosen += sub_mat[:, nxt]
    return tuple(int(v) for v in sub_ids[chosen_mask])


def _live_components(
    free_ids: np.ndarray, links: dict[tuple[int, int], float]
) -> np.ndarray:
    """Component label per free server under the live fabric's *undirected*
    connectivity (positive-capacity links; paths may transit busy servers).
    Free servers with no live fiber at all become singleton components."""
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for (a, b), c in links.items():
        if c > 0:
            ra, rb = find(int(a)), find(int(b))
            if ra != rb:
                parent[rb] = ra
    return np.asarray([find(int(v)) for v in free_ids], dtype=np.int64)


def place_arrival(
    k: int,
    free: set[int] | frozenset[int],
    links: dict[tuple[int, int], float],
    require_hostable: bool = False,
) -> tuple[int, ...] | None:
    """Pick ``k`` free servers for a newly arriving job, topology-aware.

    Greedy capacity packing: seed with the free server carrying the most
    surviving capacity toward other free servers, then repeatedly add the
    free server with the highest live capacity toward the chosen set.  On a
    degraded fabric this steers new jobs away from servers whose fibers died;
    on a healthy one it reduces fabric fragmentation versus lowest-id
    first-fit.  Falls back to lowest ids to break ties deterministically.

    ``require_hostable=True`` additionally demands that the ``k`` servers
    share one connected component of the live fabric (a job split across a
    partition can never finish an AllReduce).  When the plain greedy pick
    straddles a partition, the pack is retried inside the component holding
    the most free servers (ties toward the one with the smallest id);
    returns ``None`` when *no* live component has ``k`` free servers — the
    degraded-fabric signal :meth:`JobSetController.admit` turns into a
    refused admission.  On a connected fabric the flag is a no-op and the
    result is bit-identical to the default path.

    Vectorized: one symmetric NumPy adjacency over the free servers
    replaces the per-step dict scans; each selection is a stable
    lexicographic argmax on (cap to chosen, total cap, id), bit-identical
    to the dict reference (see :func:`_greedy_pack`).
    """
    free = set(free)
    if k > len(free):
        raise ValueError(f"need {k} servers, only {len(free)} free")
    if k == 0:
        return ()
    ids, a_mat, touch = _free_capacity_matrix(free, links)
    chosen = _greedy_pack(ids, a_mat, k, np.ones(ids.size, dtype=bool), touch)
    if not require_hostable or k == 1:
        return chosen  # a single-server tenant has no network demand
    comp = _live_components(ids, links)
    label_of = dict(zip(ids.tolist(), comp.tolist()))
    if len({label_of[v] for v in chosen}) == 1:
        return chosen  # greedy pick already lives inside one component
    # The fabric is partitioned under the free pool: retry inside the
    # component with the most free servers (ties -> smallest server id).
    best_label: int | None = None
    best_key: tuple[int, int] | None = None
    for label in dict.fromkeys(comp.tolist()):
        mask = comp == label
        size = int(mask.sum())
        if size < k:
            continue
        key = (-size, int(ids[mask][0]))
        if best_key is None or key < best_key:
            best_key, best_label = key, label
    if best_label is None:
        return None
    return _greedy_pack(ids, a_mat, k, comp == best_label, touch)


def place_candidates(
    k: int,
    free: set[int] | frozenset[int],
    links: dict[tuple[int, int], float],
    n: int = 4,
) -> list[tuple[int, ...]]:
    """Diverse candidate placements for a ``k``-server job — the input of
    the placement co-search (``co_optimize_jobset(placement_candidates=)``).

    Always seeds with the greedy capacity packing (:func:`place_arrival`)
    so candidate 0 *is* today's placement; then adds deterministic
    variants, deduplicated in order:

    * **contiguous** — the ``k`` consecutive free ids with the smallest id
      span (dense blocks keep short ring strides constructible);
    * **spread** — every ``len(free)/k``-th free server by id (leaves the
      largest contiguous holes for future arrivals);
    * **anti-affinity** — the ``k`` free servers with the *least* live
      capacity toward occupied servers (stays out of resident tenants'
      fabric neighborhoods);
    * further greedy packs with the previous seeds' top-connected server
      excluded, until ``n`` distinct candidates exist or variants repeat.

    Returns at most ``n`` distinct placements, greedy first.
    """
    free = set(free)
    if k > len(free):
        raise ValueError(f"need {k} servers, only {len(free)} free")
    if k == 0:
        return [()]
    out: list[tuple[int, ...]] = []

    def _add(p: tuple[int, ...]) -> None:
        if len(p) == k and p not in out:
            out.append(p)

    # One adjacency build serves the greedy seed, the hot-server ranking,
    # and every exclusion variant below.
    ids, a_mat, touch = _free_capacity_matrix(free, links)
    all_allowed = np.ones(ids.size, dtype=bool)
    _add(_greedy_pack(ids, a_mat, k, all_allowed, touch))
    if n <= 1:
        return out[:n]

    ordered = sorted(free)
    # Contiguous: k-window of sorted free ids minimizing the id span.
    spans = [
        (ordered[i + k - 1] - ordered[i], ordered[i], i)
        for i in range(len(ordered) - k + 1)
    ]
    _, _, i0 = min(spans)
    _add(tuple(ordered[i0:i0 + k]))
    # Spread: every ~len/k-th free id (stride >= 1, indices distinct).
    stride = len(ordered) / k
    _add(tuple(ordered[int(i * stride)] for i in range(k)))
    # Anti-affinity: least live capacity toward busy (non-free) servers.
    busy_cap = {v: 0.0 for v in ordered}
    for (a, b), c in links.items():
        if c <= 0:
            continue
        if a in busy_cap and b not in busy_cap:
            busy_cap[a] += c
        elif b in busy_cap and a not in busy_cap:
            busy_cap[b] += c
    _add(tuple(sorted(
        sorted(ordered, key=lambda v: (busy_cap[v], v))[:k]
    )))
    # Extra diversity: greedy packs avoiding the best-connected servers.
    by_total = np.lexsort((ids, -a_mat.sum(axis=1)))
    allowed = all_allowed.copy()
    for hot in by_total:
        if len(out) >= n:
            break
        allowed[hot] = False
        if k > int(allowed.sum()):
            break
        _add(_greedy_pack(ids, a_mat, k, allowed, touch))
    return out[:n]
