"""Online re-optimization: dynamic TopoOpt reacting to failures and load
shifts (ROADMAP "online re-optimization" + "topology-aware job placement").

The offline pipeline (:func:`repro.core.alternating.alternating_optimize`)
computes one (strategy, topology, routing) plan and assumes the cluster never
changes.  :class:`repro.core.simengine.SimEngine` already models the events
that make such a plan stale — fiber failures, job arrivals/departures,
stragglers — so this module closes the loop:

* :class:`ReoptPolicy` — *when* to re-optimize: on failure, on job
  arrival/departure (load shifts), periodically, or when a degradation probe
  sees the estimated iteration time exceed a tracked baseline, all gated by a
  hysteresis ``min_interval``.
* :class:`ReoptController` — *how*: a
  :class:`~repro.core.simengine.ScenarioObserver` that pauses the fluid
  simulation (an OCS-style ``replan_latency`` stall), re-runs the alternating
  optimizer **warm-started from the incumbent plan** against the surviving
  fiber pairs and resident job, and resumes in-flight flows on the new
  topology/routes via a :class:`~repro.core.simengine.PlanUpdate`.  When no
  replan triggers it still maintains the paper's §7 quick fix
  (:func:`~repro.core.topology_finder.repair_topology`) as the static
  operator's incumbent.
* :func:`run_online` — an iteration-granularity driver: each training
  iteration's flows are regenerated from the *current* plan, a
  failure/load-shift trace is injected (at iteration boundaries or
  mid-iteration through the engine's failure events), and the policy decides
  between static repair and reactive replanning.  ``benchmarks/bench_online.py``
  compares the two.
* :func:`place_arrival` — topology-aware placement of newly arriving jobs:
  pick the free servers with the most surviving pairwise capacity instead of
  the lowest ids.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .alternating import CoOptResult, alternating_optimize
from .netsim import HardwareSpec, compute_time
from .ocs_reconfig import RECONFIG_LATENCY
from .simengine import (
    EngineView,
    LinkFailure,
    PlanUpdate,
    Scenario,
    ScenarioObserver,
    SimEngine,
    SimJob,
    iteration_tasks,
    links_from_topology,
)
from .strategy_search import Strategy
from .topology_finder import Topology, remove_pair
from .workloads import JobSpec

__all__ = [
    "ReoptPolicy",
    "ReoptController",
    "TraceEvent",
    "OnlineRunResult",
    "run_online",
    "place_arrival",
]


@dataclass(frozen=True)
class ReoptPolicy:
    """Trigger rules for online re-optimization.

    Any combination of triggers may be enabled:

    * ``on_failure`` — replan when a fiber pair dies.
    * ``on_arrival`` / ``on_departure`` — replan on load shifts (a job
      joining or leaving the fabric, or :func:`run_online` swapping the
      resident job's spec).
    * ``period`` — unconditional periodic replanning every ``period`` s.
    * ``degradation_threshold`` + ``check_interval`` — every
      ``check_interval`` s, estimate the incumbent's fluid iteration time on
      the (repaired) surviving fabric; replan when it exceeds
      ``degradation_threshold`` x the baseline recorded at plan adoption.

    ``min_interval`` is hysteresis: replans closer than this to the previous
    one are suppressed (failed triggers leave the static repair in place).
    Every applied replan charges ``replan_latency`` seconds of OCS-style
    traffic pause.
    """

    on_failure: bool = True
    on_arrival: bool = False
    on_departure: bool = False
    period: float | None = None
    check_interval: float | None = None
    degradation_threshold: float | None = None
    min_interval: float = 0.0
    replan_latency: float = RECONFIG_LATENCY
    # Warm-started optimizer budget per replan (smaller than offline: the
    # incumbent is already good, we only adapt it).
    rounds: int = 2
    mcmc_iters: int = 40

    @classmethod
    def never(cls) -> "ReoptPolicy":
        """Static plan: no trigger ever fires (PR-1 engine semantics)."""
        return cls(on_failure=False, replan_latency=0.0)

    @classmethod
    def reactive(cls, min_interval: float = 0.0, **kw) -> "ReoptPolicy":
        """Replan on every failure and load shift (subject to hysteresis)."""
        return cls(on_failure=True, on_arrival=True, on_departure=True,
                   min_interval=min_interval, **kw)

    @classmethod
    def periodic(cls, period: float, **kw) -> "ReoptPolicy":
        return cls(on_failure=False, period=period, **kw)

    @classmethod
    def degradation(
        cls, threshold: float, check_interval: float, **kw
    ) -> "ReoptPolicy":
        return cls(on_failure=False, degradation_threshold=threshold,
                   check_interval=check_interval, **kw)

    @property
    def check_period(self) -> float | None:
        """Interval between observer checks, if any trigger needs them."""
        if self.period is not None:
            return self.period
        if (
            self.check_interval is not None
            and self.degradation_threshold is not None
        ):
            return self.check_interval
        return None


@dataclass
class ReplanRecord:
    """One controller decision, for logs and benchmarks."""

    time: float
    trigger: str  # "failure" | "arrival" | "departure" | "periodic" | ...
    replanned: bool
    est_before: float = float("nan")  # incumbent (repaired) iteration time
    est_after: float = float("nan")  # adopted plan's iteration time


class ReoptController(ScenarioObserver):
    """Couples :func:`alternating_optimize` into a running scenario.

    The controller tracks three things across events:

    * ``dead`` — fiber pairs that failed so far; every replanned topology is
      searched with these pairs ``forbidden``.
    * the **incumbent plan** (``plan``/``topology``/``demand``) — after a
      failure with no replan trigger, the incumbent topology is degraded in
      place (:func:`~repro.core.topology_finder.remove_pair`: dead pair
      gone, routes re-pathed over the survivors) — the plan a static
      operator keeps running; after a replan it is the freshly optimized
      plan, warm-started from the old one.
    * ``baseline`` — the one-iteration simulated makespan recorded when the
      incumbent was adopted, against which the degradation trigger compares.

    As a :class:`ScenarioObserver` it turns replans into
    :class:`PlanUpdate`s: new fabric links + a ``replan_latency`` pause, so
    in-flight flows resume (bytes preserved) on the new topology mid-run.
    A controller whose policy never triggers returns ``None`` from every
    hook, leaving the engine bit-identical to an observer-less run.
    """

    def __init__(
        self,
        job: JobSpec,
        n: int,
        hw: HardwareSpec | None = None,
        policy: ReoptPolicy | None = None,
        seed: int = 0,
        plan: CoOptResult | None = None,
    ):
        self.job = job
        self.n = n
        self.hw = hw or HardwareSpec()
        self.policy = policy or ReoptPolicy()
        self.seed = seed
        self.dead: set[tuple[int, int]] = set()
        self.n_replans = 0
        self.last_replan = -np.inf
        self.log: list[ReplanRecord] = []
        self._plan: CoOptResult | None = plan
        self._topology: Topology | None = plan.topology if plan else None
        self._baseline: float | None = None
        self._probe_engine: SimEngine | None = None
        # Hook clock = engine-local time + clock_offset.  Drivers that run a
        # sequence of scenarios (run_online: one per training iteration) set
        # the offset so hysteresis spans scenario boundaries.
        self.clock_offset = 0.0
        # run_online admits one SimJob per iteration; those admissions are
        # not load shifts, so the driver mutes the arrival/departure hooks
        # and feeds genuine load shifts through set_job instead.
        self.suppress_job_hooks = False
        interval = self.policy.check_period
        # Global-clock time of the next periodic/degradation check.
        self._next_check_global = interval if interval is not None else np.inf

    # -- incumbent plan ------------------------------------------------------

    def ensure_plan(self) -> CoOptResult:
        """Cold-start the offline optimizer once, lazily (a controller whose
        policy never fires should cost nothing)."""
        if self._plan is None:
            self._plan = alternating_optimize(
                self.job, self.n, self.hw,
                rounds=max(self.policy.rounds, 2),
                mcmc_iters=max(self.policy.mcmc_iters, 40),
                seed=self.seed,
                forbidden=tuple(self.dead),
            )
            self._topology = self._plan.topology
        return self._plan

    @property
    def plan(self) -> CoOptResult:
        return self.ensure_plan()

    @property
    def topology(self) -> Topology:
        """The live physical plan: replanned, or incumbent + §7 repairs."""
        self.ensure_plan()
        assert self._topology is not None
        return self._topology

    @property
    def strategy(self) -> Strategy:
        return self.plan.strategy

    @property
    def demand(self):
        return self.strategy.demand(self.job, self.n)

    @property
    def baseline(self) -> float:
        """Iteration-time estimate the degradation trigger compares against.

        Established on first access (and re-pinned by every replan) — read it
        once while the fabric is still healthy when using the degradation
        trigger; :func:`run_online` does this before applying any trace."""
        if self._baseline is None:
            self.ensure_plan()
            self._baseline = self.estimated_iter_time()
        return self._baseline

    def links(self) -> dict[tuple[int, int], float]:
        """Directed link capacities of the current topology on the surviving
        fabric (dead pairs carry nothing, whatever the plan says)."""
        return self._links_for(self.topology)

    def _links_for(self, topo: Topology) -> dict[tuple[int, int], float]:
        caps = links_from_topology(topo, self.hw)
        for a, b in list(caps):
            if (min(a, b), max(a, b)) in self.dead:
                del caps[(a, b)]
        return caps

    def estimated_iter_time(
        self,
        topo: Topology | None = None,
        strategy: Strategy | None = None,
    ) -> float:
        """One-iteration simulated makespan of ``strategy`` on ``topo``
        restricted to the surviving fabric (defaults: the incumbent).

        A flow-level probe rather than the fluid formula: the fluid model
        charges AllReduce rings by the *planned* ring edges, so it cannot see
        a dead ring link; the scenario engine re-routes those flows over the
        survivors and prices the resulting contention."""
        topo = topo if topo is not None else self.topology
        strategy = strategy if strategy is not None else self.strategy
        demand = strategy.demand(self.job, self.n)
        comp = compute_time(
            self.job.flops_per_sample * self.job.batch_per_gpu * self.n,
            self.n, self.hw,
        )
        tasks = iteration_tasks(topo, demand, compute_duration=comp)
        if self._probe_engine is None:
            self._probe_engine = SimEngine(self.hw)
        sc = Scenario(
            links=self._links_for(topo),
            jobs=[SimJob("probe", tasks)],
            n=self.n,
        )
        res = self._probe_engine.run(sc)
        if res.stalled:
            # Unroutable demand stall-finishes instantly in the engine; a
            # disconnected fabric must probe as unusable, not as fast.
            return np.inf
        return res.makespan

    # -- mutations -----------------------------------------------------------

    def set_job(self, job: JobSpec, now: float = 0.0) -> float:
        """Load shift: the resident job's spec changes (new batch size, new
        tables, a different model).  Returns the pause charged (seconds) if
        the arrival trigger replanned."""
        self.job = job
        if self.policy.on_arrival:
            update = self._maybe_replan(now, "arrival")
            if update is not None:
                return update.pause
        return 0.0

    def fail(self, link: tuple[int, int], now: float = 0.0) -> float:
        """A node pair dies.  Always records the pair and degrades the
        incumbent (routes re-pathed over survivors); replans when the policy
        says so.  Returns the pause charged (seconds)."""
        pair = (min(link), max(link))
        if pair in self.dead:
            return 0.0
        self.dead.add(pair)
        if self._topology is not None:
            self._topology = remove_pair(self._topology, pair)
        if self.policy.on_failure:
            update = self._maybe_replan(now, "failure")
            if update is not None:
                return update.pause
        return 0.0

    def replan(self, now: float, trigger: str) -> PlanUpdate:
        """Re-run the alternating optimizer warm-started from the incumbent,
        forbidding dead pairs; adopt whichever of {new plan, degraded
        incumbent} probes faster.  Returns the PlanUpdate to apply."""
        self.ensure_plan()
        est_before = self.estimated_iter_time()
        res = alternating_optimize(
            self.job, self.n, self.hw,
            rounds=self.policy.rounds,
            mcmc_iters=self.policy.mcmc_iters,
            seed=self.seed + 1 + self.n_replans,
            warm_topology=self.topology,
            warm_strategy=self.strategy,
            forbidden=tuple(self.dead),
        )
        est_new = self.estimated_iter_time(
            topo=res.topology, strategy=res.strategy
        )
        if est_new <= est_before:
            self._plan = res
            self._topology = res.topology
            self._baseline = est_new
        else:
            # The warm search couldn't beat the degraded incumbent — keep it
            # (still counts as a replan: the pause was spent deciding) and
            # re-baseline so the degradation trigger doesn't fire forever.
            self._baseline = est_before
        self.n_replans += 1
        self.last_replan = now
        self.log.append(ReplanRecord(
            time=now, trigger=trigger, replanned=True,
            est_before=est_before, est_after=min(est_new, est_before),
        ))
        return PlanUpdate(
            links=self.links(),
            pause=self.policy.replan_latency,
            label=f"reopt:{trigger}",
        )

    def _maybe_replan(self, now: float, trigger: str) -> PlanUpdate | None:
        if now - self.last_replan < self.policy.min_interval:
            self.log.append(ReplanRecord(time=now, trigger=trigger,
                                         replanned=False))
            return None
        return self.replan(now, trigger)

    # -- ScenarioObserver hooks ---------------------------------------------

    def next_check(self, now: float) -> float:
        # The engine speaks scenario-local time; the schedule is global.
        return self._next_check_global - self.clock_offset

    def on_failure(
        self, view: EngineView, link: tuple[int, int]
    ) -> PlanUpdate | None:
        pair = (min(link), max(link))
        if pair in self.dead:
            return None
        self.dead.add(pair)
        if self._topology is not None:
            self._topology = remove_pair(self._topology, pair)
        if not self.policy.on_failure:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "failure")

    def on_arrival(self, view: EngineView, job: SimJob) -> PlanUpdate | None:
        if not self.policy.on_arrival or self.suppress_job_hooks:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "arrival")

    def on_departure(self, view: EngineView, job_name: str) -> PlanUpdate | None:
        if not self.policy.on_departure or self.suppress_job_hooks:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "departure")

    def on_check(self, view: EngineView) -> PlanUpdate | None:
        interval = self.policy.check_period
        if interval is None:
            return None
        now = view.now + self.clock_offset
        self._next_check_global = now + interval
        if self.policy.period is not None:
            return self._maybe_replan(now, "periodic")
        # Degradation probe: estimated iteration time on the degraded
        # incumbent vs the baseline recorded at adoption.
        est = self.estimated_iter_time()
        if est > self.policy.degradation_threshold * self.baseline:
            return self._maybe_replan(now, "degradation")
        self.log.append(ReplanRecord(time=now, trigger="check",
                                     replanned=False, est_before=est))
        return None


# ---------------------------------------------------------------------------
# Iteration-granularity driver: static plan vs reactive replanning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceEvent:
    """One disruption in an online trace.

    ``kind="fail"``: the fiber pair ``link`` dies when iteration
    ``iteration`` starts (``frac=0``) or ``frac`` of the way through it.
    ``kind="load"``: the resident job's spec becomes ``job`` (a load shift —
    bigger batch, more tables, a different model) at that iteration boundary.
    """

    iteration: int
    kind: str  # "fail" | "load"
    link: tuple[int, int] | None = None
    frac: float = 0.0
    job: JobSpec | None = None


@dataclass
class OnlineRunResult:
    total_time: float
    iter_times: list[float] = field(default_factory=list)
    n_replans: int = 0
    n_failures: int = 0
    log: list[ReplanRecord] = field(default_factory=list)
    final_plan: CoOptResult | None = None


def run_online(
    job: JobSpec,
    n: int,
    hw: HardwareSpec | None = None,
    policy: ReoptPolicy | None = None,
    trace: tuple[TraceEvent, ...] = (),
    n_iters: int = 8,
    seed: int = 0,
    plan: CoOptResult | None = None,
    engine: SimEngine | None = None,
) -> OnlineRunResult:
    """Simulate ``n_iters`` training iterations under a disruption trace.

    Every iteration's flow graph is regenerated from the controller's
    *current* plan (so a replan changes the traffic of all later iterations,
    not just the routes of in-flight flows), then run through
    :meth:`SimEngine.run` with the controller attached as observer:
    mid-iteration failures hit the engine's failure event, the controller
    replans, and the engine swaps the fabric under the surviving flows.

    Pass ``policy=ReoptPolicy.never()`` for the static baseline — the same
    trace, but failures only get the paper's §7 repair — and share ``plan``
    between the two calls so both start from the identical offline optimum.
    """
    hw = hw or HardwareSpec()
    ctrl = ReoptController(job, n, hw=hw, policy=policy, seed=seed, plan=plan)
    ctrl.ensure_plan()
    if ctrl.policy.degradation_threshold is not None:
        ctrl.baseline  # pin the healthy-fabric baseline before disruptions
    # One SimJob per iteration: its admission is not a load shift.  Genuine
    # load shifts arrive through TraceEvent(kind="load") -> set_job below.
    ctrl.suppress_job_hooks = True
    eng = engine or SimEngine(hw)

    by_iter: dict[int, list[TraceEvent]] = {}
    for ev in trace:
        by_iter.setdefault(ev.iteration, []).append(ev)

    total = 0.0
    result = OnlineRunResult(total_time=0.0)
    for it in range(n_iters):
        mid_iter: list[TraceEvent] = []
        for ev in by_iter.get(it, ()):
            if ev.kind == "load" and ev.job is not None:
                total += ctrl.set_job(ev.job, now=total)
            elif ev.kind == "fail" and ev.link is not None:
                if ev.frac <= 0.0:
                    total += ctrl.fail(ev.link, now=total)
                    result.n_failures += 1
                else:
                    mid_iter.append(ev)

        cur_job = ctrl.job
        comp = compute_time(
            cur_job.flops_per_sample * cur_job.batch_per_gpu * n, n, hw
        )
        tasks = iteration_tasks(ctrl.topology, ctrl.demand,
                                compute_duration=comp)
        failures = []
        if mid_iter:  # probe only when a failure needs an in-iteration time
            est = ctrl.estimated_iter_time()
            if not np.isfinite(est):
                # Disconnected fabric: the iteration stall-finishes at t=0,
                # so land mid-iteration failures at the start.
                est = result.iter_times[-1] if result.iter_times else 0.0
            est = max(est, 1e-12)
            for ev in mid_iter:
                failures.append(LinkFailure(time=ev.frac * est, link=ev.link))
                result.n_failures += 1
        sc = Scenario(
            links=ctrl.links(),
            jobs=[SimJob(cur_job.name, tasks)],
            failures=tuple(sorted(failures, key=lambda f: f.time)),
            n=n,
        )
        ctrl.clock_offset = total  # hooks see the global training clock
        res = eng.run(sc, observer=ctrl)
        iter_time = res.makespan
        if res.replan_times:
            # A replan near the end of the iteration can leave part of its
            # pause hanging past the last task finish; charge the overhang
            # so reactive policies don't get the tail of the pause free.
            overhang = (
                res.replan_times[-1] + ctrl.policy.replan_latency
                - res.makespan
            )
            if overhang > 0:
                iter_time += overhang
        total += iter_time
        result.iter_times.append(iter_time)

    result.total_time = total
    result.n_replans = ctrl.n_replans
    result.log = ctrl.log
    result.final_plan = ctrl.plan
    return result


# ---------------------------------------------------------------------------
# Topology-aware placement of arriving jobs
# ---------------------------------------------------------------------------


def place_arrival(
    k: int,
    free: set[int] | frozenset[int],
    links: dict[tuple[int, int], float],
) -> tuple[int, ...]:
    """Pick ``k`` free servers for a newly arriving job, topology-aware.

    Greedy capacity packing: seed with the free server carrying the most
    surviving capacity toward other free servers, then repeatedly add the
    free server with the highest live capacity toward the chosen set.  On a
    degraded fabric this steers new jobs away from servers whose fibers died;
    on a healthy one it reduces fabric fragmentation versus lowest-id
    first-fit.  Falls back to lowest ids to break ties deterministically.
    """
    free = set(free)
    if k > len(free):
        raise ValueError(f"need {k} servers, only {len(free)} free")
    if k == 0:
        return ()
    cap_to: dict[int, dict[int, float]] = {v: {} for v in free}
    for (a, b), c in links.items():
        if a in free and b in free and c > 0:
            cap_to[a][b] = cap_to[a].get(b, 0.0) + c
            cap_to[b][a] = cap_to[b].get(a, 0.0) + c

    seed = min(
        free,
        key=lambda v: (-sum(cap_to.get(v, {}).values()), v),
    )
    chosen = [seed]
    pool = free - {seed}
    while len(chosen) < k:
        nxt = min(
            pool,
            key=lambda v: (
                -sum(cap_to.get(v, {}).get(u, 0.0) for u in chosen),
                -sum(cap_to.get(v, {}).values()),
                v,
            ),
        )
        chosen.append(nxt)
        pool.discard(nxt)
    return tuple(sorted(chosen))
