"""Online re-optimization: dynamic TopoOpt reacting to failures and load
shifts (ROADMAP "online re-optimization" + "topology-aware job placement").

The offline pipeline (:func:`repro.core.alternating.alternating_optimize`)
computes one (strategy, topology, routing) plan and assumes the cluster never
changes.  :class:`repro.core.simengine.SimEngine` already models the events
that make such a plan stale — fiber failures, job arrivals/departures,
stragglers — so this module closes the loop:

* :class:`ReoptPolicy` — *when* to re-optimize: on failure, on job
  arrival/departure (load shifts), periodically, or when a degradation probe
  sees the estimated iteration time exceed a tracked baseline, all gated by a
  hysteresis ``min_interval``.
* :class:`ReoptController` — *how*: a
  :class:`~repro.core.simengine.ScenarioObserver` that pauses the fluid
  simulation (an OCS-style ``replan_latency`` stall), re-runs the alternating
  optimizer **warm-started from the incumbent plan** against the surviving
  fiber pairs and resident job, and resumes in-flight flows on the new
  topology/routes via a :class:`~repro.core.simengine.PlanUpdate`.  When no
  replan triggers it still maintains the paper's §7 quick fix
  (:func:`~repro.core.topology_finder.repair_topology`) as the static
  operator's incumbent.
* :func:`run_online` — an iteration-granularity driver: each training
  iteration's flows are regenerated from the *current* plan, a
  failure/load-shift trace is injected (at iteration boundaries or
  mid-iteration through the engine's failure events), and the policy decides
  between static repair and reactive replanning.  ``benchmarks/bench_online.py``
  compares the two.
* :func:`place_arrival` — topology-aware placement of newly arriving jobs:
  pick the free servers with the most surviving pairwise capacity instead of
  the lowest ids.

Multi-tenant shared fabrics (ROADMAP "extend to multi-job shared fabrics"):
:class:`JobSetController` holds the resident
:class:`~repro.core.workloads.JobSet` instead of a single job — it
re-optimizes the *union* demand via
:func:`~repro.core.alternating.co_optimize_jobset` on arrival / departure /
failure, admits arrivals through :func:`place_arrival`, and probes with
per-tenant flow graphs under the set's weighted fairness.
:func:`run_online_jobset` drives a churn trace (jobs arriving, departing,
fibers dying) against it; ``benchmarks/bench_multitenant.py`` compares
static vs reactive shared plans.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

import numpy as np

from .alternating import (
    CoOptResult,
    JobSetPlan,
    alternating_optimize,
    co_optimize_jobset,
)
from .demand import remap_demand
from .netsim import HardwareSpec, compute_time
from .ocs_reconfig import RECONFIG_LATENCY
from .simengine import (
    EngineView,
    FairnessPolicy,
    LinkFailure,
    PlanUpdate,
    Scenario,
    ScenarioObserver,
    SimEngine,
    SimJob,
    WeightedFairness,
    iteration_tasks,
    links_from_topology,
)
from .strategy_search import Strategy, default_strategy
from .topology_finder import Topology, remove_pair
from .workloads import JobSet, JobSpec, TenantJob

__all__ = [
    "ReoptPolicy",
    "ReoptController",
    "JobSetController",
    "TraceEvent",
    "OnlineRunResult",
    "JobSetRunResult",
    "run_online",
    "run_online_jobset",
    "place_arrival",
    "edge_churn",
]


def edge_churn(old: Topology, new: Topology) -> int:
    """Fibers the patch panel must re-seat to turn ``old`` into ``new``:
    the directed-edge multiset difference (each graph edge is one physical
    port-to-port fiber; edges present in both plans stay patched)."""
    c_old = Counter(old.graph.edges())
    c_new = Counter(new.graph.edges())
    return int(sum((c_new - c_old).values()))


@dataclass(frozen=True)
class ReoptPolicy:
    """Trigger rules for online re-optimization.

    Any combination of triggers may be enabled:

    * ``on_failure`` — replan when a fiber pair dies.
    * ``on_arrival`` / ``on_departure`` — replan on load shifts (a job
      joining or leaving the fabric, or :func:`run_online` swapping the
      resident job's spec).
    * ``period`` — unconditional periodic replanning every ``period`` s.
    * ``degradation_threshold`` + ``check_interval`` — every
      ``check_interval`` s, estimate the incumbent's fluid iteration time on
      the (repaired) surviving fabric; replan when it exceeds
      ``degradation_threshold`` x the baseline recorded at plan adoption.

    ``min_interval`` is hysteresis: replans closer than this to the previous
    one are suppressed (failed triggers leave the static repair in place).
    Every applied replan charges ``replan_latency`` seconds of OCS-style
    traffic pause.

    Churn-proportional cost (``fiber_move_latency``): real patch panels
    charge per *moved fiber*, not a flat fee.  When set, an adopted replan's
    pause is ``fiber_move_latency * edges_moved`` (the directed-edge diff
    between incumbent and replanned topology, :func:`edge_churn`) and a
    replan that keeps the incumbent pauses nothing; ``None`` keeps the flat
    ``replan_latency`` (the pre-churn behaviour).  Constants to plug in live
    in :mod:`repro.core.costmodel` (``FIBER_MOVE_S``, ``OCS_FIBER_MOVE_S``).

    Adaptive hysteresis (``adaptive``): a triggered replan is *skipped* —
    no pause, no fabric change — when the probed marginal win over the
    degraded incumbent, amortized over ``payback_horizon`` iterations, is
    below its (churn-proportional) pause cost; each skip doubles the
    controller's effective ``min_interval`` (reset on the next adopted
    replan), so hopeless replanning backs off instead of burning pauses.

    ``probe_slack`` tunes the incremental degradation probe: after a full
    one-iteration flow probe the controller caches the estimate together
    with the link set whose planned utilization exceeds ``probe_slack`` x
    the bottleneck; later probes reuse the cached estimate until a failure
    touches that hot set (or the demand changes).  ``0.0`` = every loaded
    link is hot (reuse only across failures of unloaded pairs);
    ``~0.95`` = only near-bottleneck links invalidate.
    """

    on_failure: bool = True
    on_arrival: bool = False
    on_departure: bool = False
    period: float | None = None
    check_interval: float | None = None
    degradation_threshold: float | None = None
    min_interval: float = 0.0
    replan_latency: float = RECONFIG_LATENCY
    # Churn-proportional replan cost: seconds per moved fiber (None = flat).
    fiber_move_latency: float | None = None
    # Benefit-vs-cost replan gate + min_interval backoff.
    adaptive: bool = False
    payback_horizon: float = 8.0  # iterations a replan must amortize over
    # Incremental probe: bottleneck-set utilization threshold in [0, 1).
    probe_slack: float = 0.0
    # Warm-started optimizer budget per replan (smaller than offline: the
    # incumbent is already good, we only adapt it).
    rounds: int = 2
    mcmc_iters: int = 40
    # Candidate pricing inside the replan optimizer: the compiled plan
    # evaluator (repro.core.planeval) by default; False pins the reference
    # topoopt_comm_time path (fixed seeds must agree between the two).
    compiled: bool = True

    @classmethod
    def never(cls) -> "ReoptPolicy":
        """Static plan: no trigger ever fires (PR-1 engine semantics)."""
        return cls(on_failure=False, replan_latency=0.0)

    @classmethod
    def reactive(cls, min_interval: float = 0.0, **kw) -> "ReoptPolicy":
        """Replan on every failure and load shift (subject to hysteresis)."""
        return cls(on_failure=True, on_arrival=True, on_departure=True,
                   min_interval=min_interval, **kw)

    @classmethod
    def periodic(cls, period: float, **kw) -> "ReoptPolicy":
        return cls(on_failure=False, period=period, **kw)

    @classmethod
    def degradation(
        cls, threshold: float, check_interval: float, **kw
    ) -> "ReoptPolicy":
        return cls(on_failure=False, degradation_threshold=threshold,
                   check_interval=check_interval, **kw)

    @property
    def check_period(self) -> float | None:
        """Interval between observer checks, if any trigger needs them."""
        if self.period is not None:
            return self.period
        if (
            self.check_interval is not None
            and self.degradation_threshold is not None
        ):
            return self.check_interval
        return None


@dataclass
class ReplanRecord:
    """One controller decision, for logs and benchmarks."""

    time: float
    trigger: str  # "failure" | "arrival" | "departure" | "periodic" | ...
    replanned: bool
    est_before: float = float("nan")  # incumbent (repaired) iteration time
    est_after: float = float("nan")  # adopted plan's iteration time
    edges_moved: int = 0  # physical fiber churn of the adopted swap


class ReoptController(ScenarioObserver):
    """Couples :func:`alternating_optimize` into a running scenario.

    The controller tracks three things across events:

    * ``dead`` — fiber pairs that failed so far; every replanned topology is
      searched with these pairs ``forbidden``.
    * the **incumbent plan** (``plan``/``topology``/``demand``) — after a
      failure with no replan trigger, the incumbent topology is degraded in
      place (:func:`~repro.core.topology_finder.remove_pair`: dead pair
      gone, routes re-pathed over the survivors) — the plan a static
      operator keeps running; after a replan it is the freshly optimized
      plan, warm-started from the old one.
    * ``baseline`` — the one-iteration simulated makespan recorded when the
      incumbent was adopted, against which the degradation trigger compares.

    As a :class:`ScenarioObserver` it turns replans into
    :class:`PlanUpdate`s: new fabric links + a ``replan_latency`` pause, so
    in-flight flows resume (bytes preserved) on the new topology mid-run.
    A controller whose policy never triggers returns ``None`` from every
    hook, leaving the engine bit-identical to an observer-less run.
    """

    def __init__(
        self,
        job: JobSpec | None,
        n: int,
        hw: HardwareSpec | None = None,
        policy: ReoptPolicy | None = None,
        seed: int = 0,
        plan: CoOptResult | None = None,
    ):
        self.job = job
        self.n = n
        self.hw = hw or HardwareSpec()
        self.policy = policy or ReoptPolicy()
        self.seed = seed
        self.dead: set[tuple[int, int]] = set()
        self.n_replans = 0
        self.total_edges_moved = 0
        # Pause of the most recent *applied* PlanUpdate (drivers charge the
        # tail of a pause that hangs past the last task finish).
        self.last_pause = 0.0
        self.last_replan = -np.inf
        self.log: list[ReplanRecord] = []
        self._plan: CoOptResult | None = plan
        self._topology: Topology | None = plan.topology if plan else None
        self._baseline: float | None = None
        self._probe_engine: SimEngine | None = None
        # Incremental degradation probe: (estimate, hot undirected pairs)
        # from the last full flow probe of the incumbent; reused until a
        # failure touches the hot set or the demand changes.
        self._probe_cache: tuple[float, frozenset] | None = None
        self.n_full_probes = 0
        # Adaptive hysteresis: effective min_interval, doubled per skipped
        # (benefit < cost) replan, reset on adoption.
        self._adaptive_interval = self.policy.min_interval
        # Hook clock = engine-local time + clock_offset.  Drivers that run a
        # sequence of scenarios (run_online: one per training iteration) set
        # the offset so hysteresis spans scenario boundaries.
        self.clock_offset = 0.0
        # run_online admits one SimJob per iteration; those admissions are
        # not load shifts, so the driver mutes the arrival/departure hooks
        # and feeds genuine load shifts through set_job instead.
        self.suppress_job_hooks = False
        interval = self.policy.check_period
        # Global-clock time of the next periodic/degradation check.
        self._next_check_global = interval if interval is not None else np.inf

    # -- incumbent plan ------------------------------------------------------

    def _run_optimizer(self, warm: bool) -> CoOptResult:
        """One optimizer run against the current resident workload.
        Subclasses (:class:`JobSetController`) override this to optimize
        their own notion of "the resident job"."""
        if not warm:
            return alternating_optimize(
                self.job, self.n, self.hw,
                rounds=max(self.policy.rounds, 2),
                mcmc_iters=max(self.policy.mcmc_iters, 40),
                seed=self.seed,
                forbidden=tuple(self.dead),
                compiled=self.policy.compiled,
            )
        return alternating_optimize(
            self.job, self.n, self.hw,
            rounds=self.policy.rounds,
            mcmc_iters=self.policy.mcmc_iters,
            seed=self.seed + 1 + self.n_replans,
            warm_topology=self.topology,
            warm_strategy=self.strategy,
            forbidden=tuple(self.dead),
            compiled=self.policy.compiled,
        )

    def ensure_plan(self) -> CoOptResult:
        """Cold-start the offline optimizer once, lazily (a controller whose
        policy never fires should cost nothing)."""
        if self._plan is None:
            self._plan = self._run_optimizer(warm=False)
            self._topology = self._plan.topology
        return self._plan

    @property
    def plan(self) -> CoOptResult:
        return self.ensure_plan()

    @property
    def topology(self) -> Topology:
        """The live physical plan: replanned, or incumbent + §7 repairs."""
        self.ensure_plan()
        assert self._topology is not None
        return self._topology

    @property
    def strategy(self) -> Strategy:
        return self.plan.strategy

    @property
    def demand(self):
        return self.strategy.demand(self.job, self.n)

    @property
    def baseline(self) -> float:
        """Iteration-time estimate the degradation trigger compares against.

        Established on first access (and re-pinned by every replan) — read it
        once while the fabric is still healthy when using the degradation
        trigger; :func:`run_online` does this before applying any trace."""
        if self._baseline is None:
            self.ensure_plan()
            self._baseline = self.estimated_iter_time()
        return self._baseline

    def links(self) -> dict[tuple[int, int], float]:
        """Directed link capacities of the current topology on the surviving
        fabric (dead pairs carry nothing, whatever the plan says)."""
        return self._links_for(self.topology)

    def _links_for(self, topo: Topology) -> dict[tuple[int, int], float]:
        caps = links_from_topology(topo, self.hw)
        for a, b in list(caps):
            if (min(a, b), max(a, b)) in self.dead:
                del caps[(a, b)]
        return caps

    def _probe_jobs(self, topo: Topology, strategy) -> list[SimJob]:
        """The one-iteration flow graph(s) the probe simulates; subclasses
        build one SimJob per tenant."""
        demand = strategy.demand(self.job, self.n)
        comp = compute_time(
            self.job.flops_per_sample * self.job.batch_per_gpu * self.n,
            self.n, self.hw,
        )
        return [SimJob("probe", iteration_tasks(topo, demand,
                                                compute_duration=comp))]

    def _probe_fairness(self) -> FairnessPolicy | None:
        return None

    def _probe_metric(self, res) -> float:
        """Scalar the probe optimizes for; subclasses weight per-job times."""
        return res.makespan

    def _hot_pairs(
        self, jobs: list[SimJob], links: dict[tuple[int, int], float]
    ) -> frozenset | None:
        """Undirected pairs whose planned utilization exceeds
        ``probe_slack`` x the bottleneck; failures outside this set cannot
        move the cached estimate.  Returns ``None`` — *every* failure
        invalidates — when any planned hop has no live link: the engine
        detours such flows over links the plan never names, so the hot set
        cannot be known from the plan alone."""
        # Vectorized hop accounting: encode every planned hop as a dense
        # pair id, sum bytes with one bincount, and look capacities up only
        # for the unique loaded links.
        hop_a: list[np.ndarray] = []
        hop_b: list[np.ndarray] = []
        hop_bytes: list[np.ndarray] = []
        for j in jobs:
            for t in j.tasks:
                if t.kind != "flow" or len(t.route) < 2:
                    continue
                r = np.asarray(t.route, dtype=np.int64)
                hop_a.append(r[:-1])
                hop_b.append(r[1:])
                hop_bytes.append(np.full(r.size - 1, t.nbytes))
        if not hop_a:
            return frozenset()
        a = np.concatenate(hop_a)
        b = np.concatenate(hop_b)
        ids = a * self.n + b
        uniq, inv = np.unique(ids, return_inverse=True)
        loads = np.bincount(inv, weights=np.concatenate(hop_bytes))
        pairs = [(int(i) // self.n, int(i) % self.n) for i in uniq]
        caps = np.asarray([links.get(p) or 0.0 for p in pairs])
        alive = caps > 0
        if np.any(~alive & (loads > 0)):
            return None  # detour-routed flow: hot set unknowable
        if not np.any(alive):
            return frozenset()
        util = np.zeros_like(loads)
        util[alive] = loads[alive] / caps[alive]
        thresh = self.policy.probe_slack * float(util.max())
        return frozenset(
            (min(p), max(p))
            for p, u, live in zip(pairs, util, alive)
            if live and u > thresh
        )

    def estimated_iter_time(
        self,
        topo: Topology | None = None,
        strategy=None,
    ) -> float:
        """One-iteration simulated makespan of ``strategy`` on ``topo``
        restricted to the surviving fabric (defaults: the incumbent).

        A flow-level probe rather than the fluid formula: the fluid model
        charges AllReduce rings by the *planned* ring edges, so it cannot see
        a dead ring link; the scenario engine re-routes those flows over the
        survivors and prices the resulting contention.

        Incumbent probes (both arguments defaulted) are cached together with
        the hot link set (:meth:`_hot_pairs`): failures that do not touch a
        hot link, and checks with no intervening change, reuse the cached
        estimate instead of re-simulating — the incremental probe that keeps
        shared multi-job scenarios cheap."""
        incumbent = topo is None and strategy is None
        if incumbent and self._probe_cache is not None:
            return self._probe_cache[0]
        topo = topo if topo is not None else self.topology
        strategy = strategy if strategy is not None else self.strategy
        jobs = self._probe_jobs(topo, strategy)
        links = self._links_for(topo)
        if self._probe_engine is None:
            self._probe_engine = SimEngine(self.hw)
        sc = Scenario(
            links=links, jobs=jobs, n=self.n, fairness=self._probe_fairness()
        )
        res = self._probe_engine.run(sc)
        self.n_full_probes += 1
        if res.stalled:
            # Unroutable demand stall-finishes instantly in the engine; a
            # disconnected fabric must probe as unusable, not as fast.
            est = float(np.inf)
        else:
            est = float(self._probe_metric(res))
        if incumbent:
            self._probe_cache = (est, self._hot_pairs(jobs, links))
        return est

    # -- mutations -----------------------------------------------------------

    def set_job(self, job: JobSpec, now: float = 0.0) -> float:
        """Load shift: the resident job's spec changes (new batch size, new
        tables, a different model).  Returns the pause charged (seconds) if
        the arrival trigger replanned."""
        self.job = job
        self._probe_cache = None  # demand changed: cached estimate is stale
        if self.policy.on_arrival:
            update = self._maybe_replan(now, "arrival")
            if update is not None:
                return update.pause
        return 0.0

    def _note_dead(self, pair: tuple[int, int]) -> None:
        """Record a dead pair and degrade the incumbent; the probe cache
        survives only when the pair is outside the cached hot link set
        (a ``None`` hot set means any failure invalidates)."""
        if self._probe_cache is not None and (
            self._probe_cache[1] is None or pair in self._probe_cache[1]
        ):
            self._probe_cache = None
        self.dead.add(pair)
        if self._topology is not None:
            self._topology = remove_pair(self._topology, pair)

    def fail(self, link: tuple[int, int], now: float = 0.0) -> float:
        """A node pair dies.  Always records the pair and degrades the
        incumbent (routes re-pathed over survivors); replans when the policy
        says so.  Returns the pause charged (seconds)."""
        pair = (min(link), max(link))
        if pair in self.dead:
            return 0.0
        self._note_dead(pair)
        if self.policy.on_failure:
            update = self._maybe_replan(now, "failure")
            if update is not None:
                return update.pause
        return 0.0

    def _replan_pause(self, edges_moved: int) -> float:
        """Churn-proportional pause when the policy prices per moved fiber,
        the flat ``replan_latency`` otherwise."""
        if self.policy.fiber_move_latency is not None:
            return self.policy.fiber_move_latency * edges_moved
        return self.policy.replan_latency

    def replan(self, now: float, trigger: str) -> PlanUpdate | None:
        """Re-run the alternating optimizer warm-started from the incumbent,
        forbidding dead pairs; adopt whichever of {new plan, degraded
        incumbent} probes faster.  Returns the PlanUpdate to apply — or
        ``None`` when the adaptive gate skips (the probed win would not pay
        for the churn-proportional pause)."""
        self.ensure_plan()
        est_before = self.estimated_iter_time()
        res = self._run_optimizer(warm=True)
        est_new = self.estimated_iter_time(
            topo=res.topology, strategy=res.strategy
        )
        adopt = est_new <= est_before
        edges_moved = edge_churn(self.topology, res.topology) if adopt else 0
        pause = self._replan_pause(edges_moved)
        if adopt and self.policy.adaptive:
            benefit = (est_before - est_new) * self.policy.payback_horizon
            if not np.isfinite(est_before):
                benefit = np.inf if np.isfinite(est_new) else 0.0
            if benefit < pause:
                # Skip: the win doesn't pay for the fiber moves.  No pause,
                # no fabric change; back off the effective min_interval so
                # hopeless triggers stop re-running the optimizer.
                self.last_replan = now
                self._adaptive_interval = max(
                    2 * self._adaptive_interval, pause, self.policy.min_interval
                )
                self.log.append(ReplanRecord(
                    time=now, trigger=trigger, replanned=False,
                    est_before=est_before, est_after=est_new,
                ))
                return None
        if adopt:
            self._plan = res
            self._topology = res.topology
            self._baseline = est_new
            self._probe_cache = None
            self._adaptive_interval = self.policy.min_interval
        else:
            # The warm search couldn't beat the degraded incumbent — keep it
            # (still counts as a replan: the pause was spent deciding) and
            # re-baseline so the degradation trigger doesn't fire forever.
            self._baseline = est_before
        self.n_replans += 1
        self.total_edges_moved += edges_moved
        self.last_replan = now
        self.last_pause = pause
        self.log.append(ReplanRecord(
            time=now, trigger=trigger, replanned=True,
            est_before=est_before, est_after=min(est_new, est_before),
            edges_moved=edges_moved,
        ))
        return PlanUpdate(
            links=self.links(),
            pause=pause,
            label=f"reopt:{trigger}",
            edges_moved=edges_moved,
        )

    def _maybe_replan(self, now: float, trigger: str) -> PlanUpdate | None:
        gate = (
            self._adaptive_interval if self.policy.adaptive
            else self.policy.min_interval
        )
        if now - self.last_replan < gate:
            self.log.append(ReplanRecord(time=now, trigger=trigger,
                                         replanned=False))
            return None
        return self.replan(now, trigger)

    # -- ScenarioObserver hooks ---------------------------------------------

    def next_check(self, now: float) -> float:
        # The engine speaks scenario-local time; the schedule is global.
        return self._next_check_global - self.clock_offset

    def on_failure(
        self, view: EngineView, link: tuple[int, int]
    ) -> PlanUpdate | None:
        pair = (min(link), max(link))
        if pair in self.dead:
            return None
        self._note_dead(pair)
        if not self.policy.on_failure:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "failure")

    def on_arrival(self, view: EngineView, job: SimJob) -> PlanUpdate | None:
        if not self.policy.on_arrival or self.suppress_job_hooks:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "arrival")

    def on_departure(self, view: EngineView, job_name: str) -> PlanUpdate | None:
        if not self.policy.on_departure or self.suppress_job_hooks:
            return None
        return self._maybe_replan(view.now + self.clock_offset, "departure")

    def on_check(self, view: EngineView) -> PlanUpdate | None:
        interval = self.policy.check_period
        if interval is None:
            return None
        now = view.now + self.clock_offset
        self._next_check_global = now + interval
        if self.policy.period is not None:
            return self._maybe_replan(now, "periodic")
        # Degradation probe: estimated iteration time on the degraded
        # incumbent vs the baseline recorded at adoption.
        est = self.estimated_iter_time()
        if est > self.policy.degradation_threshold * self.baseline:
            return self._maybe_replan(now, "degradation")
        self.log.append(ReplanRecord(time=now, trigger="check",
                                     replanned=False, est_before=est))
        return None


# ---------------------------------------------------------------------------
# Multi-tenant controller: the resident workload is a JobSet
# ---------------------------------------------------------------------------


class JobSetController(ReoptController):
    """A :class:`ReoptController` whose resident workload is a whole
    :class:`~repro.core.workloads.JobSet` sharing one fabric.

    Replans re-optimize the *union* demand
    (:func:`~repro.core.alternating.co_optimize_jobset`, warm-started from
    the incumbent shared plan, dead pairs forbidden); probes simulate one
    iteration of every tenant contending under the set's weighted fairness;
    :meth:`admit` places arrivals on the surviving fabric via
    :func:`place_arrival` and :meth:`depart` frees a tenant's servers — both
    are load shifts the policy's arrival/departure triggers may answer with
    a replan.  Tenants admitted without a replan ride the incumbent fabric:
    their AllReduce bytes take a synthetic ring over their placement
    (``iteration_tasks(synth_missing_rings=True)``) until the next replan
    gives them real rings.
    """

    def __init__(
        self,
        jobset: JobSet,
        hw: HardwareSpec | None = None,
        policy: ReoptPolicy | None = None,
        seed: int = 0,
        plan: JobSetPlan | None = None,
    ):
        self.jobset = jobset
        super().__init__(job=None, n=jobset.n, hw=hw, policy=policy,
                         seed=seed, plan=plan)

    # -- plan machinery ------------------------------------------------------

    def _run_optimizer(self, warm: bool) -> JobSetPlan:
        if not warm:
            return co_optimize_jobset(
                self.jobset, self.hw,
                rounds=max(self.policy.rounds, 2),
                mcmc_iters=max(self.policy.mcmc_iters, 40),
                seed=self.seed,
                forbidden=tuple(self.dead),
                compiled=self.policy.compiled,
            )
        return co_optimize_jobset(
            self.jobset, self.hw,
            rounds=self.policy.rounds,
            mcmc_iters=self.policy.mcmc_iters,
            seed=self.seed + 1 + self.n_replans,
            warm_topology=self.topology,
            warm_strategies=self.strategies(),
            forbidden=tuple(self.dead),
            compiled=self.policy.compiled,
        )

    def _maybe_replan(self, now: float, trigger: str) -> PlanUpdate | None:
        if not self.jobset.tenants:
            return None  # nothing to optimize for (e.g. failure after the
            # last tenant departed); keep the incumbent fabric as-is.
        return super()._maybe_replan(now, trigger)

    def strategies(self) -> dict[str, Strategy]:
        """Per-tenant strategies of the incumbent plan, with cold defaults
        for tenants admitted after it was computed."""
        planned = dict(self.plan.strategies)
        return {
            t.label: planned.get(t.label) or default_strategy(t.spec)
            for t in self.jobset.tenants
        }

    @property
    def demand(self):
        """Cluster-level union demand of the resident set under the
        incumbent (default-extended) strategies."""
        return self.jobset.union_for(self.strategies())

    # -- probes --------------------------------------------------------------

    def _probe_jobs(self, topo: Topology, strategy) -> list[SimJob]:
        strategies = dict(strategy) if strategy else {}
        for t in self.jobset.tenants:
            strategies.setdefault(t.label, default_strategy(t.spec))
        jobs = []
        for t in self.jobset.tenants:
            dem = remap_demand(
                strategies[t.label].demand(t.spec, t.k), t.servers, self.n
            )
            comp = compute_time(t.flops_per_iteration, t.k, self.hw)
            jobs.append(SimJob(t.label, iteration_tasks(
                topo, dem, compute_duration=comp, synth_missing_rings=True,
            )))
        return jobs

    def _probe_fairness(self) -> FairnessPolicy | None:
        return self.fairness()

    def _probe_metric(self, res) -> float:
        """Weighted mean of per-job one-iteration makespans."""
        total = self.jobset.total_weight
        return sum(
            t.weight * res.job_makespans.get(t.label, 0.0)
            for t in self.jobset.tenants
        ) / total

    def iteration_jobs(self) -> list[SimJob]:
        """One SimJob per resident tenant (flows + compute) for the current
        plan — what :func:`run_online_jobset` feeds the engine each
        iteration."""
        return self._probe_jobs(self.topology, self.strategies())

    def fairness(self) -> WeightedFairness:
        return WeightedFairness(self.jobset.weights())

    # -- admission / departure ----------------------------------------------

    def admit(
        self,
        spec: JobSpec,
        k: int,
        weight: float = 1.0,
        name: str | None = None,
        now: float = 0.0,
    ) -> tuple[tuple[int, ...], float]:
        """Admit an arriving job: place it on the ``k`` free servers with
        the most surviving capacity (:func:`place_arrival`), then let the
        arrival trigger replan the shared fabric.  Returns
        ``(servers, pause_seconds)``."""
        if k < 1:
            raise ValueError(f"admit needs k >= 1 servers, got {k}")
        label = name or spec.name
        servers = place_arrival(k, self.jobset.free_servers(), self.links())
        self.jobset = self.jobset.with_tenant(
            TenantJob(spec=spec, servers=servers, weight=weight, name=label)
        )
        self._probe_cache = None
        pause = 0.0
        if self.policy.on_arrival:
            update = self._maybe_replan(now, "arrival")
            if update is not None:
                pause = update.pause
        return servers, pause

    def depart(self, label: str, now: float = 0.0) -> float:
        """A tenant finishes: free its servers; the departure trigger may
        compact the shared fabric.  Returns the pause charged (seconds)."""
        self.jobset = self.jobset.without(label)
        self._probe_cache = None
        if self.policy.on_departure:
            update = self._maybe_replan(now, "departure")
            if update is not None:
                return update.pause
        return 0.0

    def set_job(self, job: JobSpec, now: float = 0.0) -> float:
        raise TypeError(
            "JobSetController has no single resident job; use admit/depart"
        )


# ---------------------------------------------------------------------------
# Iteration-granularity driver: static plan vs reactive replanning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceEvent:
    """One disruption in an online trace.

    ``kind="fail"``: the fiber pair ``link`` dies when iteration
    ``iteration`` starts (``frac=0``) or ``frac`` of the way through it.
    ``kind="load"``: the resident job's spec becomes ``job`` (a load shift —
    bigger batch, more tables, a different model) at that iteration boundary.

    Multi-tenant traces (:func:`run_online_jobset`) additionally use
    ``kind="arrive"`` — job ``job`` joins on ``k`` servers with fairness
    ``weight`` under label ``name`` (placed by :func:`place_arrival`) — and
    ``kind="depart"`` — tenant ``name`` finishes and frees its servers.
    """

    iteration: int
    kind: str  # "fail" | "load" | "arrive" | "depart"
    link: tuple[int, int] | None = None
    frac: float = 0.0
    job: JobSpec | None = None
    k: int = 0
    weight: float = 1.0
    name: str | None = None


@dataclass
class OnlineRunResult:
    total_time: float
    iter_times: list[float] = field(default_factory=list)
    n_replans: int = 0
    n_failures: int = 0
    edges_moved: int = 0
    log: list[ReplanRecord] = field(default_factory=list)
    final_plan: CoOptResult | None = None


def run_online(
    job: JobSpec,
    n: int,
    hw: HardwareSpec | None = None,
    policy: ReoptPolicy | None = None,
    trace: tuple[TraceEvent, ...] = (),
    n_iters: int = 8,
    seed: int = 0,
    plan: CoOptResult | None = None,
    engine: SimEngine | None = None,
) -> OnlineRunResult:
    """Simulate ``n_iters`` training iterations under a disruption trace.

    Every iteration's flow graph is regenerated from the controller's
    *current* plan (so a replan changes the traffic of all later iterations,
    not just the routes of in-flight flows), then run through
    :meth:`SimEngine.run` with the controller attached as observer:
    mid-iteration failures hit the engine's failure event, the controller
    replans, and the engine swaps the fabric under the surviving flows.

    Pass ``policy=ReoptPolicy.never()`` for the static baseline — the same
    trace, but failures only get the paper's §7 repair — and share ``plan``
    between the two calls so both start from the identical offline optimum.
    """
    hw = hw or HardwareSpec()
    ctrl = ReoptController(job, n, hw=hw, policy=policy, seed=seed, plan=plan)
    ctrl.ensure_plan()
    if ctrl.policy.degradation_threshold is not None:
        ctrl.baseline  # pin the healthy-fabric baseline before disruptions
    # One SimJob per iteration: its admission is not a load shift.  Genuine
    # load shifts arrive through TraceEvent(kind="load") -> set_job below.
    ctrl.suppress_job_hooks = True
    eng = engine or SimEngine(hw)

    by_iter: dict[int, list[TraceEvent]] = {}
    for ev in trace:
        by_iter.setdefault(ev.iteration, []).append(ev)

    total = 0.0
    result = OnlineRunResult(total_time=0.0)
    for it in range(n_iters):
        mid_iter: list[TraceEvent] = []
        for ev in by_iter.get(it, ()):
            if ev.kind == "load" and ev.job is not None:
                total += ctrl.set_job(ev.job, now=total)
            elif ev.kind == "fail" and ev.link is not None:
                if ev.frac <= 0.0:
                    total += ctrl.fail(ev.link, now=total)
                    result.n_failures += 1
                else:
                    mid_iter.append(ev)

        cur_job = ctrl.job
        comp = compute_time(
            cur_job.flops_per_sample * cur_job.batch_per_gpu * n, n, hw
        )
        tasks = iteration_tasks(ctrl.topology, ctrl.demand,
                                compute_duration=comp)
        failures = []
        if mid_iter:  # probe only when a failure needs an in-iteration time
            est = ctrl.estimated_iter_time()
            if not np.isfinite(est):
                # Disconnected fabric: the iteration stall-finishes at t=0,
                # so land mid-iteration failures at the start.
                est = result.iter_times[-1] if result.iter_times else 0.0
            est = max(est, 1e-12)
            for ev in mid_iter:
                failures.append(LinkFailure(time=ev.frac * est, link=ev.link))
                result.n_failures += 1
        sc = Scenario(
            links=ctrl.links(),
            jobs=[SimJob(cur_job.name, tasks)],
            failures=tuple(sorted(failures, key=lambda f: f.time)),
            n=n,
        )
        ctrl.clock_offset = total  # hooks see the global training clock
        res = eng.run(sc, observer=ctrl)
        iter_time = res.makespan
        if res.replan_times:
            # A replan near the end of the iteration can leave part of its
            # pause hanging past the last task finish; charge the overhang
            # so reactive policies don't get the tail of the pause free.
            overhang = res.replan_times[-1] + ctrl.last_pause - res.makespan
            if overhang > 0:
                iter_time += overhang
        total += iter_time
        result.iter_times.append(iter_time)

    result.total_time = total
    result.n_replans = ctrl.n_replans
    result.edges_moved = ctrl.total_edges_moved
    result.log = ctrl.log
    result.final_plan = ctrl.plan
    return result


# ---------------------------------------------------------------------------
# Multi-tenant driver: a churn trace against a shared fabric
# ---------------------------------------------------------------------------


@dataclass
class JobSetRunResult:
    total_time: float
    iter_times: list[float] = field(default_factory=list)
    # Tenant -> sum of its per-iteration makespans while resident.
    job_times: dict[str, float] = field(default_factory=dict)
    n_replans: int = 0
    n_failures: int = 0
    edges_moved: int = 0
    log: list[ReplanRecord] = field(default_factory=list)
    final_plan: JobSetPlan | None = None
    final_jobset: JobSet | None = None


def run_online_jobset(
    jobset: JobSet,
    hw: HardwareSpec | None = None,
    policy: ReoptPolicy | None = None,
    trace: tuple[TraceEvent, ...] = (),
    n_iters: int = 8,
    seed: int = 0,
    plan: JobSetPlan | None = None,
    engine: SimEngine | None = None,
) -> JobSetRunResult:
    """Simulate ``n_iters`` training iterations of a *shared* cluster under
    a churn trace: jobs arriving (placed via :func:`place_arrival`) and
    departing, fibers dying at or inside iteration boundaries.

    Each iteration regenerates one SimJob per resident tenant from the
    controller's current shared plan and runs them through
    :meth:`SimEngine.run` contending under the set's weighted fairness, with
    the :class:`JobSetController` attached as observer.  Pass
    ``policy=ReoptPolicy.never()`` for the static shared baseline and share
    ``plan`` so both operators start from the same offline optimum.
    """
    hw = hw or HardwareSpec()
    ctrl = JobSetController(jobset, hw=hw, policy=policy, seed=seed, plan=plan)
    ctrl.ensure_plan()
    if ctrl.policy.degradation_threshold is not None:
        ctrl.baseline  # pin the healthy-fabric baseline before disruptions
    ctrl.suppress_job_hooks = True
    eng = engine or SimEngine(hw)

    by_iter: dict[int, list[TraceEvent]] = {}
    for ev in trace:
        by_iter.setdefault(ev.iteration, []).append(ev)

    total = 0.0
    result = JobSetRunResult(total_time=0.0)
    for it in range(n_iters):
        mid_iter: list[TraceEvent] = []
        for ev in by_iter.get(it, ()):
            if ev.kind == "arrive" and ev.job is not None:
                _, pause = ctrl.admit(
                    ev.job, ev.k, weight=ev.weight, name=ev.name, now=total,
                )
                total += pause
            elif ev.kind == "depart" and ev.name:
                total += ctrl.depart(ev.name, now=total)
            elif ev.kind == "fail" and ev.link is not None:
                if ev.frac <= 0.0:
                    total += ctrl.fail(ev.link, now=total)
                    result.n_failures += 1
                else:
                    mid_iter.append(ev)

        if not ctrl.jobset.tenants:
            # No resident work: the iteration is instantaneous, but queued
            # mid-iteration failures still land on the fabric.
            for ev in mid_iter:
                total += ctrl.fail(ev.link, now=total)
                result.n_failures += 1
            result.iter_times.append(0.0)
            continue
        jobs = ctrl.iteration_jobs()
        failures = []
        if mid_iter:
            est = ctrl.estimated_iter_time()
            if not np.isfinite(est):
                est = result.iter_times[-1] if result.iter_times else 0.0
            est = max(est, 1e-12)
            for ev in mid_iter:
                failures.append(LinkFailure(time=ev.frac * est, link=ev.link))
                result.n_failures += 1
        sc = Scenario(
            links=ctrl.links(),
            jobs=jobs,
            failures=tuple(sorted(failures, key=lambda f: f.time)),
            n=jobset.n,
            fairness=ctrl.fairness(),
        )
        ctrl.clock_offset = total
        res = eng.run(sc, observer=ctrl)
        iter_time = res.makespan
        if res.replan_times:
            overhang = res.replan_times[-1] + ctrl.last_pause - res.makespan
            if overhang > 0:
                iter_time += overhang
        total += iter_time
        result.iter_times.append(iter_time)
        for name, ms in res.job_makespans.items():
            result.job_times[name] = result.job_times.get(name, 0.0) + ms

    result.total_time = total
    result.n_replans = ctrl.n_replans
    result.edges_moved = ctrl.total_edges_moved
    result.log = ctrl.log
    result.final_plan = ctrl.plan
    result.final_jobset = ctrl.jobset
    return result


# ---------------------------------------------------------------------------
# Topology-aware placement of arriving jobs
# ---------------------------------------------------------------------------


def place_arrival(
    k: int,
    free: set[int] | frozenset[int],
    links: dict[tuple[int, int], float],
) -> tuple[int, ...]:
    """Pick ``k`` free servers for a newly arriving job, topology-aware.

    Greedy capacity packing: seed with the free server carrying the most
    surviving capacity toward other free servers, then repeatedly add the
    free server with the highest live capacity toward the chosen set.  On a
    degraded fabric this steers new jobs away from servers whose fibers died;
    on a healthy one it reduces fabric fragmentation versus lowest-id
    first-fit.  Falls back to lowest ids to break ties deterministically.
    """
    free = set(free)
    if k > len(free):
        raise ValueError(f"need {k} servers, only {len(free)} free")
    if k == 0:
        return ()
    cap_to: dict[int, dict[int, float]] = {v: {} for v in free}
    for (a, b), c in links.items():
        if a in free and b in free and c > 0:
            cap_to[a][b] = cap_to[a].get(b, 0.0) + c
            cap_to[b][a] = cap_to[b].get(a, 0.0) + c

    seed = min(
        free,
        key=lambda v: (-sum(cap_to.get(v, {}).values()), v),
    )
    chosen = [seed]
    pool = free - {seed}
    while len(chosen) < k:
        nxt = min(
            pool,
            key=lambda v: (
                -sum(cap_to.get(v, {}).get(u, 0.0) for u in chosen),
                -sum(cap_to.get(v, {}).values()),
                v,
            ),
        )
        chosen.append(nxt)
        pool.discard(nxt)
    return tuple(sorted(chosen))
