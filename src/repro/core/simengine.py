"""Unified scenario-driven network simulation engine.

One facade over the three simulation granularities the paper's evaluation
uses, replacing the previously disjoint ``netsim`` / ``packetsim`` /
``ocs_reconfig`` entry points (which remain as thin shims):

* **Fluid bottleneck analysis** — :meth:`SimEngine.comm_time` /
  :meth:`SimEngine.iteration_time` price demands on the compiled plan
  evaluator (:mod:`repro.core.planeval`; ``compiled=False`` falls back to
  the reference :func:`netsim.topoopt_comm_time` walk, §5.1 FlexNet
  analogue) for dedicated-cluster sweeps.
* **Event-driven max-min-fair flows** — :class:`FlowSimVec`, a vectorized
  rewrite of the old per-flow-dict ``packetsim.FlowSim`` inner loop: flow
  routes become link-index/count arrays, progressive filling runs on NumPy
  vectors, and event advancement is batched (FlexNetPacket analogue).
* **Scenario runs** — :class:`Scenario` + :meth:`SimEngine.run`: multi-job
  shared clusters with staggered arrivals, random link failures with
  reroute via the k-shortest-path machinery, straggler-skewed compute, and
  OCS reconfiguration epochs (Algorithm 5 topology rebuilds with a
  reconfiguration pause), none of which the seed modules could express.

Also hosts the vectorized ports of the benchmark inner loops
(:meth:`SimEngine.tree_times`, :meth:`SimEngine.dedicated_job_times`,
:meth:`SimEngine.reconfig_drain`) that ``benchmarks/bench_shared.py`` and
``bench_reconfig.py`` drive.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from .demand import TrafficDemand
from .netsim import (  # re-exported: the facade subsumes these
    HardwareSpec,
    _fat_tree_comm_time as fat_tree_comm_time,
    _ideal_switch_comm_time as ideal_switch_comm_time,
    _iteration_time as iteration_time,
    _topoopt_comm_time as topoopt_comm_time,
    compute_time,
    mp_flows,
)
from .ocs_reconfig import (
    _RECONFIG_LATENCY as RECONFIG_LATENCY,
    _RECONFIG_WINDOW as RECONFIG_WINDOW,
    _ocs_topology as ocs_topology,
)
from .planeval import plan_evaluator
from .routing import k_shortest_mp_routes
from .topology_finder import Topology, topology_finder

__all__ = [
    "PROPAGATION_DELAY",
    "Task",
    "SimResult",
    "FlowSimVec",
    "SimJob",
    "LinkFailure",
    "OCSPolicy",
    "FairnessPolicy",
    "WeightedFairness",
    "DeadlineFairness",
    "PlanUpdate",
    "EngineView",
    "ScenarioObserver",
    "Scenario",
    "ScenarioResult",
    "SimEngine",
    "links_from_topology",
    "iteration_tasks",
    # re-exports (the blessed, warning-free home of the legacy shim names)
    "HardwareSpec",
    "compute_time",
    "fat_tree_comm_time",
    "ideal_switch_comm_time",
    "iteration_time",
    "topoopt_comm_time",
    "ocs_topology",
    "topology_finder",
    "RECONFIG_WINDOW",
    "RECONFIG_LATENCY",
]

PROPAGATION_DELAY = 1e-6  # §5.1: link propagation delay 1 us


@dataclass
class Task:
    """A schedulable unit.  Either compute (duration) or comm (bytes+route).

    ``route`` holds the node path for flows; under a reconfigurable fabric
    only its endpoints are contractual — the engine re-derives the path on
    every topology change.  ``node`` attributes compute tasks to a server so
    straggler skew can find them.
    """

    tid: int
    kind: str  # "compute" | "flow"
    duration: float = 0.0  # compute seconds
    nbytes: float = 0.0  # flow size
    route: tuple[int, ...] = ()  # node path for flows
    deps: tuple[int, ...] = ()
    node: int = -1  # compute placement (straggler lookup)


@dataclass
class SimResult:
    makespan: float
    finish_times: dict[int, float] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Vectorized max-min-fair flow simulator
# ---------------------------------------------------------------------------


class _LinkTable:
    """Directed links -> dense indices; unknown links get infinite capacity
    (matching the old FlowSim's ``remaining_bw.get(link, inf)``)."""

    def __init__(self, link_bw: dict[tuple[int, int], float]):
        self.index: dict[tuple[int, int], int] = {}
        caps: list[float] = []
        for link, bw in link_bw.items():
            self.index[link] = len(caps)
            caps.append(float(bw))
        self.cap = np.asarray(caps, dtype=np.float64)

    def indices_for(self, route: tuple[int, ...]) -> tuple[np.ndarray, np.ndarray]:
        """(unique link idx, traversal count) for a node path; lazily grows
        the table for links outside the capacity map."""
        counts: dict[int, int] = {}
        for link in zip(route[:-1], route[1:]):
            li = self.index.get(link)
            if li is None:
                li = len(self.index)
                self.index[link] = li
                self.cap = np.append(self.cap, np.inf)
            counts[li] = counts.get(li, 0) + 1
        lids = np.fromiter(counts.keys(), dtype=np.int64, count=len(counts))
        cnts = np.fromiter(counts.values(), dtype=np.float64, count=len(counts))
        return lids, cnts


@dataclass
class _FlowState:
    task: Task
    remaining: float
    lids: np.ndarray  # unique link indices crossed
    cnts: np.ndarray  # traversal multiplicity per link
    hops: int  # len(route) - 1, for propagation delay
    rate: float = 0.0


def _maxmin_method() -> str:
    """Filling-loop selection: ``REPRO_MAXMIN_METHOD`` in {auto, heap,
    dense}; ``auto`` (default) uses the heap event queue once the link
    table reaches ``REPRO_SPARSE_MIN_LINKS`` links (default 0: always)."""
    import os

    return os.environ.get("REPRO_MAXMIN_METHOD", "auto")


def _sparse_min_links() -> int:
    import os

    return int(os.environ.get("REPRO_SPARSE_MIN_LINKS", "0"))


def _incidence_csr(flows: list[_FlowState]):
    """Inverted index link -> (flow, count), sorted by link for O(1)
    slices; stable sort keeps flows in ascending id within each link —
    the freeze order both filling loops share."""
    fid = np.concatenate(
        [
            np.full(f.lids.size, i, dtype=np.int64)
            for i, f in enumerate(flows)
            if f.lids.size
        ]
        or [np.empty(0, dtype=np.int64)]
    )
    lid = np.concatenate(
        [f.lids for f in flows if f.lids.size] or [np.empty(0, dtype=np.int64)]
    )
    cnt = np.concatenate(
        [f.cnts for f in flows if f.cnts.size] or [np.empty(0)]
    )
    order = np.argsort(lid, kind="stable")
    return lid[order], fid[order], cnt[order]


def _fill_dense(flows, rates, rem, users, alive, w, lid_s, fid_s, cnt_s, finite):
    """Reference filling loop: O(links) bottleneck scan per round.

    Kept as the baseline the heap loop (and the property tests /
    ``bench_fleet``) are pinned bit-identical against.
    """
    n_alive = int(alive.sum())
    while n_alive:
        used_idx = np.flatnonzero((users > 0) & finite)
        if used_idx.size == 0:
            break
        fair = rem[used_idx] / users[used_idx]
        b = int(used_idx[np.argmin(fair)])
        share = float(rem[b] / users[b])
        lo = np.searchsorted(lid_s, b, side="left")
        hi = np.searchsorted(lid_s, b, side="right")
        froze_any = False
        for fi, c_b in zip(fid_s[lo:hi], cnt_s[lo:hi]):
            if not alive[fi]:
                continue
            f = flows[fi]
            rates[fi] += share * w[fi] * c_b
            rem[f.lids] -= share * w[fi] * c_b * f.cnts
            users[f.lids] -= f.cnts * w[fi]
            alive[fi] = False
            n_alive -= 1
            froze_any = True
        if not froze_any:
            # Float residue: non-integer weights can leave a dust user
            # count on a link whose flows all froze (integer counts
            # subtract exactly, so the unweighted path never gets here).
            # Clear it or the filling loop would spin forever.
            users[b] = 0.0


def _fill_heap(flows, rates, rem, users, alive, w, lid_s, fid_s, cnt_s, finite):
    """Event-queue filling loop: lazy-deletion heap of (fair share, link).

    Only links whose residual actually changed are re-keyed, so a full
    fill costs O(nnz log nnz) in the flow->link incidence instead of the
    dense loop's O(rounds x links).  Bit-identical to :func:`_fill_dense`:
    the heap's (fair, lid) tuple order reproduces np.argmin's
    first-smallest-index tie-break, stored fair values are exactly the
    divisions the dense scan performs (a link's entry is invalidated by
    version counter whenever rem/users change), and the per-flow freeze
    arithmetic is byte-for-byte the same statements.
    """
    n_alive = int(alive.sum())
    version: dict[int, int] = {}
    heap: list[tuple[float, int, int]] = []
    for li in np.flatnonzero((users > 0) & finite):
        li = int(li)
        version[li] = 0
        heap.append((rem[li] / users[li], li, 0))
    heapq.heapify(heap)
    while n_alive and heap:
        share, b, ver = heapq.heappop(heap)
        if version.get(b) != ver or users[b] <= 0:
            continue  # stale entry (residual changed since push) or dust-cleared
        lo = np.searchsorted(lid_s, b, side="left")
        hi = np.searchsorted(lid_s, b, side="right")
        froze_any = False
        touched: list[np.ndarray] = []
        for fi, c_b in zip(fid_s[lo:hi], cnt_s[lo:hi]):
            if not alive[fi]:
                continue
            f = flows[fi]
            rates[fi] += share * w[fi] * c_b
            rem[f.lids] -= share * w[fi] * c_b * f.cnts
            users[f.lids] -= f.cnts * w[fi]
            alive[fi] = False
            n_alive -= 1
            froze_any = True
            touched.append(f.lids)
        if not froze_any:
            # Same float-residue dust clearing as the dense loop.
            users[b] = 0.0
            version[b] = ver + 1
            continue
        for li in np.unique(np.concatenate(touched)):
            li = int(li)
            if not finite[li]:
                continue
            v = version.get(li, 0) + 1
            version[li] = v
            if users[li] > 0:
                heapq.heappush(heap, (rem[li] / users[li], li, v))


def _max_min_rates(
    flows: list[_FlowState],
    cap: np.ndarray,
    weights: np.ndarray | None = None,
    method: str | None = None,
) -> np.ndarray:
    """Progressive-filling max-min fairness over a sparse incidence.

    Semantics match the legacy per-flow-dict loop: repeatedly find the link
    minimizing remaining_bw / n_users, hand each of its users that fair
    share (times traversal multiplicity), charge every link they cross, and
    freeze them.

    ``weights`` (per flow, default all ones) generalizes to *weighted*
    max-min: a link's fair share is split proportionally to flow weight
    (users count weight x traversal multiplicity).  With unit weights the
    arithmetic is bit-identical to the unweighted loop (multiplying by 1.0
    is exact), which is the ``FairnessPolicy`` golden invariant.

    Unknown links (infinite capacity, lazily added by :class:`_LinkTable`)
    are masked out of bottleneck selection entirely: they can never
    constrain a flow, and excluding them removes the old ``inf - inf ->
    nan`` residual update the legacy loop suppressed with ``errstate``.  A
    flow whose every link is unknown is unconstrained and finishes at
    infinite rate — the conclusion the legacy loop reached through an inf
    share, now reached without manufacturing nans.

    ``method`` ("heap" | "dense" | "auto" | None) picks the filling loop;
    None defers to ``REPRO_MAXMIN_METHOD`` / ``REPRO_SPARSE_MIN_LINKS``
    (see :func:`_maxmin_method`).  Both loops are bit-identical.
    """
    F = len(flows)
    rates = np.zeros(F)
    if F == 0:
        return rates
    w = np.ones(F) if weights is None else np.maximum(weights, 1e-12)
    rem = cap.astype(np.float64, copy=True)
    users = np.zeros(cap.size)
    alive = np.zeros(F, dtype=bool)
    for i, f in enumerate(flows):
        if f.lids.size:
            alive[i] = True
            users[f.lids] += f.cnts * w[i]
    lid_s, fid_s, cnt_s = _incidence_csr(flows)
    finite = np.isfinite(cap)

    if method is None or method == "auto":
        env = _maxmin_method() if method is None else "auto"
        if env == "auto":
            env = "heap" if cap.size >= _sparse_min_links() else "dense"
        method = env
    fill = _fill_heap if method == "heap" else _fill_dense
    fill(flows, rates, rem, users, alive, w, lid_s, fid_s, cnt_s, finite)

    # Flows still alive cross only unknown (inf-capacity) links: they are
    # unconstrained.  (A flow with any finite link would have kept that
    # link's user count positive, so the loop could not have ended.)
    if alive.any():
        for i in np.flatnonzero(alive):
            f = flows[int(i)]
            if not finite[f.lids].any():
                rates[i] = np.inf
    return rates


class FlowSimVec:
    """Event-driven max-min fair flow simulator over a task graph.

    Drop-in for the legacy ``packetsim.FlowSim`` (same task/result types,
    same event semantics — one completion per event, compute wins time
    ties), but the per-event work is NumPy: rate allocation runs on
    flows x links arrays and ETA selection on vectors.
    """

    def __init__(self, link_bandwidth: dict[tuple[int, int], float]):
        self.link_bw = dict(link_bandwidth)

    def run(self, tasks: list[Task], start_time: float = 0.0) -> SimResult:
        table = _LinkTable(self.link_bw)
        pending_deps = {t.tid: set(t.deps) for t in tasks}
        dependents: dict[int, list[Task]] = {}
        for t in tasks:
            for d in t.deps:
                dependents.setdefault(d, []).append(t)
        finish_times: dict[int, float] = {}
        active: list[_FlowState] = []
        compute_heap: list[tuple[float, int]] = []
        now = start_time

        def release(tid: int, t_done: float) -> list[Task]:
            finish_times[tid] = t_done
            out = []
            for t in dependents.get(tid, ()):
                deps = pending_deps[t.tid]
                deps.discard(tid)
                if not deps and t.tid not in finish_times:
                    out.append(t)
            return out

        def admit(t: Task) -> None:
            if t.kind == "compute":
                heapq.heappush(compute_heap, (now + t.duration, t.tid))
            else:
                lids, cnts = table.indices_for(t.route)
                active.append(
                    _FlowState(
                        task=t,
                        remaining=max(t.nbytes, 1e-9),
                        lids=lids,
                        cnts=cnts,
                        hops=max(len(t.route) - 1, 0),
                    )
                )

        for t in tasks:
            if not t.deps:
                admit(t)

        while active or compute_heap:
            rates = _max_min_rates(active, table.cap)
            t_flow = np.inf
            next_idx = -1
            if active:
                remaining = np.fromiter(
                    (f.remaining for f in active), dtype=np.float64, count=len(active)
                )
                hops = np.fromiter(
                    (f.hops for f in active), dtype=np.float64, count=len(active)
                )
                with np.errstate(divide="ignore", invalid="ignore"):
                    etas = np.where(
                        rates > 0,
                        now + remaining / rates + PROPAGATION_DELAY * hops,
                        np.inf,
                    )
                next_idx = int(np.argmin(etas))
                t_flow = float(etas[next_idx])
            t_comp = compute_heap[0][0] if compute_heap else np.inf

            if not np.isfinite(t_comp) and not np.isfinite(t_flow):
                # Deadlock (disconnected route): finish flows instantly to
                # avoid hanging; callers treat this as a routing bug.
                for f in active:
                    for nt in release(f.task.tid, now):
                        admit(nt)
                active.clear()
                continue

            t_next = min(t_flow, t_comp)
            dt = t_next - now
            if active and dt > 0:
                remaining = np.maximum(0.0, remaining - rates * dt)
                for f, r in zip(active, remaining):
                    f.remaining = float(r)
            now = t_next

            newly: list[Task] = []
            if t_comp <= t_flow and compute_heap:
                _, tid = heapq.heappop(compute_heap)
                newly.extend(release(tid, now))
            else:
                done = active.pop(next_idx)
                newly.extend(release(done.task.tid, now))
            for t in newly:
                admit(t)

        return SimResult(makespan=now - start_time, finish_times=finish_times)


# ---------------------------------------------------------------------------
# Scenarios: shared clusters, failures, stragglers, OCS epochs
# ---------------------------------------------------------------------------


@dataclass
class SimJob:
    """One job's task graph, arriving at ``arrival`` seconds."""

    name: str
    tasks: list[Task]
    arrival: float = 0.0


@dataclass(frozen=True)
class LinkFailure:
    """Both directions of ``link`` die at ``time``.

    ``repair_time`` (absolute scenario seconds, strictly after ``time``)
    makes the fault transient: at that instant the pair's pre-failure
    capacity is restored and in-flight flows are re-pathed against the
    repaired fabric with their remaining bytes intact — the same
    byte-preserving reroute a failure applies.  ``None`` (the default)
    keeps the original permanent-failure semantics.
    """

    time: float
    link: tuple[int, int]
    repair_time: float | None = None

    def __post_init__(self):
        if self.repair_time is not None and self.repair_time <= self.time:
            raise ValueError(
                f"repair_time {self.repair_time} must be strictly after "
                f"the failure time {self.time}"
            )


@dataclass(frozen=True)
class OCSPolicy:
    """Periodic optical-circuit-switch reconfiguration (Algorithm 5)."""

    window: float = RECONFIG_WINDOW
    latency: float = RECONFIG_LATENCY
    degree: int = 4
    link_bandwidth: float = 100e9 / 8
    max_epochs: int = 10_000  # safety: stall-finish whatever is left after


class FairnessPolicy:
    """Per-job bandwidth weights for the progressive-filling loop.

    Static policies (``time_varying`` False) are queried once per flow at
    admission; set ``time_varying`` True (deadline-aware policies) to be
    re-queried on every rate recomputation with the current clock.  The
    base policy weighs every job 1.0 — by the weighted-filling arithmetic
    that is bit-identical to no policy at all (the golden invariant
    ``tests/test_multitenant.py`` pins).
    """

    time_varying = False

    def weight(self, job: str, now: float) -> float:
        return 1.0


@dataclass(frozen=True)
class WeightedFairness(FairnessPolicy):
    """Static per-job weights (e.g. :meth:`repro.core.workloads.JobSet.weights`);
    jobs missing from the map get ``default``."""

    weights: dict[str, float] = field(default_factory=dict)
    default: float = 1.0

    def weight(self, job: str, now: float) -> float:
        return self.weights.get(job, self.default)


@dataclass(frozen=True)
class DeadlineFairness(FairnessPolicy):
    """Deadline-aware priority: a job's weight ramps from ``base`` up to
    ``base * max_boost`` linearly over the last ``horizon`` seconds before
    its deadline (and stays at the ceiling past it).  Jobs without a
    deadline keep ``base``."""

    time_varying = True

    deadlines: dict[str, float] = field(default_factory=dict)
    horizon: float = 1.0
    max_boost: float = 8.0
    base: float = 1.0

    def weight(self, job: str, now: float) -> float:
        deadline = self.deadlines.get(job)
        if deadline is None:
            return self.base
        slack = deadline - now
        if slack >= self.horizon:
            return self.base
        if slack <= 0:
            return self.base * self.max_boost
        ramp = 1.0 + (self.max_boost - 1.0) * (1.0 - slack / self.horizon)
        return self.base * ramp


@dataclass(frozen=True)
class MigrationRecord:
    """One tenant-migration decision (adopted or rejected), for run logs
    and :class:`ScenarioResult`.

    ``cost`` is the priced pause in seconds
    (:func:`repro.core.costmodel.migration_cost` checkpoint-restore +
    churn-priced fiber moves); ``est_before`` / ``est_after`` are the
    probed objective on the incumbent vs the post-migration plan."""

    time: float
    tenant: str
    src: tuple[int, ...]  # old placement
    dst: tuple[int, ...]  # proposed placement
    est_before: float = float("nan")
    est_after: float = float("nan")
    cost: float = 0.0
    edges_moved: int = 0
    adopted: bool = False
    reason: str = ""


@dataclass
class PlanUpdate:
    """A mid-run plan mutation, returned by :class:`ScenarioObserver` hooks.

    ``links`` (when not ``None``) replaces the live fabric wholesale: the
    engine refreshes link capacities, clears the route cache, and re-resolves
    the path of every in-flight flow against the new fabric (endpoints are
    contractual, paths are not — flows keep their remaining bytes).  ``pause``
    charges an OCS-style reconfiguration stall: no flow makes progress for
    ``pause`` seconds from the moment the update is applied.  ``edges_moved``
    is the physical churn behind the update (fibers the patch panel had to
    re-seat) — reported, summed, in ``ScenarioResult.edges_moved``.

    A migration update (``migrations`` non-empty) is the same mechanism with
    provenance: the fabric swap came from re-seating whole tenants
    (:meth:`repro.core.online.JobSetController.rebalance`), its ``pause``
    includes their checkpoint-restore cost, and the per-tenant
    :class:`MigrationRecord`\\ s are surfaced, concatenated, in
    ``ScenarioResult.migrations``.
    """

    links: dict[tuple[int, int], float] | None = None
    pause: float = 0.0
    label: str = ""
    edges_moved: int = 0
    migrations: tuple[MigrationRecord, ...] = ()


@dataclass(frozen=True)
class EngineView:
    """Read-only snapshot handed to observer hooks.

    ``active_flows`` rows are ``(job, tid, src, dst, remaining_bytes)`` —
    enough to rebuild an unsatisfied-demand matrix for replanning.  Treat
    ``links`` and ``delivered`` as read-only; mutate the fabric only through
    a returned :class:`PlanUpdate`.
    """

    now: float
    links: dict[tuple[int, int], float]
    resident: tuple[str, ...]  # arrived jobs with outstanding tasks
    active_flows: tuple[tuple[str, int, int, int, float], ...]
    delivered: dict[str, float]
    n: int | None

    def unsatisfied_demand(self) -> np.ndarray:
        """(n, n) matrix of remaining bytes per in-flight endpoint pair."""
        assert self.n is not None, "EngineView.n required for demand matrix"
        m = np.zeros((self.n, self.n))
        for _, _, src, dst, rem in self.active_flows:
            m[src, dst] += rem
        return m


class ScenarioObserver:
    """Hook interface making plan mutation a first-class scenario event.

    :meth:`SimEngine.run` calls these at the matching event; any hook may
    return a :class:`PlanUpdate` to swap the fabric and/or charge a
    reconfiguration pause.  The default implementation is a no-op, so a
    scenario run with a silent observer is bit-identical to one without.

    ``next_check`` schedules observer-initiated events (periodic replans,
    degradation probes): return the absolute time of the next check, or
    ``inf`` for none.  After a check fires, the engine re-queries; return a
    strictly later time to avoid a stuck clock (the engine additionally
    refuses to fire two checks at the same instant).
    """

    def next_check(self, now: float) -> float:
        return float("inf")

    def on_arrival(self, view: EngineView, job: "SimJob") -> PlanUpdate | None:
        return None

    def on_departure(self, view: EngineView, job_name: str) -> PlanUpdate | None:
        return None

    def on_failure(
        self, view: EngineView, link: tuple[int, int]
    ) -> PlanUpdate | None:
        return None

    def on_repair(
        self, view: EngineView, link: tuple[int, int]
    ) -> PlanUpdate | None:
        """A transient failure's ``repair_time`` elapsed; the engine has
        already restored the pair's pre-failure capacity."""
        return None

    def on_check(self, view: EngineView) -> PlanUpdate | None:
        return None


@dataclass
class Scenario:
    """Everything one simulation needs: fabric, offered load, disruptions.

    ``links`` maps directed node pairs to capacity in bytes/s (parallel
    links pre-aggregated — see :func:`links_from_topology`).  With a
    ``reconfig`` policy the fabric is instead rebuilt from unsatisfied
    demand every window and ``links`` only seeds the initial state.
    """

    links: dict[tuple[int, int], float]
    jobs: list[SimJob]
    failures: tuple[LinkFailure, ...] = ()
    stragglers: dict[int, float] = field(default_factory=dict)
    reconfig: OCSPolicy | None = None
    n: int | None = None  # node count (required for reconfig rebuilds)
    # Per-job bandwidth weights (weighted max-min); None = plain max-min.
    fairness: FairnessPolicy | None = None
    # Checkpoint-restore cost in seconds, charged to a job each time the
    # fabric reconnects it after a partition stranded one of its flows
    # (price with :func:`repro.core.costmodel.checkpoint_restart_s`).
    # Jobs absent from the map restart for free.
    restart_s: dict[str, float] = field(default_factory=dict)


@dataclass
class ScenarioResult:
    makespan: float
    job_finish: dict[str, float]  # job -> absolute finish time
    job_makespans: dict[str, float]  # job -> finish - arrival
    finish_times: dict[tuple[str, int], float]  # (job, tid) -> finish
    delivered: dict[str, float]  # job -> network bytes completed
    n_reconfigs: int = 0
    stalled: tuple[tuple[str, int], ...] = ()  # flows finished by deadlock
    n_replans: int = 0  # observer-applied PlanUpdates
    replan_times: tuple[float, ...] = ()
    edges_moved: int = 0  # physical fiber churn summed over PlanUpdates
    # Tenant migrations carried by applied PlanUpdates, in application order.
    migrations: tuple[MigrationRecord, ...] = ()
    # Fault accounting: seconds each job spent partition-stalled (an
    # unroutable flow, or blocked on a checkpoint-restore restart) and how
    # many times it restarted after reconnection.  Empty on fault-free runs.
    downtime: dict[str, float] = field(default_factory=dict)
    restarts: dict[str, int] = field(default_factory=dict)

    @property
    def goodput(self) -> dict[str, float]:
        """Network bytes delivered per wall-clock second, per job."""
        span = self.makespan if self.makespan > 0 else 1.0
        return {job: b / span for job, b in self.delivered.items()}

    def availability(self, job: str) -> float:
        """Fraction of the run the job was *not* partition-stalled."""
        if self.makespan <= 0:
            return 1.0
        return 1.0 - min(self.downtime.get(job, 0.0), self.makespan) / self.makespan


class _ScenarioFlow(_FlowState):
    """Flow with job attribution and reroutable endpoints."""

    def __init__(self, job: str, task: Task, lids, cnts, hops):
        super().__init__(task=task, remaining=max(task.nbytes, 1e-9),
                         lids=lids, cnts=cnts, hops=hops)
        self.job = job
        self.path: tuple[int, ...] = task.route
        self.weight = 1.0  # fairness weight (set at admission)


class SimEngine:
    """Facade over every simulation granularity the repo offers.

    Construct once (optionally with a :class:`HardwareSpec`) and reuse: the
    engine caches per-job topologies for dedicated-cluster sweeps.
    """

    def __init__(
        self,
        hw: HardwareSpec | None = None,
        compiled: bool = True,
        backend: str = "numpy",
    ):
        self.hw = hw or HardwareSpec()
        # Fluid pricing path: the compiled plan evaluator
        # (:func:`repro.core.planeval.plan_evaluator`, cached per topology)
        # by default; ``compiled=False`` forces the reference
        # :func:`~repro.core.netsim.topoopt_comm_time` walk.
        self.compiled = compiled
        # ``backend="jax"`` prices fluid comm times on the batched device
        # evaluator (:func:`repro.core.planeval_jax.jax_plan_evaluator`) —
        # agrees with the NumPy path to
        # :data:`~repro.core.planeval_jax.JAX_EQUIV_RTOL`, not to the bit;
        # "numpy" (default) keeps the bit-exact reference behaviour.
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown SimEngine backend {backend!r}")
        self.backend = backend
        self._dedicated_cache: dict = {}
        # job name -> (src, dst, bytes) arrays in job-local index space,
        # shared by every tree_times call on this engine.
        self._tree_flow_cache: dict[str, tuple] = {}

    # -- fluid facade (netsim) ---------------------------------------------

    def comm_time(self, topo: Topology, demand: TrafficDemand) -> dict[str, float]:
        if self.backend == "jax":
            from .planeval_jax import jax_plan_evaluator

            return jax_plan_evaluator(topo, self.hw).comm(demand)
        if self.compiled:
            return plan_evaluator(topo, self.hw).comm(demand)
        return topoopt_comm_time(topo, demand, self.hw)

    def iteration_time(
        self,
        topo: Topology,
        demand: TrafficDemand,
        flops_per_iteration: float = 0.0,
        overlap: float = 0.0,
    ) -> float:
        """Fluid comm + compute for one training iteration on ``topo``."""
        comm = self.comm_time(topo, demand)["comm_time"]
        comp = (
            compute_time(flops_per_iteration, topo.n, self.hw)
            if flops_per_iteration
            else 0.0
        )
        return iteration_time(comm, comp, overlap=overlap)

    # -- flow-level facade (packetsim) -------------------------------------

    def flow_sim(self, link_bandwidth: dict[tuple[int, int], float]) -> FlowSimVec:
        return FlowSimVec(link_bandwidth)

    def flow_makespan(
        self,
        link_bandwidth: dict[tuple[int, int], float],
        tasks: list[Task],
        start_time: float = 0.0,
    ) -> SimResult:
        return FlowSimVec(link_bandwidth).run(tasks, start_time)

    # -- scenario runs ------------------------------------------------------

    def run(
        self, scenario: Scenario, observer: ScenarioObserver | None = None
    ) -> ScenarioResult:
        """Simulate a full scenario: staggered job arrivals sharing the
        fabric max-min fairly, link failures with k-shortest-path reroute,
        straggler-skewed compute, and optional OCS reconfiguration epochs.

        ``observer`` (a :class:`ScenarioObserver`) receives arrival /
        departure / failure / check events and may return a
        :class:`PlanUpdate` to mutate the fabric mid-run — the mechanism
        behind :class:`repro.core.online.ReoptController`.  With no observer
        (or a silent one) the run is identical to the plain PR-1 engine.
        """
        table = _LinkTable(scenario.links)
        live = {l: c for l, c in scenario.links.items() if c > 0}
        reconfig = scenario.reconfig
        if reconfig is not None:
            assert scenario.n is not None, (
                "Scenario.n is required when an OCS reconfiguration policy "
                "is set (topology rebuilds need the node count)"
            )

        jobs = sorted(scenario.jobs, key=lambda j: j.arrival)
        names = [j.name for j in jobs]
        assert len(set(names)) == len(names), "SimJob names must be unique"
        jobs_by_name = {j.name: j for j in jobs}
        arrivals = [(j.arrival, i) for i, j in enumerate(jobs)]
        failures = sorted(scenario.failures, key=lambda f: f.time)
        fail_i = 0
        arr_i = 0
        # Transient faults: repairs fire in their own time order, restoring
        # the capacity snapshot the matching failure took (``cut_caps``).
        repairs = sorted(
            (f for f in failures if f.repair_time is not None),
            key=lambda f: f.repair_time,
        )
        rep_i = 0
        cut_caps: dict[tuple[int, int], dict[tuple[int, int], float]] = {}

        pending: dict[tuple[str, int], set[int]] = {}
        dependents: dict[tuple[str, int], list[Task]] = {}
        for j in jobs:
            for t in j.tasks:
                pending[(j.name, t.tid)] = set(t.deps)
                for d in t.deps:
                    dependents.setdefault((j.name, d), []).append(t)

        finish: dict[tuple[str, int], float] = {}
        delivered: dict[str, float] = {j.name: 0.0 for j in jobs}
        stalled: list[tuple[str, int]] = []
        active: list[_ScenarioFlow] = []
        compute_heap: list[tuple[float, int, str, int]] = []
        seq = 0
        now = 0.0
        n_reconfigs = 0
        n_replans = 0
        edges_moved = 0
        replan_times: list[float] = []
        migrations: list[MigrationRecord] = []
        fairness = scenario.fairness
        # Observer bookkeeping: departure detection + check scheduling.
        outstanding: dict[str, int] = {j.name: len(j.tasks) for j in jobs}
        arrived: set[str] = set()
        departed: list[str] = []
        last_check = -np.inf
        # Partition-survival accounting.  ``track_faults`` flips on the
        # first unroutable flow and stays off for fault-free runs, which
        # therefore never touch any of this state (bit-identity invariant).
        downtime: dict[str, float] = {}
        restarts: dict[str, int] = {}
        restart_until: dict[str, float] = {}
        partitioned: set[str] = set()
        track_faults = False

        # OCS epoch state: next rebuild boundary and pause end.
        next_rebuild = 0.0 if reconfig else np.inf
        pause_until = -np.inf
        # When no engine-side event can ever fire again (every flow
        # unroutable, nothing scheduled), the observer gets at most one
        # immediate rescue check per stall episode — enough for a replan to
        # reconnect the fabric, but scheduled checks alone cannot keep a
        # dead simulation spinning forever.
        stall_rescues = 1

        import networkx as nx

        route_cache: dict[tuple[int, int], tuple[int, ...] | None] = {}

        def live_graph() -> "nx.DiGraph":
            g = nx.DiGraph()
            if scenario.n:
                g.add_nodes_from(range(scenario.n))
            for (a, b), c in live.items():
                if c > 0:
                    g.add_edge(a, b)
            return g

        def resolve_route(src: int, dst: int) -> tuple[int, ...] | None:
            """Direct link if alive, else k-shortest-path on the survivors."""
            if (src, dst) in live:
                return (src, dst)
            cached = route_cache.get((src, dst), "miss")
            if cached != "miss":
                return cached
            g = live_graph()
            mp = np.zeros((max(g.number_of_nodes(), src + 1, dst + 1),) * 2)
            mp[src, dst] = 1.0
            try:
                routes = k_shortest_mp_routes(
                    nx.MultiDiGraph(g), mp, k=1
                ).get(src, dst)
            except nx.NodeNotFound:
                routes = []  # endpoint has no live links at all
            path = routes[0].path if routes else None
            route_cache[(src, dst)] = path
            return path

        def install_route(f: _ScenarioFlow) -> None:
            nonlocal track_faults
            src, dst = f.task.route[0], f.task.route[-1]
            path = resolve_route(src, dst)
            if path is None:
                track_faults = True
                f.path = ()
                f.lids = np.empty(0, dtype=np.int64)
                f.cnts = np.empty(0)
                f.hops = 0
                return
            f.path = path
            f.lids, f.cnts = table.indices_for(path)
            f.hops = len(path) - 1

        def admit(job: SimJob, t: Task) -> None:
            nonlocal seq
            if t.kind == "compute":
                factor = scenario.stragglers.get(t.node, 1.0)
                heapq.heappush(
                    compute_heap, (now + t.duration * factor, seq, job.name, t.tid)
                )
                seq += 1
            else:
                f = _ScenarioFlow(job.name, t, np.empty(0, dtype=np.int64),
                                  np.empty(0), 0)
                install_route(f)
                if fairness is not None:
                    f.weight = fairness.weight(job.name, now)
                active.append(f)

        def release(job_name: str, tid: int, t_done: float) -> None:
            finish[(job_name, tid)] = t_done
            outstanding[job_name] -= 1
            if outstanding[job_name] == 0:
                departed.append(job_name)
            job = jobs_by_name[job_name]
            for t in dependents.get((job_name, tid), ()):
                deps = pending[(job_name, t.tid)]
                deps.discard(tid)
                if not deps and (job_name, t.tid) not in finish:
                    admit(job, t)

        def refresh_partitions() -> None:
            """Recompute the partition-stalled job set after a route-changing
            event.  A resident job leaving the set (its last unroutable flow
            got a path back) restarts from checkpoint: the restart is
            counted, and ``scenario.restart_s`` seconds of blocked progress
            are charged via ``restart_until``."""
            stalled_now = {f.job for f in active if not f.path}
            for job in partitioned - stalled_now:
                if outstanding.get(job, 0) <= 0:
                    continue
                restarts[job] = restarts.get(job, 0) + 1
                pause = scenario.restart_s.get(job, 0.0)
                if pause > 0:
                    restart_until[job] = now + pause
            partitioned.clear()
            partitioned.update(stalled_now)

        def set_links(new_links: dict[tuple[int, int], float]) -> None:
            """Swap the live fabric: refresh capacities (dead links -> 0,
            new links appended), drop stale routes, re-path in-flight flows."""
            live.clear()
            for link, c in new_links.items():
                if c > 0:
                    live[link] = live.get(link, 0.0) + float(c)
            for link in list(table.index):
                table.cap[table.index[link]] = live.get(link, 0.0)
            for link, c in live.items():
                if link not in table.index:
                    table.index[link] = len(table.index)
                    table.cap = np.append(table.cap, c)
                else:
                    table.cap[table.index[link]] = c
            route_cache.clear()
            for f in active:
                install_route(f)
            if track_faults:
                refresh_partitions()

        def make_view() -> EngineView:
            return EngineView(
                now=now,
                links=dict(live),
                # Arrival order, not set order: observers must see the same
                # tuple regardless of PYTHONHASHSEED.
                resident=tuple(
                    j.name for j in jobs
                    if j.name in arrived and outstanding[j.name] > 0
                ),
                active_flows=tuple(
                    (f.job, f.task.tid, f.task.route[0], f.task.route[-1],
                     f.remaining)
                    for f in active
                ),
                delivered=dict(delivered),
                n=scenario.n,
            )

        def apply_update(update: PlanUpdate | None) -> None:
            nonlocal pause_until, n_replans, edges_moved
            if update is None:
                return
            if update.links is not None:
                set_links(update.links)
            if update.pause > 0:
                pause_until = max(pause_until, now + update.pause)
            n_replans += 1
            edges_moved += update.edges_moved
            migrations.extend(update.migrations)
            replan_times.append(now)

        def notify_departures() -> None:
            """Drain jobs that just finished their last task (observer hook)."""
            while departed:
                name = departed.pop(0)
                if observer is not None:
                    apply_update(observer.on_departure(make_view(), name))

        def rebuild_topology() -> None:
            """Algorithm 5 rebuild from unsatisfied demand (active flows)."""
            nonlocal n_reconfigs
            n = scenario.n
            assert n is not None, "Scenario.n required for OCS reconfiguration"
            remaining = np.zeros((n, n))
            for f in active:
                src, dst = f.task.route[0], f.task.route[-1]
                remaining[src, dst] += f.remaining
            g = ocs_topology(n, remaining, reconfig.degree)
            new_links: dict[tuple[int, int], float] = {}
            for a, b in g.edges():
                new_links[(a, b)] = (
                    new_links.get((a, b), 0.0) + reconfig.link_bandwidth
                )
            set_links(new_links)
            n_reconfigs += 1

        def apply_failure(link: tuple[int, int]) -> None:
            pair = (min(link), max(link))
            snap: dict[tuple[int, int], float] = {}
            for l in (link, (link[1], link[0])):
                if l in live:
                    snap[l] = live[l]
                    del live[l]
                if l in table.index:
                    table.cap[table.index[l]] = 0.0
            if snap:
                # Snapshot what the cut removed so a repair can restore it.
                cut_caps[pair] = snap
            route_cache.clear()
            dead = {link, (link[1], link[0])}
            for f in active:
                if any(hop in dead for hop in zip(f.path[:-1], f.path[1:])):
                    install_route(f)
            if track_faults:
                refresh_partitions()

        def apply_repair(link: tuple[int, int]) -> None:
            """Restore both directions of a failed pair to their pre-failure
            capacity and re-path flows that could improve (unroutable or
            detoured) — the byte-preserving reroute, in reverse."""
            snap = cut_caps.pop((min(link), max(link)), None)
            if snap is None:
                return
            for l, c in snap.items():
                live[l] = c
                if l in table.index:
                    table.cap[table.index[l]] = c
                else:
                    table.index[l] = len(table.index)
                    table.cap = np.append(table.cap, c)
            route_cache.clear()
            for f in active:
                if not f.path or len(f.path) > 2:
                    install_route(f)
            if track_faults:
                refresh_partitions()

        # Admit roots of jobs arriving at t=0 happens via the arrival queue.
        while active or compute_heap or arr_i < len(arrivals) or (
            fail_i < len(failures)
        ) or rep_i < len(repairs):
            in_pause = now < pause_until
            flow_w = None
            if fairness is not None and active and not in_pause:
                if fairness.time_varying:
                    for f in active:
                        f.weight = fairness.weight(f.job, now)
                flow_w = np.fromiter(
                    (f.weight for f in active),
                    dtype=np.float64, count=len(active),
                )
            blocked = None
            if restart_until and active and not in_pause:
                blocked = np.fromiter(
                    (restart_until.get(f.job, -np.inf) > now for f in active),
                    dtype=bool, count=len(active),
                )
                if not blocked.any():
                    blocked = None
            if in_pause:
                rates = np.zeros(len(active))
            elif blocked is not None:
                # Checkpoint-restore in progress: the restarting jobs' flows
                # make no progress; everyone else shares the fabric.
                sub = [f for f, b in zip(active, blocked) if not b]
                rates = np.zeros(len(active))
                if sub:
                    sub_w = flow_w[~blocked] if flow_w is not None else None
                    rates[~blocked] = _max_min_rates(
                        sub, table.cap, weights=sub_w
                    )
            else:
                rates = _max_min_rates(active, table.cap, weights=flow_w)
            t_flow = np.inf
            next_idx = -1
            if active and not in_pause:
                remaining = np.fromiter(
                    (f.remaining for f in active), dtype=np.float64,
                    count=len(active),
                )
                hops = np.fromiter(
                    (f.hops for f in active), dtype=np.float64, count=len(active)
                )
                with np.errstate(divide="ignore", invalid="ignore"):
                    etas = np.where(
                        rates > 0,
                        now + remaining / rates + PROPAGATION_DELAY * hops,
                        np.inf,
                    )
                next_idx = int(np.argmin(etas))
                t_flow = float(etas[next_idx])
            t_comp = compute_heap[0][0] if compute_heap else np.inf
            t_arr = arrivals[arr_i][0] if arr_i < len(arrivals) else np.inf
            t_fail = failures[fail_i].time if fail_i < len(failures) else np.inf
            t_rep = (
                repairs[rep_i].repair_time if rep_i < len(repairs) else np.inf
            )
            # A restart pause ending re-enables its job's flows: wake then.
            t_restart = np.inf
            if restart_until:
                pend = [u for u in restart_until.values() if u > now]
                if pend:
                    t_restart = min(pend)
                else:
                    restart_until.clear()
            # Clamp to now: a rebuild boundary that elapsed while only
            # compute was running fires immediately, not in the past.
            t_reconf = (
                max(next_rebuild, now)
                if active or arr_i < len(arrivals)
                else np.inf
            )
            t_pause_end = pause_until if in_pause else np.inf
            # Observer checks (periodic replans / degradation probes) only
            # fire while work remains; a check already fired at this time is
            # not re-armed until the observer advances its schedule.
            t_check = np.inf
            if observer is not None and (
                active or compute_heap or arr_i < len(arrivals)
            ):
                tc = observer.next_check(now)
                if tc > last_check:
                    t_check = max(tc, now)

            t_work = min(
                t_flow, t_comp, t_arr, t_fail, t_rep, t_restart, t_reconf,
                t_pause_end,
            )
            t_next = min(t_work, t_check)
            if not np.isfinite(t_work):
                if (
                    observer is not None
                    and np.isfinite(t_check)
                    and stall_rescues > 0
                ):
                    # One immediate rescue check: a replanning observer may
                    # reconnect the fabric; a silent one falls through to
                    # the stall-finish on the next pass.
                    stall_rescues -= 1
                    last_check = now
                    apply_update(observer.on_check(make_view()))
                    notify_departures()
                    continue
                # Deadlock: every remaining flow is unroutable.  Drop any
                # failure events that can never fire (non-finite times) —
                # they would otherwise keep the loop's while-condition true
                # with no event left to make progress.  (Pending repairs
                # keep t_work finite, so this branch means none remain.)
                fail_i = len(failures)
                for f in active:
                    stalled.append((f.job, f.task.tid))
                    release(f.job, f.task.tid, now)
                active.clear()
                partitioned.clear()
                restart_until.clear()
                notify_departures()
                continue
            stall_rescues = 1

            dt = t_next - now
            if active and not in_pause and dt > 0:
                remaining = np.maximum(0.0, remaining - rates * dt)
                for f, r in zip(active, remaining):
                    f.remaining = float(r)
            if track_faults and dt > 0:
                down = {f.job for f in active if not f.path}
                for job_name, until in restart_until.items():
                    if until > now and outstanding.get(job_name, 0) > 0:
                        down.add(job_name)
                for job_name in down:
                    downtime[job_name] = downtime.get(job_name, 0.0) + dt
            now = t_next

            # Event priority at equal times: arrival, failure, repair,
            # reconfig, check, pause-end, restart-end, compute, flow —
            # deterministic and arrival-first so new jobs contend for
            # bandwidth immediately.
            if t_arr <= t_next:
                job = jobs[arrivals[arr_i][1]]
                arr_i += 1
                arrived.add(job.name)
                for t in job.tasks:
                    if not t.deps:
                        admit(job, t)
                if track_faults:
                    # A job admitted onto a partitioned fabric starts
                    # stalled; register it so a later reconnect restarts it.
                    refresh_partitions()
                if observer is not None:
                    apply_update(observer.on_arrival(make_view(), job))
            elif t_fail <= t_next:
                failed_link = failures[fail_i].link
                apply_failure(failed_link)
                fail_i += 1
                if observer is not None:
                    apply_update(observer.on_failure(make_view(), failed_link))
            elif rep_i < len(repairs) and t_rep <= t_next:
                repaired_link = repairs[rep_i].link
                apply_repair(repaired_link)
                rep_i += 1
                if observer is not None:
                    apply_update(observer.on_repair(make_view(), repaired_link))
            elif reconfig is not None and t_reconf <= t_next:
                if n_reconfigs >= reconfig.max_epochs:
                    for f in active:
                        stalled.append((f.job, f.task.tid))
                        release(f.job, f.task.tid, now)
                    active.clear()
                    partitioned.clear()
                    restart_until.clear()
                    next_rebuild = np.inf
                    notify_departures()
                    continue
                pause_until = now + reconfig.latency
                rebuild_topology()
                next_rebuild = now + reconfig.window
            elif observer is not None and t_check <= t_next:
                last_check = now
                apply_update(observer.on_check(make_view()))
            elif in_pause and t_pause_end <= t_next:
                pass  # pause over; next iteration recomputes rates
            elif t_restart <= t_next:
                pass  # a restart pause ended; next pass unblocks its flows
            elif t_comp <= t_flow and compute_heap:
                _, _, job_name, tid = heapq.heappop(compute_heap)
                release(job_name, tid, now)
            else:
                done = active.pop(next_idx)
                delivered[done.job] += done.task.nbytes
                release(done.job, done.task.tid, now)
            notify_departures()

        job_finish = {}
        job_makespans = {}
        for j in jobs:
            ts = [finish.get((j.name, t.tid), j.arrival) for t in j.tasks]
            job_finish[j.name] = max(ts) if ts else j.arrival
            job_makespans[j.name] = job_finish[j.name] - j.arrival
        return ScenarioResult(
            makespan=max(job_finish.values(), default=0.0),
            job_finish=job_finish,
            job_makespans=job_makespans,
            finish_times=finish,
            delivered=delivered,
            n_reconfigs=n_reconfigs,
            stalled=tuple(stalled),
            n_replans=n_replans,
            replan_times=tuple(replan_times),
            edges_moved=edges_moved,
            migrations=tuple(migrations),
            downtime=dict(downtime),
            restarts=dict(restarts),
        )

    # -- vectorized benchmark inner loops -----------------------------------

    def dedicated_job_times(
        self,
        jobs: list,
        n: int,
        demand_fn,
        degree: int | None = None,
    ) -> np.ndarray:
        """Per-job iteration time on dedicated TopoOpt shards (no cross-job
        contention).  Topologies are cached by job name across calls."""
        degree = degree if degree is not None else self.hw.degree
        times = []
        for job in jobs:
            key = (job.name, n, degree)
            if key not in self._dedicated_cache:
                dem = demand_fn(job)
                topo = topology_finder(dem, degree)
                comm = self.comm_time(topo, dem)["comm_time"]
                comp = compute_time(
                    job.flops_per_sample * job.batch_per_gpu * n, n, self.hw
                )
                self._dedicated_cache[key] = comm + comp
            times.append(self._dedicated_cache[key])
        return np.asarray(times)

    def tree_times(
        self,
        jobs: list,
        n_servers: int,
        job_size: int,
        demand_fn,
        bandwidth_fraction: float = 1.0,
        oversub: float = 1.0,
        tor_radix: int = 16,
    ) -> np.ndarray:
        """Shared two-level tree with fragmented placement, fully vectorized.

        Link universe (encoded as dense ids): host->ToR uplinks [0, N),
        ToR->host downlinks [N, 2N), ToR->core [2N, 2N+T), core->ToR
        [2N+T, 2N+2T).  Per-job flows are translated to hop ids, loads
        accumulate with ``np.add.at`` across all jobs at once, and each
        job's comm time is a segmented max of load/capacity over its hops.
        """
        n_jobs = len(jobs)
        if n_jobs == 0:
            return np.zeros(0)
        N = n_servers
        T = -(-N // tor_radix)
        bw = self.hw.link_bandwidth * self.hw.degree * bandwidth_fraction
        core_cap = tor_radix * bw / oversub

        # Per unique job type: flows in job-local index space (cached on the
        # engine — identical across bandwidth_fraction/oversub sweeps).
        flow_cache = self._tree_flow_cache
        for job in jobs:
            if job.name in flow_cache:
                continue
            dem = demand_fn(job)
            a_l, b_l, nb = [], [], []
            for group in dem.allreduce:
                k = len(group.members)
                if k == 0:
                    continue
                per_link = 2.0 * (k - 1) / k * group.nbytes
                for idx in range(k):
                    a_l.append(group.members[idx])
                    b_l.append(group.members[(idx + 1) % k])
                    nb.append(per_link)
            for s, t, v in mp_flows(dem):
                a_l.append(s)
                b_l.append(t)
                nb.append(v)
            flow_cache[job.name] = (
                np.asarray(a_l, dtype=np.int64),
                np.asarray(b_l, dtype=np.int64),
                np.asarray(nb, dtype=np.float64),
            )

        # Translate every job's flows to global hop ids in one pass.
        hop_ids, hop_bytes, hop_job = [], [], []
        for j, job in enumerate(jobs):
            a_l, b_l, nb = flow_cache[job.name]
            if a_l.size == 0:
                continue
            sa = (a_l * n_jobs + j) % N
            sb = (b_l * n_jobs + j) % N
            ta = sa // tor_radix
            tb = sb // tor_radix
            same = ta == tb
            # Same-ToR flows: host-up(sa), tor-down(sb).
            # Cross-ToR flows add tor-up(ta) and core-down(tb).
            up = sa
            down = N + sb
            tor_up = 2 * N + ta
            core_down = 2 * N + T + tb
            ids = np.stack([up, tor_up, core_down, down], axis=1)
            valid = np.stack(
                [np.ones_like(same), ~same, ~same, np.ones_like(same)], axis=1
            )
            flat_ids = ids[valid]
            reps = valid.sum(axis=1)
            hop_ids.append(flat_ids)
            hop_bytes.append(np.repeat(nb, reps))
            hop_job.append(np.repeat(np.full(a_l.size, j, dtype=np.int64), reps))

        comm = np.zeros(n_jobs)
        if hop_ids:  # compute-only job mixes offer no flows at all
            ids = np.concatenate(hop_ids)
            load = np.zeros(2 * N + 2 * T)
            np.add.at(load, ids, np.concatenate(hop_bytes))

            cap = np.full(2 * N + 2 * T, bw)
            cap[2 * N:] = core_cap
            hop_time = load[ids] / cap[ids]
            np.maximum.at(comm, np.concatenate(hop_job), hop_time)

        comp = np.asarray(
            [
                compute_time(
                    job.flops_per_sample * job.batch_per_gpu * job_size,
                    job_size,
                    self.hw,
                )
                for job in jobs
            ]
        )
        return comm + comp

    def reconfig_drain(
        self,
        remaining: np.ndarray,
        n: int,
        degree: int,
        reconfig_latency: float,
        forwarding: bool,
        max_windows: int = 500,
    ) -> float:
        """Drain a demand matrix with periodic OCS rebuilds (Fig. 17).

        Vectorized port of the old ``bench_reconfig._drain_time``: the
        direct-circuit drain runs on edge arrays; host-based forwarding
        still walks shortest paths but against a per-window BFS cache.
        """
        import networkx as nx

        remaining = remaining.astype(np.float64).copy()
        window = min(RECONFIG_WINDOW, max(1e-3, 50.0 * reconfig_latency))
        t = 0.0
        for _ in range(max_windows):
            if remaining.sum() <= 1e-3:
                break
            g = ocs_topology(n, remaining, degree)
            t += reconfig_latency
            budget = window

            # Aggregate parallel circuits -> (srcs, dsts, caps) arrays.
            edges = np.asarray(list(g.edges()), dtype=np.int64)
            drained = np.zeros_like(remaining)
            spare: dict[tuple[int, int], float] = {}
            if edges.size:
                pairs, counts = np.unique(edges, axis=0, return_counts=True)
                srcs, dsts = pairs[:, 0], pairs[:, 1]
                caps = counts * self.hw.link_bandwidth
                move = np.minimum(remaining[srcs, dsts], caps * budget)
                drained[srcs, dsts] += move
                if forwarding:
                    room = caps * budget - move
                    spare = {
                        (int(a), int(b)): float(r)
                        for a, b, r in zip(srcs, dsts, room)
                    }

            if forwarding and edges.size:
                simple = nx.DiGraph(g)
                paths_from: dict[int, dict[int, list[int]]] = {}
                left = remaining - drained
                f_srcs, f_dsts = np.nonzero(left > 1e-6)
                direct = set(spare)
                for a, b in zip(f_srcs.tolist(), f_dsts.tolist()):
                    if (a, b) in direct:
                        continue
                    if a not in paths_from:
                        try:
                            paths_from[a] = nx.single_source_shortest_path(
                                simple, a
                            )
                        except nx.NodeNotFound:
                            paths_from[a] = {}
                    path = paths_from[a].get(b)
                    if path is None:
                        continue
                    links = list(zip(path[:-1], path[1:]))
                    room = min(spare.get(l, 0.0) for l in links)
                    move = min(remaining[a, b], room)
                    if move > 0:
                        drained[a, b] += move
                        for l in links:
                            spare[l] -= move
            remaining = np.maximum(remaining - drained, 0.0)
            t += budget
        return t


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def links_from_topology(
    topo: Topology, hw: HardwareSpec
) -> dict[tuple[int, int], float]:
    """Directed pair -> aggregate capacity (parallel links pooled)."""
    caps: dict[tuple[int, int], float] = {}
    for a, b in topo.graph.edges():
        caps[(a, b)] = caps.get((a, b), 0.0) + hw.link_bandwidth
    return caps


def iteration_tasks(
    topo: Topology,
    demand: TrafficDemand,
    compute_duration: float = 0.0,
    tid_offset: int = 0,
    synth_missing_rings: bool = False,
) -> list[Task]:
    """One training iteration's flows on ``topo``: AllReduce bytes chunked
    across each group's rings, MP bytes split over the routing table (with
    an endpoint-only fallback for unrouted pairs).  Prepend an optional
    compute task with no dependencies.

    ``synth_missing_rings`` covers AllReduce groups the topology was never
    built for (a tenant admitted onto an incumbent shared fabric without a
    replan): their bytes ride one synthetic ring over the group members in
    placement order, each hop an endpoint-only flow the engine routes over
    whatever fabric survives.  Off by default — the historical behaviour
    (and the single-job golden paths) silently skip such groups."""
    tasks: list[Task] = []
    tid = tid_offset
    if compute_duration > 0:
        tasks.append(Task(tid=tid, kind="compute", duration=compute_duration))
        tid += 1
    for group in demand.allreduce:
        rings = topo.rings.get(group.members, [])
        k = len(group.members)
        if k <= 1 or group.nbytes == 0.0:
            continue
        if not rings:
            if synth_missing_rings:
                per_link = 2.0 * (k - 1) / k * group.nbytes
                for i in range(k):
                    a = group.members[i]
                    b = group.members[(i + 1) % k]
                    tasks.append(
                        Task(tid=tid, kind="flow", nbytes=per_link, route=(a, b))
                    )
                    tid += 1
            continue
        per_link = 2.0 * (k - 1) / k * group.nbytes / len(rings)
        for ring in rings:
            for a, b in ring.edges():
                tasks.append(
                    Task(tid=tid, kind="flow", nbytes=per_link, route=(a, b))
                )
                tid += 1
    srcs, dsts = np.nonzero(demand.mp)
    for s, t in zip(srcs.tolist(), dsts.tolist()):
        nb = float(demand.mp[s, t])
        routes = topo.routing.get(s, t)
        if not routes:
            tasks.append(Task(tid=tid, kind="flow", nbytes=nb, route=(s, t)))
            tid += 1
            continue
        share = nb / len(routes)
        for r in routes:
            tasks.append(Task(tid=tid, kind="flow", nbytes=share, route=r.path))
            tid += 1
    return tasks
