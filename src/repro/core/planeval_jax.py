"""JAX-native batched planner: jit/vmap port of the compiled plan evaluator
(ROADMAP open item 1 — "compile once, evaluate many", on accelerator).

:mod:`repro.core.planeval` compiles a fixed
:class:`~repro.core.topology_finder.Topology` into flat NumPy structure
arrays (link-id table, per-group ring-edge incidence, CSR route cache) and
prices one candidate demand per Python call.  Those arrays are already
array-shaped, so this module lifts the whole scatter + bottleneck-division
pipeline onto JAX:

* :func:`pack_demand` flattens one demand's pricing work into two flat
  arrays — per-occurrence link ids and per-occurrence byte shares (AllReduce
  ring-edge occurrences first, then MP route hops, exactly the occurrences
  the NumPy ``np.add.at`` scatters walk);
* :class:`JaxPlanEvaluator` pads K such packs to one static shape and
  prices all K demands in **one device dispatch**: a vmapped
  ``jax.ops.segment_sum`` scatter over the link universe followed by one
  vectorized ``max(loads / caps)`` bottleneck division;
* :class:`ChainKernel` runs K independent MCMC chains entirely on device:
  the per-tenant strategy space is pre-priced into a ``(tenants, pool,
  links)`` load-vector tensor, a chain state is one pool index per tenant,
  and ``lax.scan`` carries (state, objective, best) through all iterations
  with the annealing rule applied per step — one compiled dispatch for the
  whole batch of chains (vmapped over the chain axis, per-chain
  temperatures supported).

**Numerics.**  The NumPy path stays the bit-exact reference.
:func:`repro.compat.ensure_x64` pins float64 so the JAX pipeline prices the
same arithmetic — but ``segment_sum`` and ``jnp.sum`` may reassociate float
additions, so JAX results match the reference to ~1e-9 relative
(:data:`JAX_EQUIV_RTOL`), not to the bit.  Chain *semantics* are exactly
reproducible: every random draw (proposed tenant, proposed pool index,
acceptance uniform) is pre-drawn on host with ``random.Random(seed +
chain)`` (:func:`draw_proposal_streams`), and
:func:`run_chains_reference` re-runs the identical chain sequentially in
NumPy — ``tests/test_planeval_jax.py`` pins batched-vs-sequential agreement
at fixed seeds.  Because the JAX chain explores a *pre-priced pool* rather
than proposing unbounded host subsets per step, it is a documented
different chain from ``backend="numpy"`` (same annealing rule, different
move space) — the NumPy backend is byte-stable against it.

House style: the jit/parametrized idiom follows the jaxnet excerpts in
SNIPPETS.md (compile once at construction, apply many); the Pallas kernels
under :mod:`repro.kernels` own the lower-level accelerator hot loops.
"""

from __future__ import annotations

import math
import os
import random

import numpy as np

from ..compat import ensure_x64
from .netsim import HardwareSpec
from .planeval import PlanEvaluator, plan_evaluator

__all__ = [
    "JAX_EQUIV_RTOL",
    "DEFAULT_TEMPER_LADDER",
    "have_jax",
    "pack_demand",
    "JaxPlanEvaluator",
    "jax_plan_evaluator",
    "ChainKernel",
    "check_temper_ladder",
    "default_temper_ladder",
    "draw_proposal_streams",
    "draw_grid_streams",
    "draw_swap_streams",
    "run_chains_reference",
    "run_grid_reference",
    "strategy_pool",
    "pack_jobset_grid",
    "jax_mcmc_search",
    "jax_mcmc_search_jobset",
]

# Decorrelates the pool-construction RNG from the per-chain proposal
# streams (both are seeded from the caller's one seed).
_POOL_SEED_OFFSET = 0x9E3779B9

# Decorrelates the tempering swap uniforms from the proposal streams: a
# singleton ladder draws no swap uniforms, so the proposal streams (and
# with them every pre-ladder golden) are untouched by the ladder's
# introduction.
_SWAP_SEED_OFFSET = 0x85EBCA6B

# Default parallel-tempering ladder (ascending; the coldest rung matches
# the historical single-chain temperature=0.05 regime, the hottest rung
# explores).  Override with REPRO_TEMPER_LADDER="0.05,0.1,0.2,0.4".
DEFAULT_TEMPER_LADDER = (0.05, 0.1, 0.2, 0.4)


def check_temper_ladder(temperatures) -> tuple[float, ...]:
    """Validate a tempering ladder: non-empty, positive finite, ascending.

    Returns the ladder as a float tuple.  Neighbor swap moves pair rung
    ``m`` with ``m + 1``, so the ladder must be sorted coldest-first for
    the swap acceptance rule to mean what parallel tempering means.
    """
    ladder = tuple(float(t) for t in temperatures)
    if not ladder:
        raise ValueError("temperature ladder must be non-empty")
    for t in ladder:
        if not math.isfinite(t) or t <= 0.0:
            raise ValueError(
                "ladder temperatures must be positive and finite"
            )
    if any(b < a for a, b in zip(ladder, ladder[1:])):
        raise ValueError("temperature ladder must be sorted ascending")
    return ladder


def default_temper_ladder() -> tuple[float, ...]:
    """The tempering ladder fused admission uses when the caller passes
    ``temperatures=True``-style defaults: :data:`DEFAULT_TEMPER_LADDER`,
    overridable via the ``REPRO_TEMPER_LADDER`` env knob (comma-separated
    ascending floats, e.g. ``"0.05,0.1,0.2,0.4"``)."""
    env = os.environ.get("REPRO_TEMPER_LADDER", "").strip()
    if not env:
        return DEFAULT_TEMPER_LADDER
    return check_temper_ladder(float(x) for x in env.split(","))

# Documented JAX-vs-NumPy agreement: float64 throughout (ensure_x64), but
# segment_sum/jnp.sum reassociate additions the reference performs
# sequentially, so compiled values agree to reassociation level only.
JAX_EQUIV_RTOL = 1e-9

_jax = None


def _require_jax():
    """Import jax lazily (and exactly once), pinning x64 before first use."""
    global _jax
    if _jax is None:
        ensure_x64()
        import jax  # noqa: PLC0415

        _jax = jax
    return _jax


def have_jax() -> bool:
    """True when the JAX backend can run (import succeeds)."""
    try:
        _require_jax()
        return True
    except Exception:  # pragma: no cover - jax is baked into the image
        return False


# ---------------------------------------------------------------------------
# Demand packing: one demand -> flat (link ids, byte shares) scatter arrays
# ---------------------------------------------------------------------------


def pack_demand(ev: PlanEvaluator, demand) -> tuple[np.ndarray, np.ndarray]:
    """Flatten ``demand`` into per-occurrence ``(link_ids, shares)``.

    The occurrence stream is exactly what the NumPy evaluator scatters:
    AllReduce groups in demand order (each group's ring edges in reference
    walk order, share ``2(k-1)/k * nbytes / n_rings``), then MP entries in
    ``np.nonzero`` order (each pair's route hops, share
    ``bytes / n_routes``).  ``segment_sum`` over these ids reproduces the
    reference load vector up to float reassociation.

    Compiles lazily through the shared :class:`PlanEvaluator` caches — pack
    every demand of a batch *before* reading ``ev.n_links``/``ev.caps`` so
    the link universe stops growing first.
    """
    pids, vals = ev._ensure_compiled(demand)
    ids_parts: list[np.ndarray] = []
    share_parts: list[np.ndarray] = []
    for g in demand.allreduce:
        entry = ev._group(g.members)
        if entry is None:
            continue
        ids, n_rings, k = entry
        per_link_total = 2.0 * (k - 1) / k * g.nbytes
        if per_link_total == 0.0:
            continue
        ids_parts.append(ids)
        share_parts.append(
            np.full(ids.size, per_link_total / n_rings, dtype=np.float64)
        )
    if pids.size:
        starts = ev._pair_start[pids]
        lens = ev._pair_len[pids]
        total = int(lens.sum())
        if total:
            seg_off = np.cumsum(lens) - lens
            idx = (
                np.arange(total, dtype=np.int64)
                - np.repeat(seg_off, lens)
                + np.repeat(starts, lens)
            )
            ids_parts.append(ev._mp_ids[idx])
            share_parts.append(
                np.repeat(vals / ev._pair_nroutes[pids], lens)
            )
    if not ids_parts:
        return (
            np.zeros(0, dtype=np.int64),
            np.zeros(0, dtype=np.float64),
        )
    return np.concatenate(ids_parts), np.concatenate(share_parts)


class JaxPlanEvaluator:
    """Batched demand pricing on device: K candidates, one dispatch.

    Wraps the (memoized) NumPy :class:`PlanEvaluator` of the same topology:
    structure compilation (link ids, ring incidence, routes) stays on host
    and is shared with every NumPy caller; only the scatter + bottleneck
    arithmetic moves to JAX.  Padding: each demand's occurrence stream is
    padded to the batch maximum with a sentinel id pointing one past the
    link universe (a dummy segment whose zero shares cannot leak into any
    real link).
    """

    def __init__(self, topo, hw: HardwareSpec):
        jax = _require_jax()
        self.ev = plan_evaluator(topo, hw)
        self.topo = topo
        self.hw = hw

        def _batched(idx, val, caps):
            n_links = caps.shape[0]

            def one(i, v):
                loads = jax.ops.segment_sum(
                    v, i, num_segments=n_links + 1
                )
                return jax.numpy.max(loads[:n_links] / caps)

            return jax.vmap(one)(idx, val)

        # jit recompiles per (K, pad, n_links) shape triple; shapes repeat
        # across MCMC steps, so steady-state runs hit the compile cache.
        self._batched = jax.jit(_batched)

    def pack(self, demands) -> tuple[np.ndarray, np.ndarray]:
        """Padded ``(K, pad)`` id/share arrays for a batch of demands (all
        compiled into the shared link universe first)."""
        packs = [pack_demand(self.ev, d) for d in demands]
        n_links = self.ev.n_links
        pad = max((ids.size for ids, _ in packs), default=0)
        idx = np.full((len(packs), max(pad, 1)), n_links, dtype=np.int64)
        val = np.zeros((len(packs), max(pad, 1)), dtype=np.float64)
        for row, (ids, shares) in enumerate(packs):
            idx[row, : ids.size] = ids
            val[row, : ids.size] = shares
        return idx, val

    def comm_times(self, demands) -> np.ndarray:
        """Bottleneck comm times of K demands in one device dispatch —
        agrees with :meth:`PlanEvaluator.comm_time` per demand to
        :data:`JAX_EQUIV_RTOL`."""
        demands = list(demands)
        if not demands:
            return np.zeros(0)
        idx, val = self.pack(demands)
        if self.ev.n_links:
            times = np.asarray(
                self._batched(idx, val, self.ev.caps), dtype=np.float64
            )
        else:
            times = np.zeros(len(demands))
        if self.hw.link_latency:
            from .demand import demand_steps

            times = times + self.hw.link_latency * np.asarray(
                [demand_steps(d) for d in demands]
            )
        return times

    def comm_time(self, demand) -> float:
        """Single-demand comm time through the batched kernel."""
        return float(self.comm_times([demand])[0])

    def comm(self, demand) -> dict[str, float]:
        """Drop-in for :meth:`PlanEvaluator.comm` with the comm time priced
        on device (the bandwidth tax reuses the host route cache — it is a
        per-pair average, not a hot-loop quantity)."""
        out = self.ev.comm(demand)
        return {
            "comm_time": self.comm_time(demand),
            "bandwidth_tax": out["bandwidth_tax"],
        }


def jax_plan_evaluator(topo, hw: HardwareSpec) -> JaxPlanEvaluator:
    """Memoized :class:`JaxPlanEvaluator` per (topology, hw) — the JAX
    analogue of :func:`~repro.core.planeval.plan_evaluator`, sharing its
    host-side structure caches."""
    cache = getattr(topo, "_jax_planevals", None)
    if cache is None:
        cache = {}
        topo._jax_planevals = cache
    ev = cache.get(hw)
    if ev is None:
        ev = JaxPlanEvaluator(topo, hw)
        cache[hw] = ev
    return ev


# ---------------------------------------------------------------------------
# Strategy pool: the pre-priced move space of the on-device chains
# ---------------------------------------------------------------------------


def strategy_pool(
    job, n: int, size: int, seed: int, init=None, schedules=None
) -> list:
    """A deterministic pool of ``size`` candidate strategies for one job.

    Index 0 is the chain's start state (``init`` or the cold default); the
    rest come from a fixed-seed random walk of the NumPy proposal kernel
    (:func:`~repro.core.strategy_search._propose`), deduplicated.  When the
    reachable space is smaller than ``size`` the pool is padded by cycling
    (duplicate entries are harmless: a move onto a duplicate prices
    identically to its twin).  ``schedules`` (a tuple of collective
    schedule names) widens the walk with schedule flips exactly as in the
    NumPy proposal kernel; ``None`` / single-entry keeps the walk (and its
    RNG stream) byte-identical to the pre-schedule pool.
    """
    from .strategy_search import _propose, default_strategy

    if size < 1:
        raise ValueError("strategy pool needs size >= 1")
    rng = random.Random(seed)
    current = init if init is not None else default_strategy(job)
    pool = [current]
    seen = {current}
    tries = 0
    while len(pool) < size and tries < 64 * size:
        cand = _propose(current, job, n, rng, schedules=schedules)
        tries += 1
        if cand not in seen:
            seen.add(cand)
            pool.append(cand)
        current = cand  # random-walk the space for coverage
    distinct = len(pool)
    while len(pool) < size:
        pool.append(pool[len(pool) % distinct])
    return pool


# ---------------------------------------------------------------------------
# Batched MCMC chains: K chains, one lax.scan, one dispatch
# ---------------------------------------------------------------------------


def draw_proposal_streams(
    seed: int, chains: int, iters: int, n_tenants: int, pool_size: int
):
    """Host-side randomness of K chains, pre-drawn and replayable.

    Chain ``c`` draws from ``random.Random(seed + c)`` in strict
    (tenant, pool index, acceptance uniform) per-iteration order — the
    exact stream :func:`run_chains_reference` replays sequentially, so the
    batched device run and the NumPy reference are the *same* chains.

    Returns ``(t_idx, s_idx, u)`` each of shape ``(chains, iters)``.
    """
    t_idx = np.zeros((chains, iters), dtype=np.int64)
    s_idx = np.zeros((chains, iters), dtype=np.int64)
    u = np.zeros((chains, iters), dtype=np.float64)
    for c in range(chains):
        rng = random.Random(seed + c)
        for i in range(iters):
            t_idx[c, i] = rng.randrange(n_tenants)
            s_idx[c, i] = rng.randrange(pool_size)
            u[c, i] = rng.random()
    return t_idx, s_idx, u


def draw_grid_streams(
    seed: int,
    candidates: int,
    chains: int,
    ladder: int,
    iters: int,
    n_tenants: int,
    pool_size: int,
):
    """:func:`draw_proposal_streams` lifted to the (candidate, temperature)
    grid: cell ``(ci, c, m)`` draws its own stream from
    ``random.Random(seed + c + _POOL_SEED_OFFSET * (ci * ladder + m))`` in
    the same strict (tenant, pool index, acceptance uniform) order.  The
    golden-ladder offset decorrelates cells while the degenerate cell
    ``(0, c, 0)`` reduces to exactly :func:`draw_proposal_streams`' chain
    ``c`` — the byte-identity anchor of the singleton-ladder contract.

    Returns ``(t_idx, s_idx, u)`` each of shape
    ``(candidates, chains, ladder, iters)``.
    """
    t_idx = np.zeros((candidates, chains, ladder, iters), dtype=np.int64)
    s_idx = np.zeros((candidates, chains, ladder, iters), dtype=np.int64)
    u = np.zeros((candidates, chains, ladder, iters), dtype=np.float64)
    for ci in range(candidates):
        for c in range(chains):
            for m in range(ladder):
                rng = random.Random(
                    seed + c + _POOL_SEED_OFFSET * (ci * ladder + m)
                )
                for i in range(iters):
                    t_idx[ci, c, m, i] = rng.randrange(n_tenants)
                    s_idx[ci, c, m, i] = rng.randrange(pool_size)
                    u[ci, c, m, i] = rng.random()
    return t_idx, s_idx, u


def draw_swap_streams(
    seed: int, candidates: int, chains: int, ladder: int, iters: int
) -> np.ndarray:
    """Pre-drawn swap-acceptance uniforms of the tempering ladder.

    One uniform per (iteration, neighbor pair) from a
    :data:`_SWAP_SEED_OFFSET`-shifted stream per (candidate, chain) — a
    singleton ladder has zero pairs and draws nothing, leaving the
    proposal streams byte-identical to the pre-ladder kernel.

    Returns shape ``(candidates, chains, iters, ladder // 2)``.
    """
    pairs = ladder // 2
    su = np.zeros((candidates, chains, iters, pairs), dtype=np.float64)
    for ci in range(candidates):
        for c in range(chains):
            rng = random.Random(
                seed + c + _SWAP_SEED_OFFSET + _POOL_SEED_OFFSET * ci
            )
            for i in range(iters):
                for p in range(pairs):
                    su[ci, c, i, p] = rng.random()
    return su


# Compiled grid programs, shared across ChainKernel instances: keyed by
# the scalar closure parameters; jax.jit then specializes per argument
# shape.  This is what lets the fused alternating loop rebuild its kernel
# every round (new load tensors, same shapes) without recompiling — the
# flat kernel keeps its per-instance jit (the PR 6 baseline semantics).
_GRID_PROGRAMS: dict = {}


def _grid_program(objective, overlap, alpha, total_w, has_steps):
    key = (objective, overlap, alpha, total_w, has_steps)
    fn = _GRID_PROGRAMS.get(key)
    if fn is not None:
        return fn
    jax = _require_jax()
    jnp = jax.numpy

    def _objective_rows(Vc, capsc, steps_d, w_d, comps_d, A):
        # A: (M, T) ladder of states -> (M,) objectives.  Identical
        # arithmetic to the flat kernel's _objective, vectorized over the
        # rung axis.
        T = A.shape[1]
        t_ar = jnp.arange(T)
        rows = Vc[t_ar[None, :], A]  # (M, T, L)
        if objective == "union":
            comm = jnp.max(rows.sum(axis=1) / capsc[None, :], axis=1)
            if has_steps:
                comm = comm + alpha * jnp.max(
                    steps_d[t_ar[None, :], A], axis=1
                )
            comm_t = jnp.broadcast_to(comm[:, None], A.shape)
        else:
            active = rows > 0.0
            active_w = jnp.sum(
                jnp.where(active, w_d[None, :, None], 0.0), axis=1
            )  # (M, L)
            per = jnp.where(
                active,
                rows * active_w[:, None, :]
                / (w_d[None, :, None] * capsc[None, None, :]),
                0.0,
            )
            comm_t = jnp.max(per, axis=2)  # (M, T)
            if has_steps:
                comm_t = comm_t + alpha * steps_d[t_ar[None, :], A]
        hidden = jnp.minimum(comm_t * overlap, comps_d[None, :])
        iters_t = comps_d[None, :] + comm_t - hidden
        return jnp.sum(w_d[None, :] * iters_t, axis=1) / total_w

    def _one_ladder(Vc, capsc, comps_d, w_d, steps_d, init_a, temps,
                    t_idx, s_idx, u, su, parity):
        M = t_idx.shape[0]
        P = su.shape[1]
        m_ar = jnp.arange(M)
        p_ar = jnp.arange(P)

        def step(carry, inp):
            A, cur, best_a, best = carry
            ti, si, ui, sui, par = inp
            # Per-rung annealing move (each rung mutates its own row).
            cand_A = A.at[m_ar, ti].set(si)
            cand = _objective_rows(Vc, capsc, steps_d, w_d, comps_d,
                                   cand_A)
            temp = temps * jnp.maximum(cur, 1e-12)
            accept = (cand <= cur) | (ui < jnp.exp(-(cand - cur) / temp))
            A = jnp.where(accept[:, None], cand_A, A)
            cur = jnp.where(accept, cand, cur)
            if P:
                # Even/odd neighbor swap pass: parity alternates the
                # pairing; the last pair is clipped to a self-pair
                # (valid=False) on odd ladders.
                lo = 2 * p_ar + par
                hi = lo + 1
                valid = hi < M
                lo_c = jnp.minimum(lo, M - 1)
                hi_c = jnp.minimum(hi, M - 1)
                delta = (1.0 / temps[lo_c] - 1.0 / temps[hi_c]) * (
                    cur[lo_c] - cur[hi_c]
                )
                sw = valid & (sui < jnp.exp(delta))
                A_lo, A_hi = A[lo_c], A[hi_c]
                c_lo, c_hi = cur[lo_c], cur[hi_c]
                A = A.at[lo_c].set(jnp.where(sw[:, None], A_hi, A_lo))
                A = A.at[hi_c].set(jnp.where(sw[:, None], A_lo, A_hi))
                cur = cur.at[lo_c].set(jnp.where(sw, c_hi, c_lo))
                cur = cur.at[hi_c].set(jnp.where(sw, c_lo, c_hi))
            m_star = jnp.argmin(cur)
            step_best = cur[m_star]
            better = step_best < best
            best = jnp.where(better, step_best, best)
            best_a = jnp.where(better, A[m_star], best_a)
            return (A, cur, best_a, best), step_best

        A0 = jnp.broadcast_to(init_a, (M, init_a.shape[0]))
        cur0 = _objective_rows(Vc, capsc, steps_d, w_d, comps_d, A0)
        m0 = jnp.argmin(cur0)
        (A, cur, best_a, best), hist = jax.lax.scan(
            step,
            (A0, cur0, A0[m0], cur0[m0]),
            (
                jnp.swapaxes(t_idx, 0, 1),
                jnp.swapaxes(s_idx, 0, 1),
                jnp.swapaxes(u, 0, 1),
                su,
                parity,
            ),
        )
        return best_a, best, jnp.concatenate([cur0[m0][None], hist])

    # vmap chains inside candidates: stream cells are (C, K, M, iters)
    # and swap uniforms (C, K, iters, P); V/caps/init vary per candidate,
    # the ladder, tenant tables, and parity schedule are shared.
    per_chain = jax.vmap(
        _one_ladder,
        in_axes=(None, None, None, None, None, None, None, 0, 0, 0, 0,
                 None),
    )
    fn = jax.jit(jax.vmap(
        per_chain,
        in_axes=(0, 0, None, None, None, 0, None, 0, 0, 0, 0, None),
    ))
    _GRID_PROGRAMS[key] = fn
    return fn


class ChainKernel:
    """K annealing chains over a pre-priced strategy pool, on device.

    ``V[t, s, :]`` is tenant ``t``'s cluster-level link-load vector under
    pool strategy ``s`` (priced once on host by the bit-exact NumPy
    evaluator); a chain state is one pool index per tenant.  Each scan step
    re-prices the proposed state *from scratch* — gather T rows, sum, one
    bottleneck division — so chain objectives carry no incremental float
    lineage, and the batched chains match the sequential NumPy reference to
    reassociation level.

    ``objective="union"`` anneals on the union bottleneck comm time (the
    historical jobset objective); ``objective="decomposed"`` anneals on the
    weighted per-tenant decomposed comm times
    (:func:`~repro.core.strategy_search.tenant_comm_times` semantics:
    each tenant's own bytes under weighted processor sharing of every link
    it loads).

    **Grid mode** (``V.ndim == 4``): ``V[ci, t, s, :]`` stacks one load
    tensor per placement candidate, padded to the widest candidate's link
    table (dummy links carry zero load against ``caps[ci, pad:]``, so they
    can never win a bottleneck); ``caps`` becomes ``(C, L)``.  Each chain
    then carries a whole parallel-tempering ladder: every scan step applies
    the annealing rule to all ``M`` rungs at once, follows with a
    deterministic even/odd neighbor swap pass (Metropolis swap acceptance
    ``su < exp((1/T_lo - 1/T_hi) * (E_lo - E_hi))`` on pre-drawn host
    uniforms, iteration parity alternating the pairing), and tracks the
    per-(candidate, chain) best state across rungs — the whole
    (candidate x chain x rung) grid in **one** jit dispatch
    (:meth:`run_grid`).  A singleton ladder performs no swap pass and
    replays the flat kernel's decisions exactly.
    """

    def __init__(
        self,
        V: np.ndarray,  # (T, S, L) load vectors; (C, T, S, L) = grid mode
        caps: np.ndarray,  # (L,); (C, L) in grid mode
        comps: np.ndarray,  # (T,) per-tenant compute times
        weights: np.ndarray,  # (T,) tenant weights
        overlap: float = 0.0,
        objective: str = "union",
        steps: np.ndarray | None = None,  # (T, S) latency rounds per entry
        alpha: float = 0.0,  # per-round link latency (hw.link_latency)
    ):
        jax = _require_jax()
        jnp = jax.numpy
        if objective not in ("union", "decomposed"):
            raise ValueError(f"unknown chain objective {objective!r}")
        self.objective = objective
        self.grid = V.ndim == 4
        if self.grid:
            self._init_grid(V, caps, comps, weights, overlap, objective,
                            steps, alpha)
            return
        T, S, L = V.shape
        self.shape = (T, S, L)
        V_d = jnp.asarray(V, dtype=jnp.float64)
        caps_d = jnp.asarray(caps, dtype=jnp.float64)
        comps_d = jnp.asarray(comps, dtype=jnp.float64)
        w_d = jnp.asarray(weights, dtype=jnp.float64)
        total_w = float(np.sum(weights))
        t_arange = jnp.arange(T)
        alpha = float(alpha)
        steps_d = (
            jnp.asarray(steps, dtype=jnp.float64)
            if steps is not None and alpha
            else None
        )

        def _objective(a):
            rows = V_d[t_arange, a]  # (T, L)
            if objective == "union":
                comm = jnp.max(rows.sum(axis=0) / caps_d)
                if steps_d is not None:
                    # Union latency rounds = the worst tenant's rounds
                    # (remap/union preserve group sizes and pinned steps).
                    comm = comm + alpha * jnp.max(steps_d[t_arange, a])
                comm_t = jnp.full((T,), comm)
            else:
                active = rows > 0.0
                active_w = jnp.sum(
                    jnp.where(active, w_d[:, None], 0.0), axis=0
                )  # (L,) contending weight per link
                per = jnp.where(
                    active,
                    rows * active_w[None, :]
                    / (w_d[:, None] * caps_d[None, :]),
                    0.0,
                )
                comm_t = jnp.max(per, axis=1)
                if steps_d is not None:
                    comm_t = comm_t + alpha * steps_d[t_arange, a]
            hidden = jnp.minimum(comm_t * overlap, comps_d)
            iters_t = comps_d + comm_t - hidden
            return jnp.sum(w_d * iters_t) / total_w

        def _one_chain(init_a, temperature, t_idx, s_idx, u):
            def step(carry, inp):
                a, cur, best_a, best = carry
                ti, si, ui = inp
                cand_a = a.at[ti].set(si)
                cand = _objective(cand_a)
                temp = temperature * jnp.maximum(cur, 1e-12)
                accept = (cand <= cur) | (
                    ui < jnp.exp(-(cand - cur) / temp)
                )
                a = jnp.where(accept, cand_a, a)
                cur = jnp.where(accept, cand, cur)
                better = accept & (cand < best)
                best_a = jnp.where(better, cand_a, best_a)
                best = jnp.where(better, cand, best)
                return (a, cur, best_a, best), cur

            cur0 = _objective(init_a)
            (a, cur, best_a, best), hist = jax.lax.scan(
                step, (init_a, cur0, init_a, cur0), (t_idx, s_idx, u)
            )
            return best_a, best, jnp.concatenate([cur0[None], hist])

        self._run = jax.jit(
            jax.vmap(_one_chain, in_axes=(None, 0, 0, 0, 0))
        )
        self._objective_np = None  # built on demand for the reference path

    def run(
        self,
        init_a: np.ndarray,  # (T,) shared start state
        temperatures: np.ndarray,  # (K,) per-chain temperature
        t_idx: np.ndarray,  # (K, iters)
        s_idx: np.ndarray,
        u: np.ndarray,
    ):
        """All K chains in one dispatch.  Returns
        ``(best_assignments (K, T), best_objs (K,), history (K, iters+1))``
        as NumPy arrays."""
        if self.grid:
            raise ValueError("grid-mode ChainKernel runs via run_grid()")
        jnp = _require_jax().numpy
        best_a, best, hist = self._run(
            jnp.asarray(init_a, dtype=jnp.int64),
            jnp.asarray(temperatures, dtype=jnp.float64),
            jnp.asarray(t_idx, dtype=jnp.int64),
            jnp.asarray(s_idx, dtype=jnp.int64),
            jnp.asarray(u, dtype=jnp.float64),
        )
        return (
            np.asarray(best_a),
            np.asarray(best, dtype=np.float64),
            np.asarray(hist, dtype=np.float64),
        )

    def _init_grid(self, V, caps, comps, weights, overlap, objective,
                   steps, alpha):
        jnp = _require_jax().numpy
        C, T, S, L = V.shape
        caps = np.asarray(caps, dtype=np.float64)
        if caps.shape != (C, L):
            raise ValueError(
                f"grid caps must have shape {(C, L)}, got {caps.shape}"
            )
        self.shape = (T, S, L)
        self.grid_shape = (C, T, S, L)
        self._V_g = jnp.asarray(V, dtype=jnp.float64)
        self._caps_g = jnp.asarray(caps, dtype=jnp.float64)
        self._comps_g = jnp.asarray(comps, dtype=jnp.float64)
        self._w_g = jnp.asarray(weights, dtype=jnp.float64)
        alpha = float(alpha)
        self._steps_g = (
            jnp.asarray(steps, dtype=jnp.float64)
            if steps is not None and alpha
            else None
        )
        # The compiled grid program is shared across kernel instances
        # (keyed by the scalar parameters, shape-specialized by jit), so
        # rebuilding the kernel every alternating round costs no
        # recompile as long as the padded grid shapes repeat.
        self._run_grid_fn = _grid_program(
            objective, float(overlap), alpha, float(np.sum(weights)),
            self._steps_g is not None,
        )

    def run_grid(
        self,
        init_a: np.ndarray,  # (C, T) per-candidate start states
        temperatures: np.ndarray,  # (M,) ascending tempering ladder
        t_idx: np.ndarray,  # (C, K, M, iters)
        s_idx: np.ndarray,
        u: np.ndarray,
        swap_u: np.ndarray,  # (C, K, iters, M // 2)
        device: bool = False,
    ):
        """The whole (candidate x chain x rung) grid in one dispatch.

        Returns ``(best_assignments (C, K, T), best_objs (C, K),
        history (C, K, iters + 1))`` — history is the running
        min-over-rungs objective.  ``device=True`` returns the raw JAX
        arrays so callers (the fused alternating loop) can hand the winner
        indices straight back into the next round's dispatch without a
        host round-trip.
        """
        if not self.grid:
            raise ValueError("flat ChainKernel runs via run()")
        jax = _require_jax()
        jnp = jax.numpy
        iters = t_idx.shape[3]
        parity = jnp.asarray(np.arange(iters, dtype=np.int64) % 2)
        best_a, best, hist = self._run_grid_fn(
            self._V_g,
            self._caps_g,
            self._comps_g,
            self._w_g,
            self._steps_g,
            jnp.asarray(init_a, dtype=jnp.int64),
            jnp.asarray(temperatures, dtype=jnp.float64),
            jnp.asarray(t_idx, dtype=jnp.int64),
            jnp.asarray(s_idx, dtype=jnp.int64),
            jnp.asarray(u, dtype=jnp.float64),
            jnp.asarray(swap_u, dtype=jnp.float64),
            parity,
        )
        if device:
            return best_a, best, hist
        return (
            np.asarray(best_a),
            np.asarray(best, dtype=np.float64),
            np.asarray(hist, dtype=np.float64),
        )


def _objective_reference(
    V: np.ndarray,
    caps: np.ndarray,
    comps: np.ndarray,
    weights: np.ndarray,
    overlap: float,
    objective: str,
    a: np.ndarray,
    steps: np.ndarray | None = None,
    alpha: float = 0.0,
) -> float:
    """NumPy mirror of :class:`ChainKernel`'s on-device objective."""
    T = V.shape[0]
    rows = V[np.arange(T), a]
    if objective == "union":
        comm = np.max(rows.sum(axis=0) / caps)
        if steps is not None and alpha:
            comm = comm + alpha * np.max(steps[np.arange(T), a])
        comm_t = np.full(T, comm)
    else:
        active = rows > 0.0
        active_w = np.where(active, weights[:, None], 0.0).sum(axis=0)
        per = np.where(
            active,
            rows * active_w[None, :] / (weights[:, None] * caps[None, :]),
            0.0,
        )
        comm_t = per.max(axis=1)
        if steps is not None and alpha:
            comm_t = comm_t + alpha * steps[np.arange(T), a]
    hidden = np.minimum(comm_t * overlap, comps)
    iters_t = comps + comm_t - hidden
    return float(np.sum(weights * iters_t) / np.sum(weights))


def _run_cell_or_grid(
    V, caps, comps, weights, overlap, objective, steps, alpha,
    seed, chains, iters, T, S, temperature, temperatures,
):
    """Dispatch one jobset search: the flat K-chain kernel when no ladder
    is requested, the C=1 grid kernel under a tempering ladder.  Returns
    ``(best_a (K', T), best_obj (K',), hist (K', iters + 1))`` with the
    grid's candidate axis squeezed away."""
    if temperatures is None:
        kernel = ChainKernel(
            V, caps, comps, weights, overlap=overlap, objective=objective,
            steps=steps, alpha=alpha,
        )
        t_idx, s_idx, u = draw_proposal_streams(seed, chains, iters, T, S)
        return kernel.run(
            np.zeros(T, dtype=np.int64),
            np.full(chains, temperature, dtype=np.float64),
            t_idx, s_idx, u,
        )
    ladder = np.asarray(check_temper_ladder(temperatures), dtype=np.float64)
    M = ladder.size
    kernel = ChainKernel(
        V[None], np.asarray(caps, dtype=np.float64)[None], comps, weights,
        overlap=overlap, objective=objective, steps=steps, alpha=alpha,
    )
    t_idx, s_idx, u = draw_grid_streams(seed, 1, chains, M, iters, T, S)
    su = draw_swap_streams(seed, 1, chains, M, iters)
    best_a, best_obj, hist = kernel.run_grid(
        np.zeros((1, T), dtype=np.int64), ladder, t_idx, s_idx, u, su,
    )
    return best_a[0], best_obj[0], hist[0]


def jax_mcmc_search(
    job,
    topo,
    hw: HardwareSpec,
    iters: int = 200,
    temperature: float = 0.1,
    overlap: float = 0.0,
    seed: int = 0,
    init=None,
    chains: int = 1,
    pool_size: int = 64,
    schedules=None,
    temperatures=None,
):
    """Batched single-job strategy search — the ``backend="jax"`` body of
    :func:`~repro.core.strategy_search.mcmc_search`.

    The pool's load vectors are priced once on host by the bit-exact
    evaluator; all ``chains`` annealing chains then run in one device
    dispatch (:class:`ChainKernel` with one tenant).  The winning
    strategy's reported ``iter_time`` is re-priced on the NumPy path, so
    result values carry no device float lineage; ``history`` is the best
    chain's on-device objective trace.  ``schedules`` widens the pool with
    collective-schedule flips; with ``hw.link_latency`` set the chains
    anneal on the same (α, β) objective the NumPy path prices.

    ``temperatures`` replaces the single ``temperature`` with a
    parallel-tempering ladder run through the grid kernel — a singleton
    ladder ``(t,)`` replays the flat ``temperature=t`` chains' decisions
    exactly (same proposal streams, no swap draws).
    """
    from .demand import demand_steps
    from .netsim import _iteration_time as iteration_time, compute_time
    from .strategy_search import SearchResult

    n = topo.n
    pool = strategy_pool(
        job, n, pool_size, seed + _POOL_SEED_OFFSET, init=init,
        schedules=schedules,
    )
    ev = plan_evaluator(topo, hw)
    demands = [s.demand(job, n) for s in pool]
    vecs = [ev.loads(d) for d in demands]  # grows the link universe
    L = ev.n_links
    S = len(pool)
    V = np.zeros((1, S, max(L, 1)), dtype=np.float64)
    for s, v in enumerate(vecs):
        V[0, s, : v.size] = v
    caps = ev.caps if L else np.ones(1)
    comp = compute_time(job.flops_per_sample * job.batch_per_gpu * n, n, hw)
    steps = (
        np.asarray([[demand_steps(d) for d in demands]], dtype=np.float64)
        if hw.link_latency
        else None
    )
    best_a, best_obj, hist = _run_cell_or_grid(
        V, caps, np.array([comp]), np.array([1.0]), overlap, "union",
        steps, hw.link_latency, seed, chains, iters, 1, S,
        temperature, temperatures,
    )
    c = int(np.argmin(best_obj))
    strategy = pool[int(best_a[c, 0])]
    demand = demands[int(best_a[c, 0])]
    iter_time = iteration_time(ev.comm_time(demand), comp, overlap=overlap)
    return SearchResult(
        strategy=strategy, iter_time=iter_time, demand=demand,
        history=[float(h) for h in hist[c]],
    )


def jax_mcmc_search_jobset(
    jobset,
    topo,
    hw: HardwareSpec,
    iters: int = 200,
    temperature: float = 0.1,
    overlap: float = 0.0,
    seed: int = 0,
    init=None,
    chains: int = 1,
    pool_size: int = 64,
    objective: str = "union",
    demand_cache=None,
    schedules=None,
    temperatures=None,
):
    """Batched multi-tenant strategy search — the ``backend="jax"`` body of
    :func:`~repro.core.strategy_search.mcmc_search_jobset`.

    Per tenant, a pool of ``pool_size`` candidate strategies is priced once
    into cluster-level link-load vectors (through the incremental
    evaluator's caches, so repeat pricings are shared with the NumPy path);
    ``chains`` chains of per-tenant pool moves then anneal in one dispatch
    under the requested objective.  The winner's reported
    ``iter_time``/``per_job`` are re-priced on the bit-exact NumPy path
    (union) or the reference decomposition (decomposed).

    ``temperatures`` swaps the single ``temperature`` for a
    parallel-tempering ladder through the grid kernel; the singleton
    ladder replays the flat kernel's decisions exactly.
    """
    from .netsim import compute_time
    from .planeval import JobSetEvaluator, LRUCache
    from .strategy_search import (
        JobSetSearchResult,
        demand_cache_size,
        default_strategy,
        evaluate_jobset,
        evaluate_jobset_decomposed,
    )

    if not jobset.tenants:
        raise ValueError("jax_mcmc_search_jobset needs at least one tenant")
    if demand_cache is None:
        demand_cache = LRUCache(demand_cache_size())
    jse = JobSetEvaluator(
        jobset, topo, hw, overlap=overlap, demand_cache=demand_cache
    )
    tenants = jobset.tenants
    T = len(tenants)
    init = init or {}
    pools = []
    for i, t in enumerate(tenants):
        start = init.get(t.label) or default_strategy(t.spec)
        pools.append(strategy_pool(
            t.spec, t.k, pool_size, seed + _POOL_SEED_OFFSET + i,
            init=start, schedules=schedules,
        ))
    # Price every pool entry first (the link universe grows as new MP
    # routes are compiled), then pad all vectors to the final width.
    vecs = [
        [jse.tenant_loads_at(t.label, s, t.servers) for s in pools[i]]
        for i, t in enumerate(tenants)
    ]
    L = jse.ev.n_links
    S = pool_size
    V = np.zeros((T, S, max(L, 1)), dtype=np.float64)
    for i in range(T):
        for s, v in enumerate(vecs[i]):
            V[i, s, : v.size] = v
    caps = jse.ev.caps if L else np.ones(1)
    comps = np.array([
        compute_time(t.flops_per_iteration, t.k, hw) for t in tenants
    ])
    weights = np.array([t.weight for t in tenants], dtype=np.float64)
    steps = None
    if hw.link_latency:
        # Per-(tenant, pool entry) latency rounds of the tenant's *local*
        # embedded demand — union rounds are the max over tenants, which
        # the kernel's union objective takes per chain state.
        steps = np.asarray([
            [jse._steps(t.label, s) for s in pools[i]]
            for i, t in enumerate(tenants)
        ], dtype=np.float64)
    best_a, best_obj, hist = _run_cell_or_grid(
        V, caps, comps, weights, overlap, objective, steps,
        hw.link_latency, seed, chains, iters, T, S,
        temperature, temperatures,
    )
    c = int(np.argmin(best_obj))
    best = {
        t.label: pools[i][int(best_a[c, i])] for i, t in enumerate(tenants)
    }
    if objective == "decomposed":
        obj, per_job = evaluate_jobset_decomposed(
            best, jobset, topo, hw, overlap, _demand_cache=demand_cache
        )
        union = jse.union_for(best)
    else:
        obj, union, per_job = evaluate_jobset(
            best, jobset, topo, hw, overlap,
            _demand_cache=demand_cache, compiled=True,
        )
    return JobSetSearchResult(
        strategies=best, iter_time=obj, demand=union, per_job=per_job,
        history=[float(h) for h in hist[c]],
    )


def pack_jobset_grid(
    candidates,  # list[JobSet]: same tenants, different placements
    topos,  # list[Topology], one search topology per candidate
    hw: HardwareSpec,
    pools,  # list[list[Strategy]], one pre-built pool per tenant
    overlap: float = 0.0,
    demand_cache=None,
    pad_cap: float = 1.0,
    pad_to: int = 32,
):
    """Stack per-candidate pool pricings into the padded grid tensors.

    Each candidate's pool entries are priced on its own topology through
    the incremental :class:`~repro.core.planeval.JobSetEvaluator` (one
    shared per-tenant demand cache serves all candidates — job-local
    demands are placement-independent), then every candidate's link table
    is padded to the widest one: dummy links carry zero load against
    capacity ``pad_cap``, so they can never win a bottleneck max nor
    activate in the decomposed objective, whatever ``pad_cap > 0`` is.

    ``pad_to`` additionally rounds the link axis up to a bucket multiple
    so the grid shape repeats across alternating rounds (and admissions of
    similar size) — repeated shapes hit the shared compiled grid program's
    jit cache instead of recompiling per round.

    Returns ``(V (C, T, S, L), caps (C, L), comps (T,), weights (T,),
    steps (T, S) | None, evaluators)``.
    """
    from .netsim import compute_time
    from .planeval import JobSetEvaluator, LRUCache
    from .strategy_search import demand_cache_size

    if demand_cache is None:
        demand_cache = LRUCache(demand_cache_size())
    labels = [t.label for t in candidates[0].tenants]
    for js in candidates:
        if [t.label for t in js.tenants] != labels:
            raise ValueError(
                "grid candidates must list the same tenants in the same "
                "order"
            )
    evs = []
    vecs_per = []
    for js, topo in zip(candidates, topos):
        jse = JobSetEvaluator(
            js, topo, hw, overlap=overlap, demand_cache=demand_cache
        )
        # Price every entry before reading n_links: the link universe
        # grows as new MP routes compile.
        vecs = [
            [jse.tenant_loads_at(t.label, s, t.servers) for s in pools[i]]
            for i, t in enumerate(js.tenants)
        ]
        evs.append(jse)
        vecs_per.append(vecs)
    C, T, S = len(candidates), len(labels), len(pools[0])
    L = max(max(jse.ev.n_links for jse in evs), 1)
    if pad_to > 1:
        L = -(-L // pad_to) * pad_to
    V = np.zeros((C, T, S, L), dtype=np.float64)
    caps = np.full((C, L), float(pad_cap), dtype=np.float64)
    for ci, (jse, vecs) in enumerate(zip(evs, vecs_per)):
        nl = jse.ev.n_links
        if nl:
            caps[ci, :nl] = jse.ev.caps
        for i in range(T):
            for s, v in enumerate(vecs[i]):
                V[ci, i, s, : v.size] = v
    tenants = candidates[0].tenants
    comps = np.array(
        [compute_time(t.flops_per_iteration, t.k, hw) for t in tenants]
    )
    weights = np.array([t.weight for t in tenants], dtype=np.float64)
    steps = None
    if hw.link_latency:
        # Latency rounds are placement-independent (group sizes and pinned
        # steps survive remapping), so one candidate's table serves all.
        steps = np.asarray([
            [evs[0]._steps(t.label, s) for s in pools[i]]
            for i, t in enumerate(tenants)
        ], dtype=np.float64)
    return V, caps, comps, weights, steps, evs


def run_chains_reference(
    V: np.ndarray,
    caps: np.ndarray,
    comps: np.ndarray,
    weights: np.ndarray,
    overlap: float,
    objective: str,
    init_a: np.ndarray,
    temperatures: np.ndarray,
    t_idx: np.ndarray,
    s_idx: np.ndarray,
    u: np.ndarray,
    steps: np.ndarray | None = None,
    alpha: float = 0.0,
):
    """Sequential NumPy replay of the batched chains: same pre-drawn
    streams, same annealing rule, one chain at a time — the equivalence
    oracle ``tests/test_planeval_jax.py`` pins the device kernel against."""
    K, iters = t_idx.shape
    T = V.shape[0]
    best_as = np.zeros((K, T), dtype=np.int64)
    bests = np.zeros(K, dtype=np.float64)
    hists = np.zeros((K, iters + 1), dtype=np.float64)
    for c in range(K):
        a = np.array(init_a, dtype=np.int64)
        cur = _objective_reference(
            V, caps, comps, weights, overlap, objective, a,
            steps=steps, alpha=alpha,
        )
        best_a, best = a.copy(), cur
        hists[c, 0] = cur
        for i in range(iters):
            cand_a = a.copy()
            cand_a[t_idx[c, i]] = s_idx[c, i]
            cand = _objective_reference(
                V, caps, comps, weights, overlap, objective, cand_a,
                steps=steps, alpha=alpha,
            )
            temp = temperatures[c] * max(cur, 1e-12)
            if cand <= cur or u[c, i] < math.exp(-(cand - cur) / temp):
                a, cur = cand_a, cand
                if cand < best:
                    best_a, best = cand_a.copy(), cand
            hists[c, i + 1] = cur
        best_as[c] = best_a
        bests[c] = best
    return best_as, bests, hists


def _swap_pass_reference(
    A: np.ndarray,  # (M, T) ladder states, mutated in place
    cur: np.ndarray,  # (M,) ladder energies, mutated in place
    temps: np.ndarray,  # (M,) ascending ladder
    su: np.ndarray,  # (M // 2,) swap uniforms of this iteration
    parity: int,
):
    """One even/odd neighbor swap pass — the host mirror of the grid
    kernel's tempering exchange (same clipping of the out-of-range last
    pair, same Metropolis swap acceptance)."""
    M = cur.shape[0]
    for p in range(M // 2):
        lo = 2 * p + parity
        hi = lo + 1
        if hi >= M:
            continue
        delta = (1.0 / temps[lo] - 1.0 / temps[hi]) * (cur[lo] - cur[hi])
        # exp saturates above ~709; any delta past ~50 already accepts
        # with certainty against a uniform < 1 (the device side computes
        # exp(delta) = inf, which accepts identically).
        if su[p] < math.exp(min(delta, 50.0)):
            A[[lo, hi]] = A[[hi, lo]]
            cur[lo], cur[hi] = cur[hi], cur[lo]
    return A, cur


def run_grid_reference(
    V: np.ndarray,  # (C, T, S, L)
    caps: np.ndarray,  # (C, L)
    comps: np.ndarray,
    weights: np.ndarray,
    overlap: float,
    objective: str,
    init_a: np.ndarray,  # (C, T)
    temperatures: np.ndarray,  # (M,)
    t_idx: np.ndarray,  # (C, K, M, iters)
    s_idx: np.ndarray,
    u: np.ndarray,
    swap_u: np.ndarray,  # (C, K, iters, M // 2)
    steps: np.ndarray | None = None,
    alpha: float = 0.0,
):
    """Sequential NumPy replay of the fused (candidate x chain x rung)
    grid: one cell at a time, same pre-drawn streams, same per-rung
    annealing rule, same even/odd swap passes — the equivalence oracle the
    property tests pin :meth:`ChainKernel.run_grid` against."""
    C, K, M, iters = t_idx.shape
    T = V.shape[1]
    temps = np.asarray(temperatures, dtype=np.float64)
    best_as = np.zeros((C, K, T), dtype=np.int64)
    bests = np.zeros((C, K), dtype=np.float64)
    hists = np.zeros((C, K, iters + 1), dtype=np.float64)

    def obj(ci, a):
        return _objective_reference(
            V[ci], caps[ci], comps, weights, overlap, objective, a,
            steps=steps, alpha=alpha,
        )

    for ci in range(C):
        for c in range(K):
            A = np.tile(init_a[ci].astype(np.int64), (M, 1))
            cur = np.array([obj(ci, A[m]) for m in range(M)])
            m0 = int(np.argmin(cur))
            best_a, best = A[m0].copy(), cur[m0]
            hists[ci, c, 0] = cur[m0]
            for i in range(iters):
                for m in range(M):
                    cand_a = A[m].copy()
                    cand_a[t_idx[ci, c, m, i]] = s_idx[ci, c, m, i]
                    cand = obj(ci, cand_a)
                    temp = temps[m] * max(cur[m], 1e-12)
                    if cand <= cur[m] or u[ci, c, m, i] < math.exp(
                        -(cand - cur[m]) / temp
                    ):
                        A[m] = cand_a
                        cur[m] = cand
                if M > 1:
                    _swap_pass_reference(
                        A, cur, temps, swap_u[ci, c, i], i % 2
                    )
                m_star = int(np.argmin(cur))
                if cur[m_star] < best:
                    best_a, best = A[m_star].copy(), cur[m_star]
                hists[ci, c, i + 1] = cur[m_star]
            best_as[ci, c] = best_a
            bests[ci, c] = best
    return best_as, bests, hists
