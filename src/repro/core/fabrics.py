"""Baseline fabrics simulated in §5: expander, SiP-ML ring, and helpers to
evaluate any direct-connect graph with the same fluid model as TopoOpt.
"""

from __future__ import annotations

import networkx as nx
import numpy as np

from .demand import TrafficDemand
from .netsim import HardwareSpec, _ring_bytes_per_link, mp_flows
from .routing import RoutingTable, link_loads
from .topology_finder import Topology


def _all_pairs_shortest_routing(graph: nx.MultiDiGraph) -> RoutingTable:
    table = RoutingTable()
    simple = nx.DiGraph(graph)
    for src, paths in nx.all_pairs_shortest_path(simple):
        for dst, path in paths.items():
            if src != dst:
                table.add(src, dst, tuple(path))
    return table


def expander_topology(n: int, degree: int, seed: int = 0) -> Topology:
    """Jellyfish/Xpander-style random regular direct-connect graph."""
    und = nx.random_regular_graph(degree, n, seed=seed)
    g = nx.MultiDiGraph()
    g.add_nodes_from(range(n))
    for a, b in und.edges():
        g.add_edge(a, b, kind="mp")
        g.add_edge(b, a, kind="mp")
    topo = Topology(n=n, degree=degree, graph=g, d_allreduce=0, d_mp=degree)
    topo.routing = _all_pairs_shortest_routing(g)
    return topo


def sipml_ring_topology(n: int, degree: int) -> Topology:
    """SiP-ML SiP-Ring-like physical ring: node i connects to i±1 ... i±d/2
    (wavelengths around a ring)."""
    g = nx.MultiDiGraph()
    g.add_nodes_from(range(n))
    half = max(1, degree // 2)
    for i in range(n):
        for off in range(1, half + 1):
            g.add_edge(i, (i + off) % n, kind="mp")
            g.add_edge(i, (i - off) % n, kind="mp")
    topo = Topology(n=n, degree=degree, graph=g, d_allreduce=0, d_mp=degree)
    topo.routing = _all_pairs_shortest_routing(g)
    return topo


def generic_comm_time(
    topo: Topology, demand: TrafficDemand, hw: HardwareSpec
) -> float:
    """Fluid comm time for a fixed (non-TopoOpt) direct-connect fabric:
    AllReduce rides a logical ring embedded via the routing table (no
    mutability optimization), MP follows shortest paths."""
    loads: dict[tuple[int, int], float] = {}

    for group in demand.allreduce:
        k = len(group.members)
        per_link = _ring_bytes_per_link(group.nbytes, k)
        if per_link == 0.0:
            continue
        # Default (stride-1) ring embedded on the fabric via routing.
        for idx in range(k):
            a = group.members[idx]
            b = group.members[(idx + 1) % k]
            routes = topo.routing.get(a, b)
            if not routes:
                continue
            share = per_link / len(routes)
            for r in routes:
                for u, v in zip(r.path[:-1], r.path[1:]):
                    loads[(u, v)] = loads.get((u, v), 0.0) + share

    flows = mp_flows(demand)
    for link, nbytes in link_loads(topo.graph, flows, topo.routing).items():
        loads[link] = loads.get(link, 0.0) + nbytes

    n_par: dict[tuple[int, int], int] = {}
    for a, b in topo.graph.edges():
        n_par[(a, b)] = n_par.get((a, b), 0) + 1
    worst = 0.0
    for link, nbytes in loads.items():
        worst = max(worst, nbytes / (max(1, n_par.get(link, 1)) * hw.link_bandwidth))
    return worst
