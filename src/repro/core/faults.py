"""Seeded fault injection: transient fiber flaps, correlated failure
domains, and the disruption streams they induce (§7 failure handling).

A :class:`FaultModel` turns per-component MTBF/MTTR parameters into two
equivalent fault streams:

* :meth:`FaultModel.link_failures` — engine-granularity
  :class:`~repro.core.simengine.LinkFailure` events (absolute seconds,
  ``repair_time`` set) for :class:`~repro.core.simengine.Scenario` runs;
* :meth:`FaultModel.events` — iteration-granularity
  :class:`~repro.core.online.TraceEvent` fail/repair pairs for the online
  drivers (:func:`~repro.core.online.run_online` /
  :func:`~repro.core.online.run_online_jobset`).

Components fail as independent renewal processes (exponential inter-failure
times with mean ``mtbf``, exponential outage durations with mean ``mttr``):

* every fiber pair in :attr:`FaultModel.links` flaps on its own
  (``link_mtbf`` / ``link_mttr``);
* every :class:`FaultDomain` takes out its *whole* link set atomically —
  :func:`server_domain` (a server or its NIC dies: all incident fibers go
  down together) and :func:`stride_domain` (an OCS plane / patch-panel
  tray dies: the entire stride group of the ring fabric goes with it)
  build the two correlated shapes the paper's fault analysis needs.

Determinism: component ``i`` draws from ``np.random.default_rng((seed, i))``
— its own counter-based substream — so adding or removing a domain never
shifts any other component's timeline, and the same seed reproduces the
same storm bit for bit.  Overlapping outages of the same pair (its own flap
plus a domain cut) are union-merged per pair before emission, so every
``fail`` has exactly one matching ``repair`` and the engine's capacity
snapshots can never double-cut.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .online import TraceEvent
from .simengine import LinkFailure

__all__ = [
    "FaultDomain",
    "FaultModel",
    "server_domain",
    "stride_domain",
]

# An exponential outage duration is almost surely positive, but LinkFailure
# demands repair strictly after failure — floor the duration defensively.
_MIN_OUTAGE_S = 1e-12


def _norm(pair: Iterable[int]) -> tuple[int, int]:
    a, b = pair
    return (min(int(a), int(b)), max(int(a), int(b)))


@dataclass(frozen=True)
class FaultDomain:
    """A correlated failure domain: every pair in ``links`` dies *and*
    repairs atomically (one shared outage clock).

    ``mtbf`` is the mean seconds between the domain's failures, ``mttr``
    the mean outage duration — e.g. a server power-cycle takes all of its
    fibers down for the reboot, an OCS plane swap takes a whole stride
    group down for the maintenance window."""

    name: str
    links: tuple[tuple[int, int], ...]
    mtbf: float
    mttr: float

    def __post_init__(self):
        object.__setattr__(
            self, "links", tuple(sorted({_norm(p) for p in self.links}))
        )
        if self.mtbf <= 0 or self.mttr <= 0:
            raise ValueError(
                f"domain {self.name!r} needs positive mtbf/mttr, got "
                f"{self.mtbf}/{self.mttr}"
            )


def server_domain(
    server: int,
    links: Iterable[tuple[int, int]],
    mtbf: float,
    mttr: float,
    name: str | None = None,
) -> FaultDomain:
    """The correlated domain of a server (or its NIC) dying: every fiber
    pair incident to ``server`` in ``links`` fails atomically."""
    pairs = sorted({_norm(p) for p in links if server in (p[0], p[1])})
    if not pairs:
        raise ValueError(f"server {server} has no incident links")
    return FaultDomain(
        name=name or f"server{server}", links=tuple(pairs),
        mtbf=mtbf, mttr=mttr,
    )


def stride_domain(
    n: int,
    stride: int,
    mtbf: float,
    mttr: float,
    name: str | None = None,
) -> FaultDomain:
    """The correlated domain of an OCS plane / patch-panel tray dying: the
    whole stride group ``{(i, (i + stride) mod n)}`` — one ring fabric's
    worth of fibers, the unit an optical plane carries — fails atomically."""
    if not 0 < stride < n:
        raise ValueError(f"stride {stride} must be in (0, {n})")
    pairs = sorted({_norm((i, (i + stride) % n)) for i in range(n)})
    return FaultDomain(
        name=name or f"stride{stride}", links=tuple(pairs),
        mtbf=mtbf, mttr=mttr,
    )


@dataclass
class FaultModel:
    """Seeded generator of transient-fault storms over a fabric.

    ``links`` is the fiber population subject to independent flapping
    (``link_mtbf`` / ``link_mttr``; ``link_mtbf=None`` disables flaps so a
    model can carry only correlated domains).  ``domains`` adds correlated
    failure domains on top.  All times are seconds."""

    n: int
    links: tuple[tuple[int, int], ...] = ()
    link_mtbf: float | None = None
    link_mttr: float = 1.0
    domains: list[FaultDomain] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self.links = tuple(sorted({_norm(p) for p in self.links}))
        if self.link_mtbf is not None and self.link_mtbf <= 0:
            raise ValueError(f"link_mtbf must be positive, got {self.link_mtbf}")
        if self.link_mttr <= 0:
            raise ValueError(f"link_mttr must be positive, got {self.link_mttr}")

    @classmethod
    def for_topology(
        cls,
        topo,
        link_mtbf: float | None = None,
        link_mttr: float = 1.0,
        domains: list[FaultDomain] | None = None,
        seed: int = 0,
    ) -> "FaultModel":
        """A model whose fiber population is ``topo``'s live pairs."""
        pairs = sorted({_norm((a, b)) for a, b in topo.graph.edges()})
        return cls(
            n=topo.n, links=tuple(pairs), link_mtbf=link_mtbf,
            link_mttr=link_mttr, domains=list(domains or []), seed=seed,
        )

    # -- renewal-process generation ------------------------------------------

    def _components(self) -> list[tuple[tuple[tuple[int, int], ...], float, float]]:
        """(pairs, mtbf, mttr) per independent failure clock.  Flapping
        fibers come first in a fixed sorted order, then the domains in
        declaration order — so component ``i``'s substream is stable under
        adding/removing *later* components."""
        comps: list[tuple[tuple[tuple[int, int], ...], float, float]] = []
        if self.link_mtbf is not None:
            for pair in self.links:
                comps.append(((pair,), self.link_mtbf, self.link_mttr))
        for d in self.domains:
            comps.append((d.links, d.mtbf, d.mttr))
        return comps

    def outages(self, horizon: float) -> dict[tuple[int, int], list[tuple[float, float]]]:
        """Per-pair union-merged outage intervals ``[(t_fail, t_repair),
        ...]`` over ``[0, horizon)`` seconds, each list sorted and
        non-overlapping.  Repairs may land past the horizon (an outage in
        progress when the storm window closes still heals eventually)."""
        raw: dict[tuple[int, int], list[tuple[float, float]]] = {}
        for idx, (pairs, mtbf, mttr) in enumerate(self._components()):
            rng = np.random.default_rng((self.seed, idx))
            t = 0.0
            while True:
                t += float(rng.exponential(mtbf))
                if t >= horizon:
                    break
                t_rep = t + max(float(rng.exponential(mttr)), _MIN_OUTAGE_S)
                for pair in pairs:
                    raw.setdefault(pair, []).append((t, t_rep))
                # The component cannot fail again while it is down.
                t = t_rep
        merged: dict[tuple[int, int], list[tuple[float, float]]] = {}
        for pair, ivals in raw.items():
            ivals.sort()
            out: list[list[float]] = []
            for t0, t1 in ivals:
                if out and t0 <= out[-1][1]:
                    out[-1][1] = max(out[-1][1], t1)
                else:
                    out.append([t0, t1])
            merged[pair] = [(t0, t1) for t0, t1 in out]
        return merged

    def link_failures(self, horizon: float) -> list[LinkFailure]:
        """The storm as engine events: one transient
        :class:`~repro.core.simengine.LinkFailure` (``repair_time`` set)
        per merged outage interval, sorted by failure time."""
        failures = [
            LinkFailure(time=t0, link=pair, repair_time=t1)
            for pair, ivals in self.outages(horizon).items()
            for t0, t1 in ivals
        ]
        failures.sort(key=lambda f: (f.time, f.link))
        return failures

    def events(self, n_iters: int, iter_time: float) -> tuple[TraceEvent, ...]:
        """The storm as an online trace: iteration-granularity ``fail`` /
        ``repair`` :class:`~repro.core.online.TraceEvent` pairs over
        ``n_iters`` iterations of estimated length ``iter_time`` seconds.

        Events keep chronological order (quantization never reorders a
        pair's fail/repair alternation); repairs quantized past the last
        iteration are clamped onto it so every storm the driver sees heals
        within the run."""
        if iter_time <= 0:
            raise ValueError(f"iter_time must be positive, got {iter_time}")
        horizon = n_iters * iter_time
        timed: list[tuple[float, int, TraceEvent]] = []
        for pair, ivals in self.outages(horizon).items():
            for t0, t1 in ivals:
                it_fail = min(int(t0 / iter_time), n_iters - 1)
                it_rep = min(max(int(t1 / iter_time), it_fail), n_iters - 1)
                timed.append(
                    (t0, 0, TraceEvent(iteration=it_fail, kind="fail",
                                       link=pair)))
                timed.append(
                    (t1, 1, TraceEvent(iteration=it_rep, kind="repair",
                                       link=pair)))
        timed.sort(key=lambda rec: (rec[0], rec[1], rec[2].link))
        return tuple(ev for _, _, ev in timed)
