"""TopologyFinder (Algorithm 1).

Given ``n`` servers of degree ``d`` and a :class:`TrafficDemand`, construct:

1. degree split ``d_A``/``d_MP`` proportional to AllReduce vs MP bytes,
2. the AllReduce sub-topology — ``d_k`` TotientPerms rings per group chosen
   by SelectPermutations (geometric-stride, small diameter),
3. the MP sub-topology — repeated Blossom max-weight matching with
   demand-halving (diminishing returns, App. E.4 Discount),
4. combined topology + routing: CoinChangeMod on the ring strides for
   AllReduce, k-shortest-path on the combined graph for MP.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import networkx as nx
import numpy as np

from .demand import AllReduceGroup, TrafficDemand
from .routing import RoutingTable, allreduce_routes, k_shortest_mp_routes
from .select_perms import coin_change_diameter, select_permutations
from .totient import RingPermutation, totient_perms


@dataclass
class Topology:
    """The physical plan for one job's shard of the cluster."""

    n: int
    degree: int
    graph: nx.MultiDiGraph
    # AllReduce group -> the ring permutations (strides) carrying it.
    rings: dict[tuple[int, ...], list[RingPermutation]] = field(default_factory=dict)
    routing: RoutingTable = field(default_factory=RoutingTable)
    d_allreduce: int = 0
    d_mp: int = 0

    def ring_strides(self, members: tuple[int, ...]) -> list[int]:
        return [r.p for r in self.rings.get(members, [])]

    def diameter(self) -> int:
        simple = nx.DiGraph(self.graph)
        if simple.number_of_nodes() < self.n or not nx.is_strongly_connected(simple):
            return -1
        return nx.diameter(simple)

    def out_degrees(self) -> list[int]:
        return [self.graph.out_degree(v) for v in range(self.n)]


def _add_ring(graph: nx.MultiDiGraph, ring: RingPermutation) -> None:
    for a, b in ring.edges():
        graph.add_edge(a, b, kind="allreduce", stride=ring.p)


def _add_duplex(graph: nx.MultiDiGraph, a: int, b: int) -> None:
    graph.add_edge(a, b, kind="mp")
    graph.add_edge(b, a, kind="mp")


def topology_finder(
    demand: TrafficDemand,
    degree: int,
    prime_only: bool | None = None,
    mp_route_k: int = 2,
) -> Topology:
    """Algorithm 1 (paper §4.2)."""
    n = demand.n
    graph = nx.MultiDiGraph()
    graph.add_nodes_from(range(n))

    sum_ar = demand.sum_allreduce
    sum_mp = demand.sum_mp
    total = sum_ar + sum_mp

    groups = list(demand.allreduce)
    if not groups:
        # Keep the network connected even for pure-MP jobs: a zero-traffic
        # global ring still gets the mandatory 1 degree (line 2: max(1, .)).
        groups = [AllReduceGroup(members=tuple(range(n)), nbytes=0.0)]
        sum_ar = 0.0

    # -- Step 1: distribute the degree -------------------------------------
    if total <= 0:
        d_a = 1
    else:
        d_a = max(1, math.ceil(degree * sum_ar / total))
    d_a = min(d_a, degree)
    d_mp = degree - d_a
    d_a_budget = d_a

    # -- Step 2: AllReduce sub-topology -------------------------------------
    rings: dict[tuple[int, ...], list[RingPermutation]] = {}
    group_total = sum(g.total for g in groups)
    for g in sorted(groups, key=lambda g: -g.total):
        if d_a_budget <= 0:
            break
        if group_total > 0:
            d_k = math.ceil(d_a * g.total / group_total)
        else:
            d_k = 1
        d_k = min(d_k, d_a_budget)
        perm_set = totient_perms(g.members, prime_only=prime_only)
        chosen = select_permutations(perm_set, d_k)
        if not chosen and len(g.members) >= 2:
            chosen = [perm_set.perms[0]] if perm_set.perms else []
        for ring in chosen:
            _add_ring(graph, ring)
        rings[g.members] = chosen
        d_a_budget -= max(len(chosen), 1)

    # -- Step 3: MP sub-topology (Blossom matching, demand halving) ---------
    t_mp = demand.mp.copy()
    for _ in range(d_mp):
        sym = t_mp + t_mp.T
        if sym.max() <= 0:
            break
        und = nx.Graph()
        srcs, dsts = np.nonzero(sym)
        for i, j in zip(srcs.tolist(), dsts.tolist()):
            if i < j:
                und.add_edge(i, j, weight=float(sym[i, j]))
        matching = nx.max_weight_matching(und, maxcardinality=False)
        if not matching:
            break
        for a, b in matching:
            _add_duplex(graph, a, b)
            # Diminishing return: halve served demand (line 17).
            t_mp[a, b] /= 2.0
            t_mp[b, a] /= 2.0

    # -- Step 4: final topology + routing ------------------------------------
    topo = Topology(
        n=n, degree=degree, graph=graph, rings=rings,
        d_allreduce=d_a, d_mp=d_mp,
    )
    routing = RoutingTable()
    for members, group_rings in rings.items():
        strides = [r.p for r in group_rings]
        if strides:
            sub = allreduce_routes(members, strides)
            routing.routes.update(sub.routes)
    mp_routes = k_shortest_mp_routes(graph, demand.mp, k=mp_route_k)
    # MP routes take priority on pairs where both exist (shorter on combined G).
    for pair, rs in mp_routes.routes.items():
        existing = routing.routes.get(pair)
        if existing is None or min(r.hops for r in rs) < min(r.hops for r in existing):
            routing.routes[pair] = rs
    topo.routing = routing
    return topo


def effective_diameter(topo: Topology) -> int:
    """Diameter as seen by coin-change routing on the primary AllReduce group
    (Theorem 1's quantity), falling back to the graph diameter."""
    if topo.rings:
        members, group_rings = max(topo.rings.items(), key=lambda kv: len(kv[0]))
        strides = [r.p for r in group_rings]
        if strides:
            return coin_change_diameter(len(members), strides)
    return topo.diameter()


# ---------------------------------------------------------------------------
# Failure handling (§7 "Handling failures")
# ---------------------------------------------------------------------------


def repair_topology(topo: Topology, failed: tuple[int, int]) -> Topology:
    """A fiber failure removes links between ``failed=(u, v)`` (both
    directions).  Per §7: TopoOpt donates an MP link to restore a broken
    AllReduce ring; if the failed link was MP-only, re-route around it.

    Returns a new Topology with the failed links removed, a replacement link
    rewired from the lowest-value MP link (if the failure broke a ring), and
    routing recomputed for affected pairs.
    """
    u, v = failed
    g = topo.graph.copy()
    broke_ring = False
    removed = {(u, v), (v, u)}
    for a, b in ((u, v), (v, u)):
        if g.has_edge(a, b):
            for key, data in list(g[a][b].items()):
                if data.get("kind") == "allreduce":
                    broke_ring = True
                g.remove_edge(a, b, key=key)

    if broke_ring:
        # Donate one MP link: rewire it to (u, v) to close the ring again.
        mp_edges = [
            (a, b, k)
            for a, b, k, data in g.edges(keys=True, data=True)
            if data.get("kind") == "mp" and (a, b) != (u, v) and (a, b) != (v, u)
        ]
        if mp_edges:
            a, b, k = mp_edges[0]
            g.remove_edge(a, b, key=k)
            if not g.has_edge(a, b):  # no parallel link left on that pair
                removed.add((a, b))
            g.add_edge(u, v, kind="allreduce", stride=None, repaired=True)
            removed.discard((u, v))

    repaired = Topology(
        n=topo.n, degree=topo.degree, graph=g, rings=topo.rings,
        d_allreduce=topo.d_allreduce, d_mp=topo.d_mp,
    )
    # Recompute routing on the surviving graph (shortest paths for every pair
    # previously routed through a removed link — the failure AND the donated
    # MP link).
    simple = nx.DiGraph(g)
    new_routing = RoutingTable()
    for pair, rs in topo.routing.routes.items():
        keep = [
            r for r in rs
            if not any(hop in removed for hop in zip(r.path[:-1], r.path[1:]))
        ]
        if keep:
            new_routing.routes[pair] = keep
            continue
        try:
            path = nx.shortest_path(simple, pair[0], pair[1])
            new_routing.add(pair[0], pair[1], tuple(path))
        except (nx.NetworkXNoPath, nx.NodeNotFound):
            continue
    repaired.routing = new_routing
    return repaired
