"""TopologyFinder (paper Algorithm 1, §4.2) + failure handling (§7).

Given ``n`` servers of degree ``d`` and a :class:`TrafficDemand`, construct:

1. degree split ``d_A``/``d_MP`` proportional to AllReduce vs MP bytes
   (Alg. 1 line 2: ``d_A = max(1, ceil(d * sum_AR / (sum_AR + sum_MP)))``),
2. the AllReduce sub-topology — ``d_k`` TotientPerms rings per group chosen
   by SelectPermutations (geometric-stride, small diameter; Alg. 2/3 in
   :mod:`repro.core.totient` / :mod:`repro.core.select_perms`),
3. the MP sub-topology — repeated Blossom max-weight matching with
   demand-halving (diminishing returns, App. E.4 Discount),
4. combined topology + routing: CoinChangeMod (Alg. 4,
   :mod:`repro.core.routing`) on the ring strides for AllReduce,
   k-shortest-path on the combined graph for MP.

Notation mapping (paper -> code): ``d`` -> ``degree``, ``d_A`` ->
``Topology.d_allreduce``, ``d_MP`` -> ``Topology.d_mp``, ``d_k`` (per-group
ring budget) -> computed per :class:`AllReduceGroup` from its byte share,
``T_MP`` -> ``TrafficDemand.mp``, the permutation set ``P`` ->
:class:`repro.core.totient.PermutationSet`.

Two degradation paths serve the failure story:

* :func:`repair_topology` — the paper's §7 quick fix for a cut *fiber*:
  donate the lowest-value MP link to close a broken AllReduce ring and
  re-route around the cut (the pair itself may be re-patched).
* :func:`remove_pair` — a dead node *pair* (port/transceiver loss): both
  directions disappear for good; :mod:`repro.core.online` keeps this as the
  static operator's incumbent and passes the same pairs to
  ``topology_finder(forbidden=...)`` when re-optimizing.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

import networkx as nx
import numpy as np

from .demand import AllReduceGroup, TrafficDemand
from .routing import RoutingTable, allreduce_routes, k_shortest_mp_routes
from .select_perms import coin_change_diameter, select_permutations
from .totient import PermutationSet, RingPermutation, totient_perms


@dataclass
class Topology:
    """The physical plan for one job's shard of the cluster."""

    n: int
    degree: int
    graph: nx.MultiDiGraph
    # AllReduce group -> the ring permutations (strides) carrying it.
    rings: dict[tuple[int, ...], list[RingPermutation]] = field(default_factory=dict)
    routing: RoutingTable = field(default_factory=RoutingTable)
    d_allreduce: int = 0
    d_mp: int = 0

    def ring_strides(self, members: tuple[int, ...]) -> list[int]:
        return [r.p for r in self.rings.get(members, [])]

    def diameter(self) -> int:
        simple = nx.DiGraph(self.graph)
        if simple.number_of_nodes() < self.n or not nx.is_strongly_connected(simple):
            return -1
        return nx.diameter(simple)

    def out_degrees(self) -> list[int]:
        return [self.graph.out_degree(v) for v in range(self.n)]


def _add_ring(graph: nx.MultiDiGraph, ring: RingPermutation) -> None:
    for a, b in ring.edges():
        graph.add_edge(a, b, kind="allreduce", stride=ring.p)


def _add_duplex(graph: nx.MultiDiGraph, a: int, b: int) -> None:
    graph.add_edge(a, b, kind="mp")
    graph.add_edge(b, a, kind="mp")


def _norm_pair(a: int, b: int) -> tuple[int, int]:
    return (a, b) if a <= b else (b, a)


def _select_group_rings(
    g: AllReduceGroup,
    d_k: int,
    forb: set[tuple[int, int]],
    warm_start: Topology | None,
    prime_only: bool | None,
) -> list[RingPermutation]:
    """Pick up to ``d_k`` ring permutations for one AllReduce group:
    warm-start strides first, SelectPermutations for the remainder,
    parallel-copy refill when ``forb`` thinned the set below budget."""
    perm_set = totient_perms(g.members, prime_only=prime_only)
    if forb:
        perm_set = PermutationSet(
            group=perm_set.group,
            perms=[
                r
                for r in perm_set.perms
                if not any(_norm_pair(a, b) in forb for a, b in r.edges())
            ],
        )
    chosen: list[RingPermutation] = []
    if warm_start is not None:
        # Keep incumbent strides that are still valid (warm start).
        still = {r.p: r for r in perm_set.perms}
        for r in warm_start.rings.get(g.members, []):
            if r.p in still and len(chosen) < d_k:
                chosen.append(still[r.p])
    if len(chosen) < d_k:
        rest = PermutationSet(
            group=perm_set.group,
            perms=[r for r in perm_set.perms if r not in chosen],
        )
        chosen = chosen + select_permutations(rest, d_k - len(chosen))
    if forb and chosen and len(chosen) < d_k:
        # Replanning on a degraded fabric: the forbidden pairs thinned
        # the permutation set below the ring budget.  Refill with
        # parallel copies of the surviving strides — on a max-min-fair
        # fabric a second ring of the same stride doubles that ring's
        # capacity, which beats leaving NIC ports dark.
        base = list(chosen)
        while len(chosen) < d_k:
            chosen.append(base[(len(chosen) - len(base)) % len(base)])
    if not chosen and len(g.members) >= 2:
        chosen = [perm_set.perms[0]] if perm_set.perms else []
    return chosen


def topology_finder(
    demand: TrafficDemand,
    degree: int,
    prime_only: bool | None = None,
    mp_route_k: int = 2,
    forbidden: Iterable[tuple[int, int]] = (),
    warm_start: Topology | None = None,
    pack: str = "global",
) -> Topology:
    """Algorithm 1 (paper §4.2).

    ``forbidden`` is a set of node pairs (either direction) that physically
    cannot carry a link — e.g. fiber pairs that failed mid-run.  Ring
    permutations crossing a forbidden pair are excluded from SelectPermutations
    and the Blossom matching skips those pairs, so the returned topology is
    realizable on the surviving fabric.

    ``warm_start`` seeds the ring selection from an incumbent topology
    (online re-optimization): strides the incumbent already uses for a group
    are kept when still valid, and only the remainder of the degree budget is
    re-searched.  This both converges faster and minimizes physical link
    churn when the plan is swapped on a live OCS/patch-panel fabric.

    ``pack`` selects the degree accounting.  ``"global"`` (default) is the
    paper's single-job Algorithm 1: one global ``d_A``/``d_MP`` split and a
    shared ring budget across groups — byte-identical to the pre-multi-tenant
    behaviour.  ``"per_node"`` charges the budget where links actually land
    (a node only spends degree on rings/MP links it terminates), so the
    disjoint per-job groups of a multi-tenant union demand each get their own
    ring budget instead of splitting one global count — this is how per-job
    ring budgets pack into the shared physical degree.
    """
    if pack not in ("global", "per_node"):
        raise ValueError(f"unknown pack mode {pack!r}")
    n = demand.n
    forb = {_norm_pair(a, b) for a, b in forbidden}
    graph = nx.MultiDiGraph()
    graph.add_nodes_from(range(n))

    sum_ar = demand.sum_allreduce
    sum_mp = demand.sum_mp
    total = sum_ar + sum_mp

    groups = list(demand.allreduce)
    if not groups:
        # Keep the network connected even for pure-MP jobs: a zero-traffic
        # global ring still gets the mandatory 1 degree (line 2: max(1, .)).
        groups = [AllReduceGroup(members=tuple(range(n)), nbytes=0.0)]
        sum_ar = 0.0

    # -- Step 1: distribute the degree -------------------------------------
    if total <= 0:
        d_a = 1
    else:
        d_a = max(1, math.ceil(degree * sum_ar / total))
    d_a = min(d_a, degree)
    d_mp = degree - d_a

    rings: dict[tuple[int, ...], list[RingPermutation]] = {}
    if pack == "global":
        # -- Step 2: AllReduce sub-topology ---------------------------------
        d_a_budget = d_a
        group_total = sum(g.total for g in groups)
        for g in sorted(groups, key=lambda g: -g.total):
            if d_a_budget <= 0:
                break
            if group_total > 0:
                d_k = math.ceil(d_a * g.total / group_total)
            else:
                d_k = 1
            d_k = min(d_k, d_a_budget)
            chosen = _select_group_rings(g, d_k, forb, warm_start, prime_only)
            for ring in chosen:
                _add_ring(graph, ring)
            rings[g.members] = chosen
            d_a_budget -= max(len(chosen), 1)

        # -- Step 3: MP sub-topology (Blossom matching, demand halving) -----
        t_mp = demand.mp.copy()
        for _ in range(d_mp):
            sym = t_mp + t_mp.T
            if sym.max() <= 0:
                break
            und = nx.Graph()
            srcs, dsts = np.nonzero(sym)
            for i, j in zip(srcs.tolist(), dsts.tolist()):
                if i < j and (i, j) not in forb:
                    und.add_edge(i, j, weight=float(sym[i, j]))
            matching = nx.max_weight_matching(und, maxcardinality=False)
            if not matching:
                break
            for a, b in matching:
                _add_duplex(graph, a, b)
                # Diminishing return: halve served demand (line 17).
                t_mp[a, b] /= 2.0
                t_mp[b, a] /= 2.0
    else:
        d_a, d_mp = _pack_per_node(
            demand, degree, groups, graph, rings, forb, warm_start, prime_only
        )

    # -- Step 4: final topology + routing ------------------------------------
    topo = Topology(
        n=n, degree=degree, graph=graph, rings=rings,
        d_allreduce=d_a, d_mp=d_mp,
    )
    routing = RoutingTable()
    for members, group_rings in rings.items():
        strides = [r.p for r in group_rings]
        if strides:
            sub = allreduce_routes(members, strides)
            routing.routes.update(sub.routes)
    mp_routes = k_shortest_mp_routes(graph, demand.mp, k=mp_route_k)
    # MP routes take priority on pairs where both exist (shorter on combined G).
    for pair, rs in mp_routes.routes.items():
        existing = routing.routes.get(pair)
        if existing is None or min(r.hops for r in rs) < min(r.hops for r in existing):
            routing.routes[pair] = rs
    topo.routing = routing
    return topo


def _pack_per_node(
    demand: TrafficDemand,
    degree: int,
    groups: list[AllReduceGroup],
    graph: nx.MultiDiGraph,
    rings: dict[tuple[int, ...], list[RingPermutation]],
    forb: set[tuple[int, int]],
    warm_start: Topology | None,
    prime_only: bool | None,
) -> tuple[int, int]:
    """Shared-cluster degree packing: charge the budget per node.

    A ring only consumes one out-port on each of *its* members, and an MP
    duplex only on its two endpoints — so disjoint per-job groups (a
    multi-tenant union demand) each get a full ring budget instead of
    splitting one global count.  Per node ``v`` the AllReduce/MP split of
    Algorithm 1 line 2 is applied to the bytes *terminating at v*; when no
    group spans every node, one port per node is reserved for a zero-byte
    global connectivity ring so idle servers (future arrivals) stay
    reachable.  Returns the ``(d_allreduce, d_mp)`` summary fields.
    """
    n = demand.n
    spans_all = any(set(g.members) == set(range(n)) for g in groups)
    reserve = 0 if spans_all else 1
    if degree - reserve < 1:
        reserve = 0  # degree 1: a connectivity ring would overflow the port
    budget = degree - reserve

    # Per-node byte split: ring bytes a group would put on one of v's ports
    # vs MP bytes terminating at v (a duplex serves both directions).
    per_link = {
        id(g): 2.0 * (len(g.members) - 1) / len(g.members) * g.nbytes
        if len(g.members) > 1
        else 0.0
        for g in groups
    }
    ar_v = np.zeros(n)
    for g in groups:
        for v in g.members:
            ar_v[v] += per_link[id(g)]
    mp_v = (demand.mp.sum(axis=1) + demand.mp.sum(axis=0)) / 2.0
    with np.errstate(divide="ignore", invalid="ignore"):
        frac = np.where(ar_v + mp_v > 0, ar_v / (ar_v + mp_v), 1.0)
    d_a_v = np.clip(np.ceil(budget * frac), 1, budget).astype(np.int64)

    used = np.zeros(n, dtype=np.int64)
    for g in sorted(groups, key=lambda g: -g.total):
        members = np.asarray(g.members, dtype=np.int64)
        avail = int((budget - used[members]).min()) if members.size else 0
        if per_link[id(g)] > 0:
            # The group's share of each member's AllReduce budget; the
            # tightest member bounds the ring count.
            share = d_a_v[members] * per_link[id(g)] / ar_v[members]
            d_k = max(1, int(np.ceil(share.min())))
        else:
            d_k = 1 if len(g.members) > 1 else 0
        d_k = min(d_k, avail)
        if avail <= 0:
            chosen = []  # members saturated: even a fallback ring overflows
        else:
            chosen = _select_group_rings(
                g, d_k, forb, warm_start, prime_only
            )[:avail]
        for ring in chosen:
            _add_ring(graph, ring)
        rings[g.members] = chosen
        if members.size:
            used[members] += len(chosen)
    used_ar = used.copy()

    # MP links fill whatever per-node budget remains.
    t_mp = demand.mp.copy()
    for _ in range(degree):
        sym = t_mp + t_mp.T
        if sym.max() <= 0:
            break
        und = nx.Graph()
        srcs, dsts = np.nonzero(sym)
        progress = False
        for i, j in zip(srcs.tolist(), dsts.tolist()):
            if (
                i < j
                and (i, j) not in forb
                and used[i] < budget
                and used[j] < budget
            ):
                und.add_edge(i, j, weight=float(sym[i, j]))
        matching = nx.max_weight_matching(und, maxcardinality=False)
        for a, b in matching:
            _add_duplex(graph, a, b)
            used[a] += 1
            used[b] += 1
            t_mp[a, b] /= 2.0
            t_mp[b, a] /= 2.0
            progress = True
        if not progress:
            break

    if reserve:
        # Zero-byte global connectivity ring on the reserved port: future
        # arrivals (and reroutes around failures) always have a path.
        members = tuple(range(n))
        conn = AllReduceGroup(members=members, nbytes=0.0)
        chosen = _select_group_rings(conn, 1, forb, warm_start, prime_only)
        if chosen:
            _add_ring(graph, chosen[0])
            rings.setdefault(members, [chosen[0]])
    d_allreduce = int(used_ar.max(initial=0)) + reserve
    return d_allreduce, degree - d_allreduce


def effective_diameter(topo: Topology) -> int:
    """Diameter as seen by coin-change routing on the primary AllReduce group
    (Theorem 1's quantity), falling back to the graph diameter."""
    if topo.rings:
        members, group_rings = max(topo.rings.items(), key=lambda kv: len(kv[0]))
        strides = [r.p for r in group_rings]
        if strides:
            return coin_change_diameter(len(members), strides)
    return topo.diameter()


# ---------------------------------------------------------------------------
# Failure handling (§7 "Handling failures")
# ---------------------------------------------------------------------------


def repair_topology(topo: Topology, failed: tuple[int, int]) -> Topology:
    """A fiber failure removes links between ``failed=(u, v)`` (both
    directions).  Per §7: TopoOpt donates an MP link to restore a broken
    AllReduce ring; if the failed link was MP-only, re-route around it.

    Returns a new Topology with the failed links removed, a replacement link
    rewired from the lowest-value MP link (if the failure broke a ring), and
    routing recomputed for affected pairs.
    """
    u, v = failed
    g = topo.graph.copy()
    broke_ring = False
    removed = {(u, v), (v, u)}
    for a, b in ((u, v), (v, u)):
        if g.has_edge(a, b):
            for key, data in list(g[a][b].items()):
                if data.get("kind") == "allreduce":
                    broke_ring = True
                g.remove_edge(a, b, key=key)

    if broke_ring:
        # Donate one MP link: rewire it to (u, v) to close the ring again.
        mp_edges = [
            (a, b, k)
            for a, b, k, data in g.edges(keys=True, data=True)
            if data.get("kind") == "mp" and (a, b) != (u, v) and (a, b) != (v, u)
        ]
        if mp_edges:
            a, b, k = mp_edges[0]
            g.remove_edge(a, b, key=k)
            if not g.has_edge(a, b):  # no parallel link left on that pair
                removed.add((a, b))
            g.add_edge(u, v, kind="allreduce", stride=None, repaired=True)
            removed.discard((u, v))

    repaired = Topology(
        n=topo.n, degree=topo.degree, graph=g, rings=topo.rings,
        d_allreduce=topo.d_allreduce, d_mp=topo.d_mp,
    )
    # Recompute routing on the surviving graph (shortest paths for every pair
    # previously routed through a removed link — the failure AND the donated
    # MP link).
    repaired.routing = _reroute_around(topo, g, removed)
    return repaired


def _reroute_around(topo: Topology, g: nx.MultiDiGraph,
                    removed: set) -> RoutingTable:
    """Keep routes that avoid ``removed`` links; re-path the rest by
    shortest path on ``g`` (drop pairs that became unreachable)."""
    simple = nx.DiGraph(g)
    new_routing = RoutingTable()
    for pair, rs in topo.routing.routes.items():
        keep = [
            r for r in rs
            if not any(hop in removed for hop in zip(r.path[:-1], r.path[1:]))
        ]
        if keep:
            new_routing.routes[pair] = keep
            continue
        try:
            path = nx.shortest_path(simple, pair[0], pair[1])
            new_routing.add(pair[0], pair[1], tuple(path))
        except (nx.NetworkXNoPath, nx.NodeNotFound):
            continue
    return new_routing


def remove_pair(topo: Topology, pair: tuple[int, int]) -> Topology:
    """Degrade a topology by a dead node pair (no §7 donation).

    Unlike :func:`repair_topology` — which models a cut *fiber* that a
    patch panel can re-create from a donated MP link — this models the pair
    itself becoming unusable (port/transceiver loss): both directions
    disappear, no replacement link may touch the pair, and routes that
    crossed it are re-pathed over the survivors.  This is the incumbent a
    static operator keeps running in :mod:`repro.core.online`, and the same
    constraint re-optimization passes to ``topology_finder(forbidden=...)``.
    """
    u, v = pair
    g = topo.graph.copy()
    removed = {(u, v), (v, u)}
    for a, b in ((u, v), (v, u)):
        if g.has_edge(a, b):
            for key in list(g[a][b]):
                g.remove_edge(a, b, key=key)
    degraded = Topology(
        n=topo.n, degree=topo.degree, graph=g, rings=topo.rings,
        d_allreduce=topo.d_allreduce, d_mp=topo.d_mp,
    )
    degraded.routing = _reroute_around(topo, g, removed)
    return degraded


def restore_pair(
    topo: Topology,
    pair: tuple[int, int],
    edges: list[tuple[int, int, dict]],
) -> Topology:
    """Invert :func:`remove_pair` after a transient fault heals.

    ``edges`` is the (a, b, edge-data) list snapshotted before the pair was
    removed; they are re-added verbatim and the restored directions get
    their direct route back.  Routes that were detoured around the dead
    pair keep their detour — they are valid, just suboptimal, and the next
    re-optimization (or :func:`_reroute_around`) tightens them.
    """
    g = topo.graph.copy()
    for a, b, data in edges:
        g.add_edge(a, b, **data)
    restored = Topology(
        n=topo.n, degree=topo.degree, graph=g, rings=topo.rings,
        d_allreduce=topo.d_allreduce, d_mp=topo.d_mp,
    )
    routing = RoutingTable(routes=dict(topo.routing.routes))
    for direction in {(a, b) for a, b, _ in edges}:
        routing.routes.pop(direction, None)
        routing.add(direction[0], direction[1], direction)
    restored.routing = routing
    return restored
