"""Fluid bottleneck-link comm-time model (§5.1, FlexNet analogue).

The preferred entry point is :class:`repro.core.simengine.SimEngine`, which
re-exports everything here and unifies the three simulation granularities
(fluid analysis, event-driven max-min-fair flows, scenario runs with
arrivals / failures / OCS reconfiguration).  Importing the subsumed entry
points (``topoopt_comm_time``, ``ideal_switch_comm_time``,
``fat_tree_comm_time``, ``iteration_time``) from *this* module emits a
:class:`DeprecationWarning`; the same names are warning-free on
``repro.core.simengine``.  This module keeps the fluid primitives
themselves:

* ``topoopt_comm_time`` — every flow follows its routes, link loads
  accumulate, comm time = max link (bytes / bandwidth); AllReduce groups
  ride their permutation rings with the canonical ring cost
  ``2 (k-1)/k * M`` split over the group's rings.
* ``ideal_switch_comm_time`` / ``fat_tree_comm_time`` — §5.1 baselines.

Fabrics other than TopoOpt (expander, SiP-ML ring) are built in
:mod:`repro.core.fabrics` and consumed here through the same interface.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from .demand import TrafficDemand, demand_steps
from .routing import bandwidth_tax, link_loads
from .topology_finder import Topology


@dataclass(frozen=True)
class HardwareSpec:
    """Per-node network/compute capability."""

    link_bandwidth: float = 100e9 / 8  # bytes/s per interface (100 Gbps NIC)
    degree: int = 4
    compute_flops: float = 312e12  # A100 bf16 peak
    compute_efficiency: float = 0.45
    # α of the (α, β) collective cost model: per-round link latency (s).
    # 0.0 keeps the pure fluid model (and every pre-schedule result)
    # bit-identical; set it to price latency-dominated schedules
    # (repro.core.schedules) against bandwidth-optimal rings.
    link_latency: float = 0.0

    @property
    def node_bandwidth(self) -> float:
        return self.link_bandwidth * self.degree


def _ring_bytes_per_link(group_bytes: float, k: int) -> float:
    """Ring AllReduce moves 2*(k-1)/k * M across each link of the ring."""
    if k <= 1:
        return 0.0
    return 2.0 * (k - 1) / k * group_bytes


class Flows:
    """A demand's MP flows as parallel arrays (``src``, ``dst``,
    ``nbytes``) — no per-element tuple materialization.  Iterating yields
    ``(src, dst, nbytes)`` triples for legacy consumers."""

    __slots__ = ("src", "dst", "nbytes")

    def __init__(self, src: np.ndarray, dst: np.ndarray, nbytes: np.ndarray):
        self.src = src
        self.dst = dst
        self.nbytes = nbytes

    def __len__(self) -> int:
        return int(self.src.size)

    def __iter__(self):
        return zip(self.src.tolist(), self.dst.tolist(), self.nbytes.tolist())

    @property
    def total(self) -> float:
        return float(self.nbytes.sum())


def mp_flows(demand: TrafficDemand) -> Flows:
    """Nonzero MP entries, vectorized (one ``np.nonzero`` + one gather)."""
    srcs, dsts = np.nonzero(demand.mp)
    return Flows(srcs, dsts, demand.mp[srcs, dsts])


def _topoopt_comm_time(
    topo: Topology, demand: TrafficDemand, hw: HardwareSpec
) -> dict[str, float]:
    """Fluid comm time on a TopoOpt direct-connect topology.

    AllReduce bytes are spread over each group's rings (multi-ring
    load-balancing, §6); MP bytes follow the routing table with host-based
    forwarding (bandwidth tax).  Both share the physical links.

    This is the *reference* implementation.  The search loops run on the
    compiled fast path (:func:`repro.core.planeval.plan_evaluator`), which
    must agree with this function to 1e-9 relative — keep the two in sync.
    """
    loads, flows, routing = _reference_loads(topo, demand)
    worst = _reference_worst(topo, loads, hw)
    if hw.link_latency:
        worst = worst + hw.link_latency * demand_steps(demand)
    tax = bandwidth_tax(flows, routing) if flows else 1.0
    return {"comm_time": worst, "bandwidth_tax": tax}


def reference_comm_time(
    topo: Topology, demand: TrafficDemand, hw: HardwareSpec
) -> float:
    """The ``comm_time`` of :func:`topoopt_comm_time`, bit-identical,
    without paying for the bandwidth tax — the search loops' reference
    objective (and the compiled path's tie-breaking authority)."""
    loads, _, _ = _reference_loads(topo, demand)
    worst = _reference_worst(topo, loads, hw)
    if hw.link_latency:
        worst = worst + hw.link_latency * demand_steps(demand)
    return worst


def _reference_loads(topo: Topology, demand: TrafficDemand):
    loads: dict[tuple[int, int], float] = {}

    # AllReduce traffic on its rings (chunked across rings).
    for group in demand.allreduce:
        rings = topo.rings.get(group.members, [])
        k = len(group.members)
        per_link_total = _ring_bytes_per_link(group.nbytes, k)
        if not rings or per_link_total == 0.0:
            continue
        share = per_link_total / len(rings)
        for ring in rings:
            for a, b in ring.edges():
                loads[(a, b)] = loads.get((a, b), 0.0) + share

    # MP traffic over routed paths (forwarding copies count on every hop).
    # Pairs without a precomputed route (e.g. MCMC probing placements on a
    # fixed topology) fall back to shortest-path on the current graph.
    flows = mp_flows(demand)
    routing = _routing_with_fallback(topo, flows)
    mp_loads = link_loads(topo.graph, flows, routing)
    for link, nbytes in mp_loads.items():
        loads[link] = loads.get(link, 0.0) + nbytes
    return loads, flows, routing


def _reference_worst(topo: Topology, loads, hw: HardwareSpec) -> float:
    # Parallel links between the same pair share the load.
    n_par: dict[tuple[int, int], int] = {}
    for a, b in topo.graph.edges():
        n_par[(a, b)] = n_par.get((a, b), 0) + 1
    worst = 0.0
    for link, nbytes in loads.items():
        par = max(1, n_par.get(link, 1))
        worst = max(worst, nbytes / (par * hw.link_bandwidth))
    return worst


def _routing_with_fallback(topo: Topology, flows) -> "RoutingTable":
    """Routing table covering every flow pair: the planned table, extended
    with shortest-path fallbacks for pairs the plan never routed (MCMC
    probing placements on a fixed topology).

    Fallback routes persist on the topology (``topo._sp_cache``) together
    with one memoized *merged* table (``topo._merged_routing``) — on a full
    cache hit nothing is copied, the memoized table is returned as-is, and
    the planned table is returned untouched when no pair needs a fallback.
    """
    routing = topo.routing
    cache = getattr(topo, "_sp_cache", None)
    missing_any = False
    need: list[tuple[int, int]] = []
    for s, t, _ in flows:
        if routing.get(s, t):
            continue
        missing_any = True
        if cache is None or (s, t) not in cache:
            need.append((s, t))
    if not missing_any:
        return routing
    if cache is None:
        from .routing import RoutingTable

        cache = {}
        topo._sp_cache = cache
        topo._merged_routing = RoutingTable(routes=dict(routing.routes))
    merged = topo._merged_routing
    if need:
        import networkx as nx

        simple = getattr(topo, "_simple_digraph", None)
        if simple is None:
            simple = nx.DiGraph(topo.graph)
            topo._simple_digraph = simple
        for s, t in need:
            if (s, t) in cache:
                continue  # duplicate pair in this flow list
            try:
                path = tuple(nx.shortest_path(simple, s, t))
                merged.add(s, t, path)
                cache[(s, t)] = merged.routes[(s, t)]
            except (nx.NetworkXNoPath, nx.NodeNotFound):
                cache[(s, t)] = []
    return merged


def _ideal_switch_comm_time(demand: TrafficDemand, hw: HardwareSpec) -> float:
    """Ideal non-blocking switch with node bandwidth d*B (§5.1): AllReduce at
    full node bandwidth + per-node in/out bottleneck for MP."""
    t = 0.0
    for group in demand.allreduce:
        k = len(group.members)
        t = max(t, _ring_bytes_per_link(group.nbytes, k) / hw.node_bandwidth)
    out_bytes = demand.mp.sum(axis=1)
    in_bytes = demand.mp.sum(axis=0)
    node_bottleneck = max(out_bytes.max(initial=0.0), in_bytes.max(initial=0.0))
    return max(t, t + node_bottleneck / hw.node_bandwidth)


def _fat_tree_comm_time(
    demand: TrafficDemand, hw: HardwareSpec, bandwidth_fraction: float
) -> float:
    """Cost-equivalent fat-tree: one NIC per server with d*B' bandwidth where
    B' = bandwidth_fraction * B (§5.1/§5.2); full-bisection so it behaves as
    an ideal switch at the reduced rate."""
    scaled = HardwareSpec(
        link_bandwidth=hw.link_bandwidth * bandwidth_fraction,
        degree=hw.degree,
        compute_flops=hw.compute_flops,
        compute_efficiency=hw.compute_efficiency,
        link_latency=hw.link_latency,
    )
    return _ideal_switch_comm_time(demand, scaled)


def _iteration_time(
    comm_time: float,
    compute_time: float,
    overlap: float = 0.0,
) -> float:
    """Combine compute and comm.  ``overlap`` in [0,1]: fraction of comm that
    hides under compute (the paper's Eq. 1 uses overlap=0)."""
    hidden = min(comm_time * overlap, compute_time)
    return compute_time + comm_time - hidden


def compute_time(flops_per_iteration: float, n: int, hw: HardwareSpec) -> float:
    return flops_per_iteration / (n * hw.compute_flops * hw.compute_efficiency)


# -- deprecated shim surface -------------------------------------------------
# The scenario engine subsumed these entry points; they stay importable
# here for compatibility but warn.  Warning-free homes:
# ``repro.core.simengine.<name>`` (or ``SimEngine.comm_time`` /
# ``SimEngine.iteration_time`` for the fluid facade).

_DEPRECATED_SHIMS = {
    "topoopt_comm_time": _topoopt_comm_time,
    "ideal_switch_comm_time": _ideal_switch_comm_time,
    "fat_tree_comm_time": _fat_tree_comm_time,
    "iteration_time": _iteration_time,
}


def __getattr__(name: str):
    shim = _DEPRECATED_SHIMS.get(name)
    if shim is not None:
        warnings.warn(
            f"repro.core.netsim.{name} is deprecated; import it from "
            "repro.core.simengine (or use SimEngine) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        return shim
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
