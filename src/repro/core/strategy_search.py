"""Parallelization-strategy search (FlexFlow-style MCMC, §4.1 Comp x Comm).

The strategy space mirrors what matters for the paper's workloads: pure data
parallelism vs hybrid (embedding tables / experts placed on a subset of
hosts), including *which* hosts — device placement changes the MP traffic
matrix, which is exactly what the Comm x Topo plane consumes.

The simulated-annealing proposal/acceptance follows FlexFlow's MCMC: accept
better strategies always, worse ones with probability exp(-delta/T).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field

from .demand import TrafficDemand
from .netsim import (
    HardwareSpec,
    compute_time,
    iteration_time,
    topoopt_comm_time,
)
from .topology_finder import Topology
from .workloads import JobSpec, job_demand


@dataclass(frozen=True)
class Strategy:
    """A point in the Comp x Comm plane."""

    mode: str  # "dp" | "hybrid"
    table_hosts: tuple[int, ...] = ()
    ep_group_size: int = 0

    def demand(self, job: JobSpec, n: int) -> TrafficDemand:
        hosts = self.table_hosts if self.mode == "hybrid" else None
        return job_demand(job, n, table_hosts=hosts, ep_group_size=self.ep_group_size)


@dataclass
class SearchResult:
    strategy: Strategy
    iter_time: float
    demand: TrafficDemand
    history: list[float] = field(default_factory=list)


def _evaluate(
    strategy: Strategy, job: JobSpec, topo: Topology, hw: HardwareSpec, overlap: float
) -> tuple[float, TrafficDemand]:
    demand = strategy.demand(job, topo.n)
    comm = topoopt_comm_time(topo, demand, hw)["comm_time"]
    comp = compute_time(job.flops_per_sample * job.batch_per_gpu * topo.n, topo.n, hw)
    return iteration_time(comm, comp, overlap=overlap), demand


def _propose(strategy: Strategy, job: JobSpec, n: int, rng: random.Random) -> Strategy:
    moves = ["toggle_mode"]
    if job.n_tables:
        moves += ["move_host", "add_host", "drop_host"]
    if job.n_experts:
        moves += ["ep_size"]
    move = rng.choice(moves)

    if move == "toggle_mode":
        if strategy.mode == "dp" and job.n_tables:
            k = max(1, min(job.n_tables, n // 4))
            hosts = tuple(sorted(rng.sample(range(n), k)))
            return Strategy(mode="hybrid", table_hosts=hosts,
                            ep_group_size=strategy.ep_group_size)
        return Strategy(mode="dp", ep_group_size=strategy.ep_group_size)

    hosts = list(strategy.table_hosts) or [rng.randrange(n)]
    if move == "move_host":
        idx = rng.randrange(len(hosts))
        hosts[idx] = rng.randrange(n)
    elif move == "add_host" and len(hosts) < min(n, job.n_tables):
        hosts.append(rng.randrange(n))
    elif move == "drop_host" and len(hosts) > 1:
        hosts.pop(rng.randrange(len(hosts)))
    elif move == "ep_size":
        sizes = [s for s in (2, 4, 8, 16, 32) if n % s == 0 and s <= n]
        if sizes:
            return Strategy(
                mode=strategy.mode, table_hosts=strategy.table_hosts,
                ep_group_size=rng.choice(sizes),
            )
    return Strategy(
        mode="hybrid", table_hosts=tuple(sorted(set(hosts))),
        ep_group_size=strategy.ep_group_size,
    )


def mcmc_search(
    job: JobSpec,
    topo: Topology,
    hw: HardwareSpec,
    iters: int = 200,
    temperature: float = 0.1,
    overlap: float = 0.0,
    seed: int = 0,
    init: Strategy | None = None,
) -> SearchResult:
    """Search the Comp x Comm plane for a fixed topology (§4.1)."""
    rng = random.Random(seed)
    n = topo.n
    current = init or Strategy(mode="dp",
                               ep_group_size=8 if job.n_experts else 0)
    cur_time, cur_demand = _evaluate(current, job, topo, hw, overlap)
    best, best_time, best_demand = current, cur_time, cur_demand
    history = [cur_time]

    for it in range(iters):
        cand = _propose(current, job, n, rng)
        cand_time, cand_demand = _evaluate(cand, job, topo, hw, overlap)
        t = temperature * max(cur_time, 1e-12)
        if cand_time <= cur_time or rng.random() < math.exp(
            -(cand_time - cur_time) / t
        ):
            current, cur_time, cur_demand = cand, cand_time, cand_demand
            if cur_time < best_time:
                best, best_time, best_demand = current, cur_time, cur_demand
        history.append(cur_time)

    return SearchResult(
        strategy=best, iter_time=best_time, demand=best_demand, history=history
    )
