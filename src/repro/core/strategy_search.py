"""Parallelization-strategy search (FlexFlow-style MCMC, §4.1 Comp x Comm).

The strategy space mirrors what matters for the paper's workloads: pure data
parallelism vs hybrid (embedding tables / experts placed on a subset of
hosts), including *which* hosts — device placement changes the MP traffic
matrix, which is exactly what the Comm x Topo plane consumes.

The simulated-annealing proposal/acceptance follows FlexFlow's MCMC: accept
better strategies always, worse ones with probability exp(-delta/T).

Multi-tenant mode (:func:`mcmc_search_jobset`): the state is one
:class:`Strategy` per resident tenant of a :class:`~repro.core.workloads.JobSet`;
each move picks a tenant and proposes a per-job move in its *local* index
space (its MP pairs stay pinned to its placement), and the objective is the
weighted mean of per-job iteration times on the *shared* topology under the
union demand.
"""

from __future__ import annotations

import math
import os
import random
from dataclasses import dataclass, field, replace

import numpy as np

from .demand import TrafficDemand, demand_steps
from .netsim import (
    HardwareSpec,
    _iteration_time as iteration_time,
    compute_time,
    reference_comm_time,
)
from .planeval import JobSetEvaluator, LRUCache, plan_evaluator
from .topology_finder import Topology
from .workloads import JobSet, JobSpec, job_demand

# Cap on the per-tenant demand memo the jobset search loops share (entries
# are job-local TrafficDemands; long MCMC runs used to grow it unbounded).
DEMAND_CACHE_SIZE = 512


def demand_cache_size() -> int:
    """Capacity of the default per-tenant demand memo.  Fleet runs tune it
    without code edits via ``REPRO_DEMAND_CACHE_SIZE``; every search entry
    point also takes an explicit ``demand_cache`` kwarg which wins outright.
    """
    return int(os.environ.get("REPRO_DEMAND_CACHE_SIZE", str(DEMAND_CACHE_SIZE)))

# Acceptance decisions closer to the boundary than this (relative) are
# re-confirmed on a *pure* (path-independent) compiled evaluation: the
# incremental delta path carries ulp-level arithmetic lineage, and an MCMC
# move that leaves the objective mathematically unchanged must tie exactly
# — as it does on the reference path — or fixed-seed chains diverge.
_TIE_RTOL = 1e-12


@dataclass(frozen=True)
class Strategy:
    """A point in the Comp x Comm plane.

    ``schedule`` is the collective-schedule axis (ROADMAP item 2): the
    AllReduce schedule the strategy's demand compiles under
    (:mod:`repro.core.schedules` — ``"ring"`` keeps mutable ring demand,
    byte-identical to the pre-schedule search space)."""

    mode: str  # "dp" | "hybrid"
    table_hosts: tuple[int, ...] = ()
    ep_group_size: int = 0
    schedule: str = "ring"

    def demand(self, job: JobSpec, n: int) -> TrafficDemand:
        hosts = self.table_hosts if self.mode == "hybrid" else None
        return job_demand(job, n, table_hosts=hosts,
                          ep_group_size=self.ep_group_size,
                          schedule=self.schedule)


def default_strategy(job: JobSpec) -> Strategy:
    """The cold-start point of the search: pure DP (EP groups of 8 for MoE)."""
    return Strategy(mode="dp", ep_group_size=8 if job.n_experts else 0)


@dataclass
class SearchResult:
    strategy: Strategy
    iter_time: float
    demand: TrafficDemand
    history: list[float] = field(default_factory=list)


@dataclass
class JobSetSearchResult:
    """Joint strategy search outcome for a shared cluster."""

    strategies: dict[str, Strategy]
    iter_time: float  # weighted mean of per-job iteration times
    demand: TrafficDemand  # union demand, cluster index space
    per_job: dict[str, float] = field(default_factory=dict)
    history: list[float] = field(default_factory=list)


def _evaluate(
    strategy: Strategy, job: JobSpec, topo: Topology, hw: HardwareSpec, overlap: float
) -> tuple[float, TrafficDemand]:
    demand = strategy.demand(job, topo.n)
    comm = reference_comm_time(topo, demand, hw)
    comp = compute_time(job.flops_per_sample * job.batch_per_gpu * topo.n, topo.n, hw)
    return iteration_time(comm, comp, overlap=overlap), demand


def _propose(
    strategy: Strategy,
    job: JobSpec,
    n: int,
    rng: random.Random,
    schedules: tuple[str, ...] | None = None,
) -> Strategy:
    moves = ["toggle_mode"]
    if job.n_tables:
        moves += ["move_host", "add_host", "drop_host"]
    if job.n_experts:
        moves += ["ep_size"]
    if schedules and len(schedules) > 1:
        # The collective-schedule axis joins the move set only when the
        # caller opted into searching it — a None/singleton ``schedules``
        # consumes the RNG exactly like the pre-schedule proposal kernel.
        moves += ["schedule"]
    move = rng.choice(moves)

    if move == "schedule":
        options = [s for s in schedules if s != strategy.schedule]
        return replace(strategy, schedule=rng.choice(options))
    if move == "toggle_mode":
        if strategy.mode == "dp" and job.n_tables:
            k = max(1, min(job.n_tables, n // 4))
            hosts = tuple(sorted(rng.sample(range(n), k)))
            return Strategy(mode="hybrid", table_hosts=hosts,
                            ep_group_size=strategy.ep_group_size,
                            schedule=strategy.schedule)
        return Strategy(mode="dp", ep_group_size=strategy.ep_group_size,
                        schedule=strategy.schedule)

    hosts = list(strategy.table_hosts) or [rng.randrange(n)]
    if move == "move_host":
        idx = rng.randrange(len(hosts))
        hosts[idx] = rng.randrange(n)
    elif move == "add_host" and len(hosts) < min(n, job.n_tables):
        hosts.append(rng.randrange(n))
    elif move == "drop_host" and len(hosts) > 1:
        hosts.pop(rng.randrange(len(hosts)))
    elif move == "ep_size":
        sizes = [s for s in (2, 4, 8, 16, 32) if n % s == 0 and s <= n]
        if sizes:
            return Strategy(
                mode=strategy.mode, table_hosts=strategy.table_hosts,
                ep_group_size=rng.choice(sizes),
                schedule=strategy.schedule,
            )
    return Strategy(
        mode="hybrid", table_hosts=tuple(sorted(set(hosts))),
        ep_group_size=strategy.ep_group_size,
        schedule=strategy.schedule,
    )


def _check_schedules(schedules: tuple[str, ...] | None) -> tuple[str, ...] | None:
    """Validate a searchable-schedule tuple (None = ring-only, the
    byte-identical default)."""
    if schedules is None:
        return None
    from .schedules import get_schedule

    schedules = tuple(schedules)
    for s in schedules:
        get_schedule(s)
    return schedules


def mcmc_search(
    job: JobSpec,
    topo: Topology,
    hw: HardwareSpec,
    iters: int = 200,
    temperature: float = 0.1,
    overlap: float = 0.0,
    seed: int = 0,
    init: Strategy | None = None,
    compiled: bool = True,
    proposals_per_step: int = 1,
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
    temperatures: tuple[float, ...] | None = None,
) -> SearchResult:
    """Search the Comp x Comm plane for a fixed topology (§4.1).

    ``schedules`` opens the collective-schedule axis: a tuple of schedule
    names (:data:`repro.core.schedules.SCHEDULES`) the proposal kernel may
    flip between alongside the strategy moves.  ``None`` (default) or a
    singleton keeps the pre-schedule move set — and the exact RNG stream —
    so fixed-seed results stay byte-identical to HEAD.

    ``compiled=True`` (default) prices candidates on the compiled evaluator
    (:func:`repro.core.planeval.plan_evaluator`): demands and objective
    values are memoized per :class:`Strategy`, and each evaluation is the
    vectorized :meth:`PlanEvaluator.comm_time` — *bit-identical* to the
    reference walk, so the compiled chain makes exactly the decisions the
    ``compiled=False`` reference path makes at every fixed seed (including
    ``<=`` ties on moves that leave the objective unchanged).

    ``proposals_per_step=K > 1`` (compiled only) is the *batched* mode: K
    proposals are drawn, their load vectors re-priced as deltas against the
    incumbent (:meth:`PlanEvaluator.loads_delta`) in one vectorized pass,
    and the annealing rule is applied to the best of them.  It consumes the
    RNG differently, so its chain legitimately differs from ``K=1``.

    ``backend="jax"`` runs ``chains`` independent annealing chains over a
    pre-priced pool of ``pool_size`` strategies in one device dispatch
    (:func:`repro.core.planeval_jax.jax_mcmc_search` — ``lax.scan`` carries
    each chain, ``vmap`` batches them).  A documented different chain from
    the NumPy walk (finite move space, its own RNG streams); the default
    ``backend="numpy"`` is byte-stable against its introduction, and the
    returned ``iter_time`` is always re-priced on the bit-exact NumPy path.

    ``temperatures`` (JAX only) replaces ``temperature`` with an ascending
    parallel-tempering ladder: each chain carries the whole ladder on
    device with even/odd neighbor swap moves
    (:meth:`~repro.core.planeval_jax.ChainKernel.run_grid`).  A singleton
    ladder ``(t,)`` replays the flat ``temperature=t`` chains exactly.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown mcmc_search backend {backend!r}")
    if chains < 1:
        raise ValueError("chains must be >= 1")
    if temperatures is not None and backend != "jax":
        raise ValueError(
            "temperatures (tempering ladder) needs backend='jax'"
        )
    schedules = _check_schedules(schedules)
    if backend == "jax":
        from .planeval_jax import jax_mcmc_search

        return jax_mcmc_search(
            job, topo, hw, iters=iters, temperature=temperature,
            overlap=overlap, seed=seed, init=init, chains=chains,
            pool_size=pool_size, schedules=schedules,
            temperatures=temperatures,
        )
    if chains != 1:
        raise ValueError("chains > 1 needs backend='jax'")
    if proposals_per_step < 1:
        raise ValueError("proposals_per_step must be >= 1")
    if proposals_per_step > 1 and not compiled:
        raise ValueError("batched proposals need the compiled evaluator")
    rng = random.Random(seed)
    n = topo.n
    current = init or default_strategy(job)
    ev = plan_evaluator(topo, hw) if compiled else None
    comp = compute_time(job.flops_per_sample * job.batch_per_gpu * n, n, hw)
    demand_memo: dict[Strategy, TrafficDemand] = {}

    def demand_for(s: Strategy) -> TrafficDemand:
        d = demand_memo.get(s)
        if d is None:
            d = s.demand(job, n)
            demand_memo[s] = d
        return d

    time_memo: dict[Strategy, float] = {}

    def eval_time(s: Strategy) -> float:
        """Memoized bit-exact compiled evaluation — equals the reference
        ``_evaluate`` value to the bit for every strategy."""
        v = time_memo.get(s)
        if v is None:
            v = iteration_time(
                ev.comm_time(demand_for(s)), comp, overlap=overlap
            )
            time_memo[s] = v
        return v

    if compiled:
        cur_demand = demand_for(current)
        cur_time = eval_time(current)
        cur_loads = ev.loads(cur_demand) if proposals_per_step > 1 else None
    else:
        cur_loads = None
        cur_time, cur_demand = _evaluate(current, job, topo, hw, overlap)
    best, best_time, best_demand = current, cur_time, cur_demand
    history = [cur_time]

    for it in range(iters):
        if proposals_per_step > 1:
            cands = [
                _propose(current, job, n, rng, schedules=schedules)
                for _ in range(proposals_per_step)
            ]
            loads_list = [
                ev.loads_delta(cur_loads, cur_demand, demand_for(c))
                for c in cands
            ]
            comms = ev.comm_times_from_loads(loads_list)
            if hw.link_latency:
                # Same ``worst + α * steps`` expression as the reference
                # (the load-vector path prices only the β term).
                comms = comms + hw.link_latency * np.asarray(
                    [demand_steps(demand_for(c)) for c in cands]
                )
            times = [
                iteration_time(float(c), comp, overlap=overlap) for c in comms
            ]
            j = int(np.argmin(times))
            cand, cand_time, cand_loads = cands[j], times[j], loads_list[j]
            cand_demand = demand_for(cand)
        else:
            cand = _propose(current, job, n, rng, schedules=schedules)
            cand_loads = None
            if compiled:
                cand_demand = demand_for(cand)
                cand_time = eval_time(cand)
            else:
                cand_time, cand_demand = _evaluate(cand, job, topo, hw, overlap)
        t = temperature * max(cur_time, 1e-12)
        if cand_time <= cur_time or rng.random() < math.exp(
            -(cand_time - cur_time) / t
        ):
            current, cur_time, cur_demand = cand, cand_time, cand_demand
            cur_loads = cand_loads
            if cur_time < best_time:
                best, best_time, best_demand = current, cur_time, cur_demand
        history.append(cur_time)

    return SearchResult(
        strategy=best, iter_time=best_time, demand=best_demand, history=history
    )


# ---------------------------------------------------------------------------
# Multi-tenant: joint per-job strategy search on a shared topology
# ---------------------------------------------------------------------------


def _tenant_demands(
    strategies: dict[str, Strategy],
    jobset: JobSet,
    _demand_cache: dict | None,
) -> dict[str, TrafficDemand]:
    """Per-tenant *job-local* demands under ``strategies``, memoized in
    ``_demand_cache`` with the shared ``(label, strategy, k)`` keys."""
    demands: dict[str, TrafficDemand] = {}
    for t in jobset.tenants:
        s = strategies[t.label]
        if _demand_cache is None:
            demands[t.label] = s.demand(t.spec, t.k)
            continue
        key = (t.label, s, t.k)
        if key not in _demand_cache:
            _demand_cache[key] = s.demand(t.spec, t.k)
        demands[t.label] = _demand_cache[key]
    return demands


def tenant_comm_times(
    strategies: dict[str, Strategy],
    jobset: JobSet,
    topo: Topology,
    hw: HardwareSpec,
    _demand_cache: dict | None = None,
) -> dict[str, float]:
    """Per-tenant *own* bottleneck comm time on the shared fabric.

    The union objective charges every tenant the union's bottleneck; this
    decomposition instead gives each tenant its weighted share of every
    contended link: on link ``l`` a tenant holding ``v_i[l]`` of the load
    runs at ``cap_l * w_i / sum(w_j over tenants loading l)``, so its own
    comm time is ``max_l v_i[l] * sum_active_w_l / (w_i * cap_l)`` — the
    time its *own* bytes need under weighted processor sharing.  A tenant
    alone on all of its links gets exactly ``max_l v_i[l] / cap_l``; a
    tenant's decomposed time never exceeds the union comm time scaled by
    the inverse of its weight share, and at unit weights the heaviest
    tenant on the union bottleneck recovers the union time."""
    from .demand import remap_demand

    demands = _tenant_demands(strategies, jobset, _demand_cache)
    ev = plan_evaluator(topo, hw)
    vecs = [
        ev.loads(remap_demand(demands[t.label], t.servers, jobset.n))
        for t in jobset.tenants
    ]
    n_links = ev.n_links
    out: dict[str, float] = {}
    if not n_links:
        out = {t.label: 0.0 for t in jobset.tenants}
    else:
        mat = np.zeros((len(vecs), n_links), dtype=np.float64)
        for row, v in zip(mat, vecs):
            row[: v.size] = v
        weights = np.asarray([t.weight for t in jobset.tenants])
        active = mat > 0
        active_w = active.T @ weights  # per-link sum of contending weights
        caps = ev.caps
        for i, t in enumerate(jobset.tenants):
            mask = active[i]
            if not mask.any():
                out[t.label] = 0.0
                continue
            out[t.label] = float(np.max(
                mat[i, mask] * active_w[mask] / (weights[i] * caps[mask])
            ))
    if hw.link_latency:
        # α term: each tenant pays its *own* schedule's serial rounds.
        for t in jobset.tenants:
            out[t.label] = (
                out[t.label]
                + hw.link_latency * demand_steps(demands[t.label])
            )
    return out


def evaluate_jobset(
    strategies: dict[str, Strategy],
    jobset: JobSet,
    topo: Topology,
    hw: HardwareSpec,
    overlap: float = 0.0,
    _demand_cache: dict | None = None,
    compiled: bool = False,
    decompose: bool = False,
):
    """(weighted objective, union demand, per-job iteration times).

    The shared fabric serializes the union traffic: every job sees the fluid
    comm time of the *union* demand on the shared topology, plus its own
    compute on its shard.  The objective is the tenant-weight-weighted mean
    of per-job iteration times.

    ``_demand_cache`` memoizes per-tenant demand construction across calls
    (:class:`Strategy` is frozen/hashable): an MCMC move changes one
    tenant's strategy, so the other tenants' demands are reused verbatim.
    Pass an :class:`~repro.core.planeval.LRUCache` to bound it across long
    runs — :func:`~repro.core.alternating.co_optimize_jobset` shares one
    across all of its rounds.

    ``compiled=True`` prices the union on the compiled evaluator
    (:func:`~repro.core.planeval.plan_evaluator`); the default is the
    reference :func:`~repro.core.netsim.topoopt_comm_time`.  The true hot
    loop of :func:`mcmc_search_jobset` goes further and re-prices only the
    moved tenant's delta (:class:`~repro.core.planeval.JobSetEvaluator`).

    ``decompose=True`` appends a fourth element: each tenant's *own*
    bottleneck comm time (:func:`tenant_comm_times`, weighted share of the
    contended links) reported alongside the union-charged per-job times —
    the objective itself is unchanged, so fixed-seed search results cannot
    shift."""
    demands = _tenant_demands(strategies, jobset, _demand_cache)
    union = jobset.union(demands)
    if compiled:
        comm = plan_evaluator(topo, hw).comm_time(union)
    else:
        comm = reference_comm_time(topo, union, hw)
    per_job: dict[str, float] = {}
    obj = 0.0
    for t in jobset.tenants:
        comp = compute_time(t.flops_per_iteration, t.k, hw)
        per_job[t.label] = iteration_time(comm, comp, overlap=overlap)
        obj += t.weight * per_job[t.label]
    if decompose:
        per_comm = tenant_comm_times(
            strategies, jobset, topo, hw, _demand_cache=_demand_cache
        )
        return obj / jobset.total_weight, union, per_job, per_comm
    return obj / jobset.total_weight, union, per_job


def evaluate_jobset_decomposed(
    strategies: dict[str, Strategy],
    jobset: JobSet,
    topo: Topology,
    hw: HardwareSpec,
    overlap: float = 0.0,
    _demand_cache: dict | None = None,
) -> tuple[float, dict[str, float]]:
    """(weighted decomposed objective, per-job iteration times).

    The decomposed counterpart of :func:`evaluate_jobset`: each tenant is
    charged its *own* bottleneck comm time under weighted processor sharing
    (:func:`tenant_comm_times`) instead of the union's, so heavy-weight
    tenants actually shape the objective.  This is the reference pricing of
    ``mcmc_search_jobset(objective="decomposed")`` — the compiled path
    (:meth:`~repro.core.planeval.JobSetEvaluator.decomposed_objective_of`)
    computes the identical expressions from cached vectors and matches it
    to the bit."""
    comm = tenant_comm_times(
        strategies, jobset, topo, hw, _demand_cache=_demand_cache
    )
    per_job: dict[str, float] = {}
    obj = 0.0
    for t in jobset.tenants:
        comp = compute_time(t.flops_per_iteration, t.k, hw)
        per_job[t.label] = iteration_time(
            comm[t.label], comp, overlap=overlap
        )
        obj += t.weight * per_job[t.label]
    return obj / jobset.total_weight, per_job


def _mcmc_jobset_decomposed(
    jobset: JobSet,
    topo: Topology,
    hw: HardwareSpec,
    iters: int,
    temperature: float,
    overlap: float,
    seed: int,
    init: dict[str, Strategy] | None,
    compiled: bool,
    proposals_per_step: int,
    demand_cache: dict,
    schedules: tuple[str, ...] | None = None,
) -> JobSetSearchResult:
    """The ``objective="decomposed"`` annealing loop (bugfix for the PR-5
    gap where heavy tenants could not shape the union-annealed plan).

    Every candidate state is priced *from scratch* on its per-tenant
    vectors — the decomposition has no incremental ``total - old + new``
    form (a move flips which tenants contend on which links) — so no
    tie-confirmation pass is needed: compiled and reference paths compute
    bit-identical objectives and make identical fixed-seed decisions."""
    rng = random.Random(seed)
    current: dict[str, Strategy] = {
        t.label: (init or {}).get(t.label) or default_strategy(t.spec)
        for t in jobset.tenants
    }
    if compiled:
        jse = JobSetEvaluator(
            jobset, topo, hw, overlap=overlap, demand_cache=demand_cache
        )

        def _eval(state):
            return jse.decomposed_objective_of(state)

    else:

        def _eval(state):
            return evaluate_jobset_decomposed(
                state, jobset, topo, hw, overlap,
                _demand_cache=demand_cache,
            )

    cur_obj, cur_per_job = _eval(current)
    best = dict(current)
    best_obj, best_per_job = cur_obj, cur_per_job
    history = [cur_obj]

    for _ in range(iters):
        if proposals_per_step > 1:
            cands = []
            for _k in range(proposals_per_step):
                t = jobset.tenants[rng.randrange(len(jobset.tenants))]
                cand = dict(current)
                cand[t.label] = _propose(
                    current[t.label], t.spec, t.k, rng, schedules=schedules
                )
                cands.append(cand)
            evals = [_eval(c) for c in cands]
            j = int(np.argmin([e[0] for e in evals]))
            cand, (cand_obj, cand_per_job) = cands[j], evals[j]
        else:
            t = jobset.tenants[rng.randrange(len(jobset.tenants))]
            cand = dict(current)
            cand[t.label] = _propose(
                current[t.label], t.spec, t.k, rng, schedules=schedules
            )
            cand_obj, cand_per_job = _eval(cand)
        temp = temperature * max(cur_obj, 1e-12)
        if cand_obj <= cur_obj or rng.random() < math.exp(
            -(cand_obj - cur_obj) / temp
        ):
            current, cur_obj, cur_per_job = cand, cand_obj, cand_per_job
            if cur_obj < best_obj:
                best, best_obj = dict(current), cur_obj
                best_per_job = cur_per_job
        history.append(cur_obj)

    union = jobset.union(_tenant_demands(best, jobset, demand_cache))
    return JobSetSearchResult(
        strategies=best, iter_time=best_obj, demand=union,
        per_job=best_per_job, history=history,
    )


def mcmc_search_jobset(
    jobset: JobSet,
    topo: Topology,
    hw: HardwareSpec,
    iters: int = 200,
    temperature: float = 0.1,
    overlap: float = 0.0,
    seed: int = 0,
    init: dict[str, Strategy] | None = None,
    compiled: bool = True,
    proposals_per_step: int = 1,
    demand_cache: dict | None = None,
    objective: str = "union",
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
    temperatures: tuple[float, ...] | None = None,
) -> JobSetSearchResult:
    """Joint Comp x Comm search for a shared cluster (fixed topology).

    ``schedules`` opens the per-tenant collective-schedule axis (see
    :func:`mcmc_search`): proposal moves may flip a tenant's AllReduce
    schedule alongside its strategy moves.  ``None``/singleton keeps the
    pre-schedule move set and RNG stream byte-identical to HEAD.

    Each MCMC move picks one tenant and proposes a per-job move in its local
    index space (:func:`_propose` — table-host shuffles, EP-group resizes);
    acceptance follows the single-job annealing rule on the weighted
    objective.  Per-job MP pairs stay pinned to their placements: only the
    union's AllReduce groups are ring-mutable downstream.

    ``compiled=True`` (default) runs the *incremental* objective
    (:class:`~repro.core.planeval.JobSetEvaluator`): per-tenant link-load
    vectors are cached, and a single-tenant move re-prices only
    ``total - old + new`` instead of re-unioning and re-walking the whole
    JobSet.  ``compiled=False`` is the reference path — fixed seeds must
    give identical results on both.  ``proposals_per_step=K > 1`` (compiled
    only) prices K proposals per step in one vectorized pass and anneals on
    the best of them (a different, documented, chain).

    ``demand_cache`` (default: a fresh LRU bounded at
    ``DEMAND_CACHE_SIZE``) memoizes per-tenant demand construction;
    :func:`~repro.core.alternating.co_optimize_jobset` passes one cache
    shared across all of its rounds.

    ``objective="decomposed"`` anneals on the weighted *decomposed*
    per-tenant comm times (:func:`tenant_comm_times` semantics) instead of
    charging every tenant the union bottleneck — the PR-5 gap where a
    heavy-weight tenant could not pull the plan toward its own traffic.
    The default ``"union"`` preserves all existing goldens byte-for-byte.

    ``backend="jax"`` runs ``chains`` batched annealing chains over
    per-tenant pools of ``pool_size`` strategies in one device dispatch
    (:func:`repro.core.planeval_jax.jax_mcmc_search_jobset`); the reported
    result is re-priced on the bit-exact NumPy path.  ``backend="numpy"``
    (default) is byte-stable against its introduction.

    ``temperatures`` (JAX only) swaps ``temperature`` for an ascending
    parallel-tempering ladder run through the on-device grid kernel; a
    singleton ladder replays the flat chains' decisions exactly.
    """
    if not jobset.tenants:
        raise ValueError("mcmc_search_jobset needs at least one tenant")
    if objective not in ("union", "decomposed"):
        raise ValueError(f"unknown jobset objective {objective!r}")
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown mcmc_search_jobset backend {backend!r}")
    if chains < 1:
        raise ValueError("chains must be >= 1")
    if temperatures is not None and backend != "jax":
        raise ValueError(
            "temperatures (tempering ladder) needs backend='jax'"
        )
    schedules = _check_schedules(schedules)
    if backend == "jax":
        from .planeval_jax import jax_mcmc_search_jobset

        return jax_mcmc_search_jobset(
            jobset, topo, hw, iters=iters, temperature=temperature,
            overlap=overlap, seed=seed, init=init, chains=chains,
            pool_size=pool_size, objective=objective,
            demand_cache=demand_cache, schedules=schedules,
            temperatures=temperatures,
        )
    if chains != 1:
        raise ValueError("chains > 1 needs backend='jax'")
    if proposals_per_step < 1:
        raise ValueError("proposals_per_step must be >= 1")
    if proposals_per_step > 1 and not compiled:
        raise ValueError("batched proposals need the compiled evaluator")
    if demand_cache is None:
        demand_cache = LRUCache(demand_cache_size())
    if objective == "decomposed":
        return _mcmc_jobset_decomposed(
            jobset, topo, hw, iters, temperature, overlap, seed, init,
            compiled, proposals_per_step, demand_cache,
            schedules=schedules,
        )
    rng = random.Random(seed)
    current: dict[str, Strategy] = {
        t.label: (init or {}).get(t.label) or default_strategy(t.spec)
        for t in jobset.tenants
    }

    if compiled:
        jse = JobSetEvaluator(
            jobset, topo, hw, overlap=overlap, demand_cache=demand_cache
        )
        ref_memo: dict[tuple, float] = {}

        def _ref_jobset_obj(strategies: dict[str, Strategy]) -> float:
            """Bit-exact union objective (memoized) — tie-breaking
            authority for near-boundary acceptance (see
            :func:`mcmc_search`): the compiled union evaluation reproduces
            the reference walk to the bit, unlike the incremental
            per-tenant vector sums."""
            key = tuple(strategies[t.label] for t in jobset.tenants)
            v = ref_memo.get(key)
            if v is None:
                v = evaluate_jobset(
                    strategies, jobset, topo, hw, overlap,
                    _demand_cache=demand_cache, compiled=True,
                )[0]
                ref_memo[key] = v
            return v

        cur_obj, cur_per_job = jse.set_strategies(current)
        best = dict(current)
        best_obj, best_per_job = cur_obj, cur_per_job
        history = [cur_obj]

        for _ in range(iters):
            if proposals_per_step > 1:
                moves = []
                for _k in range(proposals_per_step):
                    t = jobset.tenants[rng.randrange(len(jobset.tenants))]
                    moves.append((
                        t.label,
                        _propose(current[t.label], t.spec, t.k, rng,
                                 schedules=schedules),
                    ))
                objs = jse.propose_batch(moves)
                j = int(np.argmin(objs))
                label, cand_s = moves[j]
                cand_obj, cand_per_job = jse.select(j)
            else:
                t = jobset.tenants[rng.randrange(len(jobset.tenants))]
                label = t.label
                cand_s = _propose(current[label], t.spec, t.k, rng,
                                  schedules=schedules)
                cand_obj, cand_per_job = jse.propose(label, cand_s)
            better = cand_obj <= cur_obj
            if (
                proposals_per_step == 1
                and abs(cand_obj - cur_obj)
                <= _TIE_RTOL * max(abs(cand_obj), abs(cur_obj))
            ):
                # Boundary case: confirm on the reference objective so
                # mathematical ties accept exactly like the reference chain.
                cand_state = dict(current)
                cand_state[label] = cand_s
                better = (
                    _ref_jobset_obj(cand_state)
                    <= _ref_jobset_obj(current)
                )
            temp = temperature * max(cur_obj, 1e-12)
            if better or rng.random() < math.exp(
                -(cand_obj - cur_obj) / temp
            ):
                jse.accept()
                current[label] = cand_s
                cur_obj, cur_per_job = cand_obj, cand_per_job
                improved = cur_obj < best_obj
                if (
                    proposals_per_step == 1
                    and abs(cur_obj - best_obj)
                    <= _TIE_RTOL * max(abs(cur_obj), abs(best_obj))
                ):
                    improved = (
                        _ref_jobset_obj(current) < _ref_jobset_obj(best)
                    )
                if improved:
                    best, best_obj = dict(current), cur_obj
                    best_per_job = cur_per_job
            history.append(cur_obj)

        return JobSetSearchResult(
            strategies=best, iter_time=best_obj,
            demand=jse.union_for(best), per_job=best_per_job,
            history=history,
        )

    cur_obj, cur_union, cur_per_job = evaluate_jobset(
        current, jobset, topo, hw, overlap, _demand_cache=demand_cache
    )
    best = dict(current)
    best_obj, best_union, best_per_job = cur_obj, cur_union, cur_per_job
    history = [cur_obj]

    for _ in range(iters):
        t = jobset.tenants[rng.randrange(len(jobset.tenants))]
        cand = dict(current)
        cand[t.label] = _propose(current[t.label], t.spec, t.k, rng,
                                 schedules=schedules)
        cand_obj, cand_union, cand_per_job = evaluate_jobset(
            cand, jobset, topo, hw, overlap, _demand_cache=demand_cache
        )
        temp = temperature * max(cur_obj, 1e-12)
        if cand_obj <= cur_obj or rng.random() < math.exp(
            -(cand_obj - cur_obj) / temp
        ):
            current, cur_obj = cand, cand_obj
            cur_union, cur_per_job = cand_union, cand_per_job
            if cur_obj < best_obj:
                best, best_obj = dict(current), cur_obj
                best_union, best_per_job = cur_union, cur_per_job
        history.append(cur_obj)

    return JobSetSearchResult(
        strategies=best, iter_time=best_obj, demand=best_union,
        per_job=best_per_job, history=history,
    )
