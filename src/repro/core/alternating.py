"""Alternating optimization (paper §4.1, Fig. 6) — TopoOpt's outer loop.

The paper frames co-optimization as a search over three coupled dimensions
(computation, communication, topology) and alternates between two planes
until convergence or ``rounds`` iterations:

  Comp x Comm : parallelization-strategy search (FlexFlow-style MCMC,
                :func:`repro.core.strategy_search.mcmc_search`) with the
                network topology held fixed;
  Comm x Topo : TopologyFinder (Algorithm 1,
                :func:`repro.core.topology_finder.topology_finder`) on the
                traffic demand the chosen strategy induces.

Notation mapping (paper -> code):

  =====================  ==================================================
  paper                  here
  =====================  ==================================================
  ``S`` (strategy)       :class:`repro.core.strategy_search.Strategy`
  ``G`` (topology)       :class:`repro.core.topology_finder.Topology`
  ``T`` (traffic)        :class:`repro.core.demand.TrafficDemand`
  ``t_iter`` (Eq. 1)     :func:`repro.core.netsim.iteration_time`
  ``k`` rounds           ``rounds`` argument
  =====================  ==================================================

Online re-optimization (:mod:`repro.core.online`) re-enters this loop with
``warm_topology`` / ``warm_strategy`` (seed both planes from the incumbent
plan) and ``forbidden`` (failed fiber pairs excluded from every rebuild);
the cold-start defaults reproduce the paper's offline pipeline exactly.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .demand import TrafficDemand
from .netsim import (
    HardwareSpec,
    _iteration_time as iteration_time,
    _topoopt_comm_time as topoopt_comm_time,
    compute_time,
)
from .planeval import JobSetEvaluator, LRUCache
from .simengine import SimEngine
from .strategy_search import (
    JobSetSearchResult,
    _check_schedules,
    demand_cache_size,
    SearchResult,
    Strategy,
    default_strategy,
    evaluate_jobset,
    evaluate_jobset_decomposed,
    mcmc_search,
    mcmc_search_jobset,
    tenant_comm_times,
)
from .topology_finder import Topology, topology_finder
from .workloads import JobSet, JobSpec


@dataclass
class CoOptResult:
    strategy: Strategy
    topology: Topology
    iter_time: float
    demand: TrafficDemand
    rounds: list[float] = field(default_factory=list)


@dataclass
class JobSetPlan:
    """A shared-cluster plan: one strategy per tenant + one shared topology.

    Duck-compatible with :class:`CoOptResult` where the online layer needs it
    (``topology`` / ``demand`` / ``iter_time``; ``strategy`` is the
    per-tenant dict).

    ``jobset`` / ``candidate_index`` carry placement-co-search provenance:
    the JobSet (tenant placements) this plan was optimized for and its index
    in the ``placement_candidates`` list it won from (0 when no candidate
    search ran).  ``per_job_comm`` is each tenant's *own* bottleneck comm
    time (:func:`~repro.core.strategy_search.tenant_comm_times`) alongside
    the union-charged ``per_job`` iteration times."""

    strategies: dict[str, Strategy]
    topology: Topology
    iter_time: float  # weighted mean of per-job iteration times
    demand: TrafficDemand  # union demand, cluster index space
    per_job: dict[str, float] = field(default_factory=dict)
    rounds: list[float] = field(default_factory=list)
    jobset: "JobSet | None" = None
    candidate_index: int = 0
    per_job_comm: dict[str, float] = field(default_factory=dict)

    @property
    def strategy(self) -> dict[str, Strategy]:
        return self.strategies


def initial_topology(
    n: int, degree: int, forbidden: tuple[tuple[int, int], ...] = ()
) -> Topology:
    """Start from the naive stride-1 multi-ring (pure DP assumption).

    ``forbidden`` pairs (e.g. failed fibers) are excluded so the starting
    point is realizable on a degraded fabric."""
    from .demand import data_parallel_demand

    return topology_finder(data_parallel_demand(n, 1.0), degree,
                           forbidden=forbidden)


def evaluate(
    strategy: Strategy,
    topo: Topology,
    job: JobSpec,
    hw: HardwareSpec,
    overlap: float = 0.0,
    compiled: bool = True,
) -> float:
    """Iteration time of (strategy, topology) — thin shim over
    :meth:`repro.core.simengine.SimEngine.iteration_time` (compiled plan
    evaluator by default; ``compiled=False`` forces the reference fluid
    path)."""
    demand = strategy.demand(job, topo.n)
    return SimEngine(hw, compiled=compiled).iteration_time(
        topo,
        demand,
        flops_per_iteration=job.flops_per_sample * job.batch_per_gpu * topo.n,
        overlap=overlap,
    )


def alternating_optimize(
    job: JobSpec,
    n: int,
    hw: HardwareSpec,
    rounds: int = 4,
    mcmc_iters: int = 150,
    overlap: float = 0.0,
    seed: int = 0,
    rel_tol: float = 1e-3,
    warm_topology: Topology | None = None,
    warm_strategy: Strategy | None = None,
    forbidden: tuple[tuple[int, int], ...] = (),
    compiled: bool = True,
    proposals_per_step: int = 1,
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
    temperatures: tuple[float, ...] | None = None,
) -> CoOptResult:
    """TopoOpt's off-line co-optimization loop.

    ``schedules`` opens the collective-schedule axis in every round's
    strategy search (:func:`~repro.core.strategy_search.mcmc_search`);
    ``None`` (default) keeps the ring-only move set byte-identical to HEAD.

    Online re-optimization (:mod:`repro.core.online`) re-enters this loop
    mid-run with a **warm start**: ``warm_topology`` / ``warm_strategy``
    seed both planes from the incumbent plan instead of the naive stride-1
    ring, and ``forbidden`` pins failed fiber pairs out of every topology
    rebuild.  A warm-started call also threads the incumbent into
    :func:`topology_finder`'s ``warm_start`` so ring strides that survived
    the disruption are kept (less physical churn on the patch panel).
    Cold calls (all three defaults) are byte-identical to the offline PR-1
    behaviour.

    ``compiled`` / ``proposals_per_step`` select the candidate-pricing path
    of the inner MCMC (:func:`~repro.core.strategy_search.mcmc_search`):
    the compiled evaluator is the default and must match the
    ``compiled=False`` reference at fixed seeds.  ``backend="jax"`` runs
    each round's strategy search as ``chains`` batched on-device chains
    (:mod:`repro.core.planeval_jax`); the default NumPy backend is
    byte-stable against it.  ``temperatures`` (JAX only) upgrades each
    round's search to a parallel-tempering ladder on the grid kernel — a
    singleton ladder replays the flat chains exactly.
    """
    warm = warm_topology is not None
    topo = (
        warm_topology
        if warm
        else initial_topology(n, hw.degree, forbidden=forbidden)
    )
    best: CoOptResult | None = None
    round_times: list[float] = []
    strategy_init: Strategy | None = warm_strategy

    for r in range(rounds):
        # Comp x Comm plane: search strategy on the fixed topology.
        res: SearchResult = mcmc_search(
            job, topo, hw, iters=mcmc_iters, overlap=overlap,
            seed=seed + r, init=strategy_init,
            compiled=compiled, proposals_per_step=proposals_per_step,
            backend=backend, chains=chains, pool_size=pool_size,
            schedules=schedules, temperatures=temperatures,
        )
        # Comm x Topo plane: rebuild the topology for the found demand.
        new_topo = topology_finder(
            res.demand, hw.degree, forbidden=forbidden,
            warm_start=topo if warm else None,
        )
        t_new = evaluate(res.strategy, new_topo, job, hw, overlap,
                         compiled=compiled)
        round_times.append(t_new)

        if best is None or t_new < best.iter_time:
            best = CoOptResult(
                strategy=res.strategy, topology=new_topo,
                iter_time=t_new, demand=res.demand, rounds=round_times,
            )
        # Converged?
        if len(round_times) >= 2 and (
            abs(round_times[-2] - round_times[-1])
            <= rel_tol * max(round_times[-2], 1e-12)
        ):
            break
        topo = new_topo
        strategy_init = res.strategy

    assert best is not None
    best.rounds = round_times
    return best


def _co_optimize_single(
    jobset: JobSet,
    hw: HardwareSpec,
    rounds: int,
    mcmc_iters: int,
    overlap: float,
    seed: int,
    rel_tol: float,
    warm_topology: Topology | None,
    warm_strategies: dict[str, Strategy] | None,
    forbidden: tuple[tuple[int, int], ...],
    compiled: bool,
    proposals_per_step: int,
    demand_cache,
    objective: str = "union",
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
    temperatures: tuple[float, ...] | None = None,
) -> JobSetPlan:
    """The two-plane alternating loop for one fixed tenant placement —
    exactly the pre-placement-search ``co_optimize_jobset`` body."""
    warm = warm_topology is not None
    init: dict[str, Strategy] = {
        t.label: (warm_strategies or {}).get(t.label) or default_strategy(t.spec)
        for t in jobset.tenants
    }
    topo = (
        warm_topology
        if warm
        else topology_finder(
            jobset.union_for(init), hw.degree, forbidden=forbidden,
            pack="per_node",
        )
    )
    best: JobSetPlan | None = None
    round_times: list[float] = []
    strategy_init = init

    for r in range(rounds):
        res: JobSetSearchResult = mcmc_search_jobset(
            jobset, topo, hw, iters=mcmc_iters, overlap=overlap,
            seed=seed + r, init=strategy_init,
            compiled=compiled, proposals_per_step=proposals_per_step,
            demand_cache=demand_cache, objective=objective,
            backend=backend, chains=chains, pool_size=pool_size,
            schedules=schedules, temperatures=temperatures,
        )
        new_topo = topology_finder(
            res.demand, hw.degree, forbidden=forbidden,
            warm_start=topo if warm else None, pack="per_node",
        )
        if objective == "decomposed":
            # Round scoring must match what the chains annealed on, or the
            # outer loop would keep undoing the inner one's preferences.
            t_new, per_job = evaluate_jobset_decomposed(
                res.strategies, jobset, new_topo, hw, overlap,
                _demand_cache=demand_cache,
            )
            union = jobset.union_for(res.strategies)
        else:
            t_new, union, per_job = evaluate_jobset(
                res.strategies, jobset, new_topo, hw, overlap,
                _demand_cache=demand_cache, compiled=compiled,
            )
        round_times.append(t_new)

        if best is None or t_new < best.iter_time:
            best = JobSetPlan(
                strategies=dict(res.strategies), topology=new_topo,
                iter_time=t_new, demand=union, per_job=per_job,
                rounds=round_times, jobset=jobset,
            )
        if len(round_times) >= 2 and (
            abs(round_times[-2] - round_times[-1])
            <= rel_tol * max(round_times[-2], 1e-12)
        ):
            break
        topo = new_topo
        strategy_init = res.strategies

    assert best is not None
    best.rounds = round_times
    return best


def _co_optimize_fused(
    candidates: list[JobSet],
    order: list[int],
    hw: HardwareSpec,
    rounds: int,
    mcmc_iters: int,
    overlap: float,
    seed: int,
    rel_tol: float,
    warm_topology: Topology | None,
    warm_strategies: dict[str, Strategy] | None,
    forbidden: tuple[tuple[int, int], ...],
    demand_cache,
    objective: str,
    chains: int,
    pool_size: int,
    schedules: tuple[str, ...] | None,
    temperatures: tuple[float, ...],
) -> JobSetPlan:
    """Fused admission co-search: every screened placement candidate x the
    tempering ladder in **one** device dispatch per alternating round.

    Where the sequential path runs the whole alternating loop once per
    candidate (each round's winner re-materialized on host, every
    candidate paying its own jit dispatches), this loop prices one pool
    per tenant up front, stacks every candidate's link table into the
    padded grid (:func:`~repro.core.planeval_jax.pack_jobset_grid`), and
    per round launches a single grid dispatch
    (:meth:`~repro.core.planeval_jax.ChainKernel.run_grid`).  The winner
    hand-off between rounds stays on-device: each candidate's best
    (chain, rung) assignment — pool *indices*, valid across rounds because
    the pools are fixed — seeds the next round's grid directly; the host
    reads back only the small index array to rebuild each candidate's
    topology from its winner demand.  Only the final overall winner is
    re-priced on the bit-exact NumPy path.

    Search semantics differ from the sequential path (shared pools across
    rounds, device energies as round scores) — a documented different
    search, gated end-to-end by ``benchmarks/bench_admission_jax.py`` on
    both speedup and plan quality.  Per-candidate best tracking scores
    each round's winner on the topology the chains searched on, so the
    tracked energy and the final NumPy re-price agree to
    :data:`~repro.core.planeval_jax.JAX_EQUIV_RTOL`.
    """
    from .planeval_jax import (
        _POOL_SEED_OFFSET,
        _require_jax,
        ChainKernel,
        check_temper_ladder,
        draw_grid_streams,
        draw_swap_streams,
        pack_jobset_grid,
        strategy_pool,
    )

    jnp = _require_jax().numpy
    ladder = np.asarray(check_temper_ladder(temperatures), dtype=np.float64)
    M = ladder.size
    schedules = _check_schedules(schedules)
    subset = [candidates[ci] for ci in order]
    C = len(subset)
    tenants = subset[0].tenants
    T = len(tenants)
    init = {
        t.label: (warm_strategies or {}).get(t.label)
        or default_strategy(t.spec)
        for t in tenants
    }
    # One pre-priced pool per tenant, shared by every candidate and round
    # (the same seeds the sequential JAX path uses for its first round).
    pools = [
        strategy_pool(
            t.spec, t.k, pool_size, seed + _POOL_SEED_OFFSET + i,
            init=init[t.label], schedules=schedules,
        )
        for i, t in enumerate(tenants)
    ]
    warm = warm_topology is not None
    topos = [
        warm_topology
        if warm
        else topology_finder(
            js.union_for(init), hw.degree, forbidden=forbidden,
            pack="per_node",
        )
        for js in subset
    ]

    cur_idx = np.zeros((C, T), dtype=np.int64)  # device array after round 0
    best_obj = np.full(C, np.inf)
    best_idx = np.zeros((C, T), dtype=np.int64)
    best_topo: list[Topology] = list(topos)
    round_objs: list[list[float]] = [[] for _ in range(C)]

    for r in range(rounds):
        V, caps, comps, weights, steps, _evs = pack_jobset_grid(
            subset, topos, hw, pools, overlap=overlap,
            demand_cache=demand_cache,
        )
        kernel = ChainKernel(
            V, caps, comps, weights, overlap=overlap, objective=objective,
            steps=steps, alpha=hw.link_latency,
        )
        t_idx, s_idx, u = draw_grid_streams(
            seed + r, C, chains, M, mcmc_iters, T, pool_size
        )
        su = draw_swap_streams(seed + r, C, chains, M, mcmc_iters)
        ba, bo, _hist = kernel.run_grid(
            cur_idx, ladder, t_idx, s_idx, u, su, device=True
        )
        # Per-candidate winning chain; the index arrays stay on device as
        # the next round's start states.
        k_star = jnp.argmin(bo, axis=1)
        c_ar = jnp.arange(C)
        cur_idx = ba[c_ar, k_star]
        win_idx = np.asarray(cur_idx)
        win_obj = np.asarray(bo[c_ar, k_star], dtype=np.float64)
        new_topos = []
        for ci, js in enumerate(subset):
            round_objs[ci].append(float(win_obj[ci]))
            # Best tracking scores the winner on the topology it was
            # searched on (== the device energy); the rebuilt topology
            # feeds the next round and gets credited there if better.
            if win_obj[ci] < best_obj[ci]:
                best_obj[ci] = win_obj[ci]
                best_idx[ci] = win_idx[ci]
                best_topo[ci] = topos[ci]
            strategies = {
                t.label: pools[i][int(win_idx[ci, i])]
                for i, t in enumerate(js.tenants)
            }
            new_topos.append(topology_finder(
                js.union_for(strategies), hw.degree, forbidden=forbidden,
                warm_start=topos[ci] if warm else None, pack="per_node",
            ))
        topos = new_topos
        if r >= 1 and all(
            abs(ro[-2] - ro[-1]) <= rel_tol * max(ro[-2], 1e-12)
            for ro in round_objs
        ):
            break

    w = int(np.argmin(best_obj))  # ties resolve toward earlier candidates
    js = subset[w]
    strategies = {
        t.label: pools[i][int(best_idx[w, i])]
        for i, t in enumerate(js.tenants)
    }
    topo = best_topo[w]
    if objective == "decomposed":
        t_fin, per_job = evaluate_jobset_decomposed(
            strategies, js, topo, hw, overlap, _demand_cache=demand_cache
        )
        union = js.union_for(strategies)
    else:
        t_fin, union, per_job = evaluate_jobset(
            strategies, js, topo, hw, overlap,
            _demand_cache=demand_cache, compiled=True,
        )
    plan = JobSetPlan(
        strategies=strategies, topology=topo, iter_time=t_fin,
        demand=union, per_job=per_job, rounds=round_objs[w], jobset=js,
        candidate_index=order[w],
    )
    return plan


def co_optimize_jobset(
    jobset: JobSet,
    hw: HardwareSpec,
    rounds: int = 4,
    mcmc_iters: int = 150,
    overlap: float = 0.0,
    seed: int = 0,
    rel_tol: float = 1e-3,
    warm_topology: Topology | None = None,
    warm_strategies: dict[str, Strategy] | None = None,
    forbidden: tuple[tuple[int, int], ...] = (),
    compiled: bool = True,
    proposals_per_step: int = 1,
    placement_candidates: list[JobSet] | None = None,
    screen_candidates: int | None = None,
    objective: str = "union",
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
    temperatures: tuple[float, ...] | None = None,
) -> JobSetPlan:
    """Multi-tenant alternating optimization: co-optimize every resident
    job's parallelization strategy against one *shared* topology.

    The same two-plane loop as :func:`alternating_optimize`, lifted to a
    :class:`~repro.core.workloads.JobSet`: the Comp x Comm plane proposes
    per-job moves (:func:`~repro.core.strategy_search.mcmc_search_jobset`,
    weighted-mean objective), and the Comm x Topo plane rebuilds one shared
    topology from the *union* demand with per-node degree packing
    (``topology_finder(pack="per_node")``) — per-job ring budgets land only
    on each job's own servers, per-job MP pairs stay pinned to their
    placements, and idle servers keep a connectivity ring for future
    arrivals.  ``warm_topology`` / ``warm_strategies`` / ``forbidden``
    mirror the single-job warm-start contract for online re-optimization.

    **Placement co-search** (``placement_candidates``): placement is the
    fourth co-optimized axis.  Pass a list of candidate JobSets — the same
    tenants under different server placements, e.g. one per
    :func:`~repro.core.online.place_candidates` admission variant — and the
    full alternating loop runs *per candidate* with the same seed, scoring
    each through the compiled :class:`~repro.core.planeval.JobSetEvaluator`
    (per-tenant job-local demands are placement-independent, so one shared
    demand cache serves every candidate); the best full plan wins, ties
    resolved toward the earlier candidate (the greedy seed comes first).
    ``None`` — and a single-candidate list equal to ``jobset`` — follow the
    exact pre-search code path, so fixed-seed plans are unchanged.
    The winning plan records its ``jobset`` and ``candidate_index``
    (always the index into the *original* candidate list).

    ``screen_candidates=k`` bounds the cost of a wide candidate list: every
    candidate is first scored with the *incremental*
    :class:`~repro.core.planeval.JobSetEvaluator` on its warm (or cold
    per-candidate) topology — synthetic rings for placements the incumbent
    fabric never carried, exactly the ``rebalance`` screen — and only the
    ``k`` best-screened candidates pay the full alternating loop.  ``None``
    (default) and any ``k >= len(candidates)`` run every candidate:
    byte-identical to the unscreened behaviour.

    ``objective="decomposed"`` anneals and scores rounds on the weighted
    decomposed per-tenant comm times
    (:func:`~repro.core.strategy_search.evaluate_jobset_decomposed`);
    ``backend="jax"`` / ``chains`` run each round's search as batched
    on-device chains.  The defaults preserve existing goldens.

    ``temperatures`` (JAX only) is the **fused admission co-search** path:
    with two or more surviving candidates, the per-candidate Python loop
    below is replaced by one grid dispatch per alternating round — every
    candidate x every ladder rung x every chain in a single jit call, the
    winner hand-off between rounds staying on-device, and only the final
    plan re-priced on the bit-exact NumPy path (:func:`_co_optimize_fused`,
    a documented different search gated on end quality).  With a single
    candidate the standard per-round loop runs with the ladder threaded
    into each round's search, so a singleton ladder replays the flat JAX
    path exactly.

    One LRU-bounded per-tenant demand cache is shared across every round's
    MCMC and the final pricing (the caches used to be rebuilt per round);
    ``compiled`` / ``proposals_per_step`` select the candidate-pricing path
    exactly as in :func:`alternating_optimize`.  The winner additionally
    reports ``per_job_comm`` — each tenant's own decomposed bottleneck time
    (:func:`~repro.core.strategy_search.tenant_comm_times`).
    """
    if placement_candidates is not None and not placement_candidates:
        raise ValueError("placement_candidates must be non-empty when given")
    candidates = (
        [jobset] if placement_candidates is None else list(placement_candidates)
    )
    labels = {t.label for t in jobset.tenants}
    for js in candidates:
        if {t.label for t in js.tenants} != labels:
            raise ValueError(
                "every placement candidate must carry the same tenant labels"
            )
    if not jobset.tenants:
        raise ValueError("co_optimize_jobset needs at least one tenant")
    if screen_candidates is not None and screen_candidates < 1:
        raise ValueError("screen_candidates must be >= 1 when given")
    demand_cache = LRUCache(demand_cache_size())

    order = list(range(len(candidates)))
    if screen_candidates is not None and screen_candidates < len(candidates):
        # Fast screen (bugfix: wide candidate lists used to pay the full
        # alternating loop per candidate): incremental evaluator pricing of
        # each candidate's warm-start state, survivors in original order so
        # the tie-toward-earlier contract below is unchanged.
        scores: list[tuple[float, int]] = []
        for ci, js in enumerate(candidates):
            init = {
                t.label: (warm_strategies or {}).get(t.label)
                or default_strategy(t.spec)
                for t in js.tenants
            }
            topo0 = (
                warm_topology
                if warm_topology is not None
                else topology_finder(
                    js.union_for(init), hw.degree, forbidden=forbidden,
                    pack="per_node",
                )
            )
            jse = JobSetEvaluator(
                js, topo0, hw, overlap=overlap, demand_cache=demand_cache,
                synth_missing_rings=True,
            )
            scores.append((jse.set_strategies(init)[0], ci))
        scores.sort()
        order = sorted(ci for _, ci in scores[:screen_candidates])

    if temperatures is not None and backend != "jax":
        raise ValueError(
            "temperatures (tempering ladder) needs backend='jax'"
        )
    if temperatures is not None and len(order) > 1:
        # Fused admission co-search: all surviving candidates x the
        # tempering ladder in one grid dispatch per alternating round.
        best: JobSetPlan | None = _co_optimize_fused(
            candidates, order, hw, rounds, mcmc_iters, overlap, seed,
            rel_tol, warm_topology, warm_strategies, forbidden,
            demand_cache, objective=objective, chains=chains,
            pool_size=pool_size, schedules=schedules,
            temperatures=temperatures,
        )
    else:
        best = None
        for ci in order:
            plan = _co_optimize_single(
                candidates[ci], hw, rounds, mcmc_iters, overlap, seed,
                rel_tol, warm_topology, warm_strategies, forbidden,
                compiled, proposals_per_step, demand_cache,
                objective=objective, backend=backend, chains=chains,
                pool_size=pool_size, schedules=schedules,
                temperatures=temperatures,
            )
            plan.candidate_index = ci
            if best is None or plan.iter_time < best.iter_time:
                best = plan

    assert best is not None
    best.per_job_comm = tenant_comm_times(
        best.strategies, best.jobset, best.topology, hw,
        _demand_cache=demand_cache,
    )
    return best
