"""Alternating optimization (§4.1, Fig. 6).

Alternates between the two planes until convergence or ``k`` rounds:

  Comp x Comm : MCMC strategy search with the topology held fixed,
  Comm x Topo : TopologyFinder on the demand the strategy induces.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .demand import TrafficDemand
from .netsim import HardwareSpec, compute_time, iteration_time, topoopt_comm_time
from .simengine import SimEngine
from .strategy_search import SearchResult, Strategy, mcmc_search
from .topology_finder import Topology, topology_finder
from .workloads import JobSpec


@dataclass
class CoOptResult:
    strategy: Strategy
    topology: Topology
    iter_time: float
    demand: TrafficDemand
    rounds: list[float] = field(default_factory=list)


def initial_topology(n: int, degree: int) -> Topology:
    """Start from the naive stride-1 multi-ring (pure DP assumption)."""
    from .demand import data_parallel_demand

    return topology_finder(data_parallel_demand(n, 1.0), degree)


def evaluate(
    strategy: Strategy,
    topo: Topology,
    job: JobSpec,
    hw: HardwareSpec,
    overlap: float = 0.0,
) -> float:
    """Iteration time of (strategy, topology) — thin shim over
    :meth:`repro.core.simengine.SimEngine.iteration_time`."""
    demand = strategy.demand(job, topo.n)
    return SimEngine(hw).iteration_time(
        topo,
        demand,
        flops_per_iteration=job.flops_per_sample * job.batch_per_gpu * topo.n,
        overlap=overlap,
    )


def alternating_optimize(
    job: JobSpec,
    n: int,
    hw: HardwareSpec,
    rounds: int = 4,
    mcmc_iters: int = 150,
    overlap: float = 0.0,
    seed: int = 0,
    rel_tol: float = 1e-3,
) -> CoOptResult:
    """TopoOpt's off-line co-optimization loop."""
    topo = initial_topology(n, hw.degree)
    best: CoOptResult | None = None
    round_times: list[float] = []
    strategy_init: Strategy | None = None

    for r in range(rounds):
        # Comp x Comm plane: search strategy on the fixed topology.
        res: SearchResult = mcmc_search(
            job, topo, hw, iters=mcmc_iters, overlap=overlap,
            seed=seed + r, init=strategy_init,
        )
        # Comm x Topo plane: rebuild the topology for the found demand.
        new_topo = topology_finder(res.demand, hw.degree)
        t_new = evaluate(res.strategy, new_topo, job, hw, overlap)
        round_times.append(t_new)

        if best is None or t_new < best.iter_time:
            best = CoOptResult(
                strategy=res.strategy, topology=new_topo,
                iter_time=t_new, demand=res.demand, rounds=round_times,
            )
        # Converged?
        if len(round_times) >= 2 and (
            abs(round_times[-2] - round_times[-1])
            <= rel_tol * max(round_times[-2], 1e-12)
        ):
            break
        topo = new_topo
        strategy_init = res.strategy

    assert best is not None
    best.rounds = round_times
    return best
