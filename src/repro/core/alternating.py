"""Alternating optimization (paper §4.1, Fig. 6) — TopoOpt's outer loop.

The paper frames co-optimization as a search over three coupled dimensions
(computation, communication, topology) and alternates between two planes
until convergence or ``rounds`` iterations:

  Comp x Comm : parallelization-strategy search (FlexFlow-style MCMC,
                :func:`repro.core.strategy_search.mcmc_search`) with the
                network topology held fixed;
  Comm x Topo : TopologyFinder (Algorithm 1,
                :func:`repro.core.topology_finder.topology_finder`) on the
                traffic demand the chosen strategy induces.

Notation mapping (paper -> code):

  =====================  ==================================================
  paper                  here
  =====================  ==================================================
  ``S`` (strategy)       :class:`repro.core.strategy_search.Strategy`
  ``G`` (topology)       :class:`repro.core.topology_finder.Topology`
  ``T`` (traffic)        :class:`repro.core.demand.TrafficDemand`
  ``t_iter`` (Eq. 1)     :func:`repro.core.netsim.iteration_time`
  ``k`` rounds           ``rounds`` argument
  =====================  ==================================================

Online re-optimization (:mod:`repro.core.online`) re-enters this loop with
``warm_topology`` / ``warm_strategy`` (seed both planes from the incumbent
plan) and ``forbidden`` (failed fiber pairs excluded from every rebuild);
the cold-start defaults reproduce the paper's offline pipeline exactly.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .demand import TrafficDemand
from .netsim import HardwareSpec, compute_time, iteration_time, topoopt_comm_time
from .simengine import SimEngine
from .strategy_search import SearchResult, Strategy, mcmc_search
from .topology_finder import Topology, topology_finder
from .workloads import JobSpec


@dataclass
class CoOptResult:
    strategy: Strategy
    topology: Topology
    iter_time: float
    demand: TrafficDemand
    rounds: list[float] = field(default_factory=list)


def initial_topology(
    n: int, degree: int, forbidden: tuple[tuple[int, int], ...] = ()
) -> Topology:
    """Start from the naive stride-1 multi-ring (pure DP assumption).

    ``forbidden`` pairs (e.g. failed fibers) are excluded so the starting
    point is realizable on a degraded fabric."""
    from .demand import data_parallel_demand

    return topology_finder(data_parallel_demand(n, 1.0), degree,
                           forbidden=forbidden)


def evaluate(
    strategy: Strategy,
    topo: Topology,
    job: JobSpec,
    hw: HardwareSpec,
    overlap: float = 0.0,
) -> float:
    """Iteration time of (strategy, topology) — thin shim over
    :meth:`repro.core.simengine.SimEngine.iteration_time`."""
    demand = strategy.demand(job, topo.n)
    return SimEngine(hw).iteration_time(
        topo,
        demand,
        flops_per_iteration=job.flops_per_sample * job.batch_per_gpu * topo.n,
        overlap=overlap,
    )


def alternating_optimize(
    job: JobSpec,
    n: int,
    hw: HardwareSpec,
    rounds: int = 4,
    mcmc_iters: int = 150,
    overlap: float = 0.0,
    seed: int = 0,
    rel_tol: float = 1e-3,
    warm_topology: Topology | None = None,
    warm_strategy: Strategy | None = None,
    forbidden: tuple[tuple[int, int], ...] = (),
) -> CoOptResult:
    """TopoOpt's off-line co-optimization loop.

    Online re-optimization (:mod:`repro.core.online`) re-enters this loop
    mid-run with a **warm start**: ``warm_topology`` / ``warm_strategy``
    seed both planes from the incumbent plan instead of the naive stride-1
    ring, and ``forbidden`` pins failed fiber pairs out of every topology
    rebuild.  A warm-started call also threads the incumbent into
    :func:`topology_finder`'s ``warm_start`` so ring strides that survived
    the disruption are kept (less physical churn on the patch panel).
    Cold calls (all three defaults) are byte-identical to the offline PR-1
    behaviour.
    """
    warm = warm_topology is not None
    topo = (
        warm_topology
        if warm
        else initial_topology(n, hw.degree, forbidden=forbidden)
    )
    best: CoOptResult | None = None
    round_times: list[float] = []
    strategy_init: Strategy | None = warm_strategy

    for r in range(rounds):
        # Comp x Comm plane: search strategy on the fixed topology.
        res: SearchResult = mcmc_search(
            job, topo, hw, iters=mcmc_iters, overlap=overlap,
            seed=seed + r, init=strategy_init,
        )
        # Comm x Topo plane: rebuild the topology for the found demand.
        new_topo = topology_finder(
            res.demand, hw.degree, forbidden=forbidden,
            warm_start=topo if warm else None,
        )
        t_new = evaluate(res.strategy, new_topo, job, hw, overlap)
        round_times.append(t_new)

        if best is None or t_new < best.iter_time:
            best = CoOptResult(
                strategy=res.strategy, topology=new_topo,
                iter_time=t_new, demand=res.demand, rounds=round_times,
            )
        # Converged?
        if len(round_times) >= 2 and (
            abs(round_times[-2] - round_times[-1])
            <= rel_tol * max(round_times[-2], 1e-12)
        ):
            break
        topo = new_topo
        strategy_init = res.strategy

    assert best is not None
    best.rounds = round_times
    return best
