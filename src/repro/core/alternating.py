"""Alternating optimization (paper §4.1, Fig. 6) — TopoOpt's outer loop.

The paper frames co-optimization as a search over three coupled dimensions
(computation, communication, topology) and alternates between two planes
until convergence or ``rounds`` iterations:

  Comp x Comm : parallelization-strategy search (FlexFlow-style MCMC,
                :func:`repro.core.strategy_search.mcmc_search`) with the
                network topology held fixed;
  Comm x Topo : TopologyFinder (Algorithm 1,
                :func:`repro.core.topology_finder.topology_finder`) on the
                traffic demand the chosen strategy induces.

Notation mapping (paper -> code):

  =====================  ==================================================
  paper                  here
  =====================  ==================================================
  ``S`` (strategy)       :class:`repro.core.strategy_search.Strategy`
  ``G`` (topology)       :class:`repro.core.topology_finder.Topology`
  ``T`` (traffic)        :class:`repro.core.demand.TrafficDemand`
  ``t_iter`` (Eq. 1)     :func:`repro.core.netsim.iteration_time`
  ``k`` rounds           ``rounds`` argument
  =====================  ==================================================

Online re-optimization (:mod:`repro.core.online`) re-enters this loop with
``warm_topology`` / ``warm_strategy`` (seed both planes from the incumbent
plan) and ``forbidden`` (failed fiber pairs excluded from every rebuild);
the cold-start defaults reproduce the paper's offline pipeline exactly.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .demand import TrafficDemand
from .netsim import (
    HardwareSpec,
    _iteration_time as iteration_time,
    _topoopt_comm_time as topoopt_comm_time,
    compute_time,
)
from .planeval import JobSetEvaluator, LRUCache
from .simengine import SimEngine
from .strategy_search import (
    JobSetSearchResult,
    demand_cache_size,
    SearchResult,
    Strategy,
    default_strategy,
    evaluate_jobset,
    evaluate_jobset_decomposed,
    mcmc_search,
    mcmc_search_jobset,
    tenant_comm_times,
)
from .topology_finder import Topology, topology_finder
from .workloads import JobSet, JobSpec


@dataclass
class CoOptResult:
    strategy: Strategy
    topology: Topology
    iter_time: float
    demand: TrafficDemand
    rounds: list[float] = field(default_factory=list)


@dataclass
class JobSetPlan:
    """A shared-cluster plan: one strategy per tenant + one shared topology.

    Duck-compatible with :class:`CoOptResult` where the online layer needs it
    (``topology`` / ``demand`` / ``iter_time``; ``strategy`` is the
    per-tenant dict).

    ``jobset`` / ``candidate_index`` carry placement-co-search provenance:
    the JobSet (tenant placements) this plan was optimized for and its index
    in the ``placement_candidates`` list it won from (0 when no candidate
    search ran).  ``per_job_comm`` is each tenant's *own* bottleneck comm
    time (:func:`~repro.core.strategy_search.tenant_comm_times`) alongside
    the union-charged ``per_job`` iteration times."""

    strategies: dict[str, Strategy]
    topology: Topology
    iter_time: float  # weighted mean of per-job iteration times
    demand: TrafficDemand  # union demand, cluster index space
    per_job: dict[str, float] = field(default_factory=dict)
    rounds: list[float] = field(default_factory=list)
    jobset: "JobSet | None" = None
    candidate_index: int = 0
    per_job_comm: dict[str, float] = field(default_factory=dict)

    @property
    def strategy(self) -> dict[str, Strategy]:
        return self.strategies


def initial_topology(
    n: int, degree: int, forbidden: tuple[tuple[int, int], ...] = ()
) -> Topology:
    """Start from the naive stride-1 multi-ring (pure DP assumption).

    ``forbidden`` pairs (e.g. failed fibers) are excluded so the starting
    point is realizable on a degraded fabric."""
    from .demand import data_parallel_demand

    return topology_finder(data_parallel_demand(n, 1.0), degree,
                           forbidden=forbidden)


def evaluate(
    strategy: Strategy,
    topo: Topology,
    job: JobSpec,
    hw: HardwareSpec,
    overlap: float = 0.0,
    compiled: bool = True,
) -> float:
    """Iteration time of (strategy, topology) — thin shim over
    :meth:`repro.core.simengine.SimEngine.iteration_time` (compiled plan
    evaluator by default; ``compiled=False`` forces the reference fluid
    path)."""
    demand = strategy.demand(job, topo.n)
    return SimEngine(hw, compiled=compiled).iteration_time(
        topo,
        demand,
        flops_per_iteration=job.flops_per_sample * job.batch_per_gpu * topo.n,
        overlap=overlap,
    )


def alternating_optimize(
    job: JobSpec,
    n: int,
    hw: HardwareSpec,
    rounds: int = 4,
    mcmc_iters: int = 150,
    overlap: float = 0.0,
    seed: int = 0,
    rel_tol: float = 1e-3,
    warm_topology: Topology | None = None,
    warm_strategy: Strategy | None = None,
    forbidden: tuple[tuple[int, int], ...] = (),
    compiled: bool = True,
    proposals_per_step: int = 1,
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
) -> CoOptResult:
    """TopoOpt's off-line co-optimization loop.

    ``schedules`` opens the collective-schedule axis in every round's
    strategy search (:func:`~repro.core.strategy_search.mcmc_search`);
    ``None`` (default) keeps the ring-only move set byte-identical to HEAD.

    Online re-optimization (:mod:`repro.core.online`) re-enters this loop
    mid-run with a **warm start**: ``warm_topology`` / ``warm_strategy``
    seed both planes from the incumbent plan instead of the naive stride-1
    ring, and ``forbidden`` pins failed fiber pairs out of every topology
    rebuild.  A warm-started call also threads the incumbent into
    :func:`topology_finder`'s ``warm_start`` so ring strides that survived
    the disruption are kept (less physical churn on the patch panel).
    Cold calls (all three defaults) are byte-identical to the offline PR-1
    behaviour.

    ``compiled`` / ``proposals_per_step`` select the candidate-pricing path
    of the inner MCMC (:func:`~repro.core.strategy_search.mcmc_search`):
    the compiled evaluator is the default and must match the
    ``compiled=False`` reference at fixed seeds.  ``backend="jax"`` runs
    each round's strategy search as ``chains`` batched on-device chains
    (:mod:`repro.core.planeval_jax`); the default NumPy backend is
    byte-stable against it.
    """
    warm = warm_topology is not None
    topo = (
        warm_topology
        if warm
        else initial_topology(n, hw.degree, forbidden=forbidden)
    )
    best: CoOptResult | None = None
    round_times: list[float] = []
    strategy_init: Strategy | None = warm_strategy

    for r in range(rounds):
        # Comp x Comm plane: search strategy on the fixed topology.
        res: SearchResult = mcmc_search(
            job, topo, hw, iters=mcmc_iters, overlap=overlap,
            seed=seed + r, init=strategy_init,
            compiled=compiled, proposals_per_step=proposals_per_step,
            backend=backend, chains=chains, pool_size=pool_size,
            schedules=schedules,
        )
        # Comm x Topo plane: rebuild the topology for the found demand.
        new_topo = topology_finder(
            res.demand, hw.degree, forbidden=forbidden,
            warm_start=topo if warm else None,
        )
        t_new = evaluate(res.strategy, new_topo, job, hw, overlap,
                         compiled=compiled)
        round_times.append(t_new)

        if best is None or t_new < best.iter_time:
            best = CoOptResult(
                strategy=res.strategy, topology=new_topo,
                iter_time=t_new, demand=res.demand, rounds=round_times,
            )
        # Converged?
        if len(round_times) >= 2 and (
            abs(round_times[-2] - round_times[-1])
            <= rel_tol * max(round_times[-2], 1e-12)
        ):
            break
        topo = new_topo
        strategy_init = res.strategy

    assert best is not None
    best.rounds = round_times
    return best


def _co_optimize_single(
    jobset: JobSet,
    hw: HardwareSpec,
    rounds: int,
    mcmc_iters: int,
    overlap: float,
    seed: int,
    rel_tol: float,
    warm_topology: Topology | None,
    warm_strategies: dict[str, Strategy] | None,
    forbidden: tuple[tuple[int, int], ...],
    compiled: bool,
    proposals_per_step: int,
    demand_cache,
    objective: str = "union",
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
) -> JobSetPlan:
    """The two-plane alternating loop for one fixed tenant placement —
    exactly the pre-placement-search ``co_optimize_jobset`` body."""
    warm = warm_topology is not None
    init: dict[str, Strategy] = {
        t.label: (warm_strategies or {}).get(t.label) or default_strategy(t.spec)
        for t in jobset.tenants
    }
    topo = (
        warm_topology
        if warm
        else topology_finder(
            jobset.union_for(init), hw.degree, forbidden=forbidden,
            pack="per_node",
        )
    )
    best: JobSetPlan | None = None
    round_times: list[float] = []
    strategy_init = init

    for r in range(rounds):
        res: JobSetSearchResult = mcmc_search_jobset(
            jobset, topo, hw, iters=mcmc_iters, overlap=overlap,
            seed=seed + r, init=strategy_init,
            compiled=compiled, proposals_per_step=proposals_per_step,
            demand_cache=demand_cache, objective=objective,
            backend=backend, chains=chains, pool_size=pool_size,
            schedules=schedules,
        )
        new_topo = topology_finder(
            res.demand, hw.degree, forbidden=forbidden,
            warm_start=topo if warm else None, pack="per_node",
        )
        if objective == "decomposed":
            # Round scoring must match what the chains annealed on, or the
            # outer loop would keep undoing the inner one's preferences.
            t_new, per_job = evaluate_jobset_decomposed(
                res.strategies, jobset, new_topo, hw, overlap,
                _demand_cache=demand_cache,
            )
            union = jobset.union_for(res.strategies)
        else:
            t_new, union, per_job = evaluate_jobset(
                res.strategies, jobset, new_topo, hw, overlap,
                _demand_cache=demand_cache, compiled=compiled,
            )
        round_times.append(t_new)

        if best is None or t_new < best.iter_time:
            best = JobSetPlan(
                strategies=dict(res.strategies), topology=new_topo,
                iter_time=t_new, demand=union, per_job=per_job,
                rounds=round_times, jobset=jobset,
            )
        if len(round_times) >= 2 and (
            abs(round_times[-2] - round_times[-1])
            <= rel_tol * max(round_times[-2], 1e-12)
        ):
            break
        topo = new_topo
        strategy_init = res.strategies

    assert best is not None
    best.rounds = round_times
    return best


def co_optimize_jobset(
    jobset: JobSet,
    hw: HardwareSpec,
    rounds: int = 4,
    mcmc_iters: int = 150,
    overlap: float = 0.0,
    seed: int = 0,
    rel_tol: float = 1e-3,
    warm_topology: Topology | None = None,
    warm_strategies: dict[str, Strategy] | None = None,
    forbidden: tuple[tuple[int, int], ...] = (),
    compiled: bool = True,
    proposals_per_step: int = 1,
    placement_candidates: list[JobSet] | None = None,
    screen_candidates: int | None = None,
    objective: str = "union",
    backend: str = "numpy",
    chains: int = 1,
    pool_size: int = 64,
    schedules: tuple[str, ...] | None = None,
) -> JobSetPlan:
    """Multi-tenant alternating optimization: co-optimize every resident
    job's parallelization strategy against one *shared* topology.

    The same two-plane loop as :func:`alternating_optimize`, lifted to a
    :class:`~repro.core.workloads.JobSet`: the Comp x Comm plane proposes
    per-job moves (:func:`~repro.core.strategy_search.mcmc_search_jobset`,
    weighted-mean objective), and the Comm x Topo plane rebuilds one shared
    topology from the *union* demand with per-node degree packing
    (``topology_finder(pack="per_node")``) — per-job ring budgets land only
    on each job's own servers, per-job MP pairs stay pinned to their
    placements, and idle servers keep a connectivity ring for future
    arrivals.  ``warm_topology`` / ``warm_strategies`` / ``forbidden``
    mirror the single-job warm-start contract for online re-optimization.

    **Placement co-search** (``placement_candidates``): placement is the
    fourth co-optimized axis.  Pass a list of candidate JobSets — the same
    tenants under different server placements, e.g. one per
    :func:`~repro.core.online.place_candidates` admission variant — and the
    full alternating loop runs *per candidate* with the same seed, scoring
    each through the compiled :class:`~repro.core.planeval.JobSetEvaluator`
    (per-tenant job-local demands are placement-independent, so one shared
    demand cache serves every candidate); the best full plan wins, ties
    resolved toward the earlier candidate (the greedy seed comes first).
    ``None`` — and a single-candidate list equal to ``jobset`` — follow the
    exact pre-search code path, so fixed-seed plans are unchanged.
    The winning plan records its ``jobset`` and ``candidate_index``
    (always the index into the *original* candidate list).

    ``screen_candidates=k`` bounds the cost of a wide candidate list: every
    candidate is first scored with the *incremental*
    :class:`~repro.core.planeval.JobSetEvaluator` on its warm (or cold
    per-candidate) topology — synthetic rings for placements the incumbent
    fabric never carried, exactly the ``rebalance`` screen — and only the
    ``k`` best-screened candidates pay the full alternating loop.  ``None``
    (default) and any ``k >= len(candidates)`` run every candidate:
    byte-identical to the unscreened behaviour.

    ``objective="decomposed"`` anneals and scores rounds on the weighted
    decomposed per-tenant comm times
    (:func:`~repro.core.strategy_search.evaluate_jobset_decomposed`);
    ``backend="jax"`` / ``chains`` run each round's search as batched
    on-device chains.  The defaults preserve existing goldens.

    One LRU-bounded per-tenant demand cache is shared across every round's
    MCMC and the final pricing (the caches used to be rebuilt per round);
    ``compiled`` / ``proposals_per_step`` select the candidate-pricing path
    exactly as in :func:`alternating_optimize`.  The winner additionally
    reports ``per_job_comm`` — each tenant's own decomposed bottleneck time
    (:func:`~repro.core.strategy_search.tenant_comm_times`).
    """
    if placement_candidates is not None and not placement_candidates:
        raise ValueError("placement_candidates must be non-empty when given")
    candidates = (
        [jobset] if placement_candidates is None else list(placement_candidates)
    )
    labels = {t.label for t in jobset.tenants}
    for js in candidates:
        if {t.label for t in js.tenants} != labels:
            raise ValueError(
                "every placement candidate must carry the same tenant labels"
            )
    if not jobset.tenants:
        raise ValueError("co_optimize_jobset needs at least one tenant")
    if screen_candidates is not None and screen_candidates < 1:
        raise ValueError("screen_candidates must be >= 1 when given")
    demand_cache = LRUCache(demand_cache_size())

    order = list(range(len(candidates)))
    if screen_candidates is not None and screen_candidates < len(candidates):
        # Fast screen (bugfix: wide candidate lists used to pay the full
        # alternating loop per candidate): incremental evaluator pricing of
        # each candidate's warm-start state, survivors in original order so
        # the tie-toward-earlier contract below is unchanged.
        scores: list[tuple[float, int]] = []
        for ci, js in enumerate(candidates):
            init = {
                t.label: (warm_strategies or {}).get(t.label)
                or default_strategy(t.spec)
                for t in js.tenants
            }
            topo0 = (
                warm_topology
                if warm_topology is not None
                else topology_finder(
                    js.union_for(init), hw.degree, forbidden=forbidden,
                    pack="per_node",
                )
            )
            jse = JobSetEvaluator(
                js, topo0, hw, overlap=overlap, demand_cache=demand_cache,
                synth_missing_rings=True,
            )
            scores.append((jse.set_strategies(init)[0], ci))
        scores.sort()
        order = sorted(ci for _, ci in scores[:screen_candidates])

    best: JobSetPlan | None = None
    for ci in order:
        plan = _co_optimize_single(
            candidates[ci], hw, rounds, mcmc_iters, overlap, seed, rel_tol,
            warm_topology, warm_strategies, forbidden, compiled,
            proposals_per_step, demand_cache,
            objective=objective, backend=backend, chains=chains,
            pool_size=pool_size, schedules=schedules,
        )
        plan.candidate_index = ci
        if best is None or plan.iter_time < best.iter_time:
            best = plan

    assert best is not None
    best.per_job_comm = tenant_comm_times(
        best.strategies, best.jobset, best.topology, hw,
        _demand_cache=demand_cache,
    )
    return best
