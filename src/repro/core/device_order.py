"""Mesh device ordering from a TopoOpt plan.

On a reconfigurable fabric the paper *rewires* the physical topology to match
the chosen ring permutations.  On a TPU pod the physical links are fixed but
the *logical* order of devices in a Mesh is free — permuting the device axis
so the heaviest AllReduce ring becomes stride-1 in physical coordinates is
the TPU-native realization of the same co-optimization (DESIGN.md §2).
"""

from __future__ import annotations

import math

import numpy as np

from .totient import ring_order


def permuted_axis_order(n: int, p: int) -> list[int]:
    """Order devices along an axis so the stride-``p`` logical ring maps to
    physically adjacent devices: position j gets device (j * p) % n."""
    return ring_order(n, p)


def reorder_mesh_devices(devices: np.ndarray, axis: int, p: int) -> np.ndarray:
    """Permute ``devices`` (ndarray of jax devices, mesh-shaped) along
    ``axis`` with the stride-``p`` ring order."""
    devices = np.asarray(devices)
    n = devices.shape[axis]
    order = permuted_axis_order(n, p)
    return np.take(devices, order, axis=axis)


def topoopt_mesh(
    shape: tuple[int, ...],
    axis_names: tuple[str, ...],
    *,
    allreduce_axis: str = "data",
    stride: int = 1,
    devices: np.ndarray | None = None,
):
    """Build a Mesh whose ``allreduce_axis`` device order realizes the chosen
    TotientPerms primary stride.  Drop-in replacement for ``jax.make_mesh``.
    """
    import jax
    from jax.sharding import Mesh

    if devices is None:
        devices = np.asarray(jax.devices()[: math.prod(shape)])
    grid = np.asarray(devices).reshape(shape)
    if stride != 1:
        axis = axis_names.index(allreduce_axis)
        grid = reorder_mesh_devices(grid, axis, stride)
    return Mesh(grid, axis_names)
