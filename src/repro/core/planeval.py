"""Compiled plan evaluator — the alternating-optimization hot loop (§4.1).

:func:`repro.core.netsim.topoopt_comm_time` is the *reference* fluid
objective: per candidate it walks every AllReduce ring edge and every routed
MP hop through Python dicts.  The alternating loop evaluates hundreds of
(strategy, topology) candidates per replan, and the online layer
(:mod:`repro.core.online`) re-enters it on every failure/arrival — so the
reference path's per-candidate constant dominates end-to-end replan latency.

This module compiles a fixed :class:`~repro.core.topology_finder.Topology`
into flat NumPy structure arrays **once** and prices candidate demands
against them in microseconds:

* a growable *link-id table* — every directed node pair that can carry load
  (physical graph edges, planned ring edges, routed hops) gets a dense id,
  with per-link capacity ``parallel_links * link_bandwidth`` so the final
  bottleneck max is one vectorized ``max(loads / cap)``;
* per-AllReduce-group *ring-edge incidence* — the link ids of a group's
  ring edges in exact reference walk order, so a group's load is one
  ``np.add.at`` scatter instead of nested ring/edge loops;
* a persistent *MP route cache* in CSR form — per source/dest pair the link
  ids of its (fallback-completed) route hops, so a whole MP matrix prices
  as one segment-gather + ``np.add.at``.

**Bit-exactness.**  The full evaluation (:meth:`PlanEvaluator.loads` /
:meth:`comm_time`) reproduces the reference *to the bit*, not merely to
1e-9: shares are computed with the same expressions (``2(k-1)/k * bytes``
then ``/ n_rings``; ``bytes / n_routes`` per route), scattered per
*occurrence* in the same order the reference walks them (``np.add.at`` is
documented unbuffered-sequential), AllReduce and MP accumulate in separate
vectors merged with one elementwise add (mirroring the reference's two-dict
merge), and the bottleneck uses the same ``load / (par * bandwidth)``
division.  This matters because MCMC acceptance uses ``<=``: a move that
leaves the objective mathematically unchanged must *tie exactly*, or
fixed-seed chains diverge from the pre-compiled behaviour.

On top of the per-demand path, :class:`JobSetEvaluator` makes the
multi-tenant MCMC **incremental**: per-tenant cluster-level link-load
vectors are cached, and a single-tenant move re-prices only
``total - old_vector + new_vector`` instead of re-unioning and re-walking
the whole JobSet.  :meth:`PlanEvaluator.loads_delta` is the single-job
analogue (diff the moved demand's groups/MP entries against the incumbent
load vector).  Incremental results carry ulp-level arithmetic lineage, so
the search loops confirm near-boundary acceptance decisions on the
bit-exact full evaluation (see ``_TIE_RTOL`` in
:mod:`repro.core.strategy_search`).

``tests/test_planeval.py`` pins compiled-vs-reference agreement over random
topologies, demands, jobsets, and degraded fabrics.  Degradation helpers
(:func:`~repro.core.topology_finder.remove_pair` /
:func:`~repro.core.topology_finder.repair_topology`) return *new* Topology
objects, so their evaluators recompile from scratch — a stale cache cannot
survive a fabric change.
"""

from __future__ import annotations

import logging
import os
from collections import OrderedDict

import numpy as np

from .demand import TrafficDemand, demand_steps, remap_demand, sparse_min_nodes

logger = logging.getLogger(__name__)
from .netsim import (
    HardwareSpec,
    _iteration_time as iteration_time,
    _routing_with_fallback,
    compute_time,
)

__all__ = [
    "LRUCache",
    "PlanEvaluator",
    "JobSetEvaluator",
    "plan_evaluator",
]


class LRUCache:
    """Minimal least-recently-used mapping (bounds the long-MCMC caches).

    ``get``/``__getitem__`` refresh recency; inserting past ``maxsize``
    evicts the least recently used entry.  Drop-in for the plain dicts the
    search loops used to grow without limit.

    Tracks lookup hit/miss counts (``hits`` / ``misses`` /
    :attr:`hit_rate`) so fleet runs can tune cache sizes
    (``REPRO_DEMAND_CACHE_SIZE`` / ``REPRO_VECTOR_CACHE_SIZE``) from
    logged rates instead of code edits.
    """

    def __init__(self, maxsize: int = 512):
        if maxsize < 1:
            raise ValueError("LRUCache needs maxsize >= 1")
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._data: OrderedDict = OrderedDict()

    def __contains__(self, key) -> bool:
        found = key in self._data
        if found:
            self.hits += 1
        else:
            self.misses += 1
        return found

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups served from cache (0.0 when never probed)."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict[str, float]:
        return {
            "size": len(self._data),
            "maxsize": self.maxsize,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
        }

    def __len__(self) -> int:
        return len(self._data)

    def __getitem__(self, key):
        value = self._data[key]
        self._data.move_to_end(key)
        return value

    def __setitem__(self, key, value) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        if len(self._data) > self.maxsize:
            self._data.popitem(last=False)

    def get(self, key, default=None):
        if key in self._data:
            self.hits += 1
            return self[key]
        self.misses += 1
        return default

    def clear(self) -> None:
        self._data.clear()


class PlanEvaluator:
    """A :class:`Topology` compiled to flat arrays for microsecond pricing.

    Build once per topology (use :func:`plan_evaluator`, which memoizes the
    instance on the topology object) and call :meth:`comm` /
    :meth:`comm_time` with any demand on the same node count.  Group
    incidence and MP routes compile lazily on first touch and persist
    across evaluations — the route cache the reference path rebuilt per
    call.
    """

    def __init__(self, topo, hw: HardwareSpec, sparse_min_nodes_: int | None = None):
        self.topo = topo
        self.hw = hw
        self._n = topo.n
        # Sparse pricing: key MP entries off each demand's cached COO
        # (TrafficDemand.mp_coo) instead of re-scanning the (n, n) matrix,
        # and bottleneck only the touched links.  Bit-identical to the
        # dense path; threshold from REPRO_SPARSE_MIN_NODES (kwarg wins).
        if sparse_min_nodes_ is None:
            sparse_min_nodes_ = sparse_min_nodes()
        self._sparse = self._n >= sparse_min_nodes_
        # Parallel-link counts of the physical graph (multi-edges counted),
        # exactly the reference's ``n_par``.
        par: dict[tuple[int, int], int] = {}
        for edge in topo.graph.edges():
            par[edge] = par.get(edge, 0) + 1
        self._par = par
        # Growable link-id table: directed pair -> dense id; cap[lid] =
        # max(1, parallel_links) * link_bandwidth (the reference divisor).
        self._lid: dict[tuple[int, int], int] = {}
        self._cap = np.zeros(64, dtype=np.float64)
        self._n_links = 0
        for pair in par:
            self._link_id(pair)
        # AllReduce group incidence: members -> (occurrence link ids in
        # ring-then-edge order, n_rings, k), or None when the group carries
        # no rings on this topology (the reference skips it too).
        self._groups: dict[tuple[int, ...], tuple | None] = {}
        # MP pair route cache, CSR over pair id p = s*n + t: per-occurrence
        # hop link ids in route-then-hop order (the reference walk order),
        # the pair's route count (share divisor), and its mean route hops
        # (bandwidth-tax factor; 1.0 for unroutable ~ direct).
        n2 = self._n * self._n
        self._pair_start = np.full(n2, -1, dtype=np.int64)
        self._pair_len = np.zeros(n2, dtype=np.int64)
        self._pair_nroutes = np.ones(n2, dtype=np.float64)
        self._pair_tax = np.zeros(n2, dtype=np.float64)
        self._mp_ids = np.zeros(256, dtype=np.int64)
        self._mp_size = 0

    # -- link universe -------------------------------------------------------

    @property
    def n_links(self) -> int:
        """Current size of the compiled link universe (grows lazily)."""
        return self._n_links

    @property
    def caps(self) -> np.ndarray:
        """Per-link capacities over the current universe (read-only view:
        ``parallel_links * link_bandwidth`` per compiled directed pair)."""
        return self._cap[: self._n_links]

    def _link_id(self, pair: tuple[int, int]) -> int:
        lid = self._lid.get(pair)
        if lid is None:
            lid = self._n_links
            self._lid[pair] = lid
            if lid >= self._cap.size:
                grown = np.zeros(2 * self._cap.size, dtype=np.float64)
                grown[: self._cap.size] = self._cap
                self._cap = grown
            par = max(1, self._par.get(pair, 1))
            self._cap[lid] = par * self.hw.link_bandwidth
            self._n_links += 1
        return lid

    def pad(self, loads: np.ndarray) -> np.ndarray:
        """Zero-extend a load vector minted before the universe grew."""
        if loads.size == self._n_links:
            return loads
        out = np.zeros(self._n_links, dtype=np.float64)
        out[: loads.size] = loads
        return out

    # -- lazy compilation ----------------------------------------------------

    def _group(self, members: tuple[int, ...]):
        if members not in self._groups:
            rings = self.topo.rings.get(members, [])
            k = len(members)
            if not rings or k <= 1:
                self._groups[members] = None
            else:
                ids = np.fromiter(
                    (
                        self._link_id(edge)
                        for ring in rings
                        for edge in ring.edges()
                    ),
                    dtype=np.int64,
                )
                self._groups[members] = (ids, len(rings), k)
        return self._groups[members]

    def _compile_pair(self, s: int, t: int) -> None:
        routes = self.topo.routing.get(s, t)
        if not routes:
            routes = _routing_with_fallback(
                self.topo, [(s, t, 1.0)]
            ).get(s, t)
        pid = s * self._n + t
        self._pair_start[pid] = self._mp_size
        if not routes:
            # Unroutable ~ direct in the reference tax; no link load.
            self._pair_len[pid] = 0
            self._pair_tax[pid] = 1.0
            return
        ids = [
            self._link_id(hop)
            for r in routes
            for hop in zip(r.path[:-1], r.path[1:])
        ]
        need = self._mp_size + len(ids)
        if need > self._mp_ids.size:
            size = max(2 * self._mp_ids.size, need)
            grown = np.zeros(size, dtype=np.int64)
            grown[: self._mp_ids.size] = self._mp_ids
            self._mp_ids = grown
        self._mp_ids[self._mp_size: self._mp_size + len(ids)] = ids
        self._mp_size += len(ids)
        self._pair_len[pid] = len(ids)
        self._pair_nroutes[pid] = len(routes)
        self._pair_tax[pid] = sum(r.hops for r in routes) / len(routes)

    def _compile_missing(self, pids: np.ndarray) -> None:
        if pids.size:
            for pid in pids[self._pair_start[pids] < 0]:
                self._compile_pair(int(pid) // self._n, int(pid) % self._n)

    def _mp_arrays(self, mp: np.ndarray):
        """(pids, bytes) of a demand's nonzero MP entries, with every pair
        compiled into the CSR cache."""
        srcs, dsts = np.nonzero(mp)
        vals = mp[srcs, dsts]
        pids = srcs * self._n + dsts
        self._compile_missing(pids)
        return pids, vals

    def _ensure_compiled(self, demand: TrafficDemand):
        """Compile everything a demand touches (so the link universe stops
        growing before the load vector is allocated).

        On the sparse path the MP entries come from the demand's cached
        COO (same pairs, same row-major order, same float values as the
        ``np.nonzero`` scan — O(active pairs) on repeat pricings)."""
        for g in demand.allreduce:
            self._group(g.members)
        if self._sparse:
            srcs, dsts, vals = demand.mp_coo()
            pids = srcs.astype(np.int64) * self._n + dsts
            self._compile_missing(pids)
            return pids, vals
        return self._mp_arrays(demand.mp)

    # -- evaluation ----------------------------------------------------------

    def _scatter_mp(self, loads, pids, vals, sign: float = 1.0) -> None:
        """Add each pair's per-route share (``bytes / n_routes``) along its
        route hops — one sequential ``np.add.at`` in the reference's
        flow-then-route-then-hop order."""
        starts = self._pair_start[pids]
        lens = self._pair_len[pids]
        total = int(lens.sum())
        if not total:
            return
        seg_off = np.cumsum(lens) - lens
        idx = (
            np.arange(total, dtype=np.int64)
            - np.repeat(seg_off, lens)
            + np.repeat(starts, lens)
        )
        shares = (sign * vals) / self._pair_nroutes[pids]
        np.add.at(loads, self._mp_ids[idx], np.repeat(shares, lens))

    def _scatter_groups(self, loads, allreduce, sign: float = 1.0) -> None:
        """Add each group's per-ring-edge share in the reference's
        group-then-ring-then-edge order (duplicate edges accumulate
        sequentially, exactly like the reference dict walk)."""
        for g in allreduce:
            entry = self._group(g.members)
            if entry is None:
                continue
            ids, n_rings, k = entry
            per_link_total = 2.0 * (k - 1) / k * g.nbytes
            if per_link_total == 0.0:
                continue
            np.add.at(loads, ids, sign * (per_link_total / n_rings))

    def _eval(self, demand: TrafficDemand):
        """(loads, pids, vals) of one demand — the single scatter/merge
        body every evaluation entry point shares (the bit-exactness
        contract lives here and nowhere else)."""
        pids, vals = self._ensure_compiled(demand)
        ar = np.zeros(self._n_links, dtype=np.float64)
        self._scatter_groups(ar, demand.allreduce)
        mp = np.zeros(self._n_links, dtype=np.float64)
        self._scatter_mp(mp, pids, vals)
        # One elementwise add mirrors the reference's AllReduce-dict +
        # link_loads-dict merge (a single addition per link).
        ar += mp
        return ar, pids, vals

    def _eval_compact(self, demand: TrafficDemand):
        """(touched link ids, compact loads, pids, vals) of one demand —
        the same scatters as :meth:`_eval` into a vector over only the
        links the demand touches, so per-candidate pricing cost scales
        with active edges instead of the link-table size.

        Per-link sums are bit-identical to :meth:`_eval`: each compact
        slot receives exactly the additions its full-vector link receives,
        in the same sequential ``np.add.at`` order (groups in demand
        order, then the MP occurrence stream), and the AllReduce/MP
        vectors merge with the same single add."""
        pids, vals = self._ensure_compiled(demand)
        group_entries: list[tuple[np.ndarray, float]] = []
        occ_parts: list[np.ndarray] = []
        for g in demand.allreduce:
            entry = self._group(g.members)
            if entry is None:
                continue
            ids, n_rings, k = entry
            per_link_total = 2.0 * (k - 1) / k * g.nbytes
            if per_link_total == 0.0:
                continue
            group_entries.append((ids, per_link_total / n_rings))
            occ_parts.append(ids)
        starts = self._pair_start[pids]
        lens = self._pair_len[pids]
        total = int(lens.sum())
        if total:
            seg_off = np.cumsum(lens) - lens
            idx = (
                np.arange(total, dtype=np.int64)
                - np.repeat(seg_off, lens)
                + np.repeat(starts, lens)
            )
            occ_parts.append(self._mp_ids[idx])
        if not occ_parts:
            empty = np.zeros(0, dtype=np.int64)
            return empty, np.zeros(0, dtype=np.float64), pids, vals
        occ = np.concatenate(occ_parts)
        touched, inv = np.unique(occ, return_inverse=True)
        ar = np.zeros(touched.size, dtype=np.float64)
        off = 0
        for ids, share in group_entries:
            np.add.at(ar, inv[off: off + ids.size], share)
            off += ids.size
        mp = np.zeros(touched.size, dtype=np.float64)
        if total:
            shares = vals / self._pair_nroutes[pids]
            np.add.at(mp, inv[off:], np.repeat(shares, lens))
        ar += mp
        return touched, ar, pids, vals

    def _bottleneck_compact(self, touched: np.ndarray, loads: np.ndarray) -> float:
        """Bottleneck over the touched links only — equal to the full-max
        (untouched loads are zero and loads are nonnegative, so they can
        never win the max; an all-zero demand bottlenecks at 0.0 both
        ways)."""
        if not touched.size:
            return 0.0
        return float(np.max(loads / self._cap[touched]))

    def loads(self, demand: TrafficDemand) -> np.ndarray:
        """Per-link byte loads (AllReduce rings + routed MP) as a flat
        vector over the compiled link universe — bit-identical to the
        reference's per-link dict values."""
        return self._eval(demand)[0]

    def loads_delta(
        self,
        base: np.ndarray,
        old: TrafficDemand,
        new: TrafficDemand,
    ) -> np.ndarray:
        """Load vector of ``new`` given ``base = loads(old)``: re-prices
        only the delta between the two demands (changed AllReduce groups,
        changed MP entries) — the single-move fast path of
        :func:`~repro.core.strategy_search.mcmc_search`.  Entries untouched
        by the move stay bit-identical to ``base``; touched entries carry
        ulp-level lineage (the search loop's near-boundary confirmation
        falls back to the bit-exact :meth:`loads`)."""
        same_groups = old.allreduce is new.allreduce or (
            len(old.allreduce) == len(new.allreduce)
            and all(
                a.members == b.members and a.nbytes == b.nbytes
                for a, b in zip(old.allreduce, new.allreduce)
            )
        )
        gone: list = []
        added: list = []
        if not same_groups:
            old_keys = [(g.members, g.nbytes) for g in old.allreduce]
            new_keys = [(g.members, g.nbytes) for g in new.allreduce]
            shared = set(old_keys) & set(new_keys)
            gone = [g for g, k in zip(old.allreduce, old_keys)
                    if k not in shared]
            added = [g for g, k in zip(new.allreduce, new_keys)
                     if k not in shared]
            for g in (*gone, *added):
                self._group(g.members)
        if self._sparse:
            # COO diff: for a pair in both demands the dense cell is
            # ``new - old`` (one float subtraction); the sequential
            # ``np.add.at`` below performs ``(0 + new) + (-old)`` — the
            # identical operation — and pairs in only one demand reduce to
            # ``new`` / ``-old`` exactly.  Exact-zero diffs are dropped on
            # both paths (np.nonzero there, the mask here), and np.unique
            # returns pair ids sorted = the dense row-major order.
            os_, od_, ov = old.mp_coo()
            ns_, nd_, nv = new.mp_coo()
            keys = np.concatenate([
                ns_.astype(np.int64) * self._n + nd_,
                os_.astype(np.int64) * self._n + od_,
            ])
            contrib = np.concatenate([nv, -ov])
            uk, inv = np.unique(keys, return_inverse=True)
            dv = np.zeros(uk.size, dtype=np.float64)
            np.add.at(dv, inv, contrib)
            nzm = dv != 0.0
            pids, vals = uk[nzm], dv[nzm]
            self._compile_missing(pids)
        else:
            diff = new.mp - old.mp
            pids, vals = self._mp_arrays(diff)
        out = np.zeros(self._n_links, dtype=np.float64)
        out[: base.size] = base
        if gone:
            self._scatter_groups(out, gone, sign=-1.0)
        if added:
            self._scatter_groups(out, added, sign=1.0)
        self._scatter_mp(out, pids, vals)
        return out

    def comm_time_from_loads(self, loads: np.ndarray) -> float:
        """Bottleneck comm time of a precomputed load vector (the
        reference's ``load / (par * bandwidth)`` division, vectorized)."""
        if not loads.size:
            return 0.0
        return float(np.max(loads / self._cap[: loads.size]))

    def comm_times_from_loads(self, rows) -> np.ndarray:
        """Bottleneck comm times of ``K`` load vectors in one vectorized
        max (rows minted before the universe grew are zero-padded)."""
        rows = list(rows)
        if not rows:
            return np.zeros(0)
        n = self._n_links
        if not n:
            return np.zeros(len(rows))
        mat = np.zeros((len(rows), n), dtype=np.float64)
        for out, row in zip(mat, rows):
            out[: row.size] = row
        return np.max(mat / self._cap[:n], axis=1)

    def comm(self, demand: TrafficDemand) -> dict[str, float]:
        """Drop-in for :func:`~repro.core.netsim.topoopt_comm_time` —
        ``{"comm_time", "bandwidth_tax"}`` — on the compiled arrays.
        ``comm_time`` is bit-identical to the reference; the tax agrees to
        float-reassociation level (~1e-15 relative)."""
        if self._sparse:
            touched, loads, pids, vals = self._eval_compact(demand)
            worst = self._bottleneck_compact(touched, loads)
        else:
            loads, pids, vals = self._eval(demand)
            worst = self.comm_time_from_loads(loads)
        logical = float(vals.sum())
        if logical > 0:
            tax = float(vals @ self._pair_tax[pids]) / logical
        else:
            tax = 1.0
        if self.hw.link_latency:
            worst = worst + self.hw.link_latency * demand_steps(demand)
        return {
            "comm_time": worst,
            "bandwidth_tax": tax,
        }

    def comm_time(self, demand: TrafficDemand) -> float:
        """Bottleneck comm time of ``demand`` — bit-identical to
        ``topoopt_comm_time(...)["comm_time"]`` (including the α latency
        term when ``hw.link_latency`` is set: same ``worst + α * steps``
        expression as the reference).  On the sparse path the bottleneck
        is taken over only the touched links (:meth:`_eval_compact`,
        bit-identical)."""
        if self._sparse:
            touched, loads, _, _ = self._eval_compact(demand)
            worst = self._bottleneck_compact(touched, loads)
        else:
            worst = self.comm_time_from_loads(self._eval(demand)[0])
        if self.hw.link_latency:
            worst = worst + self.hw.link_latency * demand_steps(demand)
        return worst

    def comm_times(self, demands) -> np.ndarray:
        """Batched pricing: bottleneck comm time of ``K`` demands in one
        vectorized max over a (K, n_links) load matrix (plus each demand's
        α latency term when ``hw.link_latency`` is set)."""
        demands = list(demands)
        if not demands:
            return np.zeros(0)
        rows = [self.loads(d) for d in demands]
        times = self.comm_times_from_loads(rows)
        if self.hw.link_latency:
            times = times + self.hw.link_latency * np.asarray(
                [demand_steps(d) for d in demands]
            )
        return times


def plan_evaluator(topo, hw: HardwareSpec) -> PlanEvaluator:
    """The compiled evaluator for ``topo``, memoized on the topology object
    (one per :class:`~repro.core.netsim.HardwareSpec`).  Degraded topologies
    (:func:`~repro.core.topology_finder.remove_pair` /
    :func:`~repro.core.topology_finder.repair_topology`) are *new* objects,
    so they always recompile — no stale-cache hazard."""
    cache = getattr(topo, "_planevals", None)
    if cache is None:
        cache = {}
        topo._planevals = cache
    ev = cache.get(hw)
    if ev is None:
        ev = PlanEvaluator(topo, hw)
        cache[hw] = ev
    return ev


# ---------------------------------------------------------------------------
# Incremental multi-tenant objective (mcmc_search_jobset hot loop)
# ---------------------------------------------------------------------------


class JobSetEvaluator:
    """Incremental weighted-mean objective for a JobSet on a fixed topology.

    Caches one cluster-level link-load vector per (tenant, strategy); the
    shared comm time is the bottleneck of the *sum* of resident vectors, so
    a single-tenant MCMC move re-prices as ``total - old + new`` — two
    vector ops — instead of re-unioning and re-walking the whole JobSet.
    Matches the reference
    :func:`~repro.core.strategy_search.evaluate_jobset` objective to 1e-9
    (per-tenant vector sums reassociate the union's float additions).

    ``demand_cache`` memoizes per-tenant *job-local* demand construction
    under the same ``(label, strategy, k)`` keys ``evaluate_jobset`` uses,
    so one (LRU-bounded) cache serves both paths across
    ``co_optimize_jobset`` rounds.
    """

    def __init__(
        self,
        jobset,
        topo,
        hw: HardwareSpec,
        overlap: float = 0.0,
        demand_cache=None,
        vector_cache_size: int | None = None,
        synth_missing_rings: bool = False,
        share_vector_cache: bool = True,
    ):
        self.jobset = jobset
        self.hw = hw
        self.overlap = overlap
        if vector_cache_size is None:
            vector_cache_size = int(
                os.environ.get("REPRO_VECTOR_CACHE_SIZE", "512")
            )
        # Price AllReduce groups the topology carries no rings for (a
        # tenant probed at a placement the topology was never built for)
        # as one synthetic ring over the members in placement order, each
        # hop routed like an MP pair — mirroring
        # ``iteration_tasks(synth_missing_rings=True)``.  Off by default:
        # the MCMC hot loops must keep skipping such groups exactly like
        # the reference walk.
        self.synth_missing_rings = synth_missing_rings
        self.ev = plan_evaluator(topo, hw)
        self.demand_cache = demand_cache if demand_cache is not None else {}
        if share_vector_cache:
            # Per-tenant load vectors depend only on (tenant, strategy,
            # placement, synth flag) for a fixed (topology, hw) — exactly
            # the scope of the memoized PlanEvaluator — so evaluators
            # built back-to-back on the same fabric (one per controller
            # replan) share one vector cache: an arrival or departure
            # re-prices only the tenants it actually touched.  Keys carry
            # the synth flag so synth/non-synth evaluators cannot poison
            # each other.
            shared = getattr(self.ev, "_tenant_vecs", None)
            if shared is None:
                shared = LRUCache(vector_cache_size)
                self.ev._tenant_vecs = shared
            self._vectors = shared
        else:
            self._vectors = LRUCache(vector_cache_size)
        self._tenant = {t.label: t for t in jobset.tenants}
        self._comp = {
            t.label: compute_time(t.flops_per_iteration, t.k, hw)
            for t in jobset.tenants
        }
        self.strategies: dict[str, object] = {}
        self._total: np.ndarray | None = None
        self._pending: tuple[str, object, np.ndarray] | None = None
        # Last propose_batch's (moves, rows, comms) for select().
        self._batch: tuple | None = None
        # Per-(label, strategy) schedule step counts (α latency term) —
        # topology- and placement-independent, so memoized flat.
        self._steps_memo: dict[tuple, float] = {}

    # -- cache telemetry -----------------------------------------------------

    def cache_stats(self) -> dict[str, dict]:
        """Hit/miss statistics of the vector and demand caches (the two
        LRU-bounded hot-loop caches a fleet run tunes via
        ``REPRO_VECTOR_CACHE_SIZE`` / ``REPRO_DEMAND_CACHE_SIZE``)."""
        out: dict[str, dict] = {}
        if isinstance(self._vectors, LRUCache):
            out["vectors"] = self._vectors.stats()
        if isinstance(self.demand_cache, LRUCache):
            out["demands"] = self.demand_cache.stats()
        return out

    def log_cache_stats(self, context: str = "") -> None:
        """DEBUG-log the cache hit rates (the online controller calls this
        after each migration screen)."""
        for name, s in self.cache_stats().items():
            logger.debug(
                "%s%s cache: %d/%d entries, %.1f%% hit rate "
                "(%d hits / %d misses)",
                f"{context}: " if context else "",
                name, s["size"], s["maxsize"], 100.0 * s["hit_rate"],
                s["hits"], s["misses"],
            )

    # -- per-tenant vectors --------------------------------------------------

    def _local_demand(self, t, strategy) -> TrafficDemand:
        key = (t.label, strategy, t.k)
        dem = self.demand_cache.get(key)
        if dem is None:
            dem = strategy.demand(t.spec, t.k)
            self.demand_cache[key] = dem
        return dem

    def tenant_loads(self, label: str, strategy) -> np.ndarray:
        """Cluster-level link-load vector of one tenant under ``strategy``
        at its resident placement (cached)."""
        return self.tenant_loads_at(
            label, strategy, self._tenant[label].servers
        )

    def tenant_loads_at(
        self, label: str, strategy, servers: tuple[int, ...]
    ) -> np.ndarray:
        """Cluster-level link-load vector of one tenant under ``strategy``
        embedded at an arbitrary candidate placement ``servers``.

        Vectors are cached per ``(label, strategy, servers)`` — the
        per-candidate demand cache of the placement co-search: scoring the
        same tenant under k candidate placements re-prices only the remap +
        scatter per placement (the job-local demand construction is shared
        through ``demand_cache``), and re-visiting a placement is a cache
        hit."""
        t = self._tenant[label]
        servers = tuple(int(s) for s in servers)
        key = (label, strategy, servers, self.synth_missing_rings)
        v = self._vectors.get(key)
        if v is None:
            dem = remap_demand(
                self._local_demand(t, strategy), servers, self.jobset.n
            )
            if self.synth_missing_rings:
                dem = self._with_synth_rings(dem)
            v = self.ev.loads(dem)
            self._vectors[key] = v
        return v

    def _with_synth_rings(self, dem: TrafficDemand) -> TrafficDemand:
        """Fold AllReduce groups without rings on this topology into MP
        entries along one synthetic ring over the members (the bytes the
        engine would actually route for them), leaving ringed groups to the
        exact incidence path."""
        missing = [
            g for g in dem.allreduce
            if len(g.members) > 1 and g.nbytes > 0
            and not self.ev.topo.rings.get(g.members)
        ]
        if not missing:
            return dem
        out = TrafficDemand(n=dem.n, mp=dem.mp.copy())
        out.allreduce = [g for g in dem.allreduce if g not in missing]
        for g in missing:
            k = len(g.members)
            per_link = 2.0 * (k - 1) / k * g.nbytes
            for i in range(k):
                out.add_mp(g.members[i], g.members[(i + 1) % k], per_link)
        return out

    def _steps(self, label: str, strategy) -> float:
        """Serial latency rounds of one tenant's demand under ``strategy``
        (:func:`~repro.core.demand.demand_steps` of the job-local demand —
        equal to the remapped/unioned value, since placement preserves
        group sizes)."""
        key = (label, strategy)
        v = self._steps_memo.get(key)
        if v is None:
            v = demand_steps(
                self._local_demand(self._tenant[label], strategy)
            )
            self._steps_memo[key] = v
        return v

    def _move_steps(self, label: str, strategy) -> float:
        """Union step count of the current state with ``label`` moved to
        ``strategy`` — max over tenants, mirroring ``demand_steps`` of the
        union demand the reference walk prices."""
        if not self.hw.link_latency:
            return 0.0
        steps = 0.0
        for t in self.jobset.tenants:
            s = strategy if t.label == label else self.strategies[t.label]
            steps = max(steps, self._steps(t.label, s))
        return steps

    def _steps_of(self, strategies: dict[str, object]) -> float:
        if not self.hw.link_latency:
            return 0.0
        steps = 0.0
        for t in self.jobset.tenants:
            steps = max(steps, self._steps(t.label, strategies[t.label]))
        return steps

    def _objective(
        self, comm: float, steps: float = 0.0
    ) -> tuple[float, dict[str, float]]:
        if self.hw.link_latency:
            # Same ``worst + α * steps`` expression as the reference
            # (the cached load vectors carry only the β term).
            comm = comm + self.hw.link_latency * steps
        per_job: dict[str, float] = {}
        obj = 0.0
        for t in self.jobset.tenants:
            per_job[t.label] = iteration_time(
                comm, self._comp[t.label], overlap=self.overlap
            )
            obj += t.weight * per_job[t.label]
        return obj / self.jobset.total_weight, per_job

    # -- full + incremental evaluation ---------------------------------------

    def _full_total(self, strategies: dict[str, object]) -> np.ndarray:
        vectors = [
            self.tenant_loads(t.label, strategies[t.label])
            for t in self.jobset.tenants
        ]
        total = np.zeros(self.ev.n_links, dtype=np.float64)
        for v in vectors:
            total[: v.size] += v
        return total

    def objective_of(
        self, strategies: dict[str, object]
    ) -> tuple[float, dict[str, float]]:
        """Objective of an arbitrary strategy assignment, computed from the
        full sum of per-tenant vectors (no incremental lineage)."""
        return self._objective(
            self.ev.comm_time_from_loads(self._full_total(strategies)),
            self._steps_of(strategies),
        )

    def decomposed_objective_of(
        self, strategies: dict[str, object]
    ) -> tuple[float, dict[str, float]]:
        """Weighted *decomposed* objective of an arbitrary assignment: each
        tenant charged its own bottleneck comm time under weighted processor
        sharing of the links it loads
        (:func:`~repro.core.strategy_search.tenant_comm_times` semantics).

        Computed from the cached per-tenant vectors with the exact
        expressions of the reference decomposition, so it matches
        :func:`~repro.core.strategy_search.evaluate_jobset_decomposed` to
        the bit — the ``objective="decomposed"`` MCMC path needs compiled
        and reference chains to make identical fixed-seed decisions."""
        ts = self.jobset.tenants
        vecs = [
            self.tenant_loads(t.label, strategies[t.label]) for t in ts
        ]
        n_links = self.ev.n_links
        per_comm = {t.label: 0.0 for t in ts}
        if n_links:
            mat = np.zeros((len(vecs), n_links), dtype=np.float64)
            for row, v in zip(mat, vecs):
                row[: v.size] = v
            weights = np.asarray([t.weight for t in ts])
            active = mat > 0
            active_w = active.T @ weights
            caps = self.ev.caps
            for i, t in enumerate(ts):
                mask = active[i]
                if mask.any():
                    per_comm[t.label] = float(np.max(
                        mat[i, mask] * active_w[mask]
                        / (weights[i] * caps[mask])
                    ))
        if self.hw.link_latency:
            # α term: each tenant pays its own schedule's rounds — the
            # exact expression of the reference ``tenant_comm_times``.
            for t in ts:
                per_comm[t.label] = (
                    per_comm[t.label]
                    + self.hw.link_latency
                    * self._steps(t.label, strategies[t.label])
                )
        per_job: dict[str, float] = {}
        obj = 0.0
        for t in ts:
            per_job[t.label] = iteration_time(
                per_comm[t.label], self._comp[t.label], overlap=self.overlap
            )
            obj += t.weight * per_job[t.label]
        return obj / self.jobset.total_weight, per_job

    def set_strategies(
        self, strategies: dict[str, object]
    ) -> tuple[float, dict[str, float]]:
        """Full evaluation: adopt ``strategies`` as the current state and
        return ``(objective, per_job_iteration_times)``."""
        self.strategies = dict(strategies)
        self._total = self._full_total(strategies)
        self._pending = None
        return self._objective(
            self.ev.comm_time_from_loads(self._total),
            self._steps_of(strategies),
        )

    def _move_row(self, label: str, strategy) -> np.ndarray:
        """Load vector of the current state with ``label`` moved to
        ``strategy``: ``total - old + new``.  A no-op move returns the
        current total itself (bit-identical — keeps MCMC tie-acceptance
        aligned with the reference chain)."""
        if strategy == self.strategies[label]:
            return self._total
        v_old = self.tenant_loads(label, self.strategies[label])
        v_new = self.tenant_loads(label, strategy)
        row = self.ev.pad(self._total)
        if row is self._total:
            row = row.copy()
        row[: v_old.size] -= v_old
        row[: v_new.size] += v_new
        return row

    def placement_row(
        self, label: str, strategy, servers: tuple[int, ...]
    ) -> np.ndarray:
        """Load vector of the current state with tenant ``label`` re-seated
        at candidate placement ``servers`` under ``strategy``:
        ``total - old_vector + new_vector`` — the union demand never gets
        rebuilt.  Requires :meth:`set_strategies` first."""
        assert self._total is not None, "call set_strategies first"
        t = self._tenant[label]
        if tuple(servers) == t.servers and strategy == self.strategies[label]:
            return self._total
        v_old = self.tenant_loads(label, self.strategies[label])
        v_new = self.tenant_loads_at(label, strategy, servers)
        row = self.ev.pad(self._total)
        if row is self._total:
            row = row.copy()
        row[: v_old.size] -= v_old
        row[: v_new.size] += v_new
        return row

    def objective_at(
        self, label: str, strategy, servers: tuple[int, ...]
    ) -> float:
        """Weighted-mean objective with ``label`` moved to candidate
        placement ``servers`` (not adopted) — the fast screen of the
        migration / placement co-search."""
        return self._objective(
            self.ev.comm_time_from_loads(
                self.placement_row(label, strategy, servers)
            ),
            self._move_steps(label, strategy),
        )[0]

    def propose(
        self, label: str, strategy
    ) -> tuple[float, dict[str, float]]:
        """Price a single-tenant move without adopting it: the moved
        tenant's old vector is swapped for the new one against the cached
        total.  Call :meth:`accept` to adopt."""
        assert self._total is not None, "call set_strategies first"
        row = self._move_row(label, strategy)
        self._pending = (label, strategy, row)
        return self._objective(
            self.ev.comm_time_from_loads(row),
            self._move_steps(label, strategy),
        )

    def propose_batch(
        self, moves: list[tuple[str, object]]
    ) -> np.ndarray:
        """Objectives of ``K`` single-tenant moves in one vectorized pass
        (the batched MCMC mode).  Does not change the current state; pick
        the winner with :meth:`select` (its row is retained, not
        re-priced)."""
        assert self._total is not None, "call set_strategies first"
        rows = [self._move_row(label, strategy) for label, strategy in moves]
        comms = self.ev.comm_times_from_loads(rows)
        self._batch = (list(moves), rows, comms)
        return np.asarray([
            self._objective(float(c), self._move_steps(label, strategy))[0]
            for (label, strategy), c in zip(moves, comms)
        ])

    def select(self, index: int) -> tuple[float, dict[str, float]]:
        """Stage move ``index`` of the last :meth:`propose_batch` as the
        pending proposal (reusing its already-priced load row) and return
        its ``(objective, per_job)``.  Call :meth:`accept` to adopt."""
        moves, rows, comms = self._batch
        label, strategy = moves[index]
        self._pending = (label, strategy, rows[index])
        return self._objective(
            float(comms[index]), self._move_steps(label, strategy)
        )

    def accept(self) -> None:
        """Adopt the last proposed move as the current state."""
        assert self._pending is not None, "nothing proposed"
        label, strategy, total = self._pending
        self.strategies[label] = strategy
        self._total = total
        self._pending = None

    def union_for(self, strategies: dict[str, object]) -> TrafficDemand:
        """Cluster-level union demand under ``strategies`` (built only when
        a caller needs the demand object, e.g. for TopologyFinder)."""
        return self.jobset.union({
            t.label: self._local_demand(t, strategies[t.label])
            for t in self.jobset.tenants
        })

    def union(self) -> TrafficDemand:
        """Union demand of the *current* strategies."""
        return self.union_for(self.strategies)
