"""SelectPermutations (paper Algorithm 3, §4.2) — pick ``d_k`` ring strides.

Goal (Theorem 1): choose strides close to a geometric sequence with ratio
``x = n^(1/d_k)`` so that the AllReduce sub-topology's diameter is bounded by
``O(d_k * n^(1/d_k))`` — every node reaches every other within a small number
of coin-change hops (App. E.2), Chord-style.

Notation mapping (paper -> code): the candidate set ``P`` from TotientPerms
-> :class:`repro.core.totient.PermutationSet`; the per-group degree budget
``d_k`` -> the ``d_k`` argument; the geometric targets ``x^0..x^(d_k-1)`` ->
:func:`geometric_targets` (with the paper's App. E.2 correction to ratio 2
when ``n^(1/d_k) < 2``); the greedy L1-nearest projection of targets onto
available strides (without replacement) -> :func:`select_permutations`;
Theorem 1's diameter quantity -> :func:`coin_change_diameter` (exact BFS
over Z_n treating the chosen strides as +coins) and its analytic bound ->
:func:`theorem1_bound`.
"""

from __future__ import annotations

from .totient import PermutationSet, RingPermutation


def geometric_targets(n: int, d: int) -> list[float]:
    """The ideal stride sequence x^0, x^1, ..., x^(d-1) with x = n^(1/d).

    When n^(1/d) < 2 the paper switches to ratio 2 (uses fewer effective
    degrees, bound becomes O(log2 n))."""
    if d <= 0:
        return []
    x = n ** (1.0 / d)
    if x < 2.0 and n > 1:
        x = 2.0
    return [x**i for i in range(d)]


def select_permutations(perm_set: PermutationSet, d_k: int) -> list[RingPermutation]:
    """Algorithm 3.  Greedily project the geometric sequence onto the
    available totient strides (L1-nearest, without replacement)."""
    if d_k <= 0 or not perm_set.perms:
        return []
    by_stride = {r.p: r for r in perm_set.perms}
    candidates = sorted(by_stride)
    n = perm_set.perms[0].size
    d_k = min(d_k, len(candidates))

    selected: list[int] = []
    # q starts at the minimum candidate (stride 1 when present).
    q = candidates[0]
    selected.append(q)
    remaining = [c for c in candidates if c != q]
    x = geometric_targets(n, d_k)
    ratio = x[1] / x[0] if len(x) > 1 else 2.0

    for _ in range(1, d_k):
        if not remaining:
            break
        target = q * ratio
        # L1-nearest projection onto remaining candidates.
        qp = min(remaining, key=lambda r: abs(r - target))
        selected.append(qp)
        remaining.remove(qp)
        q = qp

    return [by_stride[p] for p in selected]


def schedule_strides(
    n: int, family: str, d: int | None = None
) -> tuple[int, ...]:
    """Stride set for one collective-schedule family on a group of ``n``
    (the TotientPerms extension backing :mod:`repro.core.schedules`).

    * ``"ring"`` / ``"multi_tree"`` — Algorithm 3's geometric selection over
      the coprime strides (``d`` rings, or ``d`` tree-seeding ring orders).
    * ``"recursive_hd"`` — the power-of-two exchange distances
      ``1, 2, 4, ... < p2`` where ``p2`` is the largest power of two
      ``<= n`` (the halving-doubling pairing offsets, not modular rings).

    ``d=None`` keeps the family's natural length.
    """
    if n < 2:
        return ()
    if family in ("ring", "multi_tree"):
        from .totient import totient_perms

        perms = totient_perms(tuple(range(n)))
        want = len(perms.perms) if d is None else d
        return tuple(r.p for r in select_permutations(perms, want))
    if family == "recursive_hd":
        out: list[int] = []
        s = 1
        while s * 2 <= n:
            out.append(s)
            s *= 2
        return tuple(out if d is None else out[:d])
    raise ValueError(
        f"unknown schedule family {family!r}: "
        "expected 'ring', 'recursive_hd' or 'multi_tree'"
    )


def coin_change_diameter(n: int, strides: list[int]) -> int:
    """Exact diameter of the union of the stride rings under directed
    coin-change routing (BFS over Z_n with the strides as +coins).

    Used by tests to check Theorem 1 and by TopologyFinder to report the
    cluster diameter seen by MP transfers."""
    if n <= 1:
        return 0
    if not strides:
        return -1  # disconnected
    dist = [-1] * n
    dist[0] = 0
    frontier = [0]
    while frontier:
        nxt = []
        for v in frontier:
            for c in strides:
                w = (v + c) % n
                if dist[w] < 0:
                    dist[w] = dist[v] + 1
                    nxt.append(w)
        frontier = nxt
    if any(d < 0 for d in dist):
        return -1
    return max(dist)


def theorem1_bound(n: int, d: int) -> float:
    """O(d * n^(1/d)) bound, with the x<2 correction of App. E.2."""
    if d <= 0:
        return float("inf")
    x = n ** (1.0 / d)
    if x < 2.0:
        import math

        return math.log2(max(n, 2)) + 1
    return d * x
