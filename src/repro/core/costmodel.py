"""Interconnect cost model (§5.2, Appendix G, Table 2).

Component prices (USD) are the paper's Table 2 values.  Fiber cost: $0.3/m,
length ~ U(0, 1000) m -> expected $150/fiber.  TopoOpt uses 2d patch-panel
ports per server (Active + Look-ahead, App. C) and d 1x2 mechanical switches.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# Table 2 (per-port / per-device prices at each link rate).
TABLE2 = {
    10e9: dict(transceiver=20, nic=185, sw_port=94),
    25e9: dict(transceiver=39, nic=185, sw_port=144),
    40e9: dict(transceiver=39, nic=354, sw_port=144),
    100e9: dict(transceiver=99, nic=678, sw_port=187),
    200e9: dict(transceiver=198, nic=815, sw_port=374),
}
PATCH_PANEL_PORT = 100.0
OCS_PORT = 520.0
SWITCH_1X2 = 25.0
EXPECTED_FIBER = 0.3 * 500.0  # $/m * E[U(0,1000)]

# -- Churn pricing (online re-optimization) ---------------------------------
# A replan does not pay a flat reconfiguration fee: the patch panel moves
# each *changed* fiber individually.  Robotic patch panels need seconds per
# move (scheduler.PATCH_PANEL_RECONFIG_S ~ 120 s for a full n*d ~ 64-fiber
# rebuild); an OCS-backed fabric amortizes its RECONFIG_LATENCY (10 ms)
# across a typical 16-circuit swing.
FIBER_MOVE_S = 2.0  # robotic patch panel, seconds per moved fiber
OCS_FIBER_MOVE_S = 10e-3 / 16  # OCS port retarget, seconds per moved fiber
FIBER_MOVE_WEAR = 0.01  # fraction of port+fiber capex consumed per re-patch


def fiber_move_cost(edges_moved: int) -> float:
    """Operational cost (USD) of re-patching ``edges_moved`` fibers: each
    move touches two patch-panel ports and wears the fiber/connectors by
    ``FIBER_MOVE_WEAR`` of their capex."""
    return edges_moved * FIBER_MOVE_WEAR * (2 * PATCH_PANEL_PORT + EXPECTED_FIBER)


# -- Migration pricing (tenant checkpoint-restore + fiber churn) -------------
# Moving a *running* tenant to a new placement is a checkpoint-restore cycle
# (drain, serialize model state, restore on the new servers, re-establish
# collectives) plus the patch-panel churn of re-seating its fibers.  The
# restore bandwidth is the aggregate parallel-filesystem / object-store rate
# the checkpoint streams at; the restart floor covers process teardown,
# container scheduling, and collective re-initialization.
CHECKPOINT_RESTORE_BW = 10e9  # bytes/s aggregate checkpoint-restore rate
MIGRATION_RESTART_S = 5.0  # per-migration drain/teardown/re-init floor


def migration_cost(
    state_bytes: float,
    edges_moved: int = 0,
    fiber_move_s: float = FIBER_MOVE_S,
    checkpoint_bw: float = CHECKPOINT_RESTORE_BW,
    restart_s: float = MIGRATION_RESTART_S,
) -> float:
    """Seconds of training pause one tenant migration charges: the restart
    floor, the checkpoint-restore transfer of ``state_bytes`` of model
    state (:attr:`repro.core.workloads.JobSpec.state_bytes`), and the
    patch-panel re-seat of ``edges_moved`` fibers
    (:func:`repro.core.online.edge_churn` between the incumbent and the
    post-migration topology)."""
    if state_bytes < 0 or edges_moved < 0:
        raise ValueError("migration_cost needs non-negative inputs")
    return (
        restart_s
        + state_bytes / checkpoint_bw
        + edges_moved * fiber_move_s
    )


def checkpoint_restart_s(
    state_bytes: float,
    checkpoint_bw: float = CHECKPOINT_RESTORE_BW,
    restart_s: float = MIGRATION_RESTART_S,
) -> float:
    """Seconds a *fault-induced* restart pauses a job: reload ``state_bytes``
    of model state from the last checkpoint at ``checkpoint_bw`` plus the
    process-teardown / collective-re-init floor.  This is
    :func:`migration_cost` without the fiber churn term — a job stalled by a
    fabric partition restores in place, it does not re-seat fibers.  Feed
    the result into :attr:`repro.core.simengine.Scenario.restart_s` to price
    partition-survival restarts."""
    if state_bytes < 0:
        raise ValueError("checkpoint_restart_s needs non-negative state_bytes")
    return restart_s + state_bytes / checkpoint_bw


def _table2(link_gbps: float) -> dict:
    key = link_gbps * 1e9
    if key not in TABLE2:
        key = min(TABLE2, key=lambda k: abs(k - link_gbps * 1e9))
    return TABLE2[key]


@dataclass(frozen=True)
class ClusterSpec:
    n_servers: int
    degree: int = 4
    link_gbps: float = 100.0


def topoopt_cost(spec: ClusterSpec, use_ocs: bool = False) -> float:
    """TopoOpt direct-connect: d NICs + d transceivers per server, 2d optical
    ports (look-ahead design) or d OCS ports, d 1x2 switches, d fibers."""
    c = _table2(spec.link_gbps)
    per_server = spec.degree * (c["nic"] + c["transceiver"] + EXPECTED_FIBER)
    if use_ocs:
        per_server += spec.degree * OCS_PORT
    else:
        per_server += 2 * spec.degree * PATCH_PANEL_PORT + spec.degree * SWITCH_1X2
    return spec.n_servers * per_server


def _fat_tree_ports(n_endpoints: int) -> tuple[int, int]:
    """(#switch ports, k) for the smallest k-ary full-bisection fat-tree
    hosting n endpoints: k^3/4 hosts, 5k^2/4 switches of k ports."""
    k = 2
    while k**3 / 4 < n_endpoints:
        k += 2
    n_switches = 5 * k * k // 4
    return n_switches * k, k


def fat_tree_cost(
    spec: ClusterSpec,
    bandwidth_fraction: float = 1.0,
    oversub: float = 1.0,
    parallel_links: bool = False,
) -> float:
    """Full-bisection k-ary fat-tree baselines (§5.1/§5.2, App. G).

    * similar-cost baseline (``parallel_links=False``): one NIC per server at
      rate ``d * B * bandwidth_fraction`` -> n endpoints; the fraction is
      tuned until the cost matches TopoOpt.
    * Ideal Switch (``parallel_links=True``): d*B per server built from d
      parallel B-rate links on commodity gear -> n*d endpoints at rate B
      (2022 gear has no (d*B)-rate single port at these d*B values).
    ``oversub`` > 1 removes that fraction of the non-host-facing ports.
    """
    if parallel_links:
        endpoints = spec.n_servers * spec.degree
        rate = spec.link_gbps * bandwidth_fraction
        nics_per_server = spec.degree
    else:
        endpoints = spec.n_servers
        rate = spec.link_gbps * spec.degree * bandwidth_fraction
        nics_per_server = 1
    c = _table2(rate)
    # price rates above Table 2's ceiling as bundles of 100G components
    scale = max(1.0, rate / 200.0) if rate > 200 else 1.0
    ports, _ = _fat_tree_ports(endpoints)
    core_ports = ports - endpoints
    ports = endpoints + math.ceil(core_ports / oversub)
    cost = spec.n_servers * nics_per_server * (
        scale * (c["nic"] + c["transceiver"]) + EXPECTED_FIBER
    )
    # every switch port carries a transceiver; half the fiber per port.
    cost += ports * (scale * (c["sw_port"] + c["transceiver"]) + EXPECTED_FIBER / 2)
    return cost


def ideal_switch_cost(spec: ClusterSpec) -> float:
    return fat_tree_cost(spec, bandwidth_fraction=1.0, parallel_links=True)


def expander_cost(spec: ClusterSpec) -> float:
    """Static direct-connect: d NICs/transceivers/fibers, no optical layer."""
    c = _table2(spec.link_gbps)
    return spec.n_servers * spec.degree * (c["nic"] + c["transceiver"] + EXPECTED_FIBER)


def sipml_cost(spec: ClusterSpec) -> float:
    """SiP-ML: d wavelengths/GPU on silicon-photonic fabric.  SiP ports are
    not commercial; the paper's Fig. 10 places SiP-ML as the most expensive —
    we price its ports at the OCS rate x2 (comb laser + MRR filters) plus
    Tbps-class NICs."""
    c = _table2(spec.link_gbps)
    per = spec.degree * (c["nic"] + 2 * OCS_PORT + c["transceiver"] + EXPECTED_FIBER)
    return spec.n_servers * per


def cost_equivalent_bandwidth_fraction(spec: ClusterSpec) -> float:
    """Find B'/B such that fat_tree_cost(B') ~= topoopt_cost (the paper's
    similar-cost Fat-tree baseline, §5.1)."""
    target = topoopt_cost(spec)
    lo, hi = 0.05, 1.0
    for _ in range(40):
        mid = (lo + hi) / 2
        if fat_tree_cost(spec, bandwidth_fraction=mid) > target:
            hi = mid
        else:
            lo = mid
    return (lo + hi) / 2


def cost_report(spec: ClusterSpec) -> dict[str, float]:
    return {
        "topoopt_patch": topoopt_cost(spec, use_ocs=False),
        "topoopt_ocs": topoopt_cost(spec, use_ocs=True),
        "fat_tree_similar_cost": fat_tree_cost(
            spec, bandwidth_fraction=cost_equivalent_bandwidth_fraction(spec)
        ),
        "oversub_fat_tree": fat_tree_cost(spec, oversub=2.0, parallel_links=True),
        "ideal_switch": ideal_switch_cost(spec),
        "expander": expander_cost(spec),
        "sipml": sipml_cost(spec),
    }
